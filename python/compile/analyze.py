"""L2 performance analysis (build-time tooling, EXPERIMENTS.md §Perf).

For a given (model, variant, rank) this prints:
* XLA cost analysis of the compiled train step (flops, bytes accessed);
* an HLO instruction histogram of the lowered module (fusion health:
  dominated by fusion/convolution/dot ops, no stray gathers);
* steady-state step wallclock on this host, pallas-kernel adapters vs
  the pure-jnp reference path (set by FLOCORA_ADAPTER_IMPL before
  import — this script re-execs itself to compare both).

Usage:
    python -m compile.analyze --model micro8 --variant lora_fc --rank 4
"""

import argparse
import collections
import os
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp


def build(model: str, variant: str, rank: int):
    from .configs import MODELS, build_spec
    from .train import example_shapes, make_train_step

    spec = build_spec(MODELS[model], variant, rank)
    return spec, make_train_step(spec), example_shapes(spec)


def hlo_histogram(hlo_text: str) -> collections.Counter:
    ops = collections.Counter()
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+\S+\s+([a-z0-9-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
    return ops


def steady_state_ms(fn, args, iters: int = 15) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e3


def analyze(model: str, variant: str, rank: int) -> None:
    impl = os.environ.get("FLOCORA_ADAPTER_IMPL", "pallas")
    spec, step, shapes = build(model, variant, rank)
    jitted = jax.jit(step, keep_unused=True)
    lowered = jitted.lower(*shapes)
    compiled = lowered.compile()

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = cost.get("flops", float("nan"))
    bytes_acc = cost.get("bytes accessed", float("nan"))
    print(f"[{impl}] {model}/{variant}/r{rank}: "
          f"P={spec.num_trainable} F={spec.num_frozen}")
    print(f"[{impl}]   flops/step       : {flops:.3e}")
    print(f"[{impl}]   bytes accessed   : {bytes_acc:.3e}")
    if flops == flops and bytes_acc == bytes_acc and bytes_acc > 0:
        print(f"[{impl}]   arith intensity  : {flops / bytes_acc:.2f}")

    hist = hlo_histogram(lowered.compiler_ir("hlo").as_hlo_text())
    top = ", ".join(f"{op}:{n}" for op, n in hist.most_common(8))
    print(f"[{impl}]   hlo ops          : {top}")
    dyn = hist.get("dynamic-update-slice", 0) + hist.get("dynamic-slice", 0)
    print(f"[{impl}]   dynamic slices   : {dyn} "
          f"(pallas interpret-mode grid loops)")

    # Steady-state step time on this host.
    key = jax.random.PRNGKey(0)
    args = []
    for s in shapes:
        if s.dtype == jnp.int32:
            args.append(jax.random.randint(key, s.shape, 0, 10))
        else:
            args.append(jnp.zeros(s.shape, s.dtype) + 0.1)
    ms = steady_state_ms(jitted, args)
    print(f"[{impl}]   step wallclock   : {ms:.1f} ms (this host, CPU)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="micro8")
    ap.add_argument("--variant", default="lora_fc")
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--compare-impls", action="store_true",
                    help="run both pallas and jnp adapter paths")
    args = ap.parse_args()

    if args.compare_impls:
        for impl in ("pallas", "jnp"):
            env = dict(os.environ, FLOCORA_ADAPTER_IMPL=impl)
            subprocess.run(
                [sys.executable, "-m", "compile.analyze",
                 "--model", args.model, "--variant", args.variant,
                 "--rank", str(args.rank)],
                env=env, check=True)
        return
    analyze(args.model, args.variant, args.rank)


if __name__ == "__main__":
    main()
