"""Model / experiment presets shared between the build path and the rust
coordinator (via ``artifacts/manifest.json``).

The paper (FLoCoRA, EUSIPCO 2024) evaluates two CIFAR-10 models:

* **ResNet-8** — conv1 + three stages of one BasicBlock, widths
  (64, 128, 256), GroupNorm instead of BatchNorm (per Hsu et al. [20]),
  1.23 M parameters (Table I).
* **ResNet-18** — conv1 + four stages of two BasicBlocks, widths
  (64, 128, 256, 512), 11.17 M parameters (44.7 MB messages, Table IV).

Because this testbed is CPU-only, we additionally define two scaled
variants used for tests and reduced-scale accuracy runs (DESIGN.md §2):

* **micro8** — ResNet-8 topology, widths (4, 8, 16), 16x16 images.
* **tiny8**  — ResNet-8 topology, widths (8, 16, 32), 32x32 images.

Every model is described by a :class:`ModelConfig`; the LoRA *variant*
axis reproduces Table II's ablation:

* ``full``      — everything trainable (FedAvg baseline).
* ``lora_all``  — "FLoCoRA Vanilla": LoRA adapters on every conv and on
                  the final FC; norm layers and FC frozen.
* ``lora_norm`` — + normalization layers trained.
* ``lora_fc``   — + final FC trained directly, no FC adapter.  This is
                  the configuration the paper uses everywhere after
                  Table II.
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

VARIANTS = ("full", "lora_all", "lora_norm", "lora_fc")


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (shared with rust)."""

    name: str
    widths: Tuple[int, ...]          # stage widths; conv1 uses widths[0]
    blocks_per_stage: int            # 1 => ResNet-8 family, 2 => ResNet-18
    image_size: int                  # square input, 3 channels
    num_classes: int = 10
    batch_size: int = 32

    @property
    def num_stages(self) -> int:
        return len(self.widths)


MODELS = {
    "micro8": ModelConfig("micro8", (4, 8, 16), 1, 16, batch_size=8),
    "tiny8": ModelConfig("tiny8", (8, 16, 32), 1, 32, batch_size=32),
    "resnet8": ModelConfig("resnet8", (64, 128, 256), 1, 32, batch_size=32),
    "resnet18": ModelConfig("resnet18", (64, 128, 256, 512), 2, 32, batch_size=32),
}


def group_count(channels: int) -> int:
    """GroupNorm group count: 8 when divisible, else the largest of
    (4, 2, 1) that divides ``channels`` (matches the rust mirror)."""
    for g in (8, 4, 2, 1):
        if channels % g == 0:
            return g
    return 1


@dataclass(frozen=True)
class ParamInfo:
    """One parameter tensor in the deterministic flat layout.

    ``kind`` drives both trainability (per variant) and the wire-codec
    quantization grouping on the rust side:

    * ``conv``/``fc_w``/``fc_b``            — base model weights
    * ``lora_b``  — B in R^{r x I x K x K}  (down-projection conv)
    * ``lora_a``  — A in R^{O x r x 1 x 1}  (up-projection, zero-init)
    * ``norm_w``/``norm_b``                 — GroupNorm affine params
    * ``fc_lora_b``/``fc_lora_a``           — FC adapter (lora_all only)
    """

    name: str
    shape: Tuple[int, ...]
    kind: str
    # Quantization grouping: number of leading-dim rows ("per channel" for
    # convs, "per column" i.e. per output unit for FC).  None => never
    # quantized (norm layers, per paper §IV).
    quant_rows: Optional[int] = None

    @property
    def numel(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclass
class LayoutEntry:
    info: ParamInfo
    offset: int  # element offset in its flat vector (trainable or frozen)


@dataclass
class ModelSpec:
    """Fully resolved parameter layout for (model config, variant, rank)."""

    config: ModelConfig
    variant: str
    rank: int
    trainable: List[LayoutEntry] = field(default_factory=list)
    frozen: List[LayoutEntry] = field(default_factory=list)

    @property
    def num_trainable(self) -> int:
        return sum(e.info.numel for e in self.trainable)

    @property
    def num_frozen(self) -> int:
        return sum(e.info.numel for e in self.frozen)

    @property
    def num_total(self) -> int:
        return self.num_trainable + self.num_frozen


def _conv_params(name: str, o: int, i: int, k: int) -> ParamInfo:
    return ParamInfo(name, (o, i, k, k), "conv", quant_rows=o)


def _norm_params(name: str, c: int) -> List[ParamInfo]:
    return [
        ParamInfo(f"{name}.w", (c,), "norm_w", quant_rows=None),
        ParamInfo(f"{name}.b", (c,), "norm_b", quant_rows=None),
    ]


def iter_convs(cfg: ModelConfig):
    """Yield (name, out_ch, in_ch, kernel, stride) for every conv in the
    model, in deterministic order.  Downsample (1x1 stride-2) convs on the
    residual path are included — they are adapted too (DESIGN.md §4)."""
    w0 = cfg.widths[0]
    yield ("conv1", w0, 3, 3, 1)
    in_ch = w0
    for s, width in enumerate(cfg.widths):
        stride = 1 if s == 0 else 2
        for b in range(cfg.blocks_per_stage):
            bs = stride if b == 0 else 1
            pre = f"s{s}.b{b}"
            yield (f"{pre}.conv1", width, in_ch, 3, bs)
            yield (f"{pre}.conv2", width, width, 3, 1)
            if bs != 1 or in_ch != width:
                yield (f"{pre}.down", width, in_ch, 1, bs)
            in_ch = width


def build_spec(cfg: ModelConfig, variant: str, rank: int) -> ModelSpec:
    """Construct the deterministic parameter layout.

    Ordering rule (mirrored in rust/src/model/spec.rs): parameters are
    visited conv-by-conv (base conv, then its LoRA pair, then its norm),
    then the final FC (and its adapter under ``lora_all``).  Within each
    vector (trainable / frozen) offsets are assigned in visit order.
    """
    assert variant in VARIANTS, variant
    spec = ModelSpec(cfg, variant, rank)

    def add(info: ParamInfo, trainable: bool):
        side = spec.trainable if trainable else spec.frozen
        off = sum(e.info.numel for e in side)
        side.append(LayoutEntry(info, off))

    lora = variant != "full"
    train_norm = variant in ("full", "lora_norm", "lora_fc")
    train_fc = variant in ("full", "lora_fc")

    for name, o, i, k, _stride in iter_convs(cfg):
        add(_conv_params(name, o, i, k), trainable=not lora)
        if lora:
            add(ParamInfo(f"{name}.lora_b", (rank, i, k, k), "lora_b",
                          quant_rows=rank), trainable=True)
            add(ParamInfo(f"{name}.lora_a", (o, rank, 1, 1), "lora_a",
                          quant_rows=o), trainable=True)
        for p in _norm_params(f"{name}.gn", o):
            add(p, trainable=train_norm)

    d = cfg.widths[-1]
    c = cfg.num_classes
    add(ParamInfo("fc.w", (d, c), "fc_w", quant_rows=c), trainable=train_fc)
    add(ParamInfo("fc.b", (c,), "fc_b", quant_rows=c), trainable=train_fc)
    if variant == "lora_all":
        add(ParamInfo("fc.lora_b", (d, rank), "fc_lora_b", quant_rows=rank),
            trainable=True)
        add(ParamInfo("fc.lora_a", (rank, c), "fc_lora_a", quant_rows=c),
            trainable=True)
    return spec


def spec_tag(model: str, variant: str, rank: int) -> str:
    """Artifact tag, e.g. ``resnet8_lora_fc_r32`` or ``tiny8_full``."""
    if variant == "full":
        return f"{model}_full"
    return f"{model}_{variant}_r{rank}"


# ---------------------------------------------------------------------------
# Paper-reported values (encoded once; used by python tests and exported to
# the manifest so the rust `experiments::paper` module shares one source).
# ---------------------------------------------------------------------------

# Table I — ResNet-8 parameter counts (millions / thousands as printed).
PAPER_TABLE1 = {
    # rank: (total_params, trained_params)
    0: (1.23e6, 1.23e6),  # FedAvg row
    8: (1.30e6, 69.45e3),
    16: (1.36e6, 131.92e3),
    32: (1.48e6, 256.84e3),
    64: (1.73e6, 506.70e3),
    128: (2.23e6, 1.00e6),
}

# Table III — TCC over 100 rounds, ResNet-8, r=32, alpha=512.
PAPER_TABLE3 = {
    "fedavg_fp": 982.07e6,
    "flocora_fp": 205.47e6,
    "flocora_q8": 55.56e6,
    "flocora_q4": 30.15e6,
    "flocora_q2": 17.44e6,
}
