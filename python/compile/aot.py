"""AOT pipeline: lower every (model, variant, rank) entry point to HLO
*text* + write ``artifacts/manifest.json`` for the rust coordinator.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--set core|full] [--only TAG]

Artifact set
  core: micro8 (all four variants, r=4) + tiny8 (full, lora_fc r{4,8},
        lora_all/lora_norm r4) + resnet8 (full + lora_fc r32) + quant
        oracles.  Enough for tests, examples and the scaled experiments.
  full: + resnet8 lora_fc r{8,16,64,128} (Fig. 2 sweep) + resnet18
        (full + lora_fc r{16,32,64}) for Table IV paper-scale runs.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs
from .configs import MODELS, build_spec, spec_tag
from .kernels.quant import fake_quant
from .train import (example_eval_shapes, example_shapes, make_eval_step,
                    make_init, make_train_step)

# (model, variant, rank) triples per set.  rank is ignored for "full".
CORE_SET = [
    ("micro8", "full", 0),
    ("micro8", "lora_all", 4),
    ("micro8", "lora_norm", 4),
    ("micro8", "lora_fc", 4),
    ("micro8", "lora_fc", 2),
    ("micro8", "lora_fc", 8),
    ("micro8", "lora_fc", 16),
    ("tiny8", "full", 0),
    ("tiny8", "lora_all", 8),
    ("tiny8", "lora_norm", 8),
    ("tiny8", "lora_fc", 4),
    ("tiny8", "lora_fc", 8),
    ("tiny8", "lora_fc", 16),
    ("resnet8", "full", 0),
    ("resnet8", "lora_fc", 32),
]
FULL_SET = CORE_SET + [
    ("resnet8", "lora_fc", 8),
    ("resnet8", "lora_fc", 16),
    ("resnet8", "lora_fc", 64),
    ("resnet8", "lora_fc", 128),
    ("resnet18", "full", 0),
    ("resnet18", "lora_fc", 16),
    ("resnet18", "lora_fc", 32),
    ("resnet18", "lora_fc", 64),
]

# Shape of the quant-oracle artifacts: odd column count + a mix of row
# patterns exercises padding and degenerate rows in the rust parity test.
QUANT_SHAPE = (64, 129)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> int:
    # keep_unused=True: the rust runtime always supplies the full typed
    # argument list; jit's default dead-argument pruning would silently
    # change the call ABI per variant (e.g. `full` ignores lora_scale).
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*example_args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def segment_json(entries):
    return [
        {
            "name": e.info.name,
            "shape": list(e.info.shape),
            "numel": e.info.numel,
            "kind": e.info.kind,
            "offset": e.offset,
            "quant_rows": e.info.quant_rows,
        }
        for e in entries
    ]


def emit_spec(spec, out_dir: str, manifest: dict) -> None:
    tag = spec_tag(spec.config.name, spec.variant, spec.rank)
    print(f"[aot] lowering {tag} "
          f"(P={spec.num_trainable} F={spec.num_frozen})", flush=True)

    train_path = f"{tag}.train.hlo.txt"
    eval_path = f"{tag}.eval.hlo.txt"
    init_path = f"{tag}.init.hlo.txt"

    lower_to_file(make_train_step(spec), example_shapes(spec),
                  os.path.join(out_dir, train_path))
    lower_to_file(make_eval_step(spec), example_eval_shapes(spec),
                  os.path.join(out_dir, eval_path))
    key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
    lower_to_file(make_init(spec), (key_shape,),
                  os.path.join(out_dir, init_path))

    cfg = spec.config
    manifest["specs"][tag] = {
        "model": cfg.name,
        "variant": spec.variant,
        "rank": spec.rank,
        "image_size": cfg.image_size,
        "batch_size": cfg.batch_size,
        "num_classes": cfg.num_classes,
        "widths": list(cfg.widths),
        "blocks_per_stage": cfg.blocks_per_stage,
        "num_trainable": spec.num_trainable,
        "num_frozen": spec.num_frozen,
        "files": {"train": train_path, "eval": eval_path, "init": init_path},
        "trainable_segments": segment_json(spec.trainable),
        "frozen_segments": segment_json(spec.frozen),
    }


def emit_quant_oracles(out_dir: str, manifest: dict) -> None:
    rows, cols = QUANT_SHAPE
    sd = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    for bits in (2, 4, 8):
        name = f"quant_rt{bits}.hlo.txt"
        print(f"[aot] lowering quant oracle bits={bits}", flush=True)
        lower_to_file(lambda w, b=bits: fake_quant(w, b), (sd,),
                      os.path.join(out_dir, name))
        manifest["quant_oracles"][str(bits)] = {
            "file": name, "rows": rows, "cols": cols,
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", choices=("core", "full"), default="core")
    ap.add_argument("--only", default=None,
                    help="lower just this tag (plus quant oracles)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    triples = CORE_SET if args.set == "core" else FULL_SET
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    # Incremental: merge into an existing manifest so `--only` additions
    # and core->full upgrades do not drop earlier entries.
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    else:
        manifest = {"version": 1, "specs": {}, "quant_oracles": {}}

    for model, variant, rank in triples:
        spec = build_spec(MODELS[model], variant, rank)
        tag = spec_tag(model, variant, rank)
        if args.only and tag != args.only:
            continue
        emit_spec(spec, args.out_dir, manifest)

    emit_quant_oracles(args.out_dir, manifest)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {manifest_path} "
          f"({len(manifest['specs'])} specs)", flush=True)


if __name__ == "__main__":
    main()
