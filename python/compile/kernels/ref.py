"""Pure-jnp oracles for the L1 pallas kernels (the CORE correctness
signal: python/tests asserts kernel == ref to float tolerance, and the
rust wire codec is parity-tested against the lowered quant kernel)."""

import jax.numpy as jnp


def lora_matmul_ref(x, b, a, scale):
    """(X @ B) @ A * scale, plain jnp."""
    return (x @ b) @ a * scale


def matmul_ref(x, y):
    return x @ y


def fake_quant_ref(w, bits):
    """Affine RTN fake-quant, row-wise; mirrors kernels/quant.py exactly
    (true row range, real-valued zero point, floor(x+0.5) rounding,
    degenerate-row scale := 1.0)."""
    qmax = float(2 ** bits - 1)
    wmin = jnp.min(w, axis=1, keepdims=True)
    wmax = jnp.max(w, axis=1, keepdims=True)
    rng = wmax - wmin
    scale = jnp.where(rng > 0, rng / qmax, jnp.ones_like(rng))
    zp = -wmin / scale
    q = jnp.clip(jnp.floor((w - wmin) / scale + 0.5), 0.0, qmax)
    return (q - zp) * scale, scale, zp
