"""L1 Pallas kernels: the fused low-rank (LoRA) matmul hot path.

The paper's compute insight is the rank-``r`` bottleneck: an adapter
touches ``r (I K^2 + O)`` weights instead of ``O I K^2``.  On TPU the
natural expression (DESIGN.md §5) is a fused two-stage matmul

    Y = (X @ B) @ A * scale        X:(M,K)  B:(K,r)  A:(r,N)

where the rank-``r`` intermediate ``T = X @ B`` lives in a VMEM scratch
accumulator and is fed straight to the MXU for the up-projection — it is
never materialized to HBM.  The grid iterates over (M-tiles, N-tiles); K
is kept whole per tile because ``r`` is small (<= 128), so ``T`` is a
(block_m, r) tile that fits comfortably in VMEM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernels lower to plain HLO (see
/opt/xla-example/README.md).  Real-TPU performance is *estimated* in
DESIGN.md §Perf from the VMEM footprint / MXU utilization of these block
shapes.

Autodiff: ``pallas_call`` has no automatic transpose rule, so
:func:`lora_matmul` carries a ``custom_vjp`` whose backward pass is built
from the same fused primitive (the gradients of a low-rank product are
themselves low-rank products):

    dX = dY @ A^T @ B^T * scale        (fused low-rank, rank r)
    dB = X^T @ (dY @ A^T) * scale      (tall matmul, r columns)
    dA = (X @ B)^T @ dY * scale        (tall matmul, r rows)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: N tiles at the MXU native 128; M tiles chosen VMEM-aware
# (perf pass, EXPERIMENTS.md §Perf): target a ~2 MiB X tile so small-K
# adapters (K = I*k*k of shallow convs) use few grid steps — fewer
# HBM<->VMEM handoffs on TPU and ~15% faster interpret-mode steps on CPU
# — while large-K adapters stay well inside VMEM with double buffering.
_BN = 128
_X_TILE_BYTES = 2 << 20
_BM_MIN = 256
_BM_MAX = 4096


def _pick_block_m(m: int, k: int) -> int:
    pref = _X_TILE_BYTES // (4 * max(k, 1))
    pref = max(_BM_MIN, min(_BM_MAX, pref))
    # round down to a power of two
    b = 1
    while b * 2 <= pref:
        b *= 2
    while b > m and b > 8:
        b //= 2
    return b


def _pick_block(dim: int, pref: int) -> int:
    """Largest power-of-two block <= pref that is <= dim (min 8)."""
    b = pref
    while b > dim and b > 8:
        b //= 2
    return b


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``axis`` of ``x`` up to a multiple of ``mult``."""
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _lora_kernel(x_ref, b_ref, a_ref, scale_ref, o_ref):
    """One (block_m, block_n) output tile.

    x_ref: (bm, K) — an M-tile of X with the full contraction dim.
    b_ref: (K, r)  — whole B (replicated across the grid).
    a_ref: (r, bn) — an N-tile of A.
    scale_ref: (1, 1) scalar in SMEM-like memory.
    The rank-r intermediate is a (bm, r) register/VMEM value: computed,
    consumed, discarded — the fusion the docstring describes.
    """
    t = jnp.dot(x_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(t, a_ref[...],
                         preferred_element_type=jnp.float32) * scale_ref[0, 0]


def _lora_matmul_raw(x, b, a, scale, *, block_m=None, block_n=None):
    """Fused (X @ B) @ A * scale via pallas.  Handles ragged M/N by
    padding to the block grid and slicing the result back."""
    m, k = x.shape
    k2, r = b.shape
    r2, n = a.shape
    assert k == k2 and r == r2, (x.shape, b.shape, a.shape)

    bm = block_m or _pick_block_m(m, k)
    bn = block_n or _pick_block(n, _BN)
    xp = _pad_to(x, 0, bm)
    ap = _pad_to(a, 1, bn)
    mp, np_ = xp.shape[0], ap.shape[1]
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _lora_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, b, ap, scale_arr)
    return out[:m, :n]


def _mm_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...],
                         preferred_element_type=jnp.float32)


def _matmul_raw(x, y, *, block_m=None, block_n=None):
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm = block_m or _pick_block_m(m, k)
    bn = block_n or _pick_block(n, _BN)
    xp = _pad_to(x, 0, bm)
    yp = _pad_to(y, 1, bn)
    mp, np_ = xp.shape[0], yp.shape[1]
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, y):
    """Differentiable tiled pallas matmul.  Used directly in the forward
    path (the 1x1 up-projection after a K x K ``B`` conv) and by the
    fused kernel's VJP, so it needs its own transpose rule — the
    cotangents are themselves plain matmuls on the raw kernel."""
    return _matmul_raw(x, y)


def _mm_fwd(x, y):
    return _matmul_raw(x, y), (x, y)


def _mm_bwd(res, do):
    x, y = res
    return _matmul_raw(do, y.T), _matmul_raw(x.T, do)


matmul.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def lora_matmul(x, b, a, scale):
    """Differentiable fused low-rank product ``(x @ b) @ a * scale``.

    x: (M, K) activations; b: (K, r) down-projection; a: (r, N)
    up-projection; scale: scalar ``alpha / r``.
    """
    return _lora_matmul_raw(x, b, a, scale)


def _fwd(x, b, a, scale):
    return _lora_matmul_raw(x, b, a, scale), (x, b, a, scale)


def _bwd(res, dy):
    x, b, a, scale = res
    # dX = dY @ A^T @ B^T * scale — itself a fused low-rank product.
    dx = _lora_matmul_raw(dy, a.T, b.T, scale)
    # dY @ A^T: (M, r) — small; then dB = X^T @ that.
    dya = _matmul_raw(dy, a.T)
    db = _matmul_raw(x.T, dya) * scale
    # T = X @ B: (M, r); dA = T^T @ dY.
    t = _matmul_raw(x, b)
    da = _matmul_raw(t.T, dy) * scale
    # scale is a hyperparameter constant at runtime; grad not needed but
    # custom_vjp must return a cotangent for it.
    dscale = jnp.sum(dy * _lora_matmul_raw(x, b, a, 1.0))
    return dx, db, da, dscale


lora_matmul.defvjp(_fwd, _bwd)
