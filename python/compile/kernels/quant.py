"""L1 Pallas kernel: affine round-to-nearest fake-quantization.

Implements the paper's §IV quantization scheme (after Nagel et al. [22]):
per-row (— "per channel" for convs, "per column" for the FC, once the
tensor is reshaped to (rows, cols)) asymmetric affine quantization over
the *true* row range (no zero-anchoring — an all-positive or
all-negative row uses its own [min, max], not [min(min,0), max(max,0)]):

    scale = (max - min) / (2^bits - 1)
    zp    = -min / scale            # real-valued, travels as f32
    q     = clip(floor((w - min) / scale + 0.5), 0, 2^bits - 1)
    deq   = (q - zp) * scale

Rounding is *floor(x + 0.5)* (round-half-up), chosen deliberately so the
rust wire codec (rust/src/compression/affine.rs) can reproduce it
bit-for-bit; ``jnp.round``'s half-to-even would not match ``f32::round``.

The kernel is the numerical oracle for the rust codec: ``make artifacts``
emits ``quant_rt{2,4,8}`` HLO from :func:`fake_quant`, and a rust
integration test asserts ``decode(encode(x)) == HLO(x)`` elementwise.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_half_up(x):
    return jnp.floor(x + 0.5)


def _quant_kernel(w_ref, o_ref, scale_ref, zp_ref, *, bits: int):
    """One block of rows.  Row-wise min/max reductions stay in VMEM."""
    w = w_ref[...]
    qmax = float(2 ** bits - 1)
    # True row range: seeding with the row's own min/max (not 0) keeps
    # the grid tight for one-sided rows; the real-valued zero point
    # shifts the grid so RTN error stays bounded by scale/2.
    wmin = jnp.min(w, axis=1, keepdims=True)
    wmax = jnp.max(w, axis=1, keepdims=True)
    rng = wmax - wmin
    # Degenerate constant rows: scale would be 0/0; use 1.0 (the row
    # quantizes to q == 0, zp == -min, and dequantizes exactly).
    scale = jnp.where(rng > 0, rng / qmax, jnp.ones_like(rng))
    zp = -wmin / scale
    q = jnp.clip(_round_half_up((w - wmin) / scale), 0.0, qmax)
    o_ref[...] = (q - zp) * scale
    scale_ref[...] = scale
    zp_ref[...] = zp


def fake_quant(w: jnp.ndarray, bits: int, *, block_rows: int = 64):
    """Affine RTN fake-quant over rows of ``w`` (rows, cols).

    Returns ``(deq, scale, zp)`` with ``scale``/``zp`` of shape (rows, 1).
    """
    rows, cols = w.shape
    br = min(block_rows, rows)
    rem = (-rows) % br
    wp = jnp.pad(w, ((0, rem), (0, 0))) if rem else w
    rp = wp.shape[0]

    deq, scale, zp = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cols), jnp.float32),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=True,
    )(wp)
    return deq[:rows], scale[:rows], zp[:rows]
