"""L1 Pallas kernel: affine round-to-nearest fake-quantization.

Implements the paper's §IV quantization scheme (after Nagel et al. [22]):
per-row (— "per channel" for convs, "per column" for the FC, once the
tensor is reshaped to (rows, cols)) asymmetric affine quantization

    scale = (max - min) / (2^bits - 1)
    zp    = clip(floor(-min / scale + 0.5), 0, 2^bits - 1)
    q     = clip(floor(w / scale + 0.5) + zp, 0, 2^bits - 1)
    deq   = (q - zp) * scale

Rounding is *floor(x + 0.5)* (round-half-up), chosen deliberately so the
rust wire codec (rust/src/compression/affine.rs) can reproduce it
bit-for-bit; ``jnp.round``'s half-to-even would not match ``f32::round``.

The kernel is the numerical oracle for the rust codec: ``make artifacts``
emits ``quant_rt{2,4,8}`` HLO from :func:`fake_quant`, and a rust
integration test asserts ``decode(encode(x)) == HLO(x)`` elementwise.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_half_up(x):
    return jnp.floor(x + 0.5)


def _quant_kernel(w_ref, o_ref, scale_ref, zp_ref, *, bits: int):
    """One block of rows.  Row-wise min/max reductions stay in VMEM."""
    w = w_ref[...]
    qmax = float(2 ** bits - 1)
    # Extend the row range to include 0 (Nagel et al. [22]): keeps the
    # zero-point inside [0, qmax] so the grid never shifts and the RTN
    # error stays bounded by scale/2.
    wmin = jnp.minimum(jnp.min(w, axis=1, keepdims=True), 0.0)
    wmax = jnp.maximum(jnp.max(w, axis=1, keepdims=True), 0.0)
    rng = wmax - wmin
    # Degenerate all-zero rows: scale would be 0/0; use 1.0 (the row
    # quantizes to q == zp == 0 and dequantizes to exactly 0).
    scale = jnp.where(rng > 0, rng / qmax, jnp.ones_like(rng))
    zp = jnp.clip(_round_half_up(-wmin / scale), 0.0, qmax)
    q = jnp.clip(_round_half_up(w / scale) + zp, 0.0, qmax)
    o_ref[...] = (q - zp) * scale
    scale_ref[...] = scale
    zp_ref[...] = zp


def fake_quant(w: jnp.ndarray, bits: int, *, block_rows: int = 64):
    """Affine RTN fake-quant over rows of ``w`` (rows, cols).

    Returns ``(deq, scale, zp)`` with ``scale``/``zp`` of shape (rows, 1).
    """
    rows, cols = w.shape
    br = min(block_rows, rows)
    rem = (-rows) % br
    wp = jnp.pad(w, ((0, rem), (0, 0))) if rem else w
    rp = wp.shape[0]

    deq, scale, zp = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cols), jnp.float32),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=True,
    )(wp)
    return deq[:rows], scale[:rows], zp[:rows]
