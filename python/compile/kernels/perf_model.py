"""L1 performance model: VMEM footprint + MXU utilization estimates for
the fused low-rank kernel's BlockSpec choices.

``interpret=True`` wallclock on CPU is *not* a TPU proxy (DESIGN.md §5),
so kernel optimization is structural: pick (block_m, block_n) so that

* the working set fits comfortably in VMEM (~16 MiB/core on TPUv4);
* the MXU (128x128 systolic array) sees well-shaped matmuls;
* the rank-r intermediate tile (block_m, r) never round-trips to HBM.

This module computes those numbers; `DESIGN.md` §Perf and
EXPERIMENTS.md §Perf record the resulting estimates for the shapes the
paper's models actually run.
"""

from dataclasses import dataclass

MXU = 128  # systolic array edge
VMEM_BYTES = 16 * 2 ** 20


@dataclass
class KernelEstimate:
    m: int
    k: int
    r: int
    n: int
    block_m: int
    block_n: int
    vmem_bytes: int
    vmem_frac: float
    mxu_util_stage1: float   # X@B: (bm, k) x (k, r)
    mxu_util_stage2: float   # T@A: (bm, r) x (r, bn)
    flops: int
    hbm_bytes_fused: int     # X, B, A read + Y write (T stays in VMEM)
    hbm_bytes_unfused: int   # + T write/read round trip
    arithmetic_intensity_fused: float

    @property
    def hbm_savings(self) -> float:
        return self.hbm_bytes_unfused / self.hbm_bytes_fused


def _util(dim: int) -> float:
    """Fraction of the MXU edge filled by a dimension of size ``dim``
    (a dim above 128 pipelines fully; below, the array idles)."""
    return min(dim, MXU) / MXU


def estimate(m: int, k: int, r: int, n: int,
             block_m: int, block_n: int) -> KernelEstimate:
    """Estimate one (block_m, block_n) tiling of ``(X@B)@A``."""
    f32 = 4
    # Per-grid-step VMEM working set: X tile + whole B + A tile +
    # rank-r intermediate + output tile (double-buffered inputs).
    x_tile = block_m * k * f32
    b_whole = k * r * f32
    a_tile = r * block_n * f32
    t_tile = block_m * r * f32
    y_tile = block_m * block_n * f32
    vmem = 2 * (x_tile + b_whole + a_tile) + t_tile + y_tile

    flops = 2 * m * k * r + 2 * m * r * n
    hbm_fused = (m * k + k * r + r * n + m * n) * f32
    hbm_unfused = hbm_fused + 2 * m * r * f32

    return KernelEstimate(
        m=m, k=k, r=r, n=n, block_m=block_m, block_n=block_n,
        vmem_bytes=vmem,
        vmem_frac=vmem / VMEM_BYTES,
        # Stage 1 contracts over k and feeds r output lanes; stage 2
        # contracts over r. The short dimension gates utilization.
        mxu_util_stage1=_util(min(block_m, k)) * _util(r),
        mxu_util_stage2=_util(min(block_m, r)) * _util(block_n),
        flops=flops,
        hbm_bytes_fused=hbm_fused,
        hbm_bytes_unfused=hbm_unfused,
        arithmetic_intensity_fused=flops / hbm_fused,
    )


def paper_shapes():
    """The adapter matmuls the paper's models actually execute
    (batch 32, 32x32 inputs): (label, m, k, r, n)."""
    return [
        # ResNet-8 r=32: A-projection after the 3x3 B conv, stage 1.
        ("resnet8 s0 A-proj", 32 * 32 * 32, 32, 32, 64),
        # Stage 3 (8x8 spatial, 256 channels).
        ("resnet8 s2 A-proj", 32 * 8 * 8, 32, 32, 256),
        # Downsample fused B/A (1x1 conv), stage 2.
        ("resnet8 s1 down fused", 32 * 16 * 16, 64, 32, 128),
        # ResNet-18 r=16 deepest stage.
        ("resnet18 s3 A-proj", 32 * 4 * 4, 16, 16, 512),
    ]


def default_blocks(m: int, n: int, k: int = 64) -> tuple:
    """Mirror of lora_matmul's VMEM-aware block choice: ~2 MiB X tile,
    power of two, clamped to [256, 4096] then to the problem size."""
    pref = max(256, min(4096, (2 << 20) // (4 * max(k, 1))))
    bm = 1
    while bm * 2 <= pref:
        bm *= 2
    while bm > m and bm > 8:
        bm //= 2
    bn = 128
    while bn > n and bn > 8:
        bn //= 2
    return bm, bn


def report() -> str:
    lines = [
        f"{'shape':<24} {'(m,k,r,n)':<22} {'blk':<10} {'VMEM':>8} "
        f"{'MXU1':>6} {'MXU2':>6} {'AI':>6} {'HBMx':>6}"
    ]
    for label, m, k, r, n in paper_shapes():
        bm, bn = default_blocks(m, n, k)
        e = estimate(m, k, r, n, bm, bn)
        lines.append(
            f"{label:<24} {str((m, k, r, n)):<22} {f'{bm}x{bn}':<10} "
            f"{e.vmem_bytes / 1024:>6.0f}KB {e.mxu_util_stage1:>6.2f} "
            f"{e.mxu_util_stage2:>6.2f} {e.arithmetic_intensity_fused:>6.1f} "
            f"{e.hbm_savings:>6.2f}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
