"""L2 training/eval/init entry points, shaped for AOT lowering.

Each function closes over a :class:`ModelSpec` and takes/returns only
arrays, so ``aot.py`` can lower it with example ``ShapeDtypeStruct``s and
the rust coordinator can call it through PJRT with flat buffers:

* ``train_step(trainable, momentum, frozen, x, y, lr, lora_scale)``
    -> ``(trainable', momentum', loss, acc)``
  One SGD-with-momentum minibatch step (paper §IV: momentum 0.9; lr and
  the LoRA ``alpha/r`` scale are runtime scalars so Fig. 2's alpha-sweep
  and lr schedules need no artifact rebuild).

* ``eval_step(trainable, frozen, x, y, mask)`` -> ``(loss_sum, correct)``
  Masked so the rust side can pad the ragged final batch.

* ``init(key)`` -> ``(trainable, frozen)``
  He init with zero up-projections (round-0 model == W_initial).
"""

from typing import Tuple

import jax
import jax.numpy as jnp

from .configs import ModelSpec
from .model import forward, init_params

MOMENTUM = 0.9


def cross_entropy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Per-example CE with integer labels (stable log-softmax)."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logz, y[:, None], axis=-1)[:, 0]


def make_train_step(spec: ModelSpec):
    def train_step(trainable, momentum, frozen, x, y, lr, lora_scale):
        def loss_fn(tr):
            logits = forward(spec, tr, frozen, x, lora_scale)
            loss = jnp.mean(cross_entropy(logits, y))
            acc = jnp.mean((jnp.argmax(logits, axis=-1) == y)
                           .astype(jnp.float32))
            return loss, acc

        (loss, acc), grad = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable)
        new_m = MOMENTUM * momentum + grad
        new_p = trainable - lr * new_m
        return new_p, new_m, loss, acc

    return train_step


def make_eval_step(spec: ModelSpec):
    """Eval step with an explicit lora_scale argument (eval must use the
    same alpha/r as training — matters for Fig. 2's alpha-sweep)."""

    def eval_step(trainable, frozen, x, y, mask, lora_scale):
        logits = forward(spec, trainable, frozen, x, lora_scale)
        loss = jnp.sum(cross_entropy(logits, y) * mask)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32) * mask)
        return loss, correct

    return eval_step


def make_init(spec: ModelSpec):
    def init(key):
        return init_params(spec, key)

    return init


def example_shapes(spec: ModelSpec) -> Tuple:
    """ShapeDtypeStructs for lowering ``train_step``."""
    cfg = spec.config
    p = spec.num_trainable
    f = spec.num_frozen
    b, s = cfg.batch_size, cfg.image_size
    sd = jax.ShapeDtypeStruct
    return (
        sd((p,), jnp.float32),            # trainable
        sd((p,), jnp.float32),            # momentum
        sd((f,), jnp.float32),            # frozen
        sd((b, s, s, 3), jnp.float32),    # x
        sd((b,), jnp.int32),              # y
        sd((), jnp.float32),              # lr
        sd((), jnp.float32),              # lora_scale
    )


def example_eval_shapes(spec: ModelSpec) -> Tuple:
    cfg = spec.config
    sd = jax.ShapeDtypeStruct
    b, s = cfg.batch_size, cfg.image_size
    return (
        sd((spec.num_trainable,), jnp.float32),
        sd((spec.num_frozen,), jnp.float32),
        sd((b, s, s, 3), jnp.float32),
        sd((b,), jnp.int32),
        sd((b,), jnp.float32),            # mask
        sd((), jnp.float32),              # lora_scale
    )
