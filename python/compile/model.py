"""L2 model: CIFAR-style ResNet family with GroupNorm and LoRA adapters.

The forward pass consumes two flat f32 vectors — ``trainable`` and
``frozen`` — whose segmentation is defined by :mod:`compile.configs`
(`build_spec`) and exported to the rust coordinator via
``artifacts/manifest.json``.  Unflattening uses static offsets, so the
whole model lowers to one fused HLO module with no gather traffic.
"""

from typing import Dict

import jax
import jax.numpy as jnp

from . import configs
from .configs import ModelSpec, group_count, iter_convs
from .layers import conv2d, group_norm, lora_conv_delta, lora_fc_delta


def unflatten(spec: ModelSpec, trainable: jnp.ndarray,
              frozen: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat vectors into named parameter tensors."""
    params = {}
    for vec, entries in ((trainable, spec.trainable), (frozen, spec.frozen)):
        for e in entries:
            seg = vec[e.offset:e.offset + e.info.numel]
            params[e.info.name] = seg.reshape(e.info.shape)
    return params


def forward(spec: ModelSpec, trainable: jnp.ndarray, frozen: jnp.ndarray,
            x: jnp.ndarray, lora_scale: jnp.ndarray) -> jnp.ndarray:
    """Logits for a batch ``x`` (N, H, W, 3) in [0, 1]."""
    cfg = spec.config
    p = unflatten(spec, trainable, frozen)
    lora = spec.variant != "full"

    def conv(name, h, stride):
        out = conv2d(h, p[name], stride)
        if lora:
            out = out + lora_conv_delta(
                h, p[f"{name}.lora_b"], p[f"{name}.lora_a"],
                lora_scale, stride)
        return group_norm(out, p[f"{name}.gn.w"], p[f"{name}.gn.b"],
                          group_count(p[name].shape[0]))

    convs = {name: (o, i, k, s) for name, o, i, k, s in iter_convs(cfg)}

    h = jnp.maximum(conv("conv1", x, 1), 0.0)
    in_ch = cfg.widths[0]
    for s, width in enumerate(cfg.widths):
        stride = 1 if s == 0 else 2
        for b in range(cfg.blocks_per_stage):
            bs = stride if b == 0 else 1
            pre = f"s{s}.b{b}"
            out = jnp.maximum(conv(f"{pre}.conv1", h, bs), 0.0)
            out = conv(f"{pre}.conv2", out, 1)
            skip = conv(f"{pre}.down", h, bs) if f"{pre}.down" in convs else h
            h = jnp.maximum(out + skip, 0.0)
            in_ch = width

    feats = jnp.mean(h, axis=(1, 2))                     # global avg pool
    logits = feats @ p["fc.w"] + p["fc.b"]
    if spec.variant == "lora_all":
        logits = logits + lora_fc_delta(
            feats, p["fc.lora_b"], p["fc.lora_a"], lora_scale)
    return logits


def init_params(spec: ModelSpec, key: jnp.ndarray):
    """He-style init matching the paper's from-scratch setting.

    LoRA pairs follow the standard LoRA convention translated to this
    naming: the down-projection (``lora_b``) gets a He-normal init, the
    up-projection (``lora_a``) is zero — the adapter starts as an exact
    no-op, so every client's round-0 model *is* W_initial.
    Returns ``(trainable_flat, frozen_flat)``.
    """
    sides = []
    for entries in (spec.trainable, spec.frozen):
        parts = []
        for e in entries:
            info = e.info
            key, sub = jax.random.split(key)
            if info.kind == "conv":
                fan_in = info.shape[1] * info.shape[2] * info.shape[3]
                w = jax.random.normal(sub, info.shape) * jnp.sqrt(2.0 / fan_in)
            elif info.kind in ("lora_b", "fc_lora_b"):
                fan_in = (info.shape[1] * info.shape[2] * info.shape[3]
                          if len(info.shape) == 4 else info.shape[0])
                w = jax.random.normal(sub, info.shape) * jnp.sqrt(2.0 / fan_in)
            elif info.kind in ("lora_a", "fc_lora_a"):
                w = jnp.zeros(info.shape)
            elif info.kind == "norm_w":
                w = jnp.ones(info.shape)
            elif info.kind in ("norm_b", "fc_b"):
                w = jnp.zeros(info.shape)
            elif info.kind == "fc_w":
                d = info.shape[0]
                w = jax.random.normal(sub, info.shape) * jnp.sqrt(1.0 / d)
            else:
                raise ValueError(info.kind)
            parts.append(w.reshape(-1).astype(jnp.float32))
        sides.append(jnp.concatenate(parts) if parts
                     else jnp.zeros((0,), jnp.float32))
    return sides[0], sides[1]
