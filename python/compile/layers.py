"""L2 building blocks: conv / GroupNorm / LoRA-adapted conv and FC.

Data layout is NHWC; conv weights are OIHW (matching the manifest layout
the rust side indexes into).  The adapter decomposition follows Huh et
al. [19] as used by the paper §III:

    P_l in R^{O x I x K x K}  ->  B in R^{r x I x K x K}   (K x K conv I->r)
                                  A in R^{O x r x 1 x 1}   (1 x 1 conv r->O)

The frozen base conv runs through ``lax.conv_general_dilated``; the
adapter's up-projection (and, for 1x1 convs, the whole fused B/A pair)
runs through the L1 pallas kernels so the low-rank hot path in the lowered
HLO is the kernel of DESIGN.md §5.
"""

import os

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.lora_matmul import lora_matmul as _lora_matmul_pallas
from .kernels.lora_matmul import matmul as _matmul_pallas
from .kernels.ref import lora_matmul_ref, matmul_ref

# L2 perf ablation (EXPERIMENTS.md §Perf): FLOCORA_ADAPTER_IMPL=jnp swaps
# the pallas kernels for the pure-jnp reference at trace time.  Default
# is the pallas path — the TPU-structured kernel of DESIGN.md §5.
_IMPL = os.environ.get("FLOCORA_ADAPTER_IMPL", "pallas")
if _IMPL == "jnp":
    lora_matmul, matmul = lora_matmul_ref, matmul_ref
else:
    lora_matmul, matmul = _lora_matmul_pallas, _matmul_pallas

_DIMNUMS = ("NHWC", "OIHW", "NHWC")


def conv2d(x, w, stride=1):
    """SAME-padded conv, NHWC activations, OIHW weights."""
    return lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=_DIMNUMS,
    )


def group_norm(x, w, b, groups, eps=1e-5):
    """GroupNorm over (H, W, C/g) per group; affine (w, b) per channel."""
    n, h, wd, c = x.shape
    g = groups
    xg = x.reshape(n, h, wd, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * lax.rsqrt(var + eps)
    x = xg.reshape(n, h, wd, c)
    return x * w.reshape(1, 1, 1, c) + b.reshape(1, 1, 1, c)


def lora_conv_delta(x, lora_b, lora_a, scale, stride=1):
    """Adapter branch of a conv: ``(alpha/r) * A(B(x))``.

    * K x K convs: B via lax.conv (I -> r), then the 1 x 1 up-projection as
      a pallas matmul over the channel dim (the rank bottleneck).
    * 1 x 1 convs (residual downsample): the entire B/A pair collapses to
      the fused low-rank pallas kernel on spatially-subsampled activations
      — the never-materialize-the-intermediate path.
    """
    r, i, kh, kw = lora_b.shape
    o = lora_a.shape[0]
    if kh == 1 and kw == 1:
        xs = x[:, ::stride, ::stride, :]
        n, h, w, _ = xs.shape
        b_mat = lora_b.reshape(r, i).T          # (I, r)
        a_mat = lora_a.reshape(o, r).T          # (r, O)
        out = lora_matmul(xs.reshape(n * h * w, i), b_mat, a_mat, scale)
        return out.reshape(n, h, w, o)
    z = conv2d(x, lora_b, stride)               # (N, H', W', r)
    n, h, w, _ = z.shape
    a_mat = lora_a.reshape(o, r).T               # (r, O)
    out = matmul(z.reshape(n * h * w, r), a_mat) * scale
    return out.reshape(n, h, w, o)


def lora_fc_delta(feats, fc_lora_b, fc_lora_a, scale):
    """FC adapter (``lora_all`` variant): fused low-rank pallas product."""
    return lora_matmul(feats, fc_lora_b, fc_lora_a, scale)
