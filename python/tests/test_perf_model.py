"""Sanity tests on the L1 kernel performance model (the structural
numbers DESIGN.md §Perf quotes)."""

from compile.kernels.perf_model import (VMEM_BYTES, default_blocks,
                                        estimate, paper_shapes, report)


def test_all_paper_shapes_fit_vmem():
    """The chosen default blocks must keep every paper shape's working
    set well under VMEM (leaving room for the surrounding model)."""
    for label, m, k, r, n in paper_shapes():
        bm, bn = default_blocks(m, n, k)
        e = estimate(m, k, r, n, bm, bn)
        assert e.vmem_frac < 0.6, (label, e.vmem_frac)


def test_fusion_saves_hbm_traffic():
    """Keeping the rank-r intermediate in VMEM must strictly reduce HBM
    bytes, and the saving grows with m (the intermediate is (m, r))."""
    small = estimate(1024, 64, 32, 128, 256, 128)
    large = estimate(32768, 64, 32, 128, 256, 128)
    assert small.hbm_savings > 1.0
    assert large.hbm_savings > small.hbm_savings


def test_mxu_util_monotone_in_rank():
    """Higher rank fills more MXU lanes in stage 1."""
    lo = estimate(4096, 64, 8, 128, 256, 128)
    hi = estimate(4096, 64, 64, 128, 256, 128)
    assert hi.mxu_util_stage1 > lo.mxu_util_stage1


def test_block_shrinks_for_small_problems():
    assert default_blocks(8, 10) == (8, 8)
    # large K => small tile to bound VMEM; small K => big tile.
    assert default_blocks(100_000, 512, k=2304) == (256, 128)
    assert default_blocks(100_000, 512, k=27) == (4096, 128)


def test_report_renders():
    r = report()
    assert "resnet8" in r and "VMEM" in r
    assert len(r.splitlines()) == len(paper_shapes()) + 1


def test_vmem_budget_constant_sane():
    assert VMEM_BYTES == 16 * 2 ** 20
