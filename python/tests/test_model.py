"""L2 model tests: parameter layout arithmetic (the exact numbers behind
the paper's Table I), forward shapes, init invariants and variant
semantics (Table II's ablation axes)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import (MODELS, PAPER_TABLE1, VARIANTS, build_spec,
                             iter_convs, spec_tag)
from compile.model import forward, init_params, unflatten
from compile.train import make_train_step


# ---------------------------------------------------------------------------
# Parameter arithmetic vs the paper
# ---------------------------------------------------------------------------

def test_resnet8_base_param_count_matches_paper():
    """Paper Table I: FedAvg ResNet-8 has 1.23 M parameters."""
    spec = build_spec(MODELS["resnet8"], "full", 0)
    assert spec.num_frozen == 0
    assert abs(spec.num_trainable - 1.23e6) / 1.23e6 < 0.005


@pytest.mark.parametrize("rank", [8, 16, 32, 64, 128])
def test_resnet8_lora_param_counts_near_paper(rank):
    """Table I trained/total params for each rank.  We allow 2% slack:
    the paper does not fully specify its ResNet-8 (e.g. downsample-conv
    adapters); our architecture reproduces every count within ~1.5%."""
    spec = build_spec(MODELS["resnet8"], "lora_fc", rank)
    total_paper, trained_paper = PAPER_TABLE1[rank]
    assert abs(spec.num_total - total_paper) / total_paper < 0.02
    assert abs(spec.num_trainable - trained_paper) / trained_paper < 0.02


def test_resnet18_message_size_matches_table4():
    """Table IV: full ResNet-18 message is 44.7 MB (fp32)."""
    spec = build_spec(MODELS["resnet18"], "full", 0)
    mb = spec.num_trainable * 4 / 1e6
    assert abs(mb - 44.7) / 44.7 < 0.01


@pytest.mark.parametrize("rank,msg_mb", [(64, 9.2), (32, 4.6), (16, 2.4)])
def test_resnet18_lora_message_sizes_match_table4(rank, msg_mb):
    spec = build_spec(MODELS["resnet18"], "lora_fc", rank)
    mb = spec.num_trainable * 4 / 1e6
    assert abs(mb - msg_mb) / msg_mb < 0.06


def test_layout_offsets_are_contiguous():
    for model in MODELS:
        for variant in VARIANTS:
            spec = build_spec(MODELS[model], variant, 4)
            for side in (spec.trainable, spec.frozen):
                off = 0
                for e in side:
                    assert e.offset == off
                    off += e.info.numel


def test_variant_trainability_semantics():
    """Table II rows: which kinds are trainable under each variant."""
    cfg = MODELS["micro8"]

    def kinds(side):
        return {e.info.kind for e in side}

    full = build_spec(cfg, "full", 0)
    assert kinds(full.frozen) == set()

    vanilla = build_spec(cfg, "lora_all", 4)
    assert kinds(vanilla.trainable) == {"lora_b", "lora_a",
                                        "fc_lora_b", "fc_lora_a"}
    assert "norm_w" in kinds(vanilla.frozen)
    assert "fc_w" in kinds(vanilla.frozen)

    norm = build_spec(cfg, "lora_norm", 4)
    assert {"norm_w", "norm_b"} <= kinds(norm.trainable)
    assert "fc_w" in kinds(norm.frozen)

    fc = build_spec(cfg, "lora_fc", 4)
    assert {"fc_w", "fc_b", "norm_w"} <= kinds(fc.trainable)
    assert "fc_lora_b" not in kinds(fc.trainable)


def test_conv_enumeration_resnet8():
    convs = list(iter_convs(MODELS["resnet8"]))
    names = [c[0] for c in convs]
    # conv1 + 3 stages x (2 block convs) + 2 downsamples (stages 1, 2)
    assert len(convs) == 9
    assert names[0] == "conv1"
    assert "s1.b0.down" in names and "s2.b0.down" in names
    assert "s0.b0.down" not in names


def test_conv_enumeration_resnet18():
    convs = list(iter_convs(MODELS["resnet18"]))
    # conv1 + 4 stages x 2 blocks x 2 convs + 3 downsamples
    assert len(convs) == 1 + 16 + 3


def test_spec_tags():
    assert spec_tag("resnet8", "full", 0) == "resnet8_full"
    assert spec_tag("tiny8", "lora_fc", 8) == "tiny8_lora_fc_r8"


# ---------------------------------------------------------------------------
# Forward / init behaviour
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def micro_spec():
    return build_spec(MODELS["micro8"], "lora_fc", 4)


@pytest.fixture(scope="module")
def micro_params(micro_spec):
    return init_params(micro_spec, jax.random.PRNGKey(7))


def test_forward_shapes(micro_spec, micro_params):
    tr, fr = micro_params
    x = jnp.zeros((8, 16, 16, 3), jnp.float32)
    logits = forward(micro_spec, tr, fr, x, jnp.float32(16.0))
    assert logits.shape == (8, 10)
    assert not np.isnan(np.asarray(logits)).any()


def test_init_zero_up_projection_makes_adapters_noop(micro_spec,
                                                     micro_params):
    """Round-0 invariant: with A = 0 the adapted model equals the frozen
    base model — changing lora_scale must not change the logits."""
    tr, fr = micro_params
    x = jax.random.uniform(jax.random.PRNGKey(3), (4, 16, 16, 3))
    l1 = forward(micro_spec, tr, fr, x, jnp.float32(16.0))
    l2 = forward(micro_spec, tr, fr, x, jnp.float32(512.0))
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_init_determinism(micro_spec):
    a = init_params(micro_spec, jax.random.PRNGKey(5))
    b = init_params(micro_spec, jax.random.PRNGKey(5))
    c = init_params(micro_spec, jax.random.PRNGKey(6))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert np.abs(np.asarray(a[0]) - np.asarray(c[0])).max() > 0


def test_unflatten_round_trip(micro_spec, micro_params):
    tr, fr = micro_params
    p = unflatten(micro_spec, tr, fr)
    assert len(p) == len(micro_spec.trainable) + len(micro_spec.frozen)
    for e in micro_spec.trainable:
        assert p[e.info.name].shape == e.info.shape
    # Spot-check one segment's content.
    e = micro_spec.trainable[0]
    np.testing.assert_array_equal(
        np.asarray(p[e.info.name]).reshape(-1),
        np.asarray(tr[e.offset:e.offset + e.info.numel]))


def test_frozen_params_do_not_change_under_training(micro_spec,
                                                    micro_params):
    tr, fr = micro_params
    step = jax.jit(make_train_step(micro_spec))
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 16, 16, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    m = jnp.zeros_like(tr)
    tr2, m2, loss, acc = step(tr, m, fr, x, y, jnp.float32(0.01),
                              jnp.float32(16.0))
    # frozen vector is an input, untouched by construction; the trainable
    # vector must actually move.
    assert np.abs(np.asarray(tr2) - np.asarray(tr)).max() > 0
    assert float(loss) > 0


@pytest.mark.parametrize("variant", VARIANTS)
def test_one_batch_overfit(variant):
    """Descent sanity for every Table II variant: 30 steps on one batch
    must cut the loss substantially (lora_all uses a smaller lr — the
    paper itself reports Vanilla's instability)."""
    spec = build_spec(MODELS["micro8"], variant, 4)
    tr, fr = init_params(spec, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(spec))
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 16, 16, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    m = jnp.zeros_like(tr)
    lr = jnp.float32(0.005 if variant == "lora_all" else 0.02)
    first = last = None
    for i in range(30):
        tr, m, loss, acc = step(tr, m, fr, x, y, lr, jnp.float32(16.0))
        if i == 0:
            first = float(loss)
        last = float(loss)
    assert last < first * 0.7, (variant, first, last)
