"""L1 kernel correctness: pallas (interpret) vs pure-jnp oracle.

Includes randomized shape sweeps (the environment has no `hypothesis`
package, so we drive the sweep from a seeded numpy RNG — same coverage
intent: many shapes/ranks/scales, deterministic replay via the seed).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels.lora_matmul import lora_matmul, matmul, _lora_matmul_raw
from compile.kernels.quant import fake_quant
from compile.kernels.ref import fake_quant_ref, lora_matmul_ref, matmul_ref

RNG = np.random.default_rng(1234)


def rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# lora_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,r,n", [
    (8, 16, 4, 10),       # FC-adapter-like
    (64, 27, 8, 32),      # conv1-adapter-like (I*K*K = 27)
    (256, 64, 16, 128),   # block-aligned
    (257, 65, 3, 129),    # ragged everything
    (1, 1, 1, 1),         # degenerate
    (300, 8, 128, 8),     # rank > dims (paper's r=128 on 64-ch convs)
])
def test_lora_matmul_matches_ref(m, k, r, n):
    x, b, a = rand(m, k), rand(k, r), rand(r, n)
    got = lora_matmul(x, b, a, 16.0)
    want = lora_matmul_ref(x, b, a, 16.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_lora_matmul_shape_sweep():
    """Randomized sweep over 25 shape/scale combos."""
    for _ in range(25):
        m = int(RNG.integers(1, 300))
        k = int(RNG.integers(1, 80))
        r = int(RNG.integers(1, 33))
        n = int(RNG.integers(1, 140))
        scale = float(RNG.uniform(0.1, 32.0))
        x, b, a = rand(m, k), rand(k, r), rand(r, n)
        got = lora_matmul(x, b, a, scale)
        want = lora_matmul_ref(x, b, a, scale)
        # f32 accumulation-order differences scale with K*r*|scale|.
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-3)


def test_lora_matmul_zero_up_projection_is_noop():
    """LoRA init invariant: A = 0 => adapter contributes exactly 0."""
    x, b = rand(32, 16), rand(16, 8)
    a = jnp.zeros((8, 12), jnp.float32)
    np.testing.assert_array_equal(np.asarray(lora_matmul(x, b, a, 16.0)),
                                  np.zeros((32, 12), np.float32))


def test_lora_matmul_grads_match_ref():
    """custom_vjp vs autodiff of the jnp reference."""
    x, b, a = rand(24, 12), rand(12, 4), rand(4, 18)

    def loss_kernel(x, b, a):
        return jnp.sum(jnp.sin(lora_matmul(x, b, a, 2.5)))

    def loss_ref(x, b, a):
        return jnp.sum(jnp.sin(lora_matmul_ref(x, b, a, 2.5)))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, b, a)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, b, a)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lora_matmul_jit_and_block_override():
    x, b, a = rand(100, 20, ), rand(20, 8), rand(8, 30)
    got = jax.jit(lambda *t: lora_matmul(*t, 1.0))(x, b, a)
    np.testing.assert_allclose(got, lora_matmul_ref(x, b, a, 1.0),
                               rtol=1e-5, atol=1e-5)
    got2 = _lora_matmul_raw(x, b, a, 1.0, block_m=16, block_n=16)
    np.testing.assert_allclose(got2, lora_matmul_ref(x, b, a, 1.0),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (257, 31, 63), (1, 5, 1),
                                   (512, 4, 512)])
def test_matmul_matches_ref(m, k, n):
    x, y = rand(m, k), rand(k, n)
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y),
                               rtol=1e-5, atol=1e-5)


def test_matmul_grads():
    x, y = rand(30, 7), rand(7, 11)
    gk = jax.grad(lambda x, y: jnp.sum(matmul(x, y) ** 2),
                  argnums=(0, 1))(x, y)
    gr = jax.grad(lambda x, y: jnp.sum((x @ y) ** 2), argnums=(0, 1))(x, y)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("rows,cols", [(4, 16), (64, 129), (3, 1), (100, 7)])
def test_fake_quant_matches_ref(bits, rows, cols):
    w = rand(rows, cols) * 3.0
    dq, s, z = fake_quant(w, bits)
    dqr, sr, zr = fake_quant_ref(w, bits)
    # ulp slack: XLA compiles the division differently in the pallas
    # program vs the plain-jnp program (reciprocal-multiply fusion),
    # and the zero point is real-valued now, so it inherits that slack
    # too instead of rounding to an identical integer.
    np.testing.assert_allclose(dq, dqr, rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(s, sr, rtol=1e-6, atol=0)
    np.testing.assert_allclose(z, zr, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fake_quant_error_bound(bits):
    """RTN error is bounded by scale/2 per element."""
    w = rand(32, 64)
    dq, s, _ = fake_quant(w, bits)
    err = np.abs(np.asarray(dq) - np.asarray(w))
    # The additive slack absorbs f32 ulp noise from the real-valued
    # zero point (zp can reach qmax, so (q - zp) * scale carries a few
    # ulps beyond the ideal half-scale bound).
    bound = np.asarray(s) * 0.5 + 1e-5
    assert (err <= bound).all()


def test_fake_quant_constant_rows():
    """Degenerate rows (zero range) must not produce NaNs and must
    round-trip near-exactly for values inside the clip range."""
    w = jnp.stack([jnp.full((16,), v) for v in (-3.0, 0.0, 5.0)])
    dq, s, z = fake_quant(w, 8)
    assert not np.isnan(np.asarray(dq)).any()
    np.testing.assert_allclose(dq, w, atol=0)


def test_fake_quant_monotone_bits():
    """More bits => no worse max reconstruction error."""
    w = rand(16, 100)
    errs = []
    for bits in (2, 4, 8):
        dq, _, _ = fake_quant(w, bits)
        errs.append(float(jnp.max(jnp.abs(dq - w))))
    assert errs[0] >= errs[1] >= errs[2]


def test_fake_quant_randomized_sweep():
    for _ in range(15):
        rows = int(RNG.integers(1, 80))
        cols = int(RNG.integers(1, 200))
        bits = int(RNG.choice([2, 4, 8]))
        w = rand(rows, cols) * float(RNG.uniform(0.01, 10))
        dq, s, z = fake_quant(w, bits)
        dqr, sr, zr = fake_quant_ref(w, bits)
        np.testing.assert_allclose(np.asarray(dq), np.asarray(dqr),
                                   rtol=1e-6, atol=1e-5)
        # The real-valued zero point may land outside [0, qmax] for
        # one-sided rows (that is the point of the true-range grid);
        # it just has to be finite.
        assert np.isfinite(np.asarray(z)).all()


def test_fake_quant_strictly_positive_rows_use_true_range():
    """A strictly-positive row must be gridded over [min, max], not
    [0, max]: the reconstruction error bound is (max-min)/qmax/2, which
    a zero-anchored grid would miss by a wide margin (mirrors the rust
    regression test in compression/affine.rs)."""
    w = jnp.asarray(RNG.uniform(10.0, 10.63, (4, 64)), jnp.float32)
    dq, s, z = fake_quant(w, 8)
    tight_scale = (np.asarray(w).max(axis=1) - np.asarray(w).min(axis=1)) / 255.0
    assert (np.asarray(s)[:, 0] <= tight_scale + 1e-7).all()
    err = np.abs(np.asarray(dq) - np.asarray(w))
    assert (err <= np.asarray(s) * 0.5 + 2e-5).all()
    # Zero-anchored gridding would have scale ~ 10.63/255 ≈ 0.0417 and
    # error up to ~0.02; the true-range grid is ~17x tighter.
    assert err.max() < 3e-3
