"""Manifest integrity: what aot.py exported must exactly describe the
specs the rust coordinator will index into.  Skipped when artifacts have
not been built yet (`make artifacts`)."""

import json
import os

import pytest

from compile.configs import MODELS, build_spec

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_has_core_specs(manifest):
    for tag in ("micro8_full", "micro8_lora_fc_r4", "tiny8_lora_fc_r8",
                "resnet8_full", "resnet8_lora_fc_r32"):
        assert tag in manifest["specs"], tag


def test_manifest_files_exist(manifest):
    for tag, spec in manifest["specs"].items():
        for role, fname in spec["files"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), (tag, role, fname)
            assert os.path.getsize(path) > 100


def test_manifest_segments_match_python_spec(manifest):
    for tag, mspec in manifest["specs"].items():
        spec = build_spec(MODELS[mspec["model"]], mspec["variant"],
                          mspec["rank"])
        assert mspec["num_trainable"] == spec.num_trainable, tag
        assert mspec["num_frozen"] == spec.num_frozen, tag
        assert len(mspec["trainable_segments"]) == len(spec.trainable)
        for mseg, e in zip(mspec["trainable_segments"], spec.trainable):
            assert mseg["name"] == e.info.name
            assert mseg["offset"] == e.offset
            assert tuple(mseg["shape"]) == e.info.shape
            assert mseg["numel"] == e.info.numel


def test_manifest_segments_cover_vector_exactly(manifest):
    for tag, mspec in manifest["specs"].items():
        for side, total in (("trainable_segments", "num_trainable"),
                            ("frozen_segments", "num_frozen")):
            end = 0
            for seg in mspec[side]:
                assert seg["offset"] == end, (tag, side, seg["name"])
                end += seg["numel"]
            assert end == mspec[total], (tag, side)


def test_quant_oracles_present(manifest):
    assert set(manifest["quant_oracles"]) == {"2", "4", "8"}
    for meta in manifest["quant_oracles"].values():
        assert os.path.exists(os.path.join(ART, meta["file"]))


def test_hlo_text_is_parseable_header(manifest):
    """Sanity: HLO text artifacts start with an HloModule header (the
    format HloModuleProto::from_text_file expects)."""
    one = next(iter(manifest["specs"].values()))
    with open(os.path.join(ART, one["files"]["train"])) as f:
        head = f.read(200)
    assert head.startswith("HloModule"), head[:50]
