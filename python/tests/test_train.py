"""Training-semantics tests: the SGD+momentum step, eval masking, and the
FedAvg-compatibility invariant at the heart of the paper's
"aggregation-agnostic" claim."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import MODELS, build_spec
from compile.model import init_params
from compile.train import MOMENTUM, cross_entropy, make_eval_step, \
    make_train_step


@pytest.fixture(scope="module")
def setup():
    spec = build_spec(MODELS["micro8"], "lora_fc", 4)
    tr, fr = init_params(spec, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(spec))
    ev = jax.jit(make_eval_step(spec))
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 16, 16, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    return spec, tr, fr, step, ev, x, y


def test_momentum_update_rule(setup):
    """p' = p - lr (m rho + g); m' = m rho + g — verified against a
    hand-computed step from a zero-momentum start (m' = g)."""
    spec, tr, fr, step, ev, x, y = setup
    m0 = jnp.zeros_like(tr)
    lr = jnp.float32(0.01)
    p1, m1, _, _ = step(tr, m0, fr, x, y, lr, jnp.float32(16.0))
    # From m=0: m1 == grad, p1 == p - lr*grad.
    np.testing.assert_allclose(np.asarray(p1),
                               np.asarray(tr - lr * m1), atol=1e-7)
    # Second step with zero grad impossible; instead verify rho folding:
    p2, m2, _, _ = step(p1, m1, fr, x, y, lr, jnp.float32(16.0))
    g2 = m2 - MOMENTUM * m1
    np.testing.assert_allclose(np.asarray(p2),
                               np.asarray(p1 - lr * (MOMENTUM * m1 + g2)),
                               atol=1e-6)


def test_zero_lr_is_identity(setup):
    spec, tr, fr, step, ev, x, y = setup
    m = jnp.zeros_like(tr)
    p1, m1, loss, acc = step(tr, m, fr, x, y, jnp.float32(0.0),
                             jnp.float32(16.0))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(tr))
    assert np.abs(np.asarray(m1)).max() > 0  # momentum still accumulates


def test_eval_mask_semantics(setup):
    """Masked-out examples contribute exactly nothing (ragged batches)."""
    spec, tr, fr, step, ev, x, y = setup
    full = ev(tr, fr, x, y, jnp.ones(8), jnp.float32(16.0))
    half_mask = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    half = ev(tr, fr, x, y, half_mask, jnp.float32(16.0))
    # Recompute the first-half-only numbers by zero-masking a shuffled
    # second half: results must be independent of masked content.
    x2 = x.at[4:].set(jax.random.uniform(jax.random.PRNGKey(9),
                                         (4, 16, 16, 3)))
    half2 = ev(tr, fr, x2, y, half_mask, jnp.float32(16.0))
    np.testing.assert_allclose(np.asarray(half), np.asarray(half2),
                               rtol=1e-5, atol=1e-5)
    assert float(half[1]) <= float(full[1])


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, 1.0, 0.1], [0.0, 0.0, 0.0]])
    y = jnp.array([0, 2])
    ce = cross_entropy(logits, y)
    probs = np.exp(np.asarray(logits))
    probs /= probs.sum(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(ce),
                               -np.log(probs[[0, 1], [0, 2]]), rtol=1e-6)


def test_aggregation_agnostic_invariant(setup):
    """The paper's central systems claim (§III): averaging *adapter
    vectors* then evaluating == the server never needs to know the
    vector is not a full model.  We verify that a weighted average of two
    trained vectors is a valid parameter vector producing finite loss,
    and that averaging identical vectors is exact identity."""
    spec, tr, fr, step, ev, x, y = setup
    m = jnp.zeros_like(tr)
    a1, _, _, _ = step(tr, m, fr, x, y, jnp.float32(0.02), jnp.float32(16.0))
    a2, _, _, _ = step(tr, m, fr, x[::-1], y[::-1], jnp.float32(0.02),
                       jnp.float32(16.0))
    avg = 0.25 * a1 + 0.75 * a2
    loss, correct = ev(avg, fr, x, y, jnp.ones(8), jnp.float32(16.0))
    assert np.isfinite(float(loss))
    same = 0.5 * a1 + 0.5 * a1
    np.testing.assert_array_equal(np.asarray(same), np.asarray(a1))


def test_train_full_vs_lora_touch_disjoint_state():
    """In `full` the frozen vector is empty; in lora variants the
    trainable vector is much smaller — the memory-saving claim of §II-C
    in concrete terms."""
    full = build_spec(MODELS["micro8"], "full", 0)
    lora = build_spec(MODELS["micro8"], "lora_fc", 4)
    assert full.num_frozen == 0
    assert lora.num_trainable < full.num_trainable / 2
    assert lora.num_total >= full.num_trainable
