"""Layer-level semantics tests, including the paper's §II-C merge claim:
"the matrix BA can be incorporated back into the original pretrained
weights W* without any additional latency" — we verify that running the
adapter branch is *numerically equivalent* to folding the low-rank
product into the conv/FC weight."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.layers import (conv2d, group_norm, lora_conv_delta,
                            lora_fc_delta)
from compile.configs import group_count

RNG = np.random.default_rng(77)


def rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# conv2d basics
# ---------------------------------------------------------------------------

def test_conv2d_identity_kernel():
    """A centered 1-hot 3x3 kernel is the identity under SAME padding."""
    x = rand(2, 8, 8, 3)
    w = jnp.zeros((3, 3, 3, 3), jnp.float32)
    for c in range(3):
        w = w.at[c, c, 1, 1].set(1.0)
    np.testing.assert_allclose(conv2d(x, w, 1), x, atol=1e-6)


def test_conv2d_stride_downsamples():
    x = rand(1, 8, 8, 2)
    w = rand(4, 2, 3, 3)
    assert conv2d(x, w, 2).shape == (1, 4, 4, 4)


def test_conv2d_matches_manual_dot_for_1x1():
    x = rand(2, 5, 5, 6)
    w = rand(7, 6, 1, 1)
    got = conv2d(x, w, 1)
    want = jnp.einsum("nhwc,oc->nhwo", x, w.reshape(7, 6))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# group norm
# ---------------------------------------------------------------------------

def test_group_norm_normalizes_per_group():
    x = rand(3, 6, 6, 8) * 5.0 + 2.0
    out = group_norm(x, jnp.ones(8), jnp.zeros(8), groups=4)
    g = np.asarray(out).reshape(3, 6, 6, 4, 2)
    mean = g.mean(axis=(1, 2, 4))
    std = g.std(axis=(1, 2, 4))
    np.testing.assert_allclose(mean, 0.0, atol=1e-4)
    np.testing.assert_allclose(std, 1.0, atol=1e-3)


def test_group_norm_affine_applies():
    x = rand(1, 4, 4, 4)
    w = jnp.array([2.0, 2.0, 2.0, 2.0])
    b = jnp.array([1.0, 1.0, 1.0, 1.0])
    base = group_norm(x, jnp.ones(4), jnp.zeros(4), groups=2)
    out = group_norm(x, w, b, groups=2)
    np.testing.assert_allclose(out, base * 2.0 + 1.0, atol=1e-5)


def test_group_count_rules():
    assert group_count(64) == 8
    assert group_count(4) == 4
    assert group_count(6) == 2
    assert group_count(7) == 1


# ---------------------------------------------------------------------------
# adapter merge equivalence (paper §II-C)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("o,i,k,stride", [(8, 4, 3, 1), (8, 4, 3, 2),
                                          (16, 8, 1, 1), (16, 8, 1, 2)])
def test_conv_adapter_equals_merged_weight(o, i, k, stride):
    """W x + scale * A(B(x))  ==  (W + scale * merge(B, A)) x.

    The merged kernel is the 1x1 conv A applied across B's output
    channels: merged[o, i, :, :] = sum_r A[o, r] * B[r, i, :, :].
    """
    x = rand(2, 8, 8, i)
    w = rand(o, i, k, k) * 0.3
    lora_b = rand(4, i, k, k) * 0.3          # r = 4
    lora_a = rand(o, 4, 1, 1) * 0.3
    scale = 16.0

    adapted = conv2d(x, w, stride) + lora_conv_delta(
        x, lora_b, lora_a, scale, stride)

    merged = w + scale * jnp.einsum(
        "or,rikl->oikl", lora_a.reshape(o, 4), lora_b)
    folded = conv2d(x, merged, stride)
    np.testing.assert_allclose(adapted, folded, rtol=2e-4, atol=2e-4)


def test_fc_adapter_equals_merged_weight():
    feats = rand(16, 32)
    w = rand(32, 10) * 0.3
    b_mat = rand(32, 4) * 0.3
    a_mat = rand(4, 10) * 0.3
    scale = 8.0
    adapted = feats @ w + lora_fc_delta(feats, b_mat, a_mat, scale)
    folded = feats @ (w + scale * (b_mat @ a_mat))
    np.testing.assert_allclose(adapted, folded, rtol=2e-4, atol=2e-4)


def test_adapter_scale_linearity():
    """The adapter branch is linear in alpha/r — doubling the scale
    doubles the delta (Fig. 2's knob is exactly an lr rescale)."""
    x = rand(1, 6, 6, 4)
    lora_b = rand(3, 4, 3, 3)
    lora_a = rand(8, 3, 1, 1)
    d1 = lora_conv_delta(x, lora_b, lora_a, 1.0, 1)
    d2 = lora_conv_delta(x, lora_b, lora_a, 2.0, 1)
    np.testing.assert_allclose(np.asarray(d2), 2.0 * np.asarray(d1),
                               rtol=1e-4, atol=1e-5)


def test_adapter_zero_up_projection_exact_zero():
    x = rand(1, 6, 6, 4)
    lora_b = rand(3, 4, 3, 3)
    lora_a = jnp.zeros((8, 3, 1, 1), jnp.float32)
    d = lora_conv_delta(x, lora_b, lora_a, 16.0, 1)
    np.testing.assert_array_equal(np.asarray(d),
                                  np.zeros_like(np.asarray(d)))


def test_downsample_adapter_subsamples_consistently():
    """The fused 1x1 path must subsample exactly like the strided conv."""
    x = rand(1, 8, 8, 4)
    lora_b = rand(2, 4, 1, 1)
    lora_a = rand(6, 2, 1, 1)
    d2 = lora_conv_delta(x, lora_b, lora_a, 1.0, 2)
    assert d2.shape == (1, 4, 4, 6)
    # Strided output equals dense output sampled at even pixels.
    d1 = lora_conv_delta(x, lora_b, lora_a, 1.0, 1)
    np.testing.assert_allclose(d2, d1[:, ::2, ::2, :], rtol=1e-5, atol=1e-5)
