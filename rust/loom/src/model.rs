//! The exploration driver: run the checked closure once per schedule
//! until the (preemption-bounded) schedule tree is exhausted.

use std::panic::{resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Once};

use crate::sched::{self, AbortIteration, Sched};

/// Exploration knobs. Environment overrides: `LOOM_PREEMPTION_BOUND`
/// (a number, or `none` for unbounded DFS) and `LOOM_MAX_ITERATIONS`.
pub struct Builder {
    /// CHESS-style budget: how many times a schedule may switch away
    /// from a still-runnable thread. `None` = full (unbounded) DFS.
    /// The default of 2 finds the overwhelming majority of real
    /// concurrency bugs while keeping iteration counts tractable.
    pub preemption_bound: Option<usize>,
    /// Hard cap on explored schedules; hitting it truncates coverage
    /// (with a note on stderr) rather than hanging the suite.
    pub max_iterations: u64,
}

impl Builder {
    pub fn new() -> Builder {
        let preemption_bound = match std::env::var("LOOM_PREEMPTION_BOUND")
        {
            Ok(v) if v == "none" => None,
            Ok(v) => Some(v.parse().unwrap_or(2)),
            Err(_) => Some(2),
        };
        let max_iterations = std::env::var("LOOM_MAX_ITERATIONS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(200_000);
        Builder { preemption_bound, max_iterations }
    }

    /// Model-check `f`: execute it under every schedule (within the
    /// bounds), panicking on the first deadlock / lost wakeup /
    /// user-assertion failure, with the failing schedule attached.
    pub fn check<F>(&self, f: F)
    where
        F: Fn() + Send + Sync + 'static,
    {
        install_abort_hook();
        let sched = Arc::new(Sched::new(self.preemption_bound));
        let f = Arc::new(f);
        let mut iterations: u64 = 0;
        loop {
            iterations += 1;
            sched.begin_iteration();
            let s2 = Arc::clone(&sched);
            let f2 = Arc::clone(&f);
            let root = std::thread::Builder::new()
                .name("loom-root".into())
                .spawn(move || {
                    sched::set_current(Some((Arc::clone(&s2), 0)));
                    let out =
                        std::panic::catch_unwind(AssertUnwindSafe(|| {
                            s2.start_park(0);
                            f2();
                        }));
                    if let Err(p) = out {
                        if p.downcast_ref::<AbortIteration>().is_none() {
                            s2.set_root_panic(p);
                        }
                    }
                    s2.op_finish(0);
                })
                .expect("loom: failed to spawn the root thread");
            sched.wait_iteration_done();
            let _ = root.join();

            let failure = sched.take_failure();
            // A user panic outranks the secondary deadlock it may have
            // caused on its way down.
            if let Some(p) = sched.take_root_panic() {
                eprintln!(
                    "loom (mini): panic on iteration {iterations}; \
                     schedule: {}",
                    sched.trail_string()
                );
                resume_unwind(p);
            }
            if let Some(msg) = failure {
                panic!(
                    "loom (mini): model failed on iteration \
                     {iterations}: {msg}\n  schedule: {}",
                    sched.trail_string()
                );
            }
            if !sched.backtrack() {
                return;
            }
            if iterations >= self.max_iterations {
                eprintln!(
                    "loom (mini): stopping after {iterations} \
                     iterations (LOOM_MAX_ITERATIONS cap) — \
                     exploration truncated"
                );
                return;
            }
        }
    }
}

impl Default for Builder {
    fn default() -> Builder {
        Builder::new()
    }
}

/// Model-check `f` with default bounds — the `loom::model` entry
/// point.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    Builder::new().check(f)
}

/// Suppress the panic-hook noise of [`AbortIteration`] sentinels (they
/// unwind every parked thread of a failed iteration); anything else is
/// forwarded to the previously installed hook.
fn install_abort_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<AbortIteration>().is_none() {
                prev(info);
            }
        }));
    });
}
