//! Instrumented twins of `std::thread` spawn/join/scope.
//!
//! Every spawned closure runs inside a wrapper that (a) registers the
//! thread with the scheduler and parks until first scheduled, (b)
//! catches panics so the `std` machinery underneath never observes
//! them (user panics are re-surfaced with `std` semantics: `join`
//! returns `Err`, an unjoined scoped thread's panic re-raises when the
//! scope closes), and (c) reports `finish` so joiners and the model
//! loop wake.
//!
//! The scope API differs from `std` in one signature detail: the
//! closure receives `&Scope<'scope, 'env>` under an independent
//! borrow lifetime rather than `&'scope Scope<...>`. `std` can unify
//! the two because it constructs the `Scope` itself; a wrapper cannot
//! borrow a local for the caller's late-bound `'scope`. Call sites
//! are source-compatible for everything flocora does.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex as StdMutex, PoisonError as StdPoisonError};

use crate::sched::{self, AbortIteration};

pub use std::thread::{available_parallelism, panicking};

type PanicPayload = Box<dyn Any + Send + 'static>;
/// One spawned thread's caught panic, if any.
type Slot = StdMutex<Option<PanicPayload>>;
/// A scope's not-yet-joined threads: `(model tid, panic slot)`.
type Pending = Arc<StdMutex<Vec<(Option<usize>, Arc<Slot>)>>>;

fn lock_slot(slot: &Slot) -> std::sync::MutexGuard<'_, Option<PanicPayload>> {
    slot.lock().unwrap_or_else(StdPoisonError::into_inner)
}

/// A decision point with no side effect — lets the scheduler explore
/// a preemption here, like `std::thread::yield_now` invites one.
pub fn yield_now() {
    match sched::current() {
        Some((sched, me)) => sched.op_atomic(me, "yield"),
        None => std::thread::yield_now(),
    }
}

/// Run `f`: park until first scheduled, catch panics (the abort
/// sentinel of a failed iteration is swallowed; user panics go to
/// `slot`), report finish. Returns `Some(value)` on clean completion
/// so the `std` join below never sees a panic.
fn run_wrapped<T>(
    model: Option<(Arc<sched::Sched>, usize)>,
    slot: &Slot,
    f: impl FnOnce() -> T,
) -> Option<T> {
    match model {
        Some((sched, tid)) => {
            sched::set_current(Some((Arc::clone(&sched), tid)));
            let out = catch_unwind(AssertUnwindSafe(|| {
                sched.start_park(tid);
                f()
            }));
            let ret = match out {
                Ok(v) => Some(v),
                Err(p) => {
                    if p.downcast_ref::<AbortIteration>().is_none() {
                        *lock_slot(slot) = Some(p);
                    }
                    None
                }
            };
            sched.op_finish(tid);
            ret
        }
        None => match catch_unwind(AssertUnwindSafe(f)) {
            Ok(v) => Some(v),
            Err(p) => {
                *lock_slot(slot) = Some(p);
                None
            }
        },
    }
}

pub struct JoinHandle<T> {
    std: std::thread::JoinHandle<Option<T>>,
    tid: Option<usize>,
    slot: Arc<Slot>,
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let cur = sched::current();
    let tid = cur.as_ref().map(|(sched, me)| sched.op_spawn(*me));
    let slot: Arc<Slot> = Arc::new(StdMutex::new(None));
    let slot2 = Arc::clone(&slot);
    let model = cur.map(|(sched, _)| (sched, tid.expect("tid set")));
    let std = std::thread::spawn(move || run_wrapped(model, &slot2, f));
    JoinHandle { std, tid, slot }
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        if let (Some(tid), Some((sched, me))) =
            (self.tid, sched::current())
        {
            sched.op_join(me, tid);
        }
        match self.std.join() {
            Ok(Some(v)) => Ok(v),
            Ok(None) => Err(lock_slot(&self.slot).take().unwrap_or_else(
                || Box::new("loom: thread aborted with the iteration"),
            )),
            Err(p) => Err(p),
        }
    }

    pub fn is_finished(&self) -> bool {
        self.std.is_finished()
    }
}

pub struct Scope<'scope, 'env: 'scope> {
    std: &'scope std::thread::Scope<'scope, 'env>,
    pending: Pending,
}

impl<'scope, 'env> Scope<'scope, 'env> {
    pub fn spawn<F, T>(&self, f: F) -> ScopedJoinHandle<'scope, T>
    where
        F: FnOnce() -> T + Send + 'scope,
        T: Send + 'scope,
    {
        let cur = sched::current();
        let tid = cur.as_ref().map(|(sched, me)| sched.op_spawn(*me));
        let slot: Arc<Slot> = Arc::new(StdMutex::new(None));
        self.pending
            .lock()
            .unwrap_or_else(StdPoisonError::into_inner)
            .push((tid, Arc::clone(&slot)));
        let slot2 = Arc::clone(&slot);
        let model = cur.map(|(sched, _)| (sched, tid.expect("tid set")));
        let std =
            self.std.spawn(move || run_wrapped(model, &slot2, f));
        ScopedJoinHandle {
            std,
            tid,
            slot,
            pending: Arc::clone(&self.pending),
        }
    }
}

pub struct ScopedJoinHandle<'scope, T> {
    std: std::thread::ScopedJoinHandle<'scope, Option<T>>,
    tid: Option<usize>,
    slot: Arc<Slot>,
    pending: Pending,
}

impl<T> ScopedJoinHandle<'_, T> {
    pub fn join(self) -> std::thread::Result<T> {
        if let (Some(tid), Some((sched, me))) =
            (self.tid, sched::current())
        {
            sched.op_join(me, tid);
        }
        // Consumed: the scope must not re-raise this thread's panic.
        self.pending
            .lock()
            .unwrap_or_else(StdPoisonError::into_inner)
            .retain(|(_, s)| !Arc::ptr_eq(s, &self.slot));
        match self.std.join() {
            Ok(Some(v)) => Ok(v),
            Ok(None) => Err(lock_slot(&self.slot).take().unwrap_or_else(
                || Box::new("loom: thread aborted with the iteration"),
            )),
            Err(p) => Err(p),
        }
    }

    pub fn is_finished(&self) -> bool {
        self.std.is_finished()
    }
}

/// Model-joins every still-pending scoped thread when the scope
/// closure ends (normally or by unwind) — without this, the real join
/// inside `std::thread::scope` would wait on workers that are parked
/// in the turnstile and nobody would ever schedule them.
struct ScopeWind {
    pending: Pending,
}

impl Drop for ScopeWind {
    fn drop(&mut self) {
        if let Some((sched, me)) = sched::current() {
            let tids: Vec<usize> = self
                .pending
                .lock()
                .unwrap_or_else(StdPoisonError::into_inner)
                .iter()
                .filter_map(|(tid, _)| *tid)
                .collect();
            for tid in tids {
                sched.op_join(me, tid);
            }
        }
    }
}

pub fn scope<'env, F, T>(f: F) -> T
where
    F: for<'scope> FnOnce(&Scope<'scope, 'env>) -> T,
{
    let pending: Pending = Arc::new(StdMutex::new(Vec::new()));
    let out = std::thread::scope(|s| {
        let scope = Scope { std: s, pending: Arc::clone(&pending) };
        let wind = ScopeWind { pending: Arc::clone(&pending) };
        let out = f(&scope);
        drop(wind);
        out
    });
    // Every real thread is joined now; re-raise the first panic of a
    // scoped thread nobody joined explicitly (std scope semantics).
    if !std::thread::panicking() {
        let entries = std::mem::take(
            &mut *pending
                .lock()
                .unwrap_or_else(StdPoisonError::into_inner),
        );
        for (_, slot) in entries {
            if let Some(p) = lock_slot(&slot).take() {
                resume_unwind(p);
            }
        }
    }
    out
}
