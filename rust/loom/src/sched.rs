//! The deterministic exploring scheduler behind the model checker.
//!
//! One schedule = one *iteration*: the checked closure runs with every
//! instrumented operation (lock, unlock, condvar wait/notify, spawn,
//! join, atomic access) serialized through a turnstile — exactly one
//! model thread is `active` at a time, everyone else parks on one
//! process-wide condvar. Each operation is a *decision point*: the
//! scheduler picks which runnable thread runs next, recording the
//! choice (and the alternatives) in a DFS `path`. When the iteration
//! finishes, the deepest decision with unexplored alternatives is
//! flipped and the prefix replayed, until the schedule tree (bounded
//! by a CHESS-style preemption budget) is exhausted.
//!
//! Failure modes detected:
//!
//! * **Deadlock / lost wakeup** — every live thread is blocked. Since
//!   condvars here have no spurious wakeups, a protocol that only
//!   terminates because real condvars happen to wake threads anyway is
//!   caught, not masked.
//! * **Replay divergence** — the checked closure behaved differently
//!   on an identical schedule prefix, i.e. it is nondeterministic
//!   (time, ambient randomness, un-instrumented races).
//!
//! On failure the scheduler wakes every parked thread with an
//! [`AbortIteration`] sentinel panic so the iteration unwinds cleanly,
//! then reports the failure with the recent op trail.

use std::collections::VecDeque;
use std::panic::panic_any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex,
                MutexGuard as StdMutexGuard,
                PoisonError as StdPoisonError};

/// Sentinel panic payload used to unwind threads of a failed
/// iteration. Never surfaces to the user: the thread wrappers catch
/// it, and the process panic hook suppresses its message.
pub(crate) struct AbortIteration;

/// Monotonic iteration stamp; object ids registered under an older
/// epoch are re-registered lazily, so primitives created outside the
/// model (or surviving across iterations) stay sound.
static EPOCH: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static CURRENT: std::cell::RefCell<Option<(Arc<Sched>, usize)>> =
        const { std::cell::RefCell::new(None) };
}

/// The scheduler handle + thread id of the current model thread, if
/// this OS thread is running inside a `model()` iteration.
pub(crate) fn current() -> Option<(Arc<Sched>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

pub(crate) fn set_current(v: Option<(Arc<Sched>, usize)>) {
    CURRENT.with(|c| *c.borrow_mut() = v);
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Run {
    Runnable,
    Blocked,
    Finished,
}

/// One DFS decision: which thread was activated, and the runnable
/// alternatives not yet explored (popped from the back on backtrack).
struct Frame {
    chosen: usize,
    remaining: Vec<usize>,
}

pub(crate) struct Core {
    pub(crate) epoch: u64,
    threads: Vec<Run>,
    /// Per-thread list of threads blocked joining it.
    joiners: Vec<Vec<usize>>,
    active: usize,
    finished: usize,
    mutex_holders: Vec<Option<usize>>,
    mutex_waiters: Vec<Vec<usize>>,
    cond_waiters: Vec<VecDeque<usize>>,
    /// DFS over scheduling decisions; survives iterations.
    path: Vec<Frame>,
    /// Cursor into `path` for the current iteration (replay prefix).
    pos: usize,
    preemptions: usize,
    pub(crate) failure: Option<String>,
    trail: Vec<(usize, &'static str, usize)>,
    root_panic: Option<Box<dyn std::any::Any + Send + 'static>>,
}

impl Core {
    fn trail_push(&mut self, tid: usize, op: &'static str, obj: usize) {
        if self.trail.len() < 512 {
            self.trail.push((tid, op, obj));
        }
    }
}

pub(crate) struct Sched {
    core: StdMutex<Core>,
    cv: StdCondvar,
    preemption_bound: Option<usize>,
}

impl Sched {
    pub(crate) fn new(preemption_bound: Option<usize>) -> Sched {
        Sched {
            core: StdMutex::new(Core {
                epoch: 0,
                threads: Vec::new(),
                joiners: Vec::new(),
                active: 0,
                finished: 0,
                mutex_holders: Vec::new(),
                mutex_waiters: Vec::new(),
                cond_waiters: Vec::new(),
                path: Vec::new(),
                pos: 0,
                preemptions: 0,
                failure: None,
                trail: Vec::new(),
                root_panic: None,
            }),
            cv: StdCondvar::new(),
            preemption_bound,
        }
    }

    fn lock_core(&self) -> StdMutexGuard<'_, Core> {
        self.core.lock().unwrap_or_else(StdPoisonError::into_inner)
    }

    /// Reset per-iteration state (the DFS `path` survives; `pos`
    /// rewinds so the recorded prefix replays).
    pub(crate) fn begin_iteration(&self) {
        let mut g = self.lock_core();
        g.epoch = EPOCH.fetch_add(1, Ordering::Relaxed);
        g.threads = vec![Run::Runnable];
        g.joiners = vec![Vec::new()];
        g.active = 0;
        g.finished = 0;
        g.mutex_holders.clear();
        g.mutex_waiters.clear();
        g.cond_waiters.clear();
        g.pos = 0;
        g.preemptions = 0;
        g.failure = None;
        g.trail.clear();
        g.root_panic = None;
    }

    /// Pick the next active thread: replay the recorded path while it
    /// lasts, then extend it with a fresh decision (preferring to keep
    /// the current thread running — switching away from a
    /// still-runnable thread is a preemption and counts against the
    /// CHESS budget). No runnable thread while live ones remain is the
    /// deadlock / lost-wakeup failure.
    fn decide(&self, core: &mut Core) {
        let runnable: Vec<usize> = core
            .threads
            .iter()
            .enumerate()
            .filter(|(_, r)| **r == Run::Runnable)
            .map(|(t, _)| t)
            .collect();
        if runnable.is_empty() {
            if core.finished == core.threads.len() {
                core.active = usize::MAX;
                return;
            }
            let states: Vec<String> = core
                .threads
                .iter()
                .enumerate()
                .map(|(t, r)| format!("t{t}:{r:?}"))
                .collect();
            core.failure = Some(format!(
                "deadlock (lost wakeup): every live thread is blocked \
                 [{}]",
                states.join(", ")
            ));
            return;
        }
        let prev = core.active;
        let prev_runnable = runnable.contains(&prev);
        let chosen = if core.pos < core.path.len() {
            let c = core.path[core.pos].chosen;
            if !runnable.contains(&c) {
                core.failure = Some(format!(
                    "replay diverged at step {}: recorded thread t{c} \
                     is not runnable — the checked closure is \
                     nondeterministic (time, ambient randomness, or an \
                     un-instrumented race)",
                    core.pos
                ));
                return;
            }
            c
        } else {
            let allow_preempt = !prev_runnable
                || match self.preemption_bound {
                    None => true,
                    Some(b) => core.preemptions < b,
                };
            let mut cands = Vec::new();
            if prev_runnable {
                cands.push(prev);
            }
            if allow_preempt {
                cands.extend(
                    runnable.iter().copied().filter(|&t| t != prev),
                );
            }
            let chosen = cands[0];
            // Alternatives explored back-to-front on backtrack;
            // reverse so lower thread ids are tried first.
            let mut remaining = cands.split_off(1);
            remaining.reverse();
            core.path.push(Frame { chosen, remaining });
            chosen
        };
        if prev_runnable && chosen != prev {
            core.preemptions += 1;
        }
        core.pos += 1;
        core.active = chosen;
    }

    /// Park until this thread is the active one. If the iteration
    /// fails meanwhile, unwind with the [`AbortIteration`] sentinel —
    /// unless this thread is already unwinding, in which case return
    /// so the caller can wind down minimally (callers re-check
    /// `failure` after every park).
    fn park<'a>(
        &self,
        mut g: StdMutexGuard<'a, Core>,
        me: usize,
    ) -> StdMutexGuard<'a, Core> {
        loop {
            if g.failure.is_some() {
                if std::thread::panicking() {
                    return g;
                }
                drop(g);
                panic_any(AbortIteration);
            }
            if g.active == me {
                return g;
            }
            g = self
                .cv
                .wait(g)
                .unwrap_or_else(StdPoisonError::into_inner);
        }
    }

    /// The decision point before an instrumented op: record it, let
    /// the scheduler (possibly) hand the turn to another thread, park
    /// until it comes back to us.
    fn boundary_locked<'a>(
        &self,
        mut g: StdMutexGuard<'a, Core>,
        me: usize,
        op: &'static str,
        obj: usize,
    ) -> StdMutexGuard<'a, Core> {
        g.trail_push(me, op, obj);
        self.decide(&mut g);
        self.cv.notify_all();
        self.park(g, me)
    }

    /// Run `f` with the core locked — used by the sync primitives to
    /// resolve their lazily-registered object ids.
    pub(crate) fn with_core<R>(&self, f: impl FnOnce(&mut Core) -> R) -> R {
        f(&mut self.lock_core())
    }

    pub(crate) fn register_mutex(core: &mut Core) -> usize {
        core.mutex_holders.push(None);
        core.mutex_waiters.push(Vec::new());
        core.mutex_holders.len() - 1
    }

    pub(crate) fn register_condvar(core: &mut Core) -> usize {
        core.cond_waiters.push(VecDeque::new());
        core.cond_waiters.len() - 1
    }

    /// Park the freshly spawned thread `me` until first scheduled.
    pub(crate) fn start_park(&self, me: usize) {
        let g = self.lock_core();
        let _g = self.park(g, me);
    }

    /// Model-level mutex acquire. After a failed iteration this is a
    /// no-op: the std mutex underneath still provides real exclusion
    /// while everything unwinds.
    pub(crate) fn op_lock(&self, me: usize, mid: usize) {
        let mut g = self.lock_core();
        if g.failure.is_none() {
            g = self.boundary_locked(g, me, "lock", mid);
        }
        loop {
            if g.failure.is_some() {
                return;
            }
            if g.mutex_holders[mid].is_none() {
                g.mutex_holders[mid] = Some(me);
                return;
            }
            // Held: block; the unlocker wakes all waiters and the
            // scheduler explores who wins the re-acquire race.
            g.mutex_waiters[mid].push(me);
            g.threads[me] = Run::Blocked;
            self.decide(&mut g);
            self.cv.notify_all();
            g = self.park(g, me);
        }
    }

    pub(crate) fn op_unlock(&self, me: usize, mid: usize) {
        let mut g = self.lock_core();
        if g.failure.is_some() {
            return;
        }
        g = self.boundary_locked(g, me, "unlock", mid);
        if g.failure.is_some() {
            return;
        }
        debug_assert_eq!(g.mutex_holders[mid], Some(me));
        g.mutex_holders[mid] = None;
        let ws = std::mem::take(&mut g.mutex_waiters[mid]);
        for w in ws {
            g.threads[w] = Run::Runnable;
        }
    }

    /// Atomically release `mid`, enqueue on condvar `cid`, block until
    /// notified (FIFO, never spuriously), then re-acquire `mid`.
    pub(crate) fn op_cond_wait(&self, me: usize, cid: usize, mid: usize) {
        let mut g = self.lock_core();
        if g.failure.is_some() {
            return;
        }
        g = self.boundary_locked(g, me, "cond-wait", cid);
        if g.failure.is_some() {
            return;
        }
        debug_assert_eq!(g.mutex_holders[mid], Some(me));
        g.mutex_holders[mid] = None;
        let ws = std::mem::take(&mut g.mutex_waiters[mid]);
        for w in ws {
            g.threads[w] = Run::Runnable;
        }
        g.cond_waiters[cid].push_back(me);
        g.threads[me] = Run::Blocked;
        self.decide(&mut g);
        self.cv.notify_all();
        g = self.park(g, me);
        // Notified (or winding down): re-acquire the mutex.
        loop {
            if g.failure.is_some() {
                return;
            }
            if g.mutex_holders[mid].is_none() {
                g.mutex_holders[mid] = Some(me);
                return;
            }
            g.mutex_waiters[mid].push(me);
            g.threads[me] = Run::Blocked;
            self.decide(&mut g);
            self.cv.notify_all();
            g = self.park(g, me);
        }
    }

    pub(crate) fn op_notify(&self, me: usize, cid: usize, all: bool) {
        let mut g = self.lock_core();
        if g.failure.is_some() {
            return;
        }
        let label = if all { "notify-all" } else { "notify-one" };
        g = self.boundary_locked(g, me, label, cid);
        if g.failure.is_some() {
            return;
        }
        if all {
            while let Some(t) = g.cond_waiters[cid].pop_front() {
                g.threads[t] = Run::Runnable;
            }
        } else if let Some(t) = g.cond_waiters[cid].pop_front() {
            g.threads[t] = Run::Runnable;
        }
    }

    /// Register a child thread; returns its tid. The child must call
    /// [`Sched::start_park`] before touching anything shared.
    pub(crate) fn op_spawn(&self, me: usize) -> usize {
        let mut g = self.lock_core();
        if g.failure.is_none() {
            g = self.boundary_locked(g, me, "spawn", 0);
        }
        g.threads.push(Run::Runnable);
        g.joiners.push(Vec::new());
        g.threads.len() - 1
    }

    /// Block until `target` finishes. Safe to call mid-unwind (the
    /// scope guard joining workers while a panic propagates): if the
    /// iteration fails while we wait, return and let the real join
    /// underneath finish the job.
    pub(crate) fn op_join(&self, me: usize, target: usize) {
        let unwinding = std::thread::panicking();
        let mut g = self.lock_core();
        if g.failure.is_some() {
            if unwinding {
                return;
            }
            drop(g);
            panic_any(AbortIteration);
        }
        g = self.boundary_locked(g, me, "join", target);
        loop {
            if g.failure.is_some() {
                if unwinding {
                    return;
                }
                drop(g);
                panic_any(AbortIteration);
            }
            if g.threads[target] == Run::Finished {
                return;
            }
            g.joiners[target].push(me);
            g.threads[me] = Run::Blocked;
            self.decide(&mut g);
            self.cv.notify_all();
            g = self.park(g, me);
        }
    }

    /// Mark `me` finished, wake its joiners, hand the turn onward.
    /// Runs in every mode (normal, failed, unwinding): the iteration
    /// only ends when every registered thread has finished.
    pub(crate) fn op_finish(&self, me: usize) {
        let mut g = self.lock_core();
        g.threads[me] = Run::Finished;
        g.finished += 1;
        let js = std::mem::take(&mut g.joiners[me]);
        for j in js {
            g.threads[j] = Run::Runnable;
        }
        if g.failure.is_none() {
            g.trail_push(me, "finish", 0);
            self.decide(&mut g);
        }
        drop(g);
        // Wakes the next active thread — and the model loop, which
        // waits on the same condvar for the last finish.
        self.cv.notify_all();
    }

    /// A sequentially-consistent atomic access: one decision point,
    /// then the std op runs under the turnstile.
    pub(crate) fn op_atomic(&self, me: usize, name: &'static str) {
        let g = self.lock_core();
        if g.failure.is_some() {
            if std::thread::panicking() {
                return;
            }
            drop(g);
            panic_any(AbortIteration);
        }
        let _g = self.boundary_locked(g, me, name, 0);
    }

    /// Record the user panic that escaped the root closure.
    pub(crate) fn set_root_panic(
        &self,
        p: Box<dyn std::any::Any + Send + 'static>,
    ) {
        let mut g = self.lock_core();
        if g.root_panic.is_none() {
            g.root_panic = Some(p);
        }
    }

    /// Block the model loop until every registered thread finished.
    pub(crate) fn wait_iteration_done(&self) {
        let mut g = self.lock_core();
        while g.finished < g.threads.len() {
            g = self
                .cv
                .wait(g)
                .unwrap_or_else(StdPoisonError::into_inner);
        }
    }

    /// Advance the DFS to the next unexplored schedule. `false` =
    /// the whole (bounded) schedule tree has been explored.
    pub(crate) fn backtrack(&self) -> bool {
        let mut g = self.lock_core();
        loop {
            match g.path.last_mut() {
                None => return false,
                Some(fr) => match fr.remaining.pop() {
                    Some(next) => {
                        fr.chosen = next;
                        return true;
                    }
                    None => {
                        g.path.pop();
                    }
                },
            }
        }
    }

    pub(crate) fn take_failure(&self) -> Option<String> {
        self.lock_core().failure.take()
    }

    pub(crate) fn take_root_panic(
        &self,
    ) -> Option<Box<dyn std::any::Any + Send + 'static>> {
        self.lock_core().root_panic.take()
    }

    /// The failing iteration's op trail, for diagnostics.
    pub(crate) fn trail_string(&self) -> String {
        let g = self.lock_core();
        let steps: Vec<String> = g
            .trail
            .iter()
            .map(|(t, op, obj)| format!("t{t}:{op}({obj})"))
            .collect();
        steps.join(" → ")
    }
}
