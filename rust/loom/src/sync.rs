//! Instrumented twins of `std::sync` primitives.
//!
//! Each primitive wraps its `std` counterpart for the actual data
//! handling (so memory safety and poisoning come for free) and calls
//! into the scheduler at every visible transition. Outside a
//! [`crate::model`] run the hooks vanish and the primitives behave
//! exactly like `std`'s.
//!
//! Fidelity notes (deliberate differences from real condvars):
//! condvar waits here never wake spuriously and are FIFO — code that
//! is only correct *because* real condvars wake threads it forgot to
//! notify is therefore caught as a deadlock, not masked. Mixing model
//! and non-model threads on one primitive is not supported.

use std::sync::{Condvar as StdCondvar, Mutex as StdMutex,
                MutexGuard as StdMutexGuard,
                PoisonError as StdPoisonError};

use crate::sched::{self, Sched};

pub use std::sync::{Arc, LockResult, PoisonError};

/// (epoch, id) cell for lazy per-iteration registration: an object
/// created before the model run (or reused across iterations) simply
/// re-registers the first time each iteration touches it.
type IdCell = StdMutex<(u64, usize)>;

fn fresh_cell() -> IdCell {
    StdMutex::new((0, 0))
}

/// A mutual-exclusion primitive; `std::sync::Mutex` API subset.
pub struct Mutex<T> {
    id: IdCell,
    data: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Mutex<T> {
        Mutex { id: fresh_cell(), data: StdMutex::new(t) }
    }

    fn model_id(&self, sched: &Sched) -> usize {
        sched.with_core(|core| {
            let mut cell = self
                .id
                .lock()
                .unwrap_or_else(StdPoisonError::into_inner);
            if cell.0 != core.epoch {
                cell.0 = core.epoch;
                cell.1 = Sched::register_mutex(core);
            }
            cell.1
        })
    }

    /// Acquire, asking the scheduler first; the std lock underneath is
    /// uncontended once the model grants it. Poisoning is the std
    /// mutex's, surfaced with the same `LockResult` shape.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let model = sched::current().map(|(sched, me)| {
            let mid = self.model_id(&sched);
            sched.op_lock(me, mid);
            (sched, me, mid)
        });
        match self.data.lock() {
            Ok(g) => Ok(MutexGuard { mx: self, inner: Some(g), model }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                mx: self,
                inner: Some(p.into_inner()),
                model,
            })),
        }
    }
}

/// RAII guard; releases the model-level lock after the std one.
pub struct MutexGuard<'a, T> {
    mx: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
    /// `(sched, tid, mutex id)` when acquired inside a model run.
    model: Option<(Arc<Sched>, usize, usize)>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            Some(g) => g,
            None => panic!("lock guard already released"),
        }
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            Some(g) => g,
            None => panic!("lock guard already released"),
        }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.inner.take() {
            drop(g);
            if let Some((sched, me, mid)) = self.model.take() {
                sched.op_unlock(me, mid);
            }
        }
    }
}

/// A condition variable; `std::sync::Condvar` API subset. Under the
/// model: FIFO wakeups, no spurious wakeups, no timeouts.
pub struct Condvar {
    id: IdCell,
    /// Used only outside a model run.
    std: StdCondvar,
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar { id: fresh_cell(), std: StdCondvar::new() }
    }

    fn model_id(&self, sched: &Sched) -> usize {
        sched.with_core(|core| {
            let mut cell = self
                .id
                .lock()
                .unwrap_or_else(StdPoisonError::into_inner);
            if cell.0 != core.epoch {
                cell.0 = core.epoch;
                cell.1 = Sched::register_condvar(core);
            }
            cell.1
        })
    }

    pub fn wait<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
    ) -> LockResult<MutexGuard<'a, T>> {
        let mx = guard.mx;
        match guard.model.take() {
            Some((sched, me, mid)) => {
                let cid = self.model_id(&sched);
                // Release the std lock before the model-level
                // release+block+reacquire; `guard` is inert now (its
                // Drop sees both fields taken).
                drop(guard.inner.take());
                drop(guard);
                sched.op_cond_wait(me, cid, mid);
                // The model granted the lock back; the std lock is
                // free (or its holder is unwinding) by construction.
                match mx.data.lock() {
                    Ok(g) => Ok(MutexGuard {
                        mx,
                        inner: Some(g),
                        model: Some((sched, me, mid)),
                    }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        mx,
                        inner: Some(p.into_inner()),
                        model: Some((sched, me, mid)),
                    })),
                }
            }
            None => {
                let std_guard =
                    guard.inner.take().expect("guard already released");
                drop(guard);
                match self.std.wait(std_guard) {
                    Ok(g) => {
                        Ok(MutexGuard { mx, inner: Some(g), model: None })
                    }
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        mx,
                        inner: Some(p.into_inner()),
                        model: None,
                    })),
                }
            }
        }
    }

    pub fn notify_one(&self) {
        match sched::current() {
            Some((sched, me)) => {
                let cid = self.model_id(&sched);
                sched.op_notify(me, cid, false);
            }
            None => self.std.notify_one(),
        }
    }

    pub fn notify_all(&self) {
        match sched::current() {
            Some((sched, me)) => {
                let cid = self.model_id(&sched);
                sched.op_notify(me, cid, true);
            }
            None => self.std.notify_all(),
        }
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

pub mod atomic {
    //! Instrumented atomics: each access is one sequentially-consistent
    //! decision point for the scheduler, then the std op.

    pub use std::sync::atomic::Ordering;

    fn hook(name: &'static str) {
        if let Some((sched, me)) = crate::sched::current() {
            sched.op_atomic(me, name);
        }
    }

    #[derive(Default, Debug)]
    pub struct AtomicUsize {
        inner: std::sync::atomic::AtomicUsize,
    }

    impl AtomicUsize {
        pub const fn new(v: usize) -> AtomicUsize {
            AtomicUsize { inner: std::sync::atomic::AtomicUsize::new(v) }
        }

        pub fn load(&self, order: Ordering) -> usize {
            hook("atomic-load");
            self.inner.load(order)
        }

        pub fn store(&self, v: usize, order: Ordering) {
            hook("atomic-store");
            self.inner.store(v, order);
        }

        pub fn fetch_add(&self, v: usize, order: Ordering) -> usize {
            hook("atomic-fetch-add");
            self.inner.fetch_add(v, order)
        }

        pub fn fetch_max(&self, v: usize, order: Ordering) -> usize {
            hook("atomic-fetch-max");
            self.inner.fetch_max(v, order)
        }

        pub fn swap(&self, v: usize, order: Ordering) -> usize {
            hook("atomic-swap");
            self.inner.swap(v, order)
        }
    }
}
