//! A miniature, dependency-free re-implementation of the [`loom`]
//! model checker's API surface, vendored for flocora's determinism
//! verification layer (the hand-maintained `Cargo.lock` admits no
//! registry crates).
//!
//! [`loom`]: https://github.com/tokio-rs/loom
//!
//! # What it does
//!
//! [`model`] runs a closure repeatedly, exploring every order in which
//! its threads can interleave at *instrumented operations* (the
//! [`sync`] / [`thread`] primitives), within a CHESS-style preemption
//! budget. A deterministic turnstile serializes the threads — exactly
//! one runs between decision points — so each schedule is replayable,
//! and a depth-first search over the decisions enumerates schedules
//! without ever running the same one twice.
//!
//! Detected failures: **deadlocks / lost wakeups** (every live thread
//! blocked — condvars here never wake spuriously, so a forgotten
//! `notify` cannot be masked), **user assertions** failing under some
//! schedule, and **nondeterminism** (replay divergence) in the checked
//! closure itself.
//!
//! # Fidelity notes (vs. real loom)
//!
//! * Atomics are modeled as sequentially-consistent single ops —
//!   weak-memory reorderings are *not* explored. flocora's hot-path
//!   atomics are diagnostics counters, so this is the right trade.
//! * `sync::Arc` is `std`'s; reference-count races are not modeled.
//! * Condvar wakeups are FIFO and never spurious (stricter than
//!   reality, so predicate-loop bugs surface as deadlocks).
//! * `thread::scope` takes its closure under an independent borrow
//!   lifetime (see `thread` module docs); call sites read the same.
//!
//! Used by the flocora crate under `RUSTFLAGS="--cfg loom"` through
//! its `flocora::sync` shim; `rust/tests/loom.rs` holds the protocol
//! models. This crate itself compiles (and self-tests) without any
//! cfg flag.

pub mod model;
pub(crate) mod sched;
pub mod sync;
pub mod thread;

pub use model::model;

#[cfg(test)]
mod tests {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    use crate::sync::atomic::{AtomicUsize, Ordering};
    use crate::sync::{Arc, Condvar, Mutex};
    use crate::{model, thread};

    #[test]
    fn mutex_counter_is_exact_under_every_schedule() {
        model(|| {
            let n = Arc::new(Mutex::new(0usize));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let n2 = Arc::clone(&n);
                    thread::spawn(move || {
                        *n2.lock().unwrap() += 1;
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(*n.lock().unwrap(), 2);
        });
    }

    #[test]
    #[should_panic]
    fn unsynchronized_read_modify_write_race_is_found() {
        model(|| {
            let a = Arc::new(AtomicUsize::new(0));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let a2 = Arc::clone(&a);
                    thread::spawn(move || {
                        // BUG on purpose: load + store is not atomic.
                        let v = a2.load(Ordering::SeqCst);
                        a2.store(v + 1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(a.load(Ordering::SeqCst), 2, "lost update");
        });
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn missed_notify_is_reported_as_deadlock() {
        model(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = Arc::clone(&pair);
            let h = thread::spawn(move || {
                let (m, cv) = &*p2;
                let mut ready = m.lock().unwrap();
                while !*ready {
                    ready = cv.wait(ready).unwrap();
                }
            });
            // BUG on purpose: flip the flag but never notify. Real
            // condvars often save this with a spurious wakeup; the
            // model must not.
            *pair.0.lock().unwrap() = true;
            h.join().unwrap();
        });
    }

    #[test]
    fn condvar_handoff_terminates_under_every_schedule() {
        model(|| {
            let pair = Arc::new((Mutex::new(0usize), Condvar::new()));
            let p2 = Arc::clone(&pair);
            let h = thread::spawn(move || {
                let (m, cv) = &*p2;
                *m.lock().unwrap() += 1;
                cv.notify_one();
            });
            let (m, cv) = &*pair;
            let mut g = m.lock().unwrap();
            while *g == 0 {
                g = cv.wait(g).unwrap();
            }
            assert_eq!(*g, 1);
            drop(g);
            h.join().unwrap();
        });
    }

    #[test]
    fn scope_joins_workers_and_propagates_their_panic() {
        model(|| {
            let sum = Arc::new(Mutex::new(0usize));
            thread::scope(|s| {
                for add in [1usize, 2] {
                    let sum2 = Arc::clone(&sum);
                    s.spawn(move || {
                        *sum2.lock().unwrap() += add;
                    });
                }
            });
            // Scope exit joined both workers.
            assert_eq!(*sum.lock().unwrap(), 3);

            let caught = catch_unwind(AssertUnwindSafe(|| {
                thread::scope(|s| {
                    s.spawn(|| panic!("worker boom"));
                });
            }));
            assert!(
                caught.is_err(),
                "scope must re-raise an unjoined worker's panic"
            );
        });
    }
}
