//! Offline stand-in for the `xla` PJRT wrapper crate.
//!
//! This container has no XLA shared library, so the real crate (an FFI
//! wrapper over `xla_extension`) cannot link here. This stub keeps the
//! exact API surface `flocora::runtime` consumes:
//!
//! * host-side [`Literal`] construction is implemented for real (it is
//!   pure data plumbing), so code that only builds literals works;
//! * every entry point that would touch PJRT — client creation, HLO
//!   parsing, compilation, execution — returns a descriptive [`Error`]
//!   instead.
//!
//! The flocora crate therefore builds, and all pure layers (codecs,
//! coordinator, data, transport, config, metrics) compile and test; the
//! artifact-driven integration tests fail fast with the message below.
//! To run against real artifacts, point the `xla` path dependency in
//! `rust/Cargo.toml` at a checkout of the actual wrapper crate.

use std::fmt;
use std::path::Path;

/// Error type mirroring the wrapper crate's (message-carrying) errors.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error::new(format!(
        "{what}: PJRT runtime unavailable — this build links the offline \
         `xla` stub (rust/xla-stub). Swap the `xla` path dependency in \
         rust/Cargo.toml for a real xla crate checkout (and run `make \
         artifacts`) to execute models."
    ))
}

/// Element types a [`Literal`] can hold (the subset flocora uses).
pub trait NativeType: Copy {
    const WIDTH: usize;
    fn write_le(self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty) => {
        impl NativeType for $t {
            const WIDTH: usize = std::mem::size_of::<$t>();
            fn write_le(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn read_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().unwrap())
            }
        }
    };
}

native!(f32);
native!(f64);
native!(i32);
native!(i64);
native!(u32);
native!(u64);

/// Host-side tensor value: raw little-endian bytes + element width +
/// dims. Construction is real; anything produced *by* execution can
/// never exist in a stub build.
#[derive(Debug, Clone)]
pub struct Literal {
    bytes: Vec<u8>,
    width: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(v.len() * T::WIDTH);
        for &x in v {
            x.write_le(&mut bytes);
        }
        Literal { bytes, width: T::WIDTH, dims: vec![v.len() as i64] }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut bytes = Vec::with_capacity(T::WIDTH);
        v.write_le(&mut bytes);
        Literal { bytes, width: T::WIDTH, dims: vec![] }
    }

    /// Reinterpret the element buffer under new dims (must preserve the
    /// element count, like the real crate).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = (self.bytes.len() / self.width.max(1)) as i64;
        if n != have {
            return Err(Error::new(format!(
                "reshape {:?} -> {:?}: element count {} != {}",
                self.dims, dims, have, n
            )));
        }
        Ok(Literal {
            bytes: self.bytes.clone(),
            width: self.width,
            dims: dims.to_vec(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.width != T::WIDTH {
            return Err(Error::new("literal element width mismatch"));
        }
        Ok(self
            .bytes
            .chunks_exact(T::WIDTH)
            .map(T::read_le)
            .collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if self.width != T::WIDTH || self.bytes.len() < T::WIDTH {
            return Err(Error::new("literal has no first element"));
        }
        Ok(T::read_le(&self.bytes[..T::WIDTH]))
    }

    /// Decompose a tuple literal. Stub literals are never tuples (only
    /// execution produces them), so this always fails.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy raw elements into a host buffer.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let v = self.to_vec::<T>()?;
        if v.len() != dst.len() {
            return Err(Error::new(format!(
                "copy_raw_to: {} elements into buffer of {}",
                v.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(&v);
        Ok(())
    }
}

/// Parsed HLO module handle. Parsing requires XLA; always unavailable.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation handle built from a proto.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. Creation requires the PJRT CPU plugin; always
/// unavailable in the stub.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Device-resident result buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors the wrapper crate's generic argument-type signature.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, -2.0, 3.5]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.5]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        let r = l.reshape(&[3, 1]).unwrap();
        let mut buf = [0.0f32; 3];
        r.copy_raw_to(&mut buf).unwrap();
        assert_eq!(buf, [1.0, -2.0, 3.5]);
        assert!(l.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn runtime_paths_fail_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("xla stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
