//! FLoCoRA + affine quantization (paper Table III / Fig. 3 shape): run
//! the same federation at fp32 / int8 / int4 / int2 wire formats and
//! print accuracy-vs-TCC, writing one convergence CSV per setting.
//!
//! ```bash
//! cargo run --release --example quantized_fl [-- --rounds 60]
//! ```

use flocora::cli::Args;
use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::coordinator::Simulation;
use flocora::metrics::Recorder;
use flocora::runtime::Engine;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let rounds = args.usize_or("rounds", 60)?;
    let engine = Engine::new("artifacts")?;

    println!("{:<10} {:>10} {:>14} {:>12}", "codec", "final acc",
             "per-client TCC", "vs fp32");
    let mut fp_tcc = None;
    for codec in [CodecKind::Fp32, CodecKind::Affine(8), CodecKind::Affine(4),
                  CodecKind::Affine(2)] {
        let mut cfg = presets::scaled_micro("micro8_lora_fc_r4", 4, codec);
        cfg.rounds = rounds;
        cfg.samples_per_client = 64;
        cfg.eval_every = 4;
        let mut sim = Simulation::new(&engine, cfg)?;
        let mut rec = Recorder::new(codec.label());
        let summary = sim.run(&mut rec)?;
        rec.write_csv(format!("target/quantized_fl_{}.csv", codec.label()))?;
        let tcc = summary.per_client_tcc_bytes;
        let ratio = match fp_tcc {
            None => {
                fp_tcc = Some(tcc);
                1.0
            }
            Some(fp) => fp / tcc,
        };
        println!(
            "{:<10} {:>10.3} {:>11.2} kB {:>11}",
            codec.label(),
            summary.final_acc,
            tcc / 1e3,
            format!("÷{ratio:.1}")
        );
    }
    println!(
        "\nPaper Table III shape: int8 tracks fp32 closely; int4 degrades \
         mildly; int2 collapses. Convergence CSVs in target/."
    );
    Ok(())
}
