//! Quickstart: load the AOT artifacts, run one FLoCoRA round, print what
//! moved and what it cost.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use flocora::compression::CodecKind;
use flocora::config::FlConfig;
use flocora::coordinator::{ExecutorKind, Simulation};
use flocora::runtime::Engine;
use flocora::transport::tcc_equation2;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Stand up the PJRT runtime over the artifact directory.
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // 2. Configure a small federation running FLoCoRA (LoRA adapters +
    //    norm + FC trainable; frozen base distributed once).
    let cfg = FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 8,
        clients_per_round: 4,
        rounds: 1,
        local_epochs: 1,
        samples_per_client: 32,
        test_samples: 80,
        codec: CodecKind::Affine(8),      // paper's int8 wire format
        executor: ExecutorKind::Parallel, // fan clients across cores —
        threads: 0,                       // bit-identical to serial
        ..FlConfig::default()
    };
    let mut sim = Simulation::new(&engine, cfg)?;

    println!(
        "model: {} trainable / {} frozen parameters (adapters travel, \
         W_initial does not)",
        sim.global.len(),
        sim.frozen.len()
    );

    // 3. One communication round: download → local SGD → upload → FedAvg.
    let (train_loss, train_acc) = sim.round()?;
    let (test_loss, test_acc) = sim.evaluate()?;

    println!("round 1: client loss {train_loss:.3} acc {train_acc:.3}");
    println!("global: test loss {test_loss:.3} acc {test_acc:.3}");
    println!(
        "bytes this round: {} up + {} down ({} messages, int8-quantized)",
        sim.ledger.up_bytes, sim.ledger.down_bytes,
        sim.ledger.up_msgs + sim.ledger.down_msgs
    );

    // 4. The headline arithmetic at paper scale (Eq. 2).
    let fp = tcc_equation2(100, 32, 1_227_594) / 1e6;
    let lora = tcc_equation2(100, 32, 258_026) / 1e6;
    println!(
        "paper scale: FedAvg {fp:.1} MB vs FLoCoRA {lora:.1} MB per client \
         over 100 rounds (÷{:.1})",
        fp / lora
    );
    Ok(())
}
