//! End-to-end driver (DESIGN.md §E2E): train a CNN federatedly on the
//! CIFAR-S workload with both FedAvg and FLoCoRA, log the full loss /
//! accuracy curves to CSV, and report the communication ledger — the
//! run recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example flocora_cifar [-- --rounds 80 --model micro8]
//! ```

use flocora::cli::Args;
use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::coordinator::Simulation;
use flocora::metrics::Recorder;
use flocora::runtime::Engine;
use flocora::transport::NetworkModel;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let rounds = args.usize_or("rounds", 60)?;
    let model = args.str_or("model", "micro8");
    let engine = Engine::new("artifacts")?;
    let net = NetworkModel::edge_lte();

    let (fedavg_tag, flocora_tag, rank) = match model.as_str() {
        "micro8" => ("micro8_full", "micro8_lora_fc_r4", 4),
        "tiny8" => ("tiny8_full", "tiny8_lora_fc_r8", 8),
        "resnet8" => ("resnet8_full", "resnet8_lora_fc_r32", 32),
        other => {
            return Err(flocora::Error::invalid(
                format!("unknown --model {other}")).into())
        }
    };

    for (name, tag, rank, codec) in [
        ("fedavg", fedavg_tag, 0usize, CodecKind::Fp32),
        ("flocora", flocora_tag, rank, CodecKind::Fp32),
    ] {
        let mut cfg = presets::scaled_micro(tag, rank, codec);
        cfg.rounds = rounds;
        cfg.samples_per_client = 64;
        cfg.eval_every = 4;
        let mut sim = Simulation::new(&engine, cfg)?;
        // Report simulated wire time on the edge-LTE profile (set
        // before the first round; it feeds the run's accumulators).
        sim.set_network(net);
        let mut rec = Recorder::new(name);
        let summary = sim.run(&mut rec)?;
        let csv = format!("target/flocora_cifar_{name}.csv");
        rec.write_csv(&csv)?;
        println!(
            "{name:>8}: final acc {:.3} | msg {:>8.1} kB | total comm \
             {:>7.2} MB | LTE wire {:>6.1} s concurrent / {:>7.1} s \
             serial | wall {:.1}s | {csv}",
            summary.final_acc,
            summary.mean_up_msg_bytes / 1e3,
            summary.total_bytes as f64 / 1e6,
            summary.sim_net_parallel_s,
            summary.sim_net_serial_s,
            summary.wall_s,
        );
    }
    println!(
        "\nFLoCoRA sends the adapter vector only; the frozen base never \
         travels. Compare the msg columns above with Table I's trained-vs-\
         total parameter split."
    );
    Ok(())
}
