//! FLoCoRA vs the conventional-compression baselines of Table IV:
//! magnitude pruning [4] and a ZeroFL-style sparse upload [12], all
//! through the identical aggregation loop (the paper's
//! aggregation-agnostic claim, demonstrated).
//!
//! ```bash
//! cargo run --release --example baselines [-- --rounds 60]
//! ```

use flocora::cli::Args;
use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::coordinator::Simulation;
use flocora::metrics::Recorder;
use flocora::runtime::Engine;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let rounds = args.usize_or("rounds", 60)?;
    let engine = Engine::new("artifacts")?;

    // (label, tag, rank, codec) — the Table IV method matrix at the
    // scaled profile. Sparse baselines compress the *full* model's
    // messages; FLoCoRA ships adapters (optionally quantized).
    let matrix: &[(&str, &str, usize, CodecKind)] = &[
        ("FedAvg", "micro8_full", 0, CodecKind::Fp32),
        ("MagPrune 40%", "micro8_full", 0, CodecKind::TopK(0.6)),
        ("MagPrune 80%", "micro8_full", 0, CodecKind::TopK(0.2)),
        ("ZeroFL 90/0.2", "micro8_full", 0, CodecKind::ZeroFl(0.9, 0.2)),
        ("ZeroFL 90/0.0", "micro8_full", 0, CodecKind::ZeroFl(0.9, 0.0)),
        ("FLoCoRA r=4", "micro8_lora_fc_r4", 4, CodecKind::Fp32),
        ("FLoCoRA r=4 Q8", "micro8_lora_fc_r4", 4, CodecKind::Affine(8)),
    ];

    println!("{:<16} {:>10} {:>12} {:>10}", "method", "final acc",
             "msg (kB)", "vs full");
    let mut full_msg = None;
    for &(label, tag, rank, codec) in matrix {
        let mut cfg = presets::scaled_micro(tag, rank, codec);
        cfg.rounds = rounds;
        cfg.samples_per_client = 64;
        cfg.eval_every = 4;
        let mut sim = Simulation::new(&engine, cfg)?;
        let mut rec = Recorder::new(label);
        let summary = sim.run(&mut rec)?;
        let msg = summary.mean_up_msg_bytes;
        let ratio = match full_msg {
            None => {
                full_msg = Some(msg);
                1.0
            }
            Some(full) => full / msg,
        };
        println!(
            "{:<16} {:>10.3} {:>9.1} kB {:>9}",
            label, summary.final_acc, msg / 1e3, format!("÷{ratio:.1}")
        );
    }
    println!(
        "\nTable IV shape: FLoCoRA reaches the best accuracy-per-byte; the\n\
         sparse baselines pay index/bitmap overhead and degrade faster at\n\
         equal message size."
    );
    Ok(())
}
