//! Rank-heterogeneous federation — the paper's future-work extension
//! (§V: "explore … rank heterogeneity"), in the spirit of HLoRA [8]:
//! the server holds the adapter state at r=8 while constrained clients
//! train truncated r=2/r=4 copies; projections are exact on shared rank
//! slots (zero-padded slots compute the same function — see
//! `coordinator::hetero`).
//!
//! Weak clients also *upload less*: the r=2 message is ~4x smaller than
//! the r=8 one, so heterogeneity is itself a communication knob.
//!
//! ```bash
//! cargo run --release --example hetero_ranks [-- --rounds 40]
//! ```

use flocora::cli::Args;
use flocora::coordinator::aggregator::FedAvg;
use flocora::coordinator::hetero::project_ranks;
use flocora::coordinator::LocalTrainer;
use flocora::data::batcher::Tail;
use flocora::data::{lda_partition, BatchIter, TestSet};
use flocora::runtime::Engine;
use flocora::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let rounds = args.usize_or("rounds", 40)?;
    let engine = Engine::new("artifacts")?;

    // Server rank 8; clients alternate between rank tiers (device
    // classes). All sessions share the same frozen base seed.
    let tiers = ["micro8_lora_fc_r2", "micro8_lora_fc_r4",
                 "micro8_lora_fc_r8"];
    let sessions: Vec<_> = tiers
        .iter()
        .map(|t| engine.session(t))
        .collect::<Result<_, _>>()?;
    let server = engine.session("micro8_lora_fc_r8")?;
    let seed = 42u64;
    let (mut global, frozen) = server.init(seed)?;

    let num_clients = 12;
    let fed = lda_partition(num_clients, 64, 10, server.spec.image_size,
                            0.5, seed);
    let test = TestSet::generate(240, server.spec.image_size, 10,
                                 seed.wrapping_add(0x7E57));
    let mut rng = Rng::new(seed ^ 0xF1F1);
    let alpha = 64.0f32; // fixed alpha; scale = alpha / r_client per tier

    let mut tier_bytes = vec![0u64; tiers.len()];
    for round in 0..rounds {
        let mut agg = FedAvg::new(global.len());
        for cid in 0..4usize {
            let client = (round * 4 + cid) % num_clients;
            let tier = client % tiers.len();
            let sess = &sessions[tier];
            // Down-project the server state to the client's rank.
            let start = project_ranks(&global,
                                      &server.spec.trainable_segments,
                                      &sess.spec.trainable_segments)?;
            tier_bytes[tier] += (start.len() * 4) as u64;
            let trainer = LocalTrainer {
                local_epochs: 2,
                lr: 0.02,
                lora_scale: alpha / sess.spec.rank as f32,
            };
            let mut crng = rng.fork((round * 100 + client) as u64);
            let out = trainer
                .run(sess, &fed.clients[client], &frozen, start, &mut crng)?;
            tier_bytes[tier] += (out.params.len() * 4) as u64;
            // Up-project back into the server's rank space.
            let up = project_ranks(&out.params,
                                   &sess.spec.trainable_segments,
                                   &server.spec.trainable_segments)?;
            agg.add(&up, out.samples as f64)?;
        }
        global = agg.finish()?;

        if (round + 1) % 8 == 0 || round + 1 == rounds {
            let mut correct = 0.0;
            for batch in BatchIter::new(&test.images, &test.labels,
                                        server.spec.image_size,
                                        server.spec.batch_size, None,
                                        Tail::PadZero) {
                let (_, c) = server
                    .eval_step(&global, &frozen, &batch,
                               alpha / server.spec.rank as f32)?;
                correct += c;
            }
            println!("round {:>3}: acc {:.3} (server rank 8; clients r2/r4/r8)",
                     round + 1, correct / test.n as f64);
        }
    }
    for (tier, tag) in tiers.iter().enumerate() {
        println!("{tag}: {:.1} kB total traffic",
                 tier_bytes[tier] as f64 / 1e3);
    }
    println!("heterogeneous ranks converge in one federation — the \
              projection keeps every tier's update exact on shared slots.");
    Ok(())
}
