//! Rank-heterogeneous federation — the paper's future-work extension
//! (§V: "explore … rank heterogeneity"), in the spirit of HLoRA [8]:
//! the server holds the adapter state at r=8 while constrained clients
//! train truncated r=2/r=4 copies; projections are exact on shared rank
//! slots (zero-padded slots compute the same function — see
//! `coordinator::hetero`).
//!
//! Weak clients also *upload less*: the r=2 message is ~4x smaller than
//! the r=8 one, so heterogeneity is itself a communication knob.
//!
//! Since the round engine grew a [`ClientPlan`] hook, this whole
//! scenario is a preset (`hetero_micro`) driven by the standard
//! `Simulation::run` loop — per-client tiers, per-tier codecs, dropout,
//! executors and the streaming merge all compose with it. (It used to
//! be a hand-rolled 70-line round loop; `tests/executor.rs` pins the
//! engine path against that reference semantics.)
//!
//! ```bash
//! cargo run --release --example hetero_ranks [-- --rounds 40]
//! ```

use flocora::cli::Args;
use flocora::config::presets;
use flocora::coordinator::Simulation;
use flocora::metrics::Recorder;
use flocora::runtime::Engine;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let engine = Engine::new("artifacts")?;

    // Server rank 8; clients round-robin across r2/r4/r8 device
    // classes. All tiers share the same frozen base.
    let mut cfg = presets::hetero_micro();
    cfg.rounds = args.usize_or("rounds", 40)?;

    let mut sim = Simulation::new(&engine, cfg)?;
    let mut rec = Recorder::new("hetero_ranks");
    let summary = sim.run(&mut rec)?;

    for r in &rec.rounds {
        println!(
            "round {:>3}: acc {:.3} (server rank 8; clients r2/r4/r8)",
            r.round, r.test_acc
        );
    }
    let plan = sim.plan().expect("hetero preset builds a plan");
    for (tier, bytes) in plan.tiers().iter().zip(sim.tier_bytes()) {
        println!("tier r{}: {:.1} kB total traffic", tier.rank,
                 *bytes as f64 / 1e3);
    }
    println!(
        "final acc {:.3} after {} rounds, {:.1} kB moved in total",
        summary.final_acc, summary.rounds,
        summary.total_bytes as f64 / 1e3
    );
    println!("heterogeneous ranks converge in one federation — the \
              projection keeps every tier's update exact on shared slots.");
    Ok(())
}
