//! `flocora` — launcher for the FLoCoRA reproduction.
//!
//! Subcommands:
//! * `train`        — run one federated simulation (config file and/or
//!   `--key value` overrides), optional CSV convergence export.
//! * `serve`        — run the same schedule as a networked TCP
//!   coordinator; remote `client` processes do the training
//!   (byte-identical artifacts, see `transport::wire`).
//! * `client`       — a wire-mode worker hosting a client-id range
//!   against a `serve` coordinator.
//! * `tables`       — print the analytic reproductions of Table I/III/IV
//!   side by side with the paper's numbers.
//! * `inspect`      — list the artifact manifest (specs, sizes, files).
//! * `quant-parity` — verify the rust affine codec against the lowered
//!   pallas quant kernel (HLO oracle), all bit widths.
//! * `bench-step`   — time the PJRT train step for a spec.

use flocora::cli::{assemble_config, Args};
use flocora::compression::Codec;
use flocora::coordinator::Simulation;
use flocora::error::{Error, Result};
use flocora::experiments::tables;
use flocora::metrics::{run_json, Recorder};
use flocora::model::ParamKind;
use flocora::runtime::{Batch, Engine};
use flocora::tensor;
use flocora::transport::TimeModelKind;
use flocora::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let artifacts = args.str_or("artifacts", "artifacts");
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args, &artifacts),
        Some("serve") => flocora::cli::serve::cmd_serve(&args, &artifacts),
        Some("client") => {
            flocora::cli::client::cmd_client(&args, &artifacts)
        }
        Some("tables") => cmd_tables(&args),
        Some("inspect") => cmd_inspect(&args, &artifacts),
        Some("quant-parity") => cmd_quant_parity(&args, &artifacts),
        Some("bench-step") => cmd_bench_step(&args, &artifacts),
        Some(other) => Err(Error::invalid(format!("unknown subcommand `{other}`"))),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "flocora — FLoCoRA (EUSIPCO 2024) reproduction\n\n\
         USAGE: flocora <subcommand> [--artifacts DIR] [options]\n\n\
         SUBCOMMANDS:\n\
         \x20 train         run a federated simulation\n\
         \x20               [--config FILE] [--preset NAME] [--csv OUT]\n\
         \x20               [--json OUT] [--tag T] [--rounds N]\n\
         \x20               [--codec fp32|q8|q4|q2|topk:K|zerofl:SP:MR\n\
         \x20               |sparse_ef:K]\n\
         \x20               [--aggregator fedavg|svt|exact]\n\
         \x20               [--svt_energy TAU]\n\
         \x20               [--executor serial|parallel] [--threads N]\n\
         \x20               [--window N] [--shards N]\n\
         \x20               [--overlap none|transfer]\n\
         \x20               [--network edge_lte|wifi]\n\
         \x20               [--net_sharing dedicated|shared]\n\
         \x20               [--sampler uniform|latency_biased|oversample_k]\n\
         \x20               [--oversample_beta B]\n\
         \x20               [--client_profiles uniform|tiered|file:PATH]\n\
         \x20               [--compute_base_s S]\n\
         \x20               [--time_model closed|event] [--chunk_kb N]\n\
         \x20               [--stage_queue N]\n\
         \x20               [--hetero_ranks 2,4,8] [--hetero_codecs ...] ...\n\
         \x20               (--artifacts synthetic runs the PJRT-free\n\
         \x20               surrogate backend — what CI's sim-smoke uses)\n\
         \x20 serve         networked coordinator: same schedule, flags\n\
         \x20               and artifacts as `train`, but remote clients\n\
         \x20               do the work (byte-identical runs)\n\
         \x20               [--wire_listen HOST:PORT] [--wire_lease_ms N]\n\
         \x20               [--wire_round_timeout_ms N]\n\
         \x20               [--wire_on_timeout drop|abort]\n\
         \x20 client        wire-mode worker (config comes from the\n\
         \x20               server's hello handshake)\n\
         \x20               --wire_cids LO-HI [--wire_connect HOST:PORT]\n\
         \x20               [--wire_retries N] [--wire_backoff_ms N]\n\
         \x20               [--kill_at ROUND:CID]\n\
         \x20 tables        print analytic Table I/III/IV + the\n\
         \x20               aggregation-zoo bytes table\n\
         \x20               [--table all|1|2|3|4|zoo]\n\
         \x20 inspect       list artifact manifest\n\
         \x20 quant-parity  rust codec vs pallas HLO oracle\n\
         \x20 bench-step    time the PJRT train step [--tag T] [--steps N]"
    );
}

fn strict(args: &Args) -> Result<()> {
    let unused = args.unused();
    if unused.is_empty() {
        Ok(())
    } else {
        Err(Error::parse(format!("unknown options: {unused:?}")))
    }
}

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    let csv = args.opt_str("csv");
    let json = args.opt_str("json");
    // Base config: named preset, config file (on top of the preset, if
    // both are given), then --key value overrides — shared with
    // `serve` so wire runs assemble the exact same config.
    let cfg = assemble_config(args, &["csv", "json"])?;

    let engine = Engine::new(artifacts)?;
    let hetero = if cfg.hetero_ranks.is_empty() {
        String::new()
    } else {
        format!(
            " tiers={}",
            cfg.hetero_ranks
                .iter()
                .map(|r| format!("r{r}"))
                .collect::<Vec<_>>()
                .join("/")
        )
    };
    println!(
        "run: tag={} codec={} aggregator={} clients={} ({}/round) rounds={} \
         epochs={} lr={} alpha={} lda={} seed={} executor={} threads={} \
         window={} shards={} overlap={} network={}:{} sampler={} \
         profiles={}{}{}",
        cfg.tag, cfg.codec.label(), cfg.aggregator.label(),
        cfg.num_clients, cfg.clients_per_round,
        cfg.rounds, cfg.local_epochs, cfg.lr, cfg.lora_alpha, cfg.lda_alpha,
        cfg.seed, cfg.executor.label(),
        if cfg.threads == 0 { "auto".to_string() }
        else { cfg.threads.to_string() },
        if cfg.window == 0 { "auto".to_string() }
        else { cfg.window.to_string() },
        cfg.shards,
        cfg.overlap.label(),
        cfg.network.label(), cfg.net_sharing.label(),
        cfg.sampler.label(), cfg.client_profiles.label(), hetero,
        if engine.is_synthetic() { " backend=synthetic" } else { "" }
    );
    let mut sim = Simulation::new(&engine, cfg)?;
    let mut rec = Recorder::new("train");
    let summary = sim.run(&mut rec)?;
    for r in &rec.rounds {
        println!(
            "round {:>4}  acc {:.4}  test_loss {:.4}  train_loss {:.4}  \
             comm {:.2} MB",
            r.round, r.test_acc, r.test_loss, r.train_loss,
            r.cum_bytes as f64 / 1e6
        );
    }
    println!(
        "final acc {:.4} (tail {:.4})  msg {:.1} kB  per-client TCC {:.2} MB  \
         wall {:.1}s",
        summary.final_acc, summary.tail_acc,
        summary.mean_up_msg_bytes / 1e3,
        summary.per_client_tcc_bytes / 1e6, summary.wall_s
    );
    println!(
        "simulated wire time ({} links, {}): {:.1}s pipelined (overlap) \
         vs {:.1}s concurrent vs {:.1}s serial ({:.1}s transfer wait \
         overlapped)",
        sim.config().network.label(), sim.config().net_sharing.label(),
        summary.sim_net_pipelined_s, summary.sim_net_parallel_s,
        summary.sim_net_serial_s, summary.transfer_wait_s
    );
    println!(
        "stragglers: {} cancelled, {} dropped, client time p50 {:.3}s \
         max {:.3}s",
        summary.cancelled_clients, sim.dropped_clients,
        summary.sim_client_p50_s, summary.sim_client_max_s
    );
    if sim.config().aggregator != flocora::coordinator::AggregatorKind::FedAvg
    {
        println!(
            "aggregation: {} mean effective rank {:.2} over {} rounds",
            sim.config().aggregator.label(), summary.mean_eff_rank,
            summary.rounds
        );
    }
    if sim.config().shards > 1 {
        let settle = sim.last_round_shard_settle_s();
        println!(
            "shards: {} (merge depth {}), last-round settle [{}]",
            sim.config().shards,
            summary.merge_depth,
            settle
                .iter()
                .map(|s| format!("{s:.3}s"))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    if sim.config().time_model == TimeModelKind::Event {
        println!(
            "event model ({} kB chunks, queue {}): {:.1}s simulated \
             (queue peak {}, producers blocked {:.1}s)",
            sim.config().chunk_kb,
            if sim.config().stage_queue == 0 { "unbounded".to_string() }
            else { sim.config().stage_queue.to_string() },
            summary.sim_net_event_s, summary.queue_peak,
            summary.queue_block_s
        );
    }
    if !sim.tier_bytes().is_empty() {
        let plan = sim.plan().expect("tier bytes imply a plan");
        for (tier, bytes) in plan.tiers().iter().zip(sim.tier_bytes()) {
            println!(
                "tier r{}: {:.1} kB total traffic ({})",
                tier.rank,
                *bytes as f64 / 1e3,
                tier.codec.name()
            );
        }
    }
    if let Some(path) = csv {
        rec.write_csv(&path)?;
        println!("wrote {path}");
    }
    if let Some(path) = json {
        let doc = run_json(&rec, &summary, sim.dropped_clients);
        std::fs::write(&path, doc.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let which = args.str_or("table", "all");
    strict(args)?;
    if which == "all" || which == "1" {
        print!("{}", tables::table1().render());
        println!();
    }
    if which == "all" || which == "3" {
        print!("{}", tables::table3().0.render());
        println!();
    }
    if which == "all" || which == "4" {
        print!("{}", tables::table4_sizes().0.render());
        println!();
    }
    if which == "all" || which == "zoo" {
        print!("{}", tables::table_zoo().0.render());
        println!();
    }
    if which == "all" || which == "2" {
        println!(
            "Table II / Fig. 2 / Fig. 3 accuracy columns require training:\n\
             see `cargo bench --bench table2|fig2|fig3` (scaled runs) and\n\
             EXPERIMENTS.md for recorded results."
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args, artifacts: &str) -> Result<()> {
    strict(args)?;
    let engine = Engine::new(artifacts)?;
    println!("platform: {}", engine.platform());
    println!("{:<24} {:>10} {:>10}  files", "tag", "trainable", "frozen");
    for (tag, spec) in &engine.manifest().specs {
        println!(
            "{:<24} {:>10} {:>10}  {}",
            tag, spec.num_trainable, spec.num_frozen, spec.files.train
        );
    }
    for (bits, q) in &engine.manifest().quant_oracles {
        println!("quant oracle {bits}-bit: {} ({}x{})", q.file, q.rows, q.cols);
    }
    Ok(())
}

fn cmd_quant_parity(args: &Args, artifacts: &str) -> Result<()> {
    strict(args)?;
    let engine = Engine::new(artifacts)?;
    let mut rng = Rng::new(20240710);
    for (&bits, oracle) in &engine.manifest().quant_oracles {
        let n = oracle.rows * oracle.cols;
        let w: Vec<f32> = (0..n).map(|_| 3.0 * rng.normal() as f32).collect();
        let (deq_hlo, _s, _z) = engine.quant_oracle(bits, &w)?;
        // The rust wire codec on an equivalent single-segment layout.
        let seg = flocora::model::Segment {
            name: "oracle".into(),
            shape: vec![oracle.rows, oracle.cols],
            numel: n,
            kind: ParamKind::Conv,
            offset: 0,
            quant_rows: Some(oracle.rows),
        };
        let codec = flocora::compression::AffineCodec::new(bits);
        let msg = codec.encode(&w, std::slice::from_ref(&seg))?;
        let deq_rust = codec.decode(&msg, std::slice::from_ref(&seg))?;
        let diff = tensor::max_abs_diff(&deq_hlo, &deq_rust);
        println!(
            "bits={bits}: max |rust - hlo| = {diff:.3e} over {n} elements \
             ({} B payload)",
            msg.size_bytes()
        );
        if diff > 1e-5 {
            return Err(Error::invalid(format!(
                "quant parity failed at {bits} bits: {diff}"
            )));
        }
    }
    println!("quant parity OK");
    Ok(())
}

fn cmd_bench_step(args: &Args, artifacts: &str) -> Result<()> {
    let tag = args.str_or("tag", "micro8_lora_fc_r4");
    let steps = args.usize_or("steps", 20)?;
    strict(args)?;
    let engine = Engine::new(artifacts)?;
    let session = engine.session(&tag)?;
    let spec = session.spec.clone();
    let (mut params, frozen) = session.init(1)?;
    let mut momentum = vec![0.0f32; params.len()];
    let px = spec.image_size * spec.image_size * 3;
    let mut rng = Rng::new(2);
    let batch = Batch {
        x: (0..spec.batch_size * px).map(|_| rng.f32()).collect(),
        y: (0..spec.batch_size).map(|_| rng.below(10) as i32).collect(),
        mask: vec![1.0; spec.batch_size],
        n: spec.batch_size,
    };
    // Warmup (includes XLA compile).
    session.train_step(&mut params, &mut momentum, &frozen, &batch, 0.01, 16.0)?;
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        session.train_step(&mut params, &mut momentum, &frozen, &batch,
                           0.01, 16.0)?;
    }
    let dt = t0.elapsed().as_secs_f64() / steps as f64;
    println!(
        "{tag}: {:.2} ms/step (P={} F={} batch={})",
        dt * 1e3, spec.num_trainable, spec.num_frozen, spec.batch_size
    );
    Ok(())
}
