//! Hand-rolled CLI argument parser (the offline vendor set has no
//! `clap`): `binary <subcommand> [--key value]... [--flag]...` with
//! typed accessors and unknown-argument rejection — plus the wire-mode
//! subcommands ([`serve`], [`client`]) and the shared preset/config/
//! override assembly every training-shaped subcommand uses.

pub mod client;
pub mod serve;

use std::collections::BTreeMap;

use crate::config::{loader, presets, FlConfig};
use crate::error::{Error, Result};

/// Assemble a run config the way `flocora train` does: named preset,
/// then config file (on top of the preset if both are given), then
/// every remaining `--key value` override, then validation. `reserved`
/// lists the option keys the calling subcommand consumes itself
/// (`csv`, `json`, `wire_*`, ...) so they are not forwarded to
/// [`FlConfig::set`]; `config`, `preset` and `artifacts` are always
/// reserved.
pub fn assemble_config(args: &Args, reserved: &[&str]) -> Result<FlConfig> {
    let mut cfg = match args.opt_str("preset") {
        Some(name) => presets::by_name(&name).ok_or_else(|| {
            Error::invalid(format!(
                "unknown preset `{name}` (paper_resnet8|paper_resnet18|\
                 scaled_micro|scaled_tiny|hetero_micro|straggler_micro|\
                 event_micro|svt_micro|sparse_ef_micro|scale_bench)"
            ))
        })?,
        None => FlConfig::default(),
    };
    if let Some(path) = args.opt_str("config") {
        loader::apply_file(&mut cfg, path)?;
    }
    for (k, v) in args.options().clone() {
        if k == "config" || k == "preset" || k == "artifacts"
            || reserved.contains(&k.as_str())
        {
            continue;
        }
        cfg.set(&k, &v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::parse(format!("unexpected argument `{a}`")))?
                .to_string();
            if key.is_empty() {
                return Err(Error::parse("empty option name"));
            }
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    if args.options.insert(key.clone(), v).is_some() {
                        return Err(Error::parse(format!("duplicate --{key}")));
                    }
                }
                _ => args.flags.push(key),
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        let hit = self.flags.iter().any(|f| f == name);
        if hit {
            self.consumed.borrow_mut().push(name.to_string());
        }
        hit
    }

    pub fn opt_str(&self, name: &str) -> Option<String> {
        let v = self.options.get(name).cloned();
        if v.is_some() {
            self.consumed.borrow_mut().push(name.to_string());
        }
        v
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or_else(|| default.to_string())
    }

    pub fn parse_opt<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.opt_str(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                Error::parse(format!("bad value `{v}` for --{name}"))
            }),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.parse_opt(name)?.unwrap_or(default))
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        Ok(self.parse_opt(name)?.unwrap_or(default))
    }

    /// Remaining (unconsumed) option keys — for strict validation.
    pub fn unused(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect()
    }

    /// All `--key value` options, for generic pass-through into
    /// `FlConfig::set` overrides.
    pub fn options(&self) -> &BTreeMap<String, String> {
        &self.options
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = mk(&["train", "--rounds", "10", "--verbose", "--lr", "0.1"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("rounds", 1).unwrap(), 10);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.unused().is_empty());
    }

    #[test]
    fn tracks_unused() {
        let a = mk(&["x", "--weird", "1"]);
        assert_eq!(a.unused(), vec!["weird".to_string()]);
    }

    #[test]
    fn rejects_bad_forms() {
        assert!(Args::parse(["train".into(), "stray".into()]).is_err());
        assert!(Args::parse(["--a".into(), "1".into(), "--a".into(),
                             "2".into()]).is_err());
        let a = mk(&["--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = mk(&["--x", "1"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.opt_str("x").as_deref(), Some("1"));
    }
}
