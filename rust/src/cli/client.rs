//! `flocora client` — a wire-mode worker process.
//!
//! Connects to a `flocora serve` coordinator, learns the run config
//! from the Hello handshake (no local config flags — the server is
//! the single source of truth), and hosts an inclusive range of
//! client ids: every round it claims each hosted slot, downloads the
//! encoded broadcast, trains through the same
//! [`run_client`](crate::coordinator::run_client) stage composition
//! the in-process executors use, and uploads the encoded delta.
//!
//! `--kill_at R:C` is fault injection for the dropout-parity tests:
//! the process hangs up right after downloading for that slot, then
//! reconnects — the server must account the slot exactly like a
//! simulated `drop_plan` entry.

use crate::cli::Args;
use crate::error::{Error, Result};
use crate::transport::wire::{run_client_loop, ClientOpts};

/// Parse `LO-HI` (inclusive) or `N` (a single id).
fn parse_cids(s: &str) -> Result<(usize, usize)> {
    let bad = || {
        Error::parse(format!(
            "bad --wire_cids `{s}` (want LO-HI, inclusive, or a \
             single id)"
        ))
    };
    match s.split_once('-') {
        None => {
            let one = s.trim().parse().map_err(|_| bad())?;
            Ok((one, one))
        }
        Some((lo, hi)) => {
            let lo = lo.trim().parse().map_err(|_| bad())?;
            let hi = hi.trim().parse().map_err(|_| bad())?;
            Ok((lo, hi))
        }
    }
}

/// Parse the `ROUND:CID` kill coordinate.
fn parse_kill(s: &str) -> Result<(usize, usize)> {
    let bad =
        || Error::parse(format!("bad --kill_at `{s}` (want ROUND:CID)"));
    let (r, c) = s.split_once(':').ok_or_else(bad)?;
    let r = r.trim().parse().map_err(|_| bad())?;
    let c = c.trim().parse().map_err(|_| bad())?;
    Ok((r, c))
}

pub fn cmd_client(args: &Args, artifacts: &str) -> Result<()> {
    let connect = args.str_or("wire_connect", "127.0.0.1:7070");
    let cids = args.opt_str("wire_cids").ok_or_else(|| {
        Error::invalid(
            "--wire_cids LO-HI is required (the inclusive client-id \
             range this process hosts)",
        )
    })?;
    let (lo, hi) = parse_cids(&cids)?;
    let retries = args.parse_opt("wire_retries")?.unwrap_or(5);
    let backoff_ms = args.parse_opt("wire_backoff_ms")?.unwrap_or(200);
    let kill_at = match args.opt_str("kill_at") {
        Some(spec) => Some(parse_kill(&spec)?),
        None => None,
    };
    let unused = args.unused();
    if !unused.is_empty() {
        return Err(Error::parse(format!("unknown options: {unused:?}")));
    }

    let opts = ClientOpts {
        connect,
        lo,
        hi,
        retries,
        backoff_ms,
        kill_at,
        artifacts: artifacts.to_string(),
    };
    println!(
        "client: {} cids {}-{}{}",
        opts.connect,
        lo,
        hi,
        match kill_at {
            Some((r, c)) => format!(" kill_at={r}:{c}"),
            None => String::new(),
        }
    );
    let report = run_client_loop(&opts)?;
    println!(
        "client cids {}-{}: {} claims, {} uploads, {} self-drops{}",
        lo,
        hi,
        report.claims,
        report.uploads,
        report.self_drops,
        if report.killed { ", killed once (fault injection)" } else { "" }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cid_ranges_parse() {
        assert_eq!(parse_cids("0-3").unwrap(), (0, 3));
        assert_eq!(parse_cids(" 4 - 4 ").unwrap(), (4, 4));
        assert_eq!(parse_cids("7").unwrap(), (7, 7));
        assert!(parse_cids("a-b").is_err());
        assert!(parse_cids("").is_err());
    }

    #[test]
    fn kill_coordinates_parse() {
        assert_eq!(parse_kill("1:3").unwrap(), (1, 3));
        assert!(parse_kill("13").is_err());
        assert!(parse_kill("1:x").is_err());
    }
}
