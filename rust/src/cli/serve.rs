//! `flocora serve` — the networked coordinator.
//!
//! Binds a TCP listener, then runs the same federated schedule
//! `flocora train` runs, except that each round's sampled clients are
//! claimed, downloaded and uploaded by remote `flocora client`
//! processes over the wire protocol
//! ([`crate::transport::wire`]). The exported CSV/JSON artifacts are
//! byte-identical to an in-process `flocora train` of the same
//! preset/seed once the wall-clock fields are stripped — CI's
//! `wire-smoke` job diffs exactly that.

use std::net::TcpListener;

use crate::cli::{assemble_config, Args};
use crate::error::Result;
use crate::metrics::{run_json, Recorder};
use crate::runtime::Engine;
use crate::transport::wire::{serve_on, ServeOpts};

/// Option keys `serve` consumes itself (not forwarded to the config).
const RESERVED: [&str; 6] = [
    "csv",
    "json",
    "wire_listen",
    "wire_lease_ms",
    "wire_round_timeout_ms",
    "wire_on_timeout",
];

pub fn cmd_serve(args: &Args, artifacts: &str) -> Result<()> {
    let listen = args.str_or("wire_listen", "127.0.0.1:7070");
    let opts = ServeOpts {
        lease_ms: args.parse_opt("wire_lease_ms")?.unwrap_or(30_000),
        round_timeout_ms: args
            .parse_opt("wire_round_timeout_ms")?
            .unwrap_or(60_000),
        on_timeout: args
            .parse_opt("wire_on_timeout")?
            .unwrap_or_default(),
    };
    let csv = args.opt_str("csv");
    let json = args.opt_str("json");
    let cfg = assemble_config(args, &RESERVED)?;

    let engine = Engine::new(artifacts)?;
    let listener = TcpListener::bind(&listen)?;
    println!(
        "serve: {} tag={} codec={} aggregator={} clients={} ({}/round) \
         rounds={} seed={} lease={}ms round_timeout={}ms on_timeout={}{}",
        listener.local_addr()?,
        cfg.tag,
        cfg.codec.label(),
        cfg.aggregator.label(),
        cfg.num_clients,
        cfg.clients_per_round,
        cfg.rounds,
        cfg.seed,
        opts.lease_ms,
        opts.round_timeout_ms,
        opts.on_timeout.label(),
        if engine.is_synthetic() { " backend=synthetic" } else { "" }
    );

    // The recorder keeps `train`'s name so the JSON document is
    // byte-identical to `flocora train --json` on the same run.
    let mut rec = Recorder::new("train");
    let (summary, dropped) = serve_on(listener, &engine, cfg, &opts, &mut rec)?;
    for r in &rec.rounds {
        println!(
            "round {:>4}  acc {:.4}  test_loss {:.4}  train_loss {:.4}  \
             comm {:.2} MB",
            r.round, r.test_acc, r.test_loss, r.train_loss,
            r.cum_bytes as f64 / 1e6
        );
    }
    println!(
        "final acc {:.4} (tail {:.4})  msg {:.1} kB  {} cancelled  \
         {} dropped",
        summary.final_acc, summary.tail_acc,
        summary.mean_up_msg_bytes / 1e3, summary.cancelled_clients,
        dropped
    );
    if let Some(path) = csv {
        rec.write_csv(&path)?;
        println!("wrote {path}");
    }
    if let Some(path) = json {
        let doc = run_json(&rec, &summary, dropped);
        std::fs::write(&path, doc.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
