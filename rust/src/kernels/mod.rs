//! Chunked, autovectorization-friendly inner loops for the per-round
//! hot paths, plus their retained scalar references.
//!
//! Every kernel here is written in the 8-lane `f32x8` style on stable
//! Rust: the body walks the input in [`LANES`]-wide chunks with
//! fixed-size array patterns so LLVM's autovectorizer emits packed
//! SIMD, and the tail falls back to the scalar loop. No `unsafe`, no
//! nightly `std::simd`.
//!
//! # Bit-identity contract
//!
//! Each kernel ships with a `*_ref` twin — a faithful scalar port of
//! the pre-kernel call-site loop — and `tests/properties.rs` pins the
//! pair bit-identical across lengths 0..~100 (including tails that
//! are not a multiple of 8). The contract holds because every kernel
//! is either purely element-wise (quantize, dequantize, axpy: lane
//! order does not touch the arithmetic) or a min/max reduction, which
//! is associative and commutative for NaN-free input. Inputs with
//! NaNs are outside the contract (the references' own behavior is
//! already order-dependent there), and a row holding both `+0.0` and
//! `-0.0` may report either zero as its min/max — value-identical,
//! sign-of-zero may differ.
//!
//! The f64 water-filling kernel is *not* element-wise — `left -=
//! caps[i]` is a sequential chain — so [`waterfill`] replays the
//! reference's exact visit order (ascending flow index, identical
//! round structure) and only removes the per-call allocations.
//!
//! # Benchmarks
//!
//! `benches/micro.rs` times each kernel against its reference on the
//! paper-scale geometry and emits `BENCH_hotpaths.json`; the CI
//! `perf-smoke` job regresses the speedup ratios against the
//! committed baseline. See ARCHITECTURE.md § "Hot paths & kernels".

/// Lane count every chunked loop is written against. 8 × f32 = one
/// AVX register, two NEON registers; narrower ISAs just unroll.
pub const LANES: usize = 8;

// ---------------------------------------------------------------------------
// Min/max row scan (affine quantization's range pass)
// ---------------------------------------------------------------------------

/// Scalar reference: sequential ±∞-seeded fold, the shape of the
/// original `compression::affine` range loop.
pub fn minmax_ref(v: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in v {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// 8-lane min/max scan. Returns `(+∞, -∞)` for an empty slice, like
/// the reference.
pub fn minmax(v: &[f32]) -> (f32, f32) {
    let mut lo = [f32::INFINITY; LANES];
    let mut hi = [f32::NEG_INFINITY; LANES];
    let mut chunks = v.chunks_exact(LANES);
    for c in &mut chunks {
        for j in 0..LANES {
            lo[j] = lo[j].min(c[j]);
            hi[j] = hi[j].max(c[j]);
        }
    }
    let (mut l, mut h) = (f32::INFINITY, f32::NEG_INFINITY);
    for j in 0..LANES {
        l = l.min(lo[j]);
        h = h.max(hi[j]);
    }
    for &x in chunks.remainder() {
        l = l.min(x);
        h = h.max(x);
    }
    (l, h)
}

// ---------------------------------------------------------------------------
// Affine quantize / dequantize / fused dequant-accumulate
// ---------------------------------------------------------------------------

/// One element of the affine RTN map: `clip(round_half_up((v - lo) /
/// scale), 0, qmax)`. `(v - lo)/scale + 0.5` is never negative on the
/// valid domain (`v >= lo`), so truncation == floor and the `as u8`
/// cast realizes the round without a `floor` libcall.
#[inline(always)]
fn quant_one(v: f32, lo: f32, scale: f32, qmax: f32) -> u8 {
    ((v - lo) / scale + 0.5).clamp(0.0, qmax) as u8
}

/// Scalar reference: push-based code emission, the shape of the
/// original `quant_row` loop.
pub fn quant_codes_ref(
    row: &[f32],
    lo: f32,
    scale: f32,
    qmax: f32,
    out: &mut Vec<u8>,
) {
    for &v in row {
        out.push(quant_one(v, lo, scale, qmax));
    }
}

/// 8-lane quantize: map `row` to codes in `out` (same length).
pub fn quant_codes(
    row: &[f32],
    lo: f32,
    scale: f32,
    qmax: f32,
    out: &mut [u8],
) {
    assert_eq!(row.len(), out.len(), "quant_codes length mismatch");
    let mut rc = row.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for (r, o) in (&mut rc).zip(&mut oc) {
        for j in 0..LANES {
            o[j] = quant_one(r[j], lo, scale, qmax);
        }
    }
    for (&v, o) in rc.remainder().iter().zip(oc.into_remainder()) {
        *o = quant_one(v, lo, scale, qmax);
    }
}

/// Scalar reference: indexed dequantize, the shape of the original
/// decode loop (`dst[i] = (codes[i] - zp) * scale`).
#[allow(clippy::needless_range_loop)] // keeps the reference loop shape
pub fn dequant_ref(codes: &[u8], scale: f32, zp: f32, dst: &mut [f32]) {
    assert_eq!(codes.len(), dst.len(), "dequant length mismatch");
    for i in 0..dst.len() {
        dst[i] = (codes[i] as f32 - zp) * scale;
    }
}

/// 8-lane dequantize.
pub fn dequant(codes: &[u8], scale: f32, zp: f32, dst: &mut [f32]) {
    assert_eq!(codes.len(), dst.len(), "dequant length mismatch");
    let mut cc = codes.chunks_exact(LANES);
    let mut dc = dst.chunks_exact_mut(LANES);
    for (c, d) in (&mut cc).zip(&mut dc) {
        for j in 0..LANES {
            d[j] = (c[j] as f32 - zp) * scale;
        }
    }
    for (&c, d) in cc.remainder().iter().zip(dc.into_remainder()) {
        *d = (c as f32 - zp) * scale;
    }
}

/// Scalar reference for [`dequant_axpy`]: the fused fold one element
/// at a time — same three float ops per element, same operand order.
pub fn dequant_axpy_ref(
    codes: &[u8],
    scale: f32,
    zp: f32,
    w: f32,
    acc: &mut [f32],
) {
    assert_eq!(codes.len(), acc.len(), "dequant_axpy length mismatch");
    for (&c, a) in codes.iter().zip(acc.iter_mut()) {
        *a += w * ((c as f32 - zp) * scale);
    }
}

/// Fused dequantize-and-accumulate: `acc[i] += w * ((codes[i] - zp) *
/// scale)` — the zero-copy merge fold. Bit-identical to [`dequant`]
/// into a temporary followed by [`axpy`]: per element the same three
/// float ops run on the same operands in the same order, the
/// temporary just never materializes.
pub fn dequant_axpy(
    codes: &[u8],
    scale: f32,
    zp: f32,
    w: f32,
    acc: &mut [f32],
) {
    assert_eq!(codes.len(), acc.len(), "dequant_axpy length mismatch");
    let mut cc = codes.chunks_exact(LANES);
    let mut ac = acc.chunks_exact_mut(LANES);
    for (c, a) in (&mut cc).zip(&mut ac) {
        for j in 0..LANES {
            a[j] += w * ((c[j] as f32 - zp) * scale);
        }
    }
    for (&c, a) in cc.remainder().iter().zip(ac.into_remainder()) {
        *a += w * ((c as f32 - zp) * scale);
    }
}

// ---------------------------------------------------------------------------
// Weighted folds (FedAvg inner loops)
// ---------------------------------------------------------------------------

/// Scalar reference: the original `tensor::axpy_weighted` zip loop.
pub fn axpy_ref(acc: &mut [f32], x: &[f32], w: f32) {
    assert_eq!(acc.len(), x.len(), "axpy length mismatch");
    for (a, &b) in acc.iter_mut().zip(x.iter()) {
        *a += w * b;
    }
}

/// 8-lane weighted accumulation `acc += w * x`.
pub fn axpy(acc: &mut [f32], x: &[f32], w: f32) {
    assert_eq!(acc.len(), x.len(), "axpy length mismatch");
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (a, b) in (&mut ac).zip(&mut xc) {
        for j in 0..LANES {
            a[j] += w * b[j];
        }
    }
    for (a, &b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += w * b;
    }
}

/// Scalar reference for [`axpy_from_le`]: decode one little-endian
/// f32 at a time and fold it in — same per-element arithmetic.
pub fn axpy_from_le_ref(bytes: &[u8], w: f32, acc: &mut [f32]) {
    assert_eq!(bytes.len(), acc.len() * 4, "axpy_from_le length mismatch");
    for (b, a) in bytes.chunks_exact(4).zip(acc.iter_mut()) {
        *a += w * f32::from_le_bytes(b.try_into().unwrap());
    }
}

/// Fold little-endian f32 bytes into `acc` with weight `w` — the
/// fp32 codec's zero-copy merge (`acc[i] += w * le_f32(bytes[4i..])`).
/// `bytes.len()` must be `4 * acc.len()`.
pub fn axpy_from_le(bytes: &[u8], w: f32, acc: &mut [f32]) {
    assert_eq!(bytes.len(), acc.len() * 4, "axpy_from_le length mismatch");
    let mut bc = bytes.chunks_exact(4 * LANES);
    let mut ac = acc.chunks_exact_mut(LANES);
    for (b, a) in (&mut bc).zip(&mut ac) {
        for j in 0..LANES {
            let v = f32::from_le_bytes(
                b[4 * j..4 * j + 4].try_into().unwrap(),
            );
            a[j] += w * v;
        }
    }
    for (b, a) in bc
        .remainder()
        .chunks_exact(4)
        .zip(ac.into_remainder().iter_mut())
    {
        *a += w * f32::from_le_bytes(b.try_into().unwrap());
    }
}

/// Scalar reference: elementwise sum via iterator collect, the shape
/// of the original error-feedback `corrected` construction.
pub fn vadd_ref(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len(), "vadd length mismatch");
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

/// 8-lane elementwise sum `a + b` (error-feedback residual apply).
pub fn vadd(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len(), "vadd length mismatch");
    let mut out = vec![0.0f32; a.len()];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for ((x, y), o) in (&mut ac).zip(&mut bc).zip(&mut oc) {
        for j in 0..LANES {
            o[j] = x[j] + y[j];
        }
    }
    for ((&x, &y), o) in ac
        .remainder()
        .iter()
        .zip(bc.remainder())
        .zip(oc.into_remainder().iter_mut())
    {
        *o = x + y;
    }
    out
}

// ---------------------------------------------------------------------------
// Sub-byte packing
// ---------------------------------------------------------------------------

/// Codes packed per byte at `bits` per code: `floor(8 / bits)`.
/// Widths that do not divide 8 (3, 5, 6, 7) waste the remainder bits
/// of each byte rather than splitting codes across bytes.
// det-lint: allow(kernel-ref) — size arithmetic, not a fast path;
// there is no loop to hold a scalar reference against.
#[inline]
pub fn codes_per_byte(bits: u32) -> usize {
    assert!(
        (1..=8).contains(&bits),
        "pack: bits must be in 1..=8, got {bits}"
    );
    (8 / bits) as usize
}

/// Packed byte length for `n` codes at `bits` per code.
// det-lint: allow(kernel-ref) — size arithmetic, not a fast path;
// there is no loop to hold a scalar reference against.
pub fn packed_len(n: usize, bits: u32) -> usize {
    n.div_ceil(codes_per_byte(bits))
}

/// Scalar reference: the original per-element `i / per`, `i % per`
/// pack loop, generalized to any width in 1..=8.
pub fn pack_ref(codes: &[u8], bits: u32, out: &mut [u8]) {
    let per = codes_per_byte(bits);
    assert_eq!(out.len(), packed_len(codes.len(), bits));
    out.fill(0);
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(
            u32::from(c) < (1 << bits),
            "code {c} exceeds {bits} bits"
        );
        out[i / per] |= c << ((i % per) as u32 * bits);
    }
}

/// Byte-group pack: one output byte per loop step, no per-element
/// div/mod. 8-bit is a straight copy; 4/2-bit shift whole groups;
/// other widths fall back to the reference loop.
pub fn pack_into(codes: &[u8], bits: u32, out: &mut [u8]) {
    assert_eq!(out.len(), packed_len(codes.len(), bits));
    match bits {
        8 => out.copy_from_slice(codes),
        4 => {
            let mut cc = codes.chunks_exact(2);
            for (c, o) in (&mut cc).zip(out.iter_mut()) {
                debug_assert!(c[0] < 16 && c[1] < 16);
                *o = c[0] | (c[1] << 4);
            }
            if let [c] = cc.remainder() {
                debug_assert!(*c < 16);
                out[codes.len() / 2] = *c;
            }
        }
        2 => {
            let mut cc = codes.chunks_exact(4);
            for (c, o) in (&mut cc).zip(out.iter_mut()) {
                debug_assert!(c.iter().all(|&x| x < 4));
                *o = c[0] | (c[1] << 2) | (c[2] << 4) | (c[3] << 6);
            }
            let tail = cc.remainder();
            if !tail.is_empty() {
                let mut b = 0u8;
                for (s, &c) in tail.iter().enumerate() {
                    debug_assert!(c < 4);
                    b |= c << (2 * s as u32);
                }
                out[codes.len() / 4] = b;
            }
        }
        _ => pack_ref(codes, bits, out),
    }
}

/// Scalar reference: the original per-element unpack loop.
pub fn unpack_ref(bytes: &[u8], bits: u32, out: &mut [u8]) {
    let per = codes_per_byte(bits);
    assert!(
        bytes.len() >= packed_len(out.len(), bits),
        "not enough packed bytes"
    );
    let mask = ((1u16 << bits) - 1) as u8;
    for (i, o) in out.iter_mut().enumerate() {
        *o = (bytes[i / per] >> ((i % per) as u32 * bits)) & mask;
    }
}

/// Byte-group unpack of `out.len()` codes.
pub fn unpack_into(bytes: &[u8], bits: u32, out: &mut [u8]) {
    assert!(
        bytes.len() >= packed_len(out.len(), bits),
        "not enough packed bytes"
    );
    match bits {
        8 => out.copy_from_slice(&bytes[..out.len()]),
        4 => {
            let mut oc = out.chunks_exact_mut(2);
            let mut used = 0usize;
            for (o, &b) in (&mut oc).zip(bytes.iter()) {
                o[0] = b & 0xF;
                o[1] = b >> 4;
                used += 1;
            }
            if let [o] = oc.into_remainder() {
                *o = bytes[used] & 0xF;
            }
        }
        2 => {
            let mut oc = out.chunks_exact_mut(4);
            let mut used = 0usize;
            for (o, &b) in (&mut oc).zip(bytes.iter()) {
                o[0] = b & 3;
                o[1] = (b >> 2) & 3;
                o[2] = (b >> 4) & 3;
                o[3] = b >> 6;
                used += 1;
            }
            let tail = oc.into_remainder();
            if !tail.is_empty() {
                let b = bytes[used];
                for (s, o) in tail.iter_mut().enumerate() {
                    *o = (b >> (2 * s as u32)) & 3;
                }
            }
        }
        _ => unpack_ref(bytes, bits, out),
    }
}

// ---------------------------------------------------------------------------
// Top-k threshold selection (sparse codecs)
// ---------------------------------------------------------------------------

/// Scalar reference: the original index-array selection with an
/// indirect `(|v| desc, index asc)` comparator.
pub fn topk_indices_ref(v: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..v.len() as u32).collect();
    if k >= v.len() {
        return idx;
    }
    idx.select_nth_unstable_by(k, |&a, &b| {
        let ma = v[a as usize].abs();
        let mb = v[b as usize].abs();
        mb.partial_cmp(&ma)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Indices of the `k` largest-magnitude elements, `(|v| desc, index
/// asc)` order deciding ties — the same total order as the reference,
/// so the returned *set* is identical (order within it is
/// unspecified, as before; callers sort).
///
/// Packs `(|v|, index)` into one `u64` key — non-negative IEEE floats
/// order like their bit patterns, and the complemented index in the
/// low word turns "index asc" into plain integer "desc" — so the
/// selection runs branchless u64 compares on a contiguous array
/// instead of indirect float loads. Requires NaN-free input (the
/// reference's comparator is ill-defined there anyway).
pub fn topk_indices(v: &[f32], k: usize) -> Vec<u32> {
    if k >= v.len() {
        return (0..v.len() as u32).collect();
    }
    let mut keys: Vec<u64> = v
        .iter()
        .enumerate()
        .map(|(i, &x)| {
            ((x.abs().to_bits() as u64) << 32) | u64::from(!(i as u32))
        })
        .collect();
    keys.select_nth_unstable_by(k, |a, b| b.cmp(a));
    keys.truncate(k);
    keys.iter().map(|&key| !(key as u32)).collect()
}

// ---------------------------------------------------------------------------
// Rank projection (hetero tiers)
// ---------------------------------------------------------------------------

/// Scalar reference: the original indexed per-outer-row copy of the
/// first `width` columns (`dst[o*dst_stride..][..width] <-
/// src[o*src_stride..][..width]`).
pub fn gather_rows_ref(
    src: &[f32],
    src_stride: usize,
    dst: &mut [f32],
    dst_stride: usize,
    width: usize,
) {
    let outer = src.len() / src_stride;
    for o in 0..outer {
        dst[o * dst_stride..o * dst_stride + width]
            .copy_from_slice(&src[o * src_stride..o * src_stride + width]);
    }
}

/// Strided row gather via exact chunk iterators — the index
/// arithmetic and its bounds checks drop out of the loop.
pub fn gather_rows(
    src: &[f32],
    src_stride: usize,
    dst: &mut [f32],
    dst_stride: usize,
    width: usize,
) {
    debug_assert!(width <= src_stride && width <= dst_stride);
    for (s, d) in src
        .chunks_exact(src_stride)
        .zip(dst.chunks_exact_mut(dst_stride))
    {
        d[..width].copy_from_slice(&s[..width]);
    }
}

// ---------------------------------------------------------------------------
// Max-min fair water-filling (transport::sim)
// ---------------------------------------------------------------------------

/// Scalar reference: the original allocating progressive-filling loop
/// from `transport::sim` — normalized rate 1.0 split max-min fairly
/// across flows capped at `caps[i]`.
pub fn waterfill_ref(caps: &[f64], rates: &mut [f64]) {
    rates.fill(0.0);
    let mut active: Vec<usize> = (0..caps.len()).collect();
    let mut left = 1.0f64;
    while !active.is_empty() && left > 0.0 {
        let fair = left / active.len() as f64;
        let mut kept = Vec::with_capacity(active.len());
        for &i in &active {
            if caps[i] <= fair {
                rates[i] = caps[i];
                left -= caps[i];
            } else {
                kept.push(i);
            }
        }
        if kept.len() == active.len() {
            for &i in &kept {
                rates[i] = fair;
            }
            break;
        }
        active = kept;
    }
}

/// Allocation-free water-filling. The first round walks `caps`
/// directly (no index array at all — the common case resolves there);
/// later rounds compact the survivor list in place in `scratch`,
/// whose capacity is reused across calls. The f64 arithmetic replays
/// the reference exactly: same ascending visit order, same
/// `left -= caps[i]` chain, same all-uncapped early exit — so the
/// rates are bit-identical, which the event simulator's cross-
/// executor determinism contract depends on.
#[allow(clippy::needless_range_loop)] // read + compact-in-place on `scratch`
pub fn waterfill(caps: &[f64], rates: &mut [f64], scratch: &mut Vec<u32>) {
    assert_eq!(caps.len(), rates.len(), "waterfill length mismatch");
    rates.fill(0.0);
    scratch.clear();
    let mut left = 1.0f64;
    let mut active_len = caps.len();
    let mut dense = true;
    while active_len > 0 && left > 0.0 {
        let fair = left / active_len as f64;
        let kept;
        if dense {
            for (i, (&c, r)) in caps.iter().zip(rates.iter_mut()).enumerate()
            {
                if c <= fair {
                    *r = c;
                    left -= c;
                } else {
                    scratch.push(i as u32);
                }
            }
            kept = scratch.len();
        } else {
            let mut w = 0usize;
            for r in 0..active_len {
                let i = scratch[r] as usize;
                if caps[i] <= fair {
                    rates[i] = caps[i];
                    left -= caps[i];
                } else {
                    scratch[w] = scratch[r];
                    w += 1;
                }
            }
            scratch.truncate(w);
            kept = w;
        }
        if kept == active_len {
            for &i in scratch.iter() {
                rates[i as usize] = fair;
            }
            break;
        }
        active_len = kept;
        dense = false;
    }
}

/// Flow count above which [`waterfill_pair`] recomputes the two pipes
/// on separate threads. Thread spawn costs tens of microseconds, so
/// the split only pays once each pipe's fill is itself that large —
/// far above the simulator's default presets, which stay sequential.
pub const WATERFILL_PAR_MIN: usize = 4096;

/// Recompute both pipes of a shared link (down + up) — the per-event
/// hot call in `transport::sim`. Sequential below
/// [`WATERFILL_PAR_MIN`] flows; above it the two independent fills
/// run on scoped threads via the [`crate::sync`] shim (the pipes
/// share no state, so the result is identical either way — and the
/// loom build swaps in instrumented threads here like everywhere
/// else).
// det-lint: allow(kernel-ref) — a parallel *composition* of
// `waterfill`, whose scalar reference (`waterfill_ref`) already
// exists; the sequential branch below IS the reference behavior.
#[allow(clippy::too_many_arguments)]
pub fn waterfill_pair(
    down_caps: &[f64],
    down_rates: &mut [f64],
    down_scratch: &mut Vec<u32>,
    up_caps: &[f64],
    up_rates: &mut [f64],
    up_scratch: &mut Vec<u32>,
) {
    if down_caps.len().min(up_caps.len()) >= WATERFILL_PAR_MIN {
        crate::sync::thread::scope(|s| {
            s.spawn(|| waterfill(down_caps, down_rates, down_scratch));
            waterfill(up_caps, up_rates, up_scratch);
        });
    } else {
        waterfill(down_caps, down_rates, down_scratch);
        waterfill(up_caps, up_rates, up_scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| 2.0 * rng.normal() as f32).collect()
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len()
            && a.iter()
                .zip(b.iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn minmax_matches_ref_all_tails() {
        for n in 0..100 {
            let v = randv(n, n as u64);
            let (l, h) = minmax(&v);
            let (lr, hr) = minmax_ref(&v);
            assert_eq!(l.to_bits(), lr.to_bits(), "n={n}");
            assert_eq!(h.to_bits(), hr.to_bits(), "n={n}");
        }
    }

    #[test]
    fn quant_dequant_match_ref_all_tails() {
        for n in 0..100 {
            let v = randv(n, 1000 + n as u64);
            let (lo, hi) = minmax(&v);
            let (scale, _) = if hi > lo {
                ((hi - lo) / 255.0, 0.0)
            } else {
                (1.0, 0.0)
            };
            let mut codes = vec![0u8; n];
            quant_codes(&v, lo, scale, 255.0, &mut codes);
            let mut codes_ref = Vec::new();
            quant_codes_ref(&v, lo, scale, 255.0, &mut codes_ref);
            assert_eq!(codes, codes_ref, "n={n}");

            let zp = if scale > 0.0 { -lo / scale } else { 0.0 };
            let mut d = vec![0.0f32; n];
            let mut dr = vec![0.0f32; n];
            dequant(&codes, scale, zp, &mut d);
            dequant_ref(&codes, scale, zp, &mut dr);
            assert!(bits_eq(&d, &dr), "n={n}");
        }
    }

    #[test]
    fn dequant_axpy_is_fused_dequant_plus_axpy() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 100] {
            let v = randv(n, 7);
            let (lo, hi) = minmax(&v);
            let scale = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
            let zp = -lo / scale;
            let mut codes = vec![0u8; n];
            quant_codes(&v, lo, scale, 255.0, &mut codes);

            let mut acc = randv(n, 8);
            let mut acc2 = acc.clone();
            dequant_axpy(&codes, scale, zp, 0.37, &mut acc);
            let mut tmp = vec![0.0f32; n];
            dequant_ref(&codes, scale, zp, &mut tmp);
            axpy_ref(&mut acc2, &tmp, 0.37);
            assert!(bits_eq(&acc, &acc2), "n={n}");
        }
    }

    #[test]
    fn axpy_and_vadd_match_ref_all_tails() {
        for n in 0..100 {
            let x = randv(n, 2000 + n as u64);
            let mut a = randv(n, 3000 + n as u64);
            let mut b = a.clone();
            axpy(&mut a, &x, 0.5);
            axpy_ref(&mut b, &x, 0.5);
            assert!(bits_eq(&a, &b), "n={n}");

            let s = vadd(&a, &x);
            let sr = vadd_ref(&a, &x);
            assert!(bits_eq(&s, &sr), "n={n}");
        }
    }

    #[test]
    fn axpy_from_le_matches_decode_then_axpy() {
        for n in [0usize, 1, 7, 8, 9, 33, 100] {
            let v = randv(n, 11);
            let bytes: Vec<u8> =
                v.iter().flat_map(|x| x.to_le_bytes()).collect();
            let mut acc = randv(n, 12);
            let mut acc2 = acc.clone();
            axpy_from_le(&bytes, 1.7, &mut acc);
            axpy_ref(&mut acc2, &v, 1.7);
            assert!(bits_eq(&acc, &acc2), "n={n}");
        }
    }

    #[test]
    fn pack_unpack_match_ref_all_widths_and_tails() {
        let mut rng = Rng::new(5);
        for bits in 1..=8u32 {
            let max = 1usize << bits;
            for n in 0..80 {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(max) as u8).collect();
                let plen = packed_len(n, bits);
                let mut a = vec![0u8; plen];
                let mut b = vec![0u8; plen];
                pack_into(&codes, bits, &mut a);
                pack_ref(&codes, bits, &mut b);
                assert_eq!(a, b, "pack bits={bits} n={n}");

                let mut ua = vec![0u8; n];
                let mut ub = vec![0u8; n];
                unpack_into(&a, bits, &mut ua);
                unpack_ref(&a, bits, &mut ub);
                assert_eq!(ua, codes, "unpack bits={bits} n={n}");
                assert_eq!(ua, ub, "unpack ref bits={bits} n={n}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=8")]
    fn pack_rejects_zero_bits() {
        packed_len(4, 0);
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=8")]
    fn pack_rejects_wide_bits() {
        let mut out = [0u8; 4];
        pack_into(&[1, 2, 3, 4], 9, &mut out);
    }

    #[test]
    fn topk_matches_ref_as_a_set() {
        for n in 0..60 {
            let v = randv(n, 4000 + n as u64);
            for k in [0usize, 1, n / 3, n.saturating_sub(1), n, n + 5] {
                let mut a = topk_indices(&v, k);
                let mut b = topk_indices_ref(&v, k);
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn topk_tie_break_prefers_low_index() {
        // Equal magnitudes: the (|v| desc, index asc) order must keep
        // the earliest indices, in both implementations.
        let v = vec![1.0f32, -1.0, 1.0, -1.0, 1.0];
        let mut a = topk_indices(&v, 3);
        let mut b = topk_indices_ref(&v, 3);
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(a, b);
    }

    #[test]
    fn gather_rows_matches_ref() {
        for (outer, rs, rd, w) in
            [(4usize, 7usize, 9usize, 5usize), (3, 8, 8, 8), (1, 3, 2, 2)]
        {
            let src = randv(outer * rs, 6);
            let mut a = vec![0.0f32; outer * rd];
            let mut b = vec![0.0f32; outer * rd];
            gather_rows(&src, rs, &mut a, rd, w);
            gather_rows_ref(&src, rs, &mut b, rd, w);
            assert!(bits_eq(&a, &b), "{outer}x{rs}->{rd} w={w}");
        }
    }

    #[test]
    fn waterfill_matches_ref_bitwise() {
        let mut rng = Rng::new(9);
        for n in 0..50 {
            let caps: Vec<f64> =
                (0..n).map(|_| 0.002 + rng.f64() * 0.2).collect();
            let mut a = vec![0.0f64; n];
            let mut b = vec![0.0f64; n];
            let mut scratch = Vec::new();
            waterfill(&caps, &mut a, &mut scratch);
            waterfill_ref(&caps, &mut b);
            for i in 0..n {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn waterfill_pair_equals_two_fills() {
        let mut rng = Rng::new(10);
        let dc: Vec<f64> = (0..37).map(|_| 0.01 + rng.f64()).collect();
        let uc: Vec<f64> = (0..37).map(|_| 0.01 + rng.f64()).collect();
        let (mut dr, mut ur) = (vec![0.0; 37], vec![0.0; 37]);
        let (mut ds, mut us) = (Vec::new(), Vec::new());
        waterfill_pair(&dc, &mut dr, &mut ds, &uc, &mut ur, &mut us);
        let (mut dr2, mut ur2) = (vec![0.0; 37], vec![0.0; 37]);
        waterfill_ref(&dc, &mut dr2);
        waterfill_ref(&uc, &mut ur2);
        assert_eq!(dr, dr2);
        assert_eq!(ur, ur2);
    }

    #[test]
    fn dequant_axpy_matches_ref_all_tails() {
        for n in 0..100 {
            let v = randv(n, 5000 + n as u64);
            let (lo, hi) = minmax(&v);
            let scale = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
            let zp = -lo / scale;
            let mut codes = vec![0u8; n];
            quant_codes(&v, lo, scale, 255.0, &mut codes);

            let mut a = randv(n, 6000 + n as u64);
            let mut b = a.clone();
            dequant_axpy(&codes, scale, zp, 0.73, &mut a);
            dequant_axpy_ref(&codes, scale, zp, 0.73, &mut b);
            assert!(bits_eq(&a, &b), "n={n}");
        }
    }

    #[test]
    fn axpy_from_le_matches_ref_all_tails() {
        for n in 0..100 {
            let v = randv(n, 7000 + n as u64);
            let bytes: Vec<u8> =
                v.iter().flat_map(|x| x.to_le_bytes()).collect();
            let mut a = randv(n, 8000 + n as u64);
            let mut b = a.clone();
            axpy_from_le(&bytes, -0.41, &mut a);
            axpy_from_le_ref(&bytes, -0.41, &mut b);
            assert!(bits_eq(&a, &b), "n={n}");
        }
    }

    #[test]
    fn waterfill_scratch_is_reused_across_calls() {
        let mut scratch = Vec::new();
        let caps = vec![0.05f64, 0.9, 0.9, 0.9];
        let mut rates = vec![0.0f64; 4];
        waterfill(&caps, &mut rates, &mut scratch);
        let cap_after_first = scratch.capacity();
        assert!(cap_after_first > 0);
        waterfill(&caps, &mut rates, &mut scratch);
        assert_eq!(scratch.capacity(), cap_after_first);
        // Capped flow got its cap; the rest split the remainder.
        assert_eq!(rates[0], 0.05);
        assert!((rates[1] - (1.0 - 0.05) / 3.0).abs() < 1e-12);
    }
}
