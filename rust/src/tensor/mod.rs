//! Flat `f32` vector math — the coordinator's entire numerical surface.
//!
//! Model state crosses the PJRT boundary as flat vectors (DESIGN.md §4),
//! so aggregation, quantization, pruning and accounting are all O(P)
//! loops over `&[f32]`. The hot one (`axpy_weighted`, used once per
//! client per round) routes through [`crate::kernels`].

/// Weighted accumulation `acc += w * x` (FedAvg's inner loop).
pub fn axpy_weighted(acc: &mut [f32], x: &[f32], w: f32) {
    crate::kernels::axpy(acc, x, w);
}

/// Elementwise scale in place.
pub fn scale(v: &mut [f32], s: f32) {
    for x in v.iter_mut() {
        *x *= s;
    }
}

/// L2 norm.
pub fn l2(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Max absolute difference (parity tests, convergence checks).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Mean of a slice (metrics).
pub fn mean(v: &[f32]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64
}

/// `out = a - b` (update deltas for the sparse baselines).
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x - y).collect()
}

/// `out = a + b` in place on `a`.
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b.iter()) {
        *x += y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_scale() {
        let mut acc = vec![1.0, 2.0];
        axpy_weighted(&mut acc, &[10.0, 20.0], 0.5);
        assert_eq!(acc, vec![6.0, 12.0]);
        scale(&mut acc, 2.0);
        assert_eq!(acc, vec![12.0, 24.0]);
    }

    #[test]
    fn norms_and_diffs() {
        assert_eq!(l2(&[3.0, 4.0]), 5.0);
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 5.0]), 0.5);
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn sub_add_round_trip() {
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![0.5, 1.0, 1.5];
        let d = sub(&a, &b);
        let mut c = b.clone();
        add_assign(&mut c, &d);
        assert_eq!(c, a);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn axpy_rejects_mismatch() {
        axpy_weighted(&mut [0.0], &[1.0, 2.0], 1.0);
    }
}
