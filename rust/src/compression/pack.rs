//! Sub-byte integer packing: 1..=8-bit codes, little-endian within
//! the byte (code 0 in the lowest bits). 8-bit is a plain byte per
//! code; widths that do not divide 8 (3, 5, 6, 7) pack
//! `floor(8/bits)` codes per byte and waste the remainder bits.
//!
//! The inner loops live in [`crate::kernels`] (byte-group processing,
//! no per-element div/mod); these wrappers own allocation and the
//! width validation: `bits == 0` and `bits > 8` are rejected loudly
//! instead of shifting by garbage.

use crate::kernels;

/// Pack `codes` (each < 2^bits) at `bits` per element.
///
/// Panics if `bits` is 0 or greater than 8.
pub fn pack(codes: &[u8], bits: u32) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    kernels::pack_into(codes, bits, &mut out);
    out
}

/// Unpack `n` codes at `bits` per element.
///
/// Panics if `bits` is 0 or greater than 8, or if `bytes` is shorter
/// than `packed_len(n, bits)`.
pub fn unpack(bytes: &[u8], bits: u32, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    kernels::unpack_into(bytes, bits, &mut out);
    out
}

/// Packed byte length for `n` codes at `bits`.
///
/// Panics if `bits` is 0 or greater than 8.
pub fn packed_len(n: usize, bits: u32) -> usize {
    kernels::packed_len(n, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_all_widths() {
        let mut rng = Rng::new(11);
        for bits in 1..=8u32 {
            let max = (1u16 << bits) as usize;
            for n in [0usize, 1, 3, 8, 9, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(max) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), packed_len(n, bits));
                assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn density() {
        assert_eq!(packed_len(8, 2), 2);
        assert_eq!(packed_len(8, 4), 4);
        assert_eq!(packed_len(8, 8), 8);
        assert_eq!(packed_len(9, 2), 3);
    }

    #[test]
    fn known_layout() {
        // codes [1, 2, 3, 0] at 2 bits -> 0b00_11_10_01.
        assert_eq!(pack(&[1, 2, 3, 0], 2), vec![0b0011_1001]);
        // codes [0xA, 0x5] at 4 bits -> 0b0101_1010.
        assert_eq!(pack(&[0xA, 0x5], 4), vec![0b0101_1010]);
    }

    #[test]
    fn empty_input_packs_to_empty() {
        for bits in 1..=8u32 {
            assert_eq!(pack(&[], bits), Vec::<u8>::new());
            assert_eq!(unpack(&[], bits, 0), Vec::<u8>::new());
            assert_eq!(packed_len(0, bits), 0);
        }
    }

    #[test]
    fn non_dividing_widths_are_defined() {
        // 3 bits: floor(8/3) = 2 codes per byte, 2 wasted bits.
        assert_eq!(packed_len(4, 3), 2);
        let packed = pack(&[0b101, 0b011, 0b111, 0b001], 3);
        assert_eq!(packed, vec![0b011_101, 0b001_111]);
        assert_eq!(unpack(&packed, 3, 4), vec![0b101, 0b011, 0b111, 0b001]);
        // 5..7 bits degrade to one code per byte.
        assert_eq!(packed_len(3, 5), 3);
        assert_eq!(packed_len(3, 7), 3);
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=8")]
    fn zero_bits_rejected() {
        pack(&[1], 0);
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=8")]
    fn wide_bits_rejected() {
        packed_len(10, 9);
    }

    #[test]
    #[should_panic(expected = "not enough packed bytes")]
    fn short_buffer_rejected() {
        unpack(&[0u8; 1], 2, 9);
    }
}
