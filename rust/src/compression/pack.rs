//! Sub-byte integer packing: 2/4/8-bit codes, little-endian within the
//! byte (code 0 in the lowest bits). 8-bit is a plain byte per code.

/// Pack `codes` (each < 2^bits) at `bits` per element.
pub fn pack(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!(matches!(bits, 2 | 4 | 8));
    let per = 8 / bits as usize;
    let mut out = vec![0u8; codes.len().div_ceil(per)];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(u32::from(c) < (1 << bits), "code {c} exceeds {bits} bits");
        let byte = i / per;
        let slot = (i % per) as u32;
        out[byte] |= c << (slot * bits);
    }
    out
}

/// Unpack `n` codes at `bits` per element.
pub fn unpack(bytes: &[u8], bits: u32, n: usize) -> Vec<u8> {
    assert!(matches!(bits, 2 | 4 | 8));
    let per = 8 / bits as usize;
    assert!(bytes.len() >= n.div_ceil(per), "not enough packed bytes");
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let byte = bytes[i / per];
        let slot = (i % per) as u32;
        out.push((byte >> (slot * bits)) & mask);
    }
    out
}

/// Packed byte length for `n` codes at `bits`.
pub fn packed_len(n: usize, bits: u32) -> usize {
    n.div_ceil((8 / bits) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_all_widths() {
        let mut rng = Rng::new(11);
        for bits in [2u32, 4, 8] {
            let max = (1u16 << bits) as usize;
            for n in [0usize, 1, 3, 8, 9, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(max) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), packed_len(n, bits));
                assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn density() {
        assert_eq!(packed_len(8, 2), 2);
        assert_eq!(packed_len(8, 4), 4);
        assert_eq!(packed_len(8, 8), 8);
        assert_eq!(packed_len(9, 2), 3);
    }

    #[test]
    fn known_layout() {
        // codes [1, 2, 3, 0] at 2 bits -> 0b00_11_10_01.
        assert_eq!(pack(&[1, 2, 3, 0], 2), vec![0b0011_1001]);
        // codes [0xA, 0x5] at 4 bits -> 0b0101_1010.
        assert_eq!(pack(&[0xA, 0x5], 4), vec![0b0101_1010]);
    }
}
