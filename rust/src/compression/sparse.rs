//! Sparse baselines the paper compares against in Table IV, plus the
//! error-feedback sparsifier from the aggregation zoo.
//!
//! * [`TopKCodec`] — Magnitude Pruning [4]: keep the global top-`keep`
//!   fraction by |w|; wire format = presence bitmap (1 bit/element) +
//!   surviving values in f32. A 40% prune of ResNet-18 gives
//!   0.6·44.7 MB + 1.4 MB bitmap ≈ 28.2 MB vs the paper's 27.1 MB
//!   (they do not itemize mask overhead; shape preserved).
//! * [`ZeroFlCodec`] — ZeroFL [12] with sparsity `SP` and mask ratio
//!   `MR`: uploads the top (1-SP) fraction plus an extra MR·SP slice of
//!   the next-largest entries, as (u32 index, f32 value) pairs — the
//!   8-byte-per-entry encoding reproduces ZeroFL's reported 27.3 MB /
//!   10.1 MB messages for (0.9, 0.2) / (0.9, 0.0).
//! * [`SparseEfCodec`] — FLASC-style sparse LoRA communication with
//!   error feedback (arXiv 2406.05233): the same bitmap wire format as
//!   top-k, but each client carries a residual accumulator across
//!   rounds — what the mask drops this round is added back before
//!   masking next round, so no update mass is ever lost, only delayed.
//!   Residuals key on the client id (the
//!   [`Codec::encode_client`](crate::compression::Codec::encode_client)
//!   path), each slot written by exactly one client per round, so
//!   executor choice and thread count cannot perturb the stream.

use std::collections::BTreeMap;

use crate::compression::{check_fold_dim, Codec, Message};
use crate::error::{Error, Result};
use crate::kernels;
use crate::model::Segment;
use crate::sync::{Mutex, PoisonError};

/// Indices of the `k` largest |v| (deterministic tie-break by index).
fn top_k_indices(v: &[f32], k: usize) -> Vec<u32> {
    // Packed-key threshold selection; same (|v| desc, index asc) total
    // order as the retained reference (`kernels::topk_indices_ref`),
    // so the kept *set* is identical — property-pinned.
    kernels::topk_indices(v, k)
}

/// Round a keep-fraction to an element count: at least one survivor on
/// non-empty inputs, and exactly zero on empty ones (an `n = 0` vector
/// has nothing to keep — `clamp(1, 0)` would panic).
fn fraction_count(n: usize, fraction: f64) -> usize {
    if n == 0 {
        return 0;
    }
    ((n as f64 * fraction).round() as usize).clamp(1, n)
}

/// Bitmap + survivors payload shared by [`TopKCodec`] and
/// [`SparseEfCodec`]: u64 element count, presence bitmap
/// (1 bit/element), surviving values in index order as f32.
fn encode_bitmap_payload(v: &[f32], keep_idx: &[u32]) -> Vec<u8> {
    let mut bitmap = vec![0u8; v.len().div_ceil(8)];
    let mut payload =
        Vec::with_capacity(8 + bitmap.len() + 4 * keep_idx.len());
    payload.extend_from_slice(&(v.len() as u64).to_le_bytes());
    for &i in keep_idx {
        bitmap[(i / 8) as usize] |= 1 << (i % 8);
    }
    payload.extend_from_slice(&bitmap);
    for &i in keep_idx {
        payload.extend_from_slice(&v[i as usize].to_le_bytes());
    }
    payload
}

/// Inverse of [`encode_bitmap_payload`]; `tag` labels decode errors
/// with the owning codec's name.
fn decode_bitmap_payload(b: &[u8], tag: &str) -> Result<Vec<f32>> {
    if b.len() < 8 {
        return Err(Error::parse(format!("{tag}: truncated header")));
    }
    let n = u64::from_le_bytes(b[..8].try_into().unwrap()) as usize;
    let bm_len = n.div_ceil(8);
    if b.len() < 8 + bm_len {
        return Err(Error::parse(format!("{tag}: truncated bitmap")));
    }
    let bitmap = &b[8..8 + bm_len];
    let mut out = vec![0.0f32; n];
    let mut pos = 8 + bm_len;
    for (i, slot) in out.iter_mut().enumerate() {
        if bitmap[i / 8] & (1 << (i % 8)) != 0 {
            if pos + 4 > b.len() {
                return Err(Error::parse(format!("{tag}: truncated values")));
            }
            *slot = f32::from_le_bytes(b[pos..pos + 4].try_into().unwrap());
            pos += 4;
        }
    }
    if pos != b.len() {
        return Err(Error::parse(format!("{tag}: trailing bytes")));
    }
    Ok(out)
}

/// Streaming fold of a bitmap payload: `acc[i] += w * value` for each
/// present element, skipping the absent ones. The bitmap guarantees
/// each index appears at most once, and skipping an absent slot is
/// bitwise identical to the dense fold's `acc[i] += w * 0.0` (see
/// [`Codec::decode_into`]'s contract), so this matches
/// decode-then-fold exactly without materializing the dense vector.
fn fold_bitmap_payload(
    b: &[u8],
    tag: &str,
    acc: &mut [f32],
    w: f32,
) -> Result<()> {
    if b.len() < 8 {
        return Err(Error::parse(format!("{tag}: truncated header")));
    }
    let n = u64::from_le_bytes(b[..8].try_into().unwrap()) as usize;
    check_fold_dim(n, acc.len())?;
    let bm_len = n.div_ceil(8);
    if b.len() < 8 + bm_len {
        return Err(Error::parse(format!("{tag}: truncated bitmap")));
    }
    let bitmap = &b[8..8 + bm_len];
    let mut pos = 8 + bm_len;
    for (i, slot) in acc.iter_mut().enumerate() {
        if bitmap[i / 8] & (1 << (i % 8)) != 0 {
            if pos + 4 > b.len() {
                return Err(Error::parse(format!("{tag}: truncated values")));
            }
            let v = f32::from_le_bytes(b[pos..pos + 4].try_into().unwrap());
            *slot += w * v;
            pos += 4;
        }
    }
    if pos != b.len() {
        return Err(Error::parse(format!("{tag}: trailing bytes")));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Magnitude pruning: bitmap + values
// ---------------------------------------------------------------------------

pub struct TopKCodec {
    keep: f32,
}

impl TopKCodec {
    pub fn new(keep: f32) -> TopKCodec {
        assert!(keep > 0.0 && keep <= 1.0, "keep fraction in (0,1]");
        TopKCodec { keep }
    }

    pub fn kept_count(&self, n: usize) -> usize {
        fraction_count(n, self.keep as f64)
    }
}

impl Codec for TopKCodec {
    fn name(&self) -> String {
        format!("topk:{}", self.keep)
    }

    fn encode(&self, v: &[f32], _segments: &[Segment]) -> Result<Message> {
        let mut keep_idx = top_k_indices(v, self.kept_count(v.len()));
        keep_idx.sort_unstable();
        Ok(Message {
            payload: encode_bitmap_payload(v, &keep_idx),
            codec: self.name(),
        })
    }

    fn decode(&self, msg: &Message, _segments: &[Segment]) -> Result<Vec<f32>> {
        decode_bitmap_payload(&msg.payload, "topk")
    }

    fn decode_into(
        &self,
        msg: &Message,
        _segments: &[Segment],
        acc: &mut [f32],
        w: f32,
    ) -> Result<()> {
        fold_bitmap_payload(&msg.payload, "topk", acc, w)
    }
}

// ---------------------------------------------------------------------------
// ZeroFL-style: (index, value) pairs
// ---------------------------------------------------------------------------

pub struct ZeroFlCodec {
    sp: f32,
    mask_ratio: f32,
}

impl ZeroFlCodec {
    pub fn new(sp: f32, mask_ratio: f32) -> ZeroFlCodec {
        assert!((0.0..1.0).contains(&sp));
        assert!((0.0..=1.0).contains(&mask_ratio));
        ZeroFlCodec { sp, mask_ratio }
    }

    /// Uploaded fraction: the dense (1-SP) slice plus MR of the pruned
    /// SP slice (ZeroFL's "sparsity + mask" upload policy).
    pub fn kept_fraction(&self) -> f64 {
        (1.0 - self.sp as f64) + self.mask_ratio as f64 * self.sp as f64
    }

    pub fn kept_count(&self, n: usize) -> usize {
        fraction_count(n, self.kept_fraction())
    }
}

impl Codec for ZeroFlCodec {
    fn name(&self) -> String {
        format!("zerofl:{}:{}", self.sp, self.mask_ratio)
    }

    fn encode(&self, v: &[f32], _segments: &[Segment]) -> Result<Message> {
        let k = self.kept_count(v.len());
        let mut keep_idx = top_k_indices(v, k);
        keep_idx.sort_unstable();
        let mut payload = Vec::with_capacity(8 + 8 * k);
        payload.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for &i in &keep_idx {
            payload.extend_from_slice(&i.to_le_bytes());
            payload.extend_from_slice(&v[i as usize].to_le_bytes());
        }
        Ok(Message { payload, codec: self.name() })
    }

    fn decode(&self, msg: &Message, _segments: &[Segment]) -> Result<Vec<f32>> {
        let b = &msg.payload;
        if b.len() < 8 || (b.len() - 8) % 8 != 0 {
            return Err(Error::parse("zerofl: bad payload length"));
        }
        let n = u64::from_le_bytes(b[..8].try_into().unwrap()) as usize;
        let mut out = vec![0.0f32; n];
        for pair in b[8..].chunks_exact(8) {
            let i = u32::from_le_bytes(pair[..4].try_into().unwrap()) as usize;
            if i >= n {
                return Err(Error::parse(format!("zerofl: index {i} >= {n}")));
            }
            out[i] = f32::from_le_bytes(pair[4..].try_into().unwrap());
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Top-k with per-client error feedback
// ---------------------------------------------------------------------------

/// FLASC-style sparse upload with error-feedback residuals.
///
/// On the upload path ([`Codec::encode_client`]) the client's stored
/// residual is added to the fresh vector before masking; the mass the
/// mask drops becomes the next round's residual. The invariant the
/// property suite pins:
///
/// ```text
/// decode(encode_client(cid, v)) + residual'(cid) == v + residual(cid)
/// ```
///
/// bit-for-bit in f32 — the kept and dropped entries partition the
/// corrected vector, no arithmetic crosses the partition.
///
/// The keyed residual map makes the codec stateful but still
/// deterministic: each client id's slot is read and written by exactly
/// one upload per round. The map is a `BTreeMap` so that even if a
/// future change *does* iterate it (a checkpoint dump, a debug
/// export), the order is the sorted client ids, never hash order —
/// the `lint-determinism` stance on maps in settle paths. The mutex
/// comes from [`crate::sync`], so the loom suite model-checks
/// concurrent [`Codec::encode_client`] calls against this exact code.
/// The plain [`Codec::encode`] path (server broadcasts, size
/// estimates) is stateless top-k with the same wire format.
pub struct SparseEfCodec {
    keep: f32,
    residuals: Mutex<BTreeMap<usize, Vec<f32>>>,
}

impl SparseEfCodec {
    pub fn new(keep: f32) -> SparseEfCodec {
        assert!(keep > 0.0 && keep <= 1.0, "keep fraction in (0,1]");
        SparseEfCodec { keep, residuals: Mutex::new(BTreeMap::new()) }
    }

    pub fn kept_count(&self, n: usize) -> usize {
        fraction_count(n, self.keep as f64)
    }

    /// A snapshot of client `cid`'s residual accumulator (`None`
    /// before its first upload) — exposed for the conservation
    /// property tests. Read-only, so it tolerates a poisoned lock
    /// (diagnostics must stay readable after a worker panic; the
    /// *write* path refuses instead — see [`Codec::encode_client`]).
    pub fn residual(&self, cid: usize) -> Option<Vec<f32>> {
        self.residuals
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(&cid)
            .cloned()
    }
}

impl Codec for SparseEfCodec {
    fn name(&self) -> String {
        format!("sparse_ef:{}", self.keep)
    }

    fn encode(&self, v: &[f32], _segments: &[Segment]) -> Result<Message> {
        let mut keep_idx = top_k_indices(v, self.kept_count(v.len()));
        keep_idx.sort_unstable();
        Ok(Message {
            payload: encode_bitmap_payload(v, &keep_idx),
            codec: self.name(),
        })
    }

    fn encode_client(
        &self,
        cid: usize,
        v: &[f32],
        _segments: &[Segment],
    ) -> Result<Message> {
        // A poisoned lock means some upload panicked mid-update: the
        // residual state may be half-written, and silently continuing
        // would corrupt every later round's stream. Fail the upload
        // loudly instead of panicking the whole round.
        let mut map = self.residuals.lock().map_err(|_| {
            Error::invalid(
                "sparse_ef: residual state poisoned by an earlier panic",
            )
        })?;
        let residual =
            map.entry(cid).or_insert_with(|| vec![0.0f32; v.len()]);
        if residual.len() != v.len() {
            // A rank change mid-run cannot happen today (tier
            // assignment is static); a stale residual would silently
            // corrupt the stream, so fail loudly.
            return Err(Error::invalid(format!(
                "sparse_ef: client {cid} residual dim {} vs upload {}",
                residual.len(),
                v.len()
            )));
        }
        let corrected = kernels::vadd(v, residual);
        let mut keep_idx =
            top_k_indices(&corrected, self.kept_count(corrected.len()));
        keep_idx.sort_unstable();
        // New residual = corrected with the transmitted entries zeroed.
        residual.copy_from_slice(&corrected);
        for &i in &keep_idx {
            residual[i as usize] = 0.0;
        }
        Ok(Message {
            payload: encode_bitmap_payload(&corrected, &keep_idx),
            codec: self.name(),
        })
    }

    fn decode(&self, msg: &Message, _segments: &[Segment]) -> Result<Vec<f32>> {
        decode_bitmap_payload(&msg.payload, "sparse_ef")
    }

    fn decode_into(
        &self,
        msg: &Message,
        _segments: &[Segment],
        acc: &mut [f32],
        w: f32,
    ) -> Result<()> {
        fold_bitmap_payload(&msg.payload, "sparse_ef", acc, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn topk_keeps_largest() {
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05, 1.0];
        let c = TopKCodec::new(0.5);
        let out = c.decode(&c.encode(&v, &[]).unwrap(), &[]).unwrap();
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn topk_size_formula() {
        let v = randv(1000, 1);
        let c = TopKCodec::new(0.6);
        let msg = c.encode(&v, &[]).unwrap();
        assert_eq!(msg.size_bytes(), 8 + 125 + 600 * 4);
    }

    #[test]
    fn topk_keep_one_and_all() {
        let v = randv(64, 2);
        let all = TopKCodec::new(1.0);
        assert_eq!(all.decode(&all.encode(&v, &[]).unwrap(), &[]).unwrap(), v);
        let one = TopKCodec::new(1e-9);
        let out = one.decode(&one.encode(&v, &[]).unwrap(), &[]).unwrap();
        assert_eq!(out.iter().filter(|&&x| x != 0.0).count(), 1);
    }

    /// The `n = 0` edge the kept_count audit found: `clamp(1, 0)`
    /// panics, so empty vectors must short-circuit to zero survivors —
    /// and the wire format must round-trip them (8-byte header only).
    #[test]
    fn kept_count_edge_cases() {
        for keep in [1e-9f32, 0.5, 1.0] {
            assert_eq!(TopKCodec::new(keep).kept_count(0), 0, "{keep}");
            assert_eq!(SparseEfCodec::new(keep).kept_count(0), 0, "{keep}");
            assert_eq!(TopKCodec::new(keep).kept_count(1), 1, "{keep}");
        }
        assert_eq!(ZeroFlCodec::new(0.9, 0.2).kept_count(0), 0);
        assert_eq!(ZeroFlCodec::new(0.999, 0.0).kept_count(1), 1);
        // keep = 1.0 keeps everything, tiny keep keeps exactly one.
        assert_eq!(TopKCodec::new(1.0).kept_count(777), 777);
        assert_eq!(TopKCodec::new(1e-9).kept_count(777), 1);
        for codec in [&TopKCodec::new(0.5) as &dyn Codec,
                      &ZeroFlCodec::new(0.5, 0.0),
                      &SparseEfCodec::new(0.5)] {
            let msg = codec.encode(&[], &[]).unwrap();
            assert_eq!(msg.size_bytes(), 8, "{}", codec.name());
            assert_eq!(codec.decode(&msg, &[]).unwrap(), Vec::<f32>::new());
        }
    }

    #[test]
    #[should_panic(expected = "keep fraction")]
    fn topk_rejects_zero_keep() {
        TopKCodec::new(0.0);
    }

    #[test]
    #[should_panic(expected = "keep fraction")]
    fn topk_rejects_nan_keep() {
        TopKCodec::new(f32::NAN);
    }

    #[test]
    #[should_panic(expected = "keep fraction")]
    fn sparse_ef_rejects_oversized_keep() {
        SparseEfCodec::new(1.5);
    }

    #[test]
    fn zerofl_fraction_and_size() {
        let c = ZeroFlCodec::new(0.9, 0.2);
        assert!((c.kept_fraction() - 0.28).abs() < 1e-6);
        let v = randv(1000, 3);
        let msg = c.encode(&v, &[]).unwrap();
        assert_eq!(msg.size_bytes(), 8 + 280 * 8);
    }

    #[test]
    fn zerofl_preserves_top_values() {
        let v = randv(500, 4);
        let c = ZeroFlCodec::new(0.9, 0.0);
        let out = c.decode(&c.encode(&v, &[]).unwrap(), &[]).unwrap();
        let kept: Vec<usize> =
            (0..v.len()).filter(|&i| out[i] != 0.0).collect();
        assert_eq!(kept.len(), 50);
        let min_kept = kept.iter().map(|&i| v[i].abs()).fold(f32::INFINITY,
                                                             f32::min);
        let max_dropped = (0..v.len())
            .filter(|&i| out[i] == 0.0)
            .map(|i| v[i].abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped);
        for &i in &kept {
            assert_eq!(out[i], v[i]);
        }
    }

    #[test]
    fn corrupted_payloads_rejected() {
        let v = randv(64, 5);
        let tk = TopKCodec::new(0.5);
        let mut m = tk.encode(&v, &[]).unwrap();
        m.payload.truncate(10);
        assert!(tk.decode(&m, &[]).is_err());

        let zf = ZeroFlCodec::new(0.5, 0.0);
        let mut m = zf.encode(&v, &[]).unwrap();
        m.payload.push(0);
        assert!(zf.decode(&m, &[]).is_err());
        // Out-of-range index.
        let mut m = zf.encode(&v, &[]).unwrap();
        m.payload[8..12].copy_from_slice(&1000u32.to_le_bytes());
        assert!(zf.decode(&m, &[]).is_err());

        let ef = SparseEfCodec::new(0.5);
        let mut m = ef.encode(&v, &[]).unwrap();
        m.payload.push(0);
        let err = ef.decode(&m, &[]).unwrap_err().to_string();
        assert!(err.contains("sparse_ef"), "{err}");
    }

    #[test]
    fn sparse_ef_first_upload_matches_plain_topk() {
        let v = randv(256, 6);
        let ef = SparseEfCodec::new(0.25);
        let tk = TopKCodec::new(0.25);
        // No residual yet: the corrected vector is v itself.
        let from_ef =
            ef.decode(&ef.encode_client(3, &v, &[]).unwrap(), &[]).unwrap();
        let from_tk = tk.decode(&tk.encode(&v, &[]).unwrap(), &[]).unwrap();
        assert_eq!(from_ef, from_tk);
    }

    #[test]
    fn sparse_ef_residual_conserves_mass() {
        let ef = SparseEfCodec::new(0.25);
        let mut carried = vec![0.0f32; 200];
        for round in 0..5 {
            let v = randv(200, 100 + round);
            let sent = ef
                .decode(&ef.encode_client(7, &v, &[]).unwrap(), &[])
                .unwrap();
            let residual = ef.residual(7).unwrap();
            // sent + residual' == v + residual, bit-for-bit.
            for i in 0..200 {
                let expect = v[i] + carried[i];
                assert_eq!(sent[i] + residual[i], expect, "round {round} i {i}");
                // And the partition is strict: one side is zero.
                assert!(sent[i] == 0.0 || residual[i] == 0.0);
            }
            carried = residual;
        }
        // A dropped round (no upload) leaves the residual untouched.
        assert_eq!(ef.residual(7).unwrap(), carried);
        assert!(ef.residual(9).is_none(), "unseen client has no residual");
    }

    #[test]
    fn sparse_ef_residuals_are_per_client() {
        let ef = SparseEfCodec::new(0.5);
        let (a, b) = (randv(64, 8), randv(64, 9));
        ef.encode_client(0, &a, &[]).unwrap();
        ef.encode_client(1, &b, &[]).unwrap();
        assert_ne!(ef.residual(0).unwrap(), ef.residual(1).unwrap());
        // Client order cannot matter: fresh codec, swapped order.
        let ef2 = SparseEfCodec::new(0.5);
        ef2.encode_client(1, &b, &[]).unwrap();
        ef2.encode_client(0, &a, &[]).unwrap();
        assert_eq!(ef.residual(0), ef2.residual(0));
        assert_eq!(ef.residual(1), ef2.residual(1));
    }

    #[test]
    fn sparse_ef_rejects_dim_change() {
        let ef = SparseEfCodec::new(0.5);
        ef.encode_client(0, &randv(64, 10), &[]).unwrap();
        assert!(ef.encode_client(0, &randv(32, 11), &[]).is_err());
    }

    /// A panic while holding the residual lock (simulated directly —
    /// the codec itself never panics under the lock) must not corrupt
    /// later rounds: the write path refuses with a descriptive error,
    /// the read-only accessor still serves the last snapshot.
    #[test]
    fn sparse_ef_poisoned_lock_fails_loudly_not_silently() {
        use crate::sync::{thread, Arc};

        let ef = Arc::new(SparseEfCodec::new(0.5));
        let v = randv(16, 20);
        ef.encode_client(0, &v, &[]).unwrap();
        let before = ef.residual(0).unwrap();

        let poisoner = Arc::clone(&ef);
        let handle = thread::spawn(move || {
            let _guard = poisoner.residuals.lock().unwrap();
            panic!("simulated panic while holding the residual lock");
        });
        assert!(handle.join().is_err(), "the poisoner must have panicked");

        let err = ef
            .encode_client(0, &randv(16, 21), &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("poisoned"), "{err}");
        // Diagnostics stay readable, and untouched by the refusal.
        assert_eq!(ef.residual(0).unwrap(), before);
        // The stateless broadcast path never touches the residual
        // lock, so it keeps working.
        ef.encode(&v, &[]).unwrap();
    }

    /// Zero-length vectors: legal end to end — header-only message,
    /// empty residual, and the dim guard still fires on a later
    /// non-empty upload from the same client.
    #[test]
    fn sparse_ef_zero_length_roundtrip() {
        let ef = SparseEfCodec::new(0.5);
        let msg = ef.encode_client(4, &[], &[]).unwrap();
        assert_eq!(msg.size_bytes(), 8, "empty upload is header-only");
        assert_eq!(ef.decode(&msg, &[]).unwrap(), Vec::<f32>::new());
        assert_eq!(ef.residual(4).unwrap(), Vec::<f32>::new());
        // Re-encoding empty is stable...
        ef.encode_client(4, &[], &[]).unwrap();
        assert_eq!(ef.residual(4).unwrap(), Vec::<f32>::new());
        // ...and growing the dim later is still a loud error.
        assert!(ef.encode_client(4, &randv(8, 22), &[]).is_err());
    }

    /// Same-cid re-encode within a round (an upload retry): the second
    /// encode sees the residual the first one left, and conservation
    /// holds across the pair — retries delay mass, never lose it.
    #[test]
    fn sparse_ef_same_cid_reencode_conserves_mass() {
        let ef = SparseEfCodec::new(0.25);
        let v = randv(64, 23);

        let sent1 =
            ef.decode(&ef.encode_client(5, &v, &[]).unwrap(), &[]).unwrap();
        let r1 = ef.residual(5).unwrap();
        for i in 0..64 {
            assert_eq!(sent1[i] + r1[i], v[i], "first upload conserves v");
        }

        let sent2 =
            ef.decode(&ef.encode_client(5, &v, &[]).unwrap(), &[]).unwrap();
        let r2 = ef.residual(5).unwrap();
        for i in 0..64 {
            // sent2 + r2 == v + r1, bit-for-bit, with a strict
            // kept/dropped partition — exactly the cross-round
            // invariant, applied within a round.
            assert_eq!(sent2[i] + r2[i], v[i] + r1[i], "i {i}");
            assert!(sent2[i] == 0.0 || r2[i] == 0.0);
        }
    }
}
