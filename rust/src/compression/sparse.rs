//! Sparse baselines the paper compares against in Table IV.
//!
//! * [`TopKCodec`] — Magnitude Pruning [4]: keep the global top-`keep`
//!   fraction by |w|; wire format = presence bitmap (1 bit/element) +
//!   surviving values in f32. A 40% prune of ResNet-18 gives
//!   0.6·44.7 MB + 1.4 MB bitmap ≈ 28.2 MB vs the paper's 27.1 MB
//!   (they do not itemize mask overhead; shape preserved).
//! * [`ZeroFlCodec`] — ZeroFL [12] with sparsity `SP` and mask ratio
//!   `MR`: uploads the top (1-SP) fraction plus an extra MR·SP slice of
//!   the next-largest entries, as (u32 index, f32 value) pairs — the
//!   8-byte-per-entry encoding reproduces ZeroFL's reported 27.3 MB /
//!   10.1 MB messages for (0.9, 0.2) / (0.9, 0.0).

use crate::compression::{Codec, Message};
use crate::error::{Error, Result};
use crate::model::Segment;

/// Indices of the `k` largest |v| (deterministic tie-break by index).
fn top_k_indices(v: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..v.len() as u32).collect();
    if k >= v.len() {
        return idx;
    }
    idx.select_nth_unstable_by(k, |&a, &b| {
        let ma = v[a as usize].abs();
        let mb = v[b as usize].abs();
        mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

// ---------------------------------------------------------------------------
// Magnitude pruning: bitmap + values
// ---------------------------------------------------------------------------

pub struct TopKCodec {
    keep: f32,
}

impl TopKCodec {
    pub fn new(keep: f32) -> TopKCodec {
        assert!(keep > 0.0 && keep <= 1.0, "keep fraction in (0,1]");
        TopKCodec { keep }
    }

    pub fn kept_count(&self, n: usize) -> usize {
        ((n as f64 * self.keep as f64).round() as usize).clamp(1, n)
    }
}

impl Codec for TopKCodec {
    fn name(&self) -> String {
        format!("topk:{}", self.keep)
    }

    fn encode(&self, v: &[f32], _segments: &[Segment]) -> Result<Message> {
        let k = self.kept_count(v.len());
        let mut keep_idx = top_k_indices(v, k);
        keep_idx.sort_unstable();
        let mut bitmap = vec![0u8; v.len().div_ceil(8)];
        let mut payload = Vec::with_capacity(bitmap.len() + 4 * k + 8);
        payload.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for &i in &keep_idx {
            bitmap[(i / 8) as usize] |= 1 << (i % 8);
        }
        payload.extend_from_slice(&bitmap);
        for &i in &keep_idx {
            payload.extend_from_slice(&v[i as usize].to_le_bytes());
        }
        Ok(Message { payload, codec: self.name() })
    }

    fn decode(&self, msg: &Message, _segments: &[Segment]) -> Result<Vec<f32>> {
        let b = &msg.payload;
        if b.len() < 8 {
            return Err(Error::parse("topk: truncated header"));
        }
        let n = u64::from_le_bytes(b[..8].try_into().unwrap()) as usize;
        let bm_len = n.div_ceil(8);
        if b.len() < 8 + bm_len {
            return Err(Error::parse("topk: truncated bitmap"));
        }
        let bitmap = &b[8..8 + bm_len];
        let mut out = vec![0.0f32; n];
        let mut pos = 8 + bm_len;
        for (i, slot) in out.iter_mut().enumerate() {
            if bitmap[i / 8] & (1 << (i % 8)) != 0 {
                if pos + 4 > b.len() {
                    return Err(Error::parse("topk: truncated values"));
                }
                *slot = f32::from_le_bytes(b[pos..pos + 4].try_into().unwrap());
                pos += 4;
            }
        }
        if pos != b.len() {
            return Err(Error::parse("topk: trailing bytes"));
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// ZeroFL-style: (index, value) pairs
// ---------------------------------------------------------------------------

pub struct ZeroFlCodec {
    sp: f32,
    mask_ratio: f32,
}

impl ZeroFlCodec {
    pub fn new(sp: f32, mask_ratio: f32) -> ZeroFlCodec {
        assert!((0.0..1.0).contains(&sp));
        assert!((0.0..=1.0).contains(&mask_ratio));
        ZeroFlCodec { sp, mask_ratio }
    }

    /// Uploaded fraction: the dense (1-SP) slice plus MR of the pruned
    /// SP slice (ZeroFL's "sparsity + mask" upload policy).
    pub fn kept_fraction(&self) -> f64 {
        (1.0 - self.sp as f64) + self.mask_ratio as f64 * self.sp as f64
    }

    pub fn kept_count(&self, n: usize) -> usize {
        ((n as f64 * self.kept_fraction()).round() as usize).clamp(1, n)
    }
}

impl Codec for ZeroFlCodec {
    fn name(&self) -> String {
        format!("zerofl:{}:{}", self.sp, self.mask_ratio)
    }

    fn encode(&self, v: &[f32], _segments: &[Segment]) -> Result<Message> {
        let k = self.kept_count(v.len());
        let mut keep_idx = top_k_indices(v, k);
        keep_idx.sort_unstable();
        let mut payload = Vec::with_capacity(8 + 8 * k);
        payload.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for &i in &keep_idx {
            payload.extend_from_slice(&i.to_le_bytes());
            payload.extend_from_slice(&v[i as usize].to_le_bytes());
        }
        Ok(Message { payload, codec: self.name() })
    }

    fn decode(&self, msg: &Message, _segments: &[Segment]) -> Result<Vec<f32>> {
        let b = &msg.payload;
        if b.len() < 8 || (b.len() - 8) % 8 != 0 {
            return Err(Error::parse("zerofl: bad payload length"));
        }
        let n = u64::from_le_bytes(b[..8].try_into().unwrap()) as usize;
        let mut out = vec![0.0f32; n];
        for pair in b[8..].chunks_exact(8) {
            let i = u32::from_le_bytes(pair[..4].try_into().unwrap()) as usize;
            if i >= n {
                return Err(Error::parse(format!("zerofl: index {i} >= {n}")));
            }
            out[i] = f32::from_le_bytes(pair[4..].try_into().unwrap());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn topk_keeps_largest() {
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05, 1.0];
        let c = TopKCodec::new(0.5);
        let out = c.decode(&c.encode(&v, &[]).unwrap(), &[]).unwrap();
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn topk_size_formula() {
        let v = randv(1000, 1);
        let c = TopKCodec::new(0.6);
        let msg = c.encode(&v, &[]).unwrap();
        assert_eq!(msg.size_bytes(), 8 + 125 + 600 * 4);
    }

    #[test]
    fn topk_keep_one_and_all() {
        let v = randv(64, 2);
        let all = TopKCodec::new(1.0);
        assert_eq!(all.decode(&all.encode(&v, &[]).unwrap(), &[]).unwrap(), v);
        let one = TopKCodec::new(1e-9);
        let out = one.decode(&one.encode(&v, &[]).unwrap(), &[]).unwrap();
        assert_eq!(out.iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn zerofl_fraction_and_size() {
        let c = ZeroFlCodec::new(0.9, 0.2);
        assert!((c.kept_fraction() - 0.28).abs() < 1e-6);
        let v = randv(1000, 3);
        let msg = c.encode(&v, &[]).unwrap();
        assert_eq!(msg.size_bytes(), 8 + 280 * 8);
    }

    #[test]
    fn zerofl_preserves_top_values() {
        let v = randv(500, 4);
        let c = ZeroFlCodec::new(0.9, 0.0);
        let out = c.decode(&c.encode(&v, &[]).unwrap(), &[]).unwrap();
        let kept: Vec<usize> =
            (0..v.len()).filter(|&i| out[i] != 0.0).collect();
        assert_eq!(kept.len(), 50);
        let min_kept = kept.iter().map(|&i| v[i].abs()).fold(f32::INFINITY,
                                                             f32::min);
        let max_dropped = (0..v.len())
            .filter(|&i| out[i] == 0.0)
            .map(|i| v[i].abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped);
        for &i in &kept {
            assert_eq!(out[i], v[i]);
        }
    }

    #[test]
    fn corrupted_payloads_rejected() {
        let v = randv(64, 5);
        let tk = TopKCodec::new(0.5);
        let mut m = tk.encode(&v, &[]).unwrap();
        m.payload.truncate(10);
        assert!(tk.decode(&m, &[]).is_err());

        let zf = ZeroFlCodec::new(0.5, 0.0);
        let mut m = zf.encode(&v, &[]).unwrap();
        m.payload.push(0);
        assert!(zf.decode(&m, &[]).is_err());
        // Out-of-range index.
        let mut m = zf.encode(&v, &[]).unwrap();
        m.payload[8..12].copy_from_slice(&1000u32.to_le_bytes());
        assert!(zf.decode(&m, &[]).is_err());
    }
}
