//! Wire codecs: how a flat parameter vector becomes bytes on the
//! (simulated) network.
//!
//! The paper's method stack maps to:
//! * [`CodecKind::Fp32`]    — FedAvg / FLoCoRA-FP baseline rows.
//! * [`CodecKind::Affine`]  — FLoCoRA + affine RTN quantization (§IV),
//!   per-channel scale/zero-point for convs, per-column for the FC,
//!   norm layers kept FP; 8/4/2-bit packed codes.
//! * [`CodecKind::TopK`]    — Magnitude Pruning baseline [4]: keep the
//!   largest-|w| fraction, bitmap + packed survivors.
//! * [`CodecKind::ZeroFl`]  — ZeroFL-style baseline [12]: SP sparsity +
//!   mask-ratio extra upload, (index, value)-pair encoding.
//! * [`CodecKind::SparseEf`] — FLASC-style sparse LoRA upload with
//!   per-client error-feedback residuals (aggregation zoo): top-k
//!   masks where the dropped mass re-enters next round's upload.
//!
//! Every codec is *lossy-transparent*: `decode(encode(v))` returns a
//! dense vector the aggregator can consume; message size is the exact
//! byte length of the encoded payload (no hidden framing).

pub mod affine;
pub mod pack;
pub mod sparse;

use crate::error::{Error, Result};
use crate::kernels;
use crate::model::Segment;

pub use affine::AffineCodec;
pub use sparse::{SparseEfCodec, TopKCodec, ZeroFlCodec};

/// An encoded message plus provenance.
#[derive(Debug, Clone)]
pub struct Message {
    pub payload: Vec<u8>,
    pub codec: String,
}

impl Message {
    pub fn size_bytes(&self) -> usize {
        self.payload.len()
    }
}

/// A parameter-vector codec.
///
/// Implementations must be `Send + Sync`: the parallel round engine
/// shares one codec instance across all client-executor threads. Most
/// implementations are stateless, so encode/decode are naturally
/// reentrant; the stateful exception ([`SparseEfCodec`]'s per-client
/// residuals) keys its state on the client id via
/// [`Codec::encode_client`], which the engine calls with each upload —
/// one writer per client per round, so thread scheduling cannot
/// perturb it.
///
/// ```
/// use flocora::compression::{Codec, CodecKind};
/// use flocora::model::{ParamKind, Segment};
///
/// // Parse a wire format the same way the CLI does, then round-trip a
/// // vector through it. `segments` must describe `v`'s layout (their
/// // `numel`s sum to `v.len()`); real layouts come from the manifest
/// // or `model::build_spec`. Codecs are lossy-transparent: decode
/// // always returns a dense vector of the layout's total length.
/// let seg = Segment {
///     name: "fc_w".into(),
///     shape: vec![2, 2],
///     numel: 4,
///     kind: ParamKind::FcW,
///     offset: 0,
///     quant_rows: None,
/// };
/// let codec = CodecKind::parse("fp32").unwrap().build();
/// let v = vec![1.0f32, -2.5, 0.25, 3.0];
/// let msg = codec.encode(&v, std::slice::from_ref(&seg)).unwrap();
/// assert_eq!(msg.size_bytes(), v.len() * 4);
/// assert_eq!(codec.decode(&msg, std::slice::from_ref(&seg)).unwrap(), v);
/// ```
pub trait Codec: Send + Sync {
    fn name(&self) -> String;

    /// Encode `v` (layout described by `segments`, whose `numel`s must
    /// sum to `v.len()`).
    fn encode(&self, v: &[f32], segments: &[Segment]) -> Result<Message>;

    /// Encode client `cid`'s *upload*. The default forwards to
    /// [`Codec::encode`]; stateful codecs (error feedback) override it
    /// to key per-client accumulators on the id. Broadcasts always use
    /// the plain `encode` — the server has no client identity.
    fn encode_client(
        &self,
        _cid: usize,
        v: &[f32],
        segments: &[Segment],
    ) -> Result<Message> {
        self.encode(v, segments)
    }

    /// Decode back to a dense vector of the layout's total length.
    fn decode(&self, msg: &Message, segments: &[Segment]) -> Result<Vec<f32>>;

    /// Decode `msg` and fold it straight into `acc` with weight `w`:
    /// `acc[i] += w * decoded[i]` — the zero-copy merge path. The
    /// default materializes via [`Codec::decode`] and folds; codecs
    /// with streaming decoders override it to skip the intermediate
    /// vector entirely.
    ///
    /// Contract: bit-identical to `decode` followed by the weighted
    /// fold. Overrides keep it by running the same per-element float
    /// ops on the same operands in the same element order (sparse
    /// overrides may skip absent slots: folding `w * 0.0` into an
    /// accumulator that is not `-0.0` is a bitwise no-op, and FedAvg
    /// accumulators never hold `-0.0` — they start at `+0.0` and
    /// round-to-nearest addition cannot produce `-0.0` from it).
    /// `tests/properties.rs` pins the equivalence for every codec.
    ///
    /// On error the accumulator contents are unspecified (a streaming
    /// override may have partially folded before detecting a corrupt
    /// tail); callers treat a failed fold as fatal to the round.
    fn decode_into(
        &self,
        msg: &Message,
        segments: &[Segment],
        acc: &mut [f32],
        w: f32,
    ) -> Result<()> {
        let v = self.decode(msg, segments)?;
        check_fold_dim(v.len(), acc.len())?;
        kernels::axpy(acc, &v, w);
        Ok(())
    }
}

/// Shared dimension guard for [`Codec::decode_into`] implementations.
pub(crate) fn check_fold_dim(decoded: usize, acc: usize) -> Result<()> {
    if decoded != acc {
        return Err(Error::invalid(format!(
            "decode_into: decoded {decoded} elements into a {acc}-dim \
             accumulator"
        )));
    }
    Ok(())
}

/// Plain little-endian fp32 — the uncompressed baseline (Q_p = 32).
pub struct Fp32Codec;

impl Codec for Fp32Codec {
    fn name(&self) -> String {
        "fp32".into()
    }

    fn encode(&self, v: &[f32], _segments: &[Segment]) -> Result<Message> {
        let mut payload = Vec::with_capacity(v.len() * 4);
        for x in v {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Message { payload, codec: self.name() })
    }

    fn decode(&self, msg: &Message, _segments: &[Segment]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(msg.payload.len() / 4);
        for chunk in msg.payload.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(out)
    }

    fn decode_into(
        &self,
        msg: &Message,
        _segments: &[Segment],
        acc: &mut [f32],
        w: f32,
    ) -> Result<()> {
        check_fold_dim(msg.payload.len() / 4, acc.len())?;
        kernels::axpy_from_le(&msg.payload[..acc.len() * 4], w, acc);
        Ok(())
    }
}

/// Codec selection, parseable from CLI/config strings.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CodecKind {
    Fp32,
    /// bits ∈ {2, 4, 8}
    Affine(u32),
    /// keep fraction ∈ (0, 1]; paper rows: 0.6 (40% prune), 0.2 (80%).
    TopK(f32),
    /// (sparsity SP, mask ratio MR); paper rows: (0.9, 0.2), (0.9, 0.0).
    ZeroFl(f32, f32),
    /// keep fraction ∈ (0, 1] with per-client error-feedback residuals
    /// on the upload path.
    SparseEf(f32),
}

impl CodecKind {
    /// Parse `fp32 | q8 | q4 | q2 | topk:<keep> | zerofl:<sp>:<mr> |
    /// sparse_ef:<keep>`. Out-of-range or non-finite parameters are a
    /// parse failure, not a deferred panic in the constructor —
    /// `topk:nan` used to parse here and abort at build time.
    pub fn parse(s: &str) -> Option<CodecKind> {
        match s {
            "fp32" => return Some(CodecKind::Fp32),
            "q8" => return Some(CodecKind::Affine(8)),
            "q4" => return Some(CodecKind::Affine(4)),
            "q2" => return Some(CodecKind::Affine(2)),
            _ => {}
        }
        let keep_ok = |k: &f32| k.is_finite() && *k > 0.0 && *k <= 1.0;
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["topk", keep] => {
                keep.parse().ok().filter(keep_ok).map(CodecKind::TopK)
            }
            ["sparse_ef", keep] => {
                keep.parse().ok().filter(keep_ok).map(CodecKind::SparseEf)
            }
            ["zerofl", sp, mr] => {
                let sp: f32 = sp.parse().ok()?;
                let mr: f32 = mr.parse().ok()?;
                if !sp.is_finite() || !(0.0..1.0).contains(&sp) {
                    return None;
                }
                if !mr.is_finite() || !(0.0..=1.0).contains(&mr) {
                    return None;
                }
                Some(CodecKind::ZeroFl(sp, mr))
            }
            _ => None,
        }
    }

    pub fn build(&self) -> Box<dyn Codec> {
        match *self {
            CodecKind::Fp32 => Box::new(Fp32Codec),
            CodecKind::Affine(bits) => Box::new(AffineCodec::new(bits)),
            CodecKind::TopK(keep) => Box::new(TopKCodec::new(keep)),
            CodecKind::ZeroFl(sp, mr) => Box::new(ZeroFlCodec::new(sp, mr)),
            CodecKind::SparseEf(keep) => Box::new(SparseEfCodec::new(keep)),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            CodecKind::Fp32 => "fp32".into(),
            CodecKind::Affine(b) => format!("q{b}"),
            CodecKind::TopK(k) => format!("topk:{k}"),
            CodecKind::ZeroFl(sp, mr) => format!("zerofl:{sp}:{mr}"),
            CodecKind::SparseEf(k) => format!("sparse_ef:{k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_spec, ModelCfg, Variant};
    use crate::util::rng::Rng;

    fn test_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn fp32_round_trip_exact() {
        let spec = build_spec(ModelCfg::by_name("micro8").unwrap(),
                              Variant::LoraFc, 4);
        let v = test_vec(spec.num_trainable(), 1);
        let c = Fp32Codec;
        let msg = c.encode(&v, &spec.trainable).unwrap();
        assert_eq!(msg.size_bytes(), v.len() * 4);
        assert_eq!(c.decode(&msg, &spec.trainable).unwrap(), v);
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(CodecKind::parse("fp32"), Some(CodecKind::Fp32));
        assert_eq!(CodecKind::parse("q4"), Some(CodecKind::Affine(4)));
        assert_eq!(CodecKind::parse("topk:0.6"), Some(CodecKind::TopK(0.6)));
        assert_eq!(CodecKind::parse("zerofl:0.9:0.2"),
                   Some(CodecKind::ZeroFl(0.9, 0.2)));
        assert_eq!(CodecKind::parse("sparse_ef:0.25"),
                   Some(CodecKind::SparseEf(0.25)));
        assert_eq!(CodecKind::parse("nope"), None);
        assert_eq!(CodecKind::parse("topk:x"), None);
    }

    #[test]
    fn kind_parsing_rejects_out_of_range_params() {
        // These used to parse and then abort inside the constructor.
        for s in ["topk:nan", "topk:0", "topk:-0.5", "topk:1.5", "topk:inf",
                  "sparse_ef:nan", "sparse_ef:0", "sparse_ef:2",
                  "zerofl:nan:0.2", "zerofl:1.0:0.2", "zerofl:-0.1:0.2",
                  "zerofl:0.9:nan", "zerofl:0.9:1.5", "zerofl:0.9:-0.1"] {
            assert_eq!(CodecKind::parse(s), None, "{s}");
        }
        // Boundary values that are valid stay valid.
        assert!(CodecKind::parse("topk:1.0").is_some());
        assert!(CodecKind::parse("zerofl:0.0:1.0").is_some());
        assert!(CodecKind::parse("sparse_ef:1.0").is_some());
    }

    #[test]
    fn all_kinds_round_trip_to_correct_length() {
        let spec = build_spec(ModelCfg::by_name("micro8").unwrap(),
                              Variant::LoraFc, 4);
        let v = test_vec(spec.num_trainable(), 2);
        for kind in [CodecKind::Fp32, CodecKind::Affine(8),
                     CodecKind::Affine(4), CodecKind::Affine(2),
                     CodecKind::TopK(0.5), CodecKind::ZeroFl(0.9, 0.2),
                     CodecKind::SparseEf(0.25)] {
            let c = kind.build();
            let msg = c.encode(&v, &spec.trainable).unwrap();
            let out = c.decode(&msg, &spec.trainable).unwrap();
            assert_eq!(out.len(), v.len(), "{:?}", kind);
            // The client path round-trips to the same length too.
            let msg = c.encode_client(3, &v, &spec.trainable).unwrap();
            let out = c.decode(&msg, &spec.trainable).unwrap();
            assert_eq!(out.len(), v.len(), "{:?} client path", kind);
        }
    }
}
