//! Affine RTN quantization codec — the paper's §IV scheme, byte-exact
//! with the L1 pallas kernel (`python/compile/kernels/quant.py`):
//!
//! ```text
//! lo = min(w);  hi = max(w)                 (true per-row range)
//! scale = (hi - lo) / (2^bits - 1)          (1.0 if the row is constant)
//! zp    = -lo / scale                       (real-valued, f32 on the wire)
//! q     = clip(floor((w - lo) / scale + 0.5), 0, 2^bits - 1)
//! deq   = (q - zp) * scale
//! ```
//!
//! The range is the row's *actual* min/max — an earlier revision
//! anchored it at 0 (`min(min(w), 0)`, `max(max(w), 0)`), which
//! inflated the quantization step for every all-positive or
//! all-negative row (e.g. a row in `[10.0, 10.6]` paid a step sized
//! for `[0, 10.6]`). With the true range the zero-point is fractional,
//! so it travels as plain f32 (it always did on the wire) instead of
//! being rounded into the grid, and the RTN error stays `<= scale/2`
//! for every row: `(w - lo)/scale` lands in `[0, qmax]` by
//! construction, so the clip never bites. Constant rows round-trip
//! exactly (`scale = 1`, `zp = -lo`, `q = 0`).
//!
//! Grouping follows the paper: per *channel* for conv-shaped tensors,
//! per *column* for the FC (both expressed as `Segment::quant_rows` —
//! the leading dim after the python side reshapes); normalization
//! layers (`quant_rows == None`) travel in fp32.
//!
//! Wire format, per segment, in layout order:
//! * quantized segment: `[scales f32 x rows][zps f32 x rows][codes
//!   packed bits]` (scales and zero-points in f32, exactly the
//!   overhead the paper says it includes in its TCC numbers)
//! * fp segment: raw f32 little-endian.
//!
//! The per-row loops (range scan, code mapping, dequantize, and the
//! fused dequantize-accumulate behind [`Codec::decode_into`]) live in
//! [`crate::kernels`]. An `Engine::quant_oracle` integration test
//! asserts `decode(encode(x)) == HLO fake_quant(x)` to float
//! tolerance.

use crate::compression::pack::packed_len;
use crate::compression::{check_fold_dim, Codec, Message};
use crate::error::{Error, Result};
use crate::kernels;
use crate::model::Segment;

pub struct AffineCodec {
    bits: u32,
}

impl AffineCodec {
    pub fn new(bits: u32) -> AffineCodec {
        assert!(matches!(bits, 2 | 4 | 8), "supported widths: 2/4/8");
        AffineCodec { bits }
    }

    fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }
}

/// Per-row affine parameters from the row's true range: `(scale, zp)`
/// with `scale = (hi - lo)/qmax` (1.0 for constant or empty rows) and
/// the real-valued zero-point `zp = -lo/scale`.
fn row_params(lo: f32, hi: f32, qmax: f32) -> (f32, f32) {
    let rng = hi - lo;
    if rng > 0.0 {
        let scale = rng / qmax;
        (scale, -lo / scale)
    } else if lo.is_finite() {
        // Constant row: any value is exactly representable as code 0.
        (1.0, -lo)
    } else {
        // Empty row (minmax returned the +/-inf seeds): nothing to
        // encode, keep the wire parameters finite.
        (1.0, 0.0)
    }
}

/// Exact encoded size of one segment under `bits` (used by the analytic
/// TCC calculators — keep in sync with `encode`).
pub fn segment_encoded_size(seg: &Segment, bits: u32) -> usize {
    match seg.quant_rows {
        None => seg.numel * 4,
        Some(rows) => rows * 8 + packed_len(seg.numel, bits),
    }
}

/// Validate a quantized segment's row layout before using it to slice
/// the payload: `quant_rows == Some(0)` would divide by zero, and a
/// row count that does not divide `numel` would silently mis-shape
/// every row after the first (a `debug_assert` before this fix — i.e.
/// unchecked in release builds). Malformed layouts come from corrupt
/// manifests, so they are an [`Error::invalid`], not a panic.
fn check_quant_rows(seg: &Segment, rows: usize, dir: &str) -> Result<()> {
    if rows == 0 || seg.numel % rows != 0 {
        return Err(Error::invalid(format!(
            "affine {dir}: segment {} has a malformed quant layout: \
             {} elements in {rows} rows",
            seg.name, seg.numel
        )));
    }
    Ok(())
}

/// Read one little-endian f32, advancing `pos`.
fn rd_f32(b: &[u8], pos: &mut usize) -> Result<f32> {
    if *pos + 4 > b.len() {
        return Err(Error::parse("affine decode: truncated payload"));
    }
    let v = f32::from_le_bytes(b[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

/// Per-quantized-segment header: scales then zero-points, f32 each.
fn rd_row_params(
    b: &[u8],
    pos: &mut usize,
    rows: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut scales = Vec::with_capacity(rows);
    let mut zps = Vec::with_capacity(rows);
    for _ in 0..rows {
        scales.push(rd_f32(b, pos)?);
    }
    for _ in 0..rows {
        zps.push(rd_f32(b, pos)?);
    }
    Ok((scales, zps))
}

impl Codec for AffineCodec {
    fn name(&self) -> String {
        format!("q{}", self.bits)
    }

    fn encode(&self, v: &[f32], segments: &[Segment]) -> Result<Message> {
        let total: usize = segments.iter().map(|s| s.numel).sum();
        if total != v.len() {
            return Err(Error::invalid(format!(
                "affine encode: layout {} vs vector {}",
                total,
                v.len()
            )));
        }
        let qmax = self.qmax();
        let mut payload = Vec::new();
        let mut codes: Vec<u8> = Vec::new();
        for seg in segments {
            let data = &v[seg.offset..seg.offset + seg.numel];
            match seg.quant_rows {
                None => {
                    for x in data {
                        payload.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Some(rows) => {
                    check_quant_rows(seg, rows, "encode")?;
                    let cols = seg.numel / rows;
                    codes.clear();
                    codes.resize(seg.numel, 0);
                    let mut scales = Vec::with_capacity(rows);
                    let mut zps = Vec::with_capacity(rows);
                    for (row, out) in data
                        .chunks_exact(cols)
                        .zip(codes.chunks_exact_mut(cols))
                    {
                        let (lo, hi) = kernels::minmax(row);
                        let (scale, zp) = row_params(lo, hi, qmax);
                        kernels::quant_codes(row, lo, scale, qmax, out);
                        scales.push(scale);
                        zps.push(zp);
                    }
                    for s in &scales {
                        payload.extend_from_slice(&s.to_le_bytes());
                    }
                    for z in &zps {
                        payload.extend_from_slice(&z.to_le_bytes());
                    }
                    let start = payload.len();
                    payload.resize(start + packed_len(seg.numel, self.bits), 0);
                    kernels::pack_into(&codes, self.bits, &mut payload[start..]);
                }
            }
        }
        Ok(Message { payload, codec: self.name() })
    }

    fn decode(&self, msg: &Message, segments: &[Segment]) -> Result<Vec<f32>> {
        let total: usize = segments.iter().map(|s| s.numel).sum();
        let mut out = vec![0.0f32; total];
        let b = &msg.payload;
        let mut pos = 0usize;
        let mut codes: Vec<u8> = Vec::new();
        for seg in segments {
            let dst = &mut out[seg.offset..seg.offset + seg.numel];
            match seg.quant_rows {
                None => {
                    for d in dst.iter_mut() {
                        *d = rd_f32(b, &mut pos)?;
                    }
                }
                Some(rows) => {
                    check_quant_rows(seg, rows, "decode")?;
                    let cols = seg.numel / rows;
                    let (scales, zps) = rd_row_params(b, &mut pos, rows)?;
                    let plen = packed_len(seg.numel, self.bits);
                    if pos + plen > b.len() {
                        return Err(Error::parse("affine decode: truncated codes"));
                    }
                    codes.clear();
                    codes.resize(seg.numel, 0);
                    kernels::unpack_into(&b[pos..pos + plen], self.bits,
                                         &mut codes);
                    pos += plen;
                    for (r, (crow, drow)) in codes
                        .chunks_exact(cols)
                        .zip(dst.chunks_exact_mut(cols))
                        .enumerate()
                    {
                        kernels::dequant(crow, scales[r], zps[r], drow);
                    }
                }
            }
        }
        if pos != b.len() {
            return Err(Error::parse(format!(
                "affine decode: {} trailing bytes",
                b.len() - pos
            )));
        }
        Ok(out)
    }

    /// Streaming decode-and-fold: dequantized rows go straight into
    /// the accumulator via the fused [`kernels::dequant_axpy`] — the
    /// dense per-client vector never materializes.
    fn decode_into(
        &self,
        msg: &Message,
        segments: &[Segment],
        acc: &mut [f32],
        w: f32,
    ) -> Result<()> {
        let total: usize = segments.iter().map(|s| s.numel).sum();
        check_fold_dim(total, acc.len())?;
        let b = &msg.payload;
        let mut pos = 0usize;
        let mut codes: Vec<u8> = Vec::new();
        for seg in segments {
            let dst = &mut acc[seg.offset..seg.offset + seg.numel];
            match seg.quant_rows {
                None => {
                    for d in dst.iter_mut() {
                        *d += w * rd_f32(b, &mut pos)?;
                    }
                }
                Some(rows) => {
                    check_quant_rows(seg, rows, "decode")?;
                    let cols = seg.numel / rows;
                    let (scales, zps) = rd_row_params(b, &mut pos, rows)?;
                    let plen = packed_len(seg.numel, self.bits);
                    if pos + plen > b.len() {
                        return Err(Error::parse("affine decode: truncated codes"));
                    }
                    codes.clear();
                    codes.resize(seg.numel, 0);
                    kernels::unpack_into(&b[pos..pos + plen], self.bits,
                                         &mut codes);
                    pos += plen;
                    for (r, (crow, drow)) in codes
                        .chunks_exact(cols)
                        .zip(dst.chunks_exact_mut(cols))
                        .enumerate()
                    {
                        kernels::dequant_axpy(crow, scales[r], zps[r], w, drow);
                    }
                }
            }
        }
        if pos != b.len() {
            return Err(Error::parse(format!(
                "affine decode: {} trailing bytes",
                b.len() - pos
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamKind;
    use crate::util::rng::Rng;

    fn seg(name: &str, numel: usize, offset: usize,
           quant_rows: Option<usize>) -> Segment {
        Segment { name: name.into(), shape: vec![numel], numel,
                  kind: ParamKind::Conv, offset, quant_rows }
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| 3.0 * rng.normal() as f32).collect()
    }

    #[test]
    fn round_trip_error_bounded_by_half_scale() {
        for bits in [2u32, 4, 8] {
            let c = AffineCodec::new(bits);
            let segs = vec![seg("a", 64, 0, Some(8)), seg("n", 10, 64, None),
                            seg("b", 30, 74, Some(10))];
            let v = randv(104, bits as u64);
            let msg = c.encode(&v, &segs).unwrap();
            let out = c.decode(&msg, &segs).unwrap();
            // fp segment exact:
            assert_eq!(&out[64..74], &v[64..74]);
            // quantized segments bounded by scale/2 per row, with the
            // scale built from the row's *true* range.
            let qmax = ((1u32 << bits) - 1) as f32;
            for (seg_range, rows) in [(0..64, 8), (74..104, 10)] {
                let cols = seg_range.len() / rows;
                for r in 0..rows {
                    let row: Vec<f32> = v[seg_range.clone()]
                        [r * cols..(r + 1) * cols].to_vec();
                    let lo = row.iter().cloned()
                        .fold(f32::INFINITY, f32::min);
                    let hi = row.iter().cloned()
                        .fold(f32::NEG_INFINITY, f32::max);
                    let scale = ((hi - lo) / qmax).max(1e-12);
                    for c_ in 0..cols {
                        let i = seg_range.start + r * cols + c_;
                        assert!((out[i] - v[i]).abs() <= scale * 0.5 + 1e-5,
                                "bits={bits} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn strictly_positive_rows_use_the_true_range() {
        // Regression for the 0-anchored range scan: a row in
        // [10.0, 10.63] must quantize with scale ~ 0.63/qmax, not
        // ~ 10.63/qmax. At 8 bits that is a ~17x tighter error bound
        // than the old scheme could ever meet.
        let c = AffineCodec::new(8);
        let segs = vec![seg("p", 64, 0, Some(1))];
        let v: Vec<f32> = (0..64).map(|i| 10.0 + 0.01 * i as f32).collect();
        let out = c.decode(&c.encode(&v, &segs).unwrap(), &segs).unwrap();
        // 2e-5 slack absorbs the f32 zero-point rounding (zp ~ 4048
        // here, whose ulp scaled back by `scale` is ~1e-6); the old
        // 0-anchored scheme's half-step was ~0.0208, three orders off.
        let true_scale = (10.63 - 10.0) / 255.0;
        for i in 0..64 {
            let err = (out[i] - v[i]).abs();
            assert!(err <= true_scale * 0.5 + 2e-5,
                    "i={i} err={err} vs half-scale {}", true_scale * 0.5);
        }
        // Strictly negative rows get the same treatment.
        let neg: Vec<f32> = v.iter().map(|x| -x).collect();
        let out = c.decode(&c.encode(&neg, &segs).unwrap(), &segs).unwrap();
        for i in 0..64 {
            assert!((out[i] - neg[i]).abs() <= true_scale * 0.5 + 2e-5);
        }
    }

    #[test]
    fn sizes_match_formula() {
        for bits in [2u32, 4, 8] {
            let c = AffineCodec::new(bits);
            let segs = vec![seg("a", 64, 0, Some(8)), seg("n", 10, 64, None)];
            let v = randv(74, 9);
            let msg = c.encode(&v, &segs).unwrap();
            let expect: usize =
                segs.iter().map(|s| segment_encoded_size(s, bits)).sum();
            assert_eq!(msg.size_bytes(), expect);
        }
    }

    #[test]
    fn compression_ratio_roughly_bits_over_32() {
        // For a large all-quantized layout the ratio approaches 32/bits.
        let c = AffineCodec::new(8);
        let segs = vec![seg("a", 64 * 256, 0, Some(64))];
        let v = randv(64 * 256, 3);
        let msg = c.encode(&v, &segs).unwrap();
        let ratio = (v.len() * 4) as f64 / msg.size_bytes() as f64;
        assert!(ratio > 3.7 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    fn constant_rows_round_trip_exactly() {
        let c = AffineCodec::new(8);
        let segs = vec![seg("a", 16, 0, Some(4))];
        let v = vec![-3.0f32; 4].into_iter()
            .chain(vec![0.0; 4])
            .chain(vec![5.0; 4])
            .chain(vec![120.0; 4])
            .collect::<Vec<_>>();
        let out = c.decode(&c.encode(&v, &segs).unwrap(), &segs).unwrap();
        assert_eq!(out, v);
    }

    #[test]
    fn decode_into_matches_decode_then_fold() {
        for bits in [2u32, 4, 8] {
            let c = AffineCodec::new(bits);
            let segs = vec![seg("a", 64, 0, Some(8)), seg("n", 10, 64, None),
                            seg("b", 30, 74, Some(10))];
            let v = randv(104, 40 + bits as u64);
            let msg = c.encode(&v, &segs).unwrap();
            let mut acc = randv(104, 50);
            let mut acc2 = acc.clone();
            c.decode_into(&msg, &segs, &mut acc, 0.73).unwrap();
            let dec = c.decode(&msg, &segs).unwrap();
            crate::kernels::axpy_ref(&mut acc2, &dec, 0.73);
            let same = acc.iter().zip(acc2.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "bits={bits}");
        }
    }

    #[test]
    fn malformed_quant_rows_rejected_not_panicking() {
        let c = AffineCodec::new(8);
        let v = randv(64, 7);
        // rows = 0 used to divide by zero in encode and decode.
        let zero_rows = vec![seg("z", 64, 0, Some(0))];
        assert!(c.encode(&v, &zero_rows).is_err());
        // numel % rows != 0 used to be a debug_assert (unchecked in
        // release): 64 elements in 7 rows mis-shapes every row.
        let ragged = vec![seg("r", 64, 0, Some(7))];
        assert!(c.encode(&v, &ragged).is_err());
        // Decode must reject the same layouts — a valid message
        // decoded against a corrupt manifest, not just a bad encode.
        let good = vec![seg("g", 64, 0, Some(8))];
        let msg = c.encode(&v, &good).unwrap();
        assert!(c.decode(&msg, &zero_rows).is_err());
        assert!(c.decode(&msg, &ragged).is_err());
        let mut acc = vec![0.0f32; 64];
        assert!(c.decode_into(&msg, &zero_rows, &mut acc, 1.0).is_err());
        // The error is typed, not a bare panic/parse failure.
        match c.encode(&v, &zero_rows) {
            Err(crate::error::Error::Invalid(m)) => {
                assert!(m.contains("quant layout"), "{m}");
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn truncated_payload_rejected() {
        let c = AffineCodec::new(4);
        let segs = vec![seg("a", 64, 0, Some(8))];
        let v = randv(64, 4);
        let mut msg = c.encode(&v, &segs).unwrap();
        msg.payload.truncate(msg.payload.len() - 3);
        assert!(c.decode(&msg, &segs).is_err());
        let mut acc = vec![0.0f32; 64];
        assert!(c.decode_into(&msg, &segs, &mut acc, 1.0).is_err());
        msg.payload.extend_from_slice(&[0; 10]);
        assert!(c.decode(&msg, &segs).is_err());
        assert!(c.decode_into(&msg, &segs, &mut acc, 1.0).is_err());
    }
}
