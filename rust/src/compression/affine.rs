//! Affine RTN quantization codec — the paper's §IV scheme, byte-exact
//! with the L1 pallas kernel (`python/compile/kernels/quant.py`):
//!
//! ```text
//! lo = min(min(w), 0);  hi = max(max(w), 0)     (range includes 0)
//! scale = (hi - lo) / (2^bits - 1)          (1.0 if the row is constant)
//! zp    = clip(floor(-min / scale + 0.5), 0, 2^bits - 1)
//! q     = clip(floor(w / scale + 0.5) + zp, 0, 2^bits - 1)
//! deq   = (q - zp) * scale
//! ```
//!
//! Grouping follows the paper: per *channel* for conv-shaped tensors,
//! per *column* for the FC (both expressed as `Segment::quant_rows` —
//! the leading dim after the python side reshapes); normalization
//! layers (`quant_rows == None`) travel in fp32.
//!
//! Wire format, per segment, in layout order:
//! * quantized segment: `[scale f32 x rows][zp u8/u16-packed? no — f32 x rows][codes packed bits]`
//!   (scales and zero-points in f32, exactly the overhead the paper
//!   says it includes in its TCC numbers)
//! * fp segment: raw f32 little-endian.
//!
//! An `Engine::quant_oracle` integration test asserts
//! `decode(encode(x)) == HLO fake_quant(x)` to float tolerance.

use crate::compression::pack::{pack, packed_len, unpack};
use crate::compression::{Codec, Message};
use crate::error::{Error, Result};
use crate::model::Segment;

pub struct AffineCodec {
    bits: u32,
}

impl AffineCodec {
    pub fn new(bits: u32) -> AffineCodec {
        assert!(matches!(bits, 2 | 4 | 8), "supported widths: 2/4/8");
        AffineCodec { bits }
    }

    fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Quantize one row; returns (scale, zp) and appends codes.
    fn quant_row(&self, row: &[f32], codes: &mut Vec<u8>) -> (f32, f32) {
        let qmax = self.qmax();
        // Range extended to include 0 (Nagel et al. [22]) so the
        // zero-point never clamps and RTN error stays <= scale/2.
        let mut lo = 0.0f32;
        let mut hi = 0.0f32;
        for &v in row {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let rng = hi - lo;
        let scale = if rng > 0.0 { rng / qmax } else { 1.0 };
        let zp = (-lo / scale + 0.5).floor().clamp(0.0, qmax);
        for &v in row {
            let q = ((v / scale + 0.5).floor() + zp).clamp(0.0, qmax);
            codes.push(q as u8);
        }
        (scale, zp)
    }
}

/// Exact encoded size of one segment under `bits` (used by the analytic
/// TCC calculators — keep in sync with `encode`).
pub fn segment_encoded_size(seg: &Segment, bits: u32) -> usize {
    match seg.quant_rows {
        None => seg.numel * 4,
        Some(rows) => rows * 8 + packed_len(seg.numel, bits),
    }
}

/// Validate a quantized segment's row layout before using it to slice
/// the payload: `quant_rows == Some(0)` would divide by zero, and a
/// row count that does not divide `numel` would silently mis-shape
/// every row after the first (a `debug_assert` before this fix — i.e.
/// unchecked in release builds). Malformed layouts come from corrupt
/// manifests, so they are an [`Error::invalid`], not a panic.
fn check_quant_rows(seg: &Segment, rows: usize, dir: &str) -> Result<()> {
    if rows == 0 || seg.numel % rows != 0 {
        return Err(Error::invalid(format!(
            "affine {dir}: segment {} has a malformed quant layout: \
             {} elements in {rows} rows",
            seg.name, seg.numel
        )));
    }
    Ok(())
}

impl Codec for AffineCodec {
    fn name(&self) -> String {
        format!("q{}", self.bits)
    }

    fn encode(&self, v: &[f32], segments: &[Segment]) -> Result<Message> {
        let total: usize = segments.iter().map(|s| s.numel).sum();
        if total != v.len() {
            return Err(Error::invalid(format!(
                "affine encode: layout {} vs vector {}",
                total,
                v.len()
            )));
        }
        let mut payload = Vec::new();
        for seg in segments {
            let data = &v[seg.offset..seg.offset + seg.numel];
            match seg.quant_rows {
                None => {
                    for x in data {
                        payload.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Some(rows) => {
                    check_quant_rows(seg, rows, "encode")?;
                    let cols = seg.numel / rows;
                    let mut scales = Vec::with_capacity(rows);
                    let mut zps = Vec::with_capacity(rows);
                    let mut codes = Vec::with_capacity(seg.numel);
                    for r in 0..rows {
                        let (s, z) =
                            self.quant_row(&data[r * cols..(r + 1) * cols],
                                           &mut codes);
                        scales.push(s);
                        zps.push(z);
                    }
                    for s in &scales {
                        payload.extend_from_slice(&s.to_le_bytes());
                    }
                    for z in &zps {
                        payload.extend_from_slice(&z.to_le_bytes());
                    }
                    payload.extend_from_slice(&pack(&codes, self.bits));
                }
            }
        }
        Ok(Message { payload, codec: self.name() })
    }

    fn decode(&self, msg: &Message, segments: &[Segment]) -> Result<Vec<f32>> {
        let total: usize = segments.iter().map(|s| s.numel).sum();
        let mut out = vec![0.0f32; total];
        let b = &msg.payload;
        let mut pos = 0usize;
        let rd_f32 = |b: &[u8], pos: &mut usize| -> Result<f32> {
            if *pos + 4 > b.len() {
                return Err(Error::parse("affine decode: truncated payload"));
            }
            let v = f32::from_le_bytes(b[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        for seg in segments {
            let dst = &mut out[seg.offset..seg.offset + seg.numel];
            match seg.quant_rows {
                None => {
                    for d in dst.iter_mut() {
                        *d = rd_f32(b, &mut pos)?;
                    }
                }
                Some(rows) => {
                    check_quant_rows(seg, rows, "decode")?;
                    let cols = seg.numel / rows;
                    let mut scales = Vec::with_capacity(rows);
                    let mut zps = Vec::with_capacity(rows);
                    for _ in 0..rows {
                        scales.push(rd_f32(b, &mut pos)?);
                    }
                    for _ in 0..rows {
                        zps.push(rd_f32(b, &mut pos)?);
                    }
                    let plen = packed_len(seg.numel, self.bits);
                    if pos + plen > b.len() {
                        return Err(Error::parse("affine decode: truncated codes"));
                    }
                    let codes = unpack(&b[pos..pos + plen], self.bits, seg.numel);
                    pos += plen;
                    for r in 0..rows {
                        let s = scales[r];
                        let z = zps[r];
                        for c in 0..cols {
                            dst[r * cols + c] =
                                (codes[r * cols + c] as f32 - z) * s;
                        }
                    }
                }
            }
        }
        if pos != b.len() {
            return Err(Error::parse(format!(
                "affine decode: {} trailing bytes",
                b.len() - pos
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamKind;
    use crate::util::rng::Rng;

    fn seg(name: &str, numel: usize, offset: usize,
           quant_rows: Option<usize>) -> Segment {
        Segment { name: name.into(), shape: vec![numel], numel,
                  kind: ParamKind::Conv, offset, quant_rows }
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| 3.0 * rng.normal() as f32).collect()
    }

    #[test]
    fn round_trip_error_bounded_by_half_scale() {
        for bits in [2u32, 4, 8] {
            let c = AffineCodec::new(bits);
            let segs = vec![seg("a", 64, 0, Some(8)), seg("n", 10, 64, None),
                            seg("b", 30, 74, Some(10))];
            let v = randv(104, bits as u64);
            let msg = c.encode(&v, &segs).unwrap();
            let out = c.decode(&msg, &segs).unwrap();
            // fp segment exact:
            assert_eq!(&out[64..74], &v[64..74]);
            // quantized segments bounded by scale/2 per row; scale is
            // range/qmax <= (2*maxabs)/qmax.
            let qmax = ((1u32 << bits) - 1) as f32;
            for (seg_range, rows) in [(0..64, 8), (74..104, 10)] {
                let cols = seg_range.len() / rows;
                for r in 0..rows {
                    let row: Vec<f32> = v[seg_range.clone()]
                        [r * cols..(r + 1) * cols].to_vec();
                    let lo = row.iter().cloned().fold(0.0f32, f32::min);
                    let hi = row.iter().cloned().fold(0.0f32, f32::max);
                    let scale = ((hi - lo) / qmax).max(1e-12);
                    for c_ in 0..cols {
                        let i = seg_range.start + r * cols + c_;
                        assert!((out[i] - v[i]).abs() <= scale * 0.5 + 1e-5,
                                "bits={bits} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn sizes_match_formula() {
        for bits in [2u32, 4, 8] {
            let c = AffineCodec::new(bits);
            let segs = vec![seg("a", 64, 0, Some(8)), seg("n", 10, 64, None)];
            let v = randv(74, 9);
            let msg = c.encode(&v, &segs).unwrap();
            let expect: usize =
                segs.iter().map(|s| segment_encoded_size(s, bits)).sum();
            assert_eq!(msg.size_bytes(), expect);
        }
    }

    #[test]
    fn compression_ratio_roughly_bits_over_32() {
        // For a large all-quantized layout the ratio approaches 32/bits.
        let c = AffineCodec::new(8);
        let segs = vec![seg("a", 64 * 256, 0, Some(64))];
        let v = randv(64 * 256, 3);
        let msg = c.encode(&v, &segs).unwrap();
        let ratio = (v.len() * 4) as f64 / msg.size_bytes() as f64;
        assert!(ratio > 3.7 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    fn constant_rows_round_trip_exactly() {
        let c = AffineCodec::new(8);
        let segs = vec![seg("a", 16, 0, Some(4))];
        let v = vec![-3.0f32; 4].into_iter()
            .chain(vec![0.0; 4])
            .chain(vec![5.0; 4])
            .chain(vec![120.0; 4])
            .collect::<Vec<_>>();
        let out = c.decode(&c.encode(&v, &segs).unwrap(), &segs).unwrap();
        assert_eq!(out, v);
    }

    #[test]
    fn malformed_quant_rows_rejected_not_panicking() {
        let c = AffineCodec::new(8);
        let v = randv(64, 7);
        // rows = 0 used to divide by zero in encode and decode.
        let zero_rows = vec![seg("z", 64, 0, Some(0))];
        assert!(c.encode(&v, &zero_rows).is_err());
        // numel % rows != 0 used to be a debug_assert (unchecked in
        // release): 64 elements in 7 rows mis-shapes every row.
        let ragged = vec![seg("r", 64, 0, Some(7))];
        assert!(c.encode(&v, &ragged).is_err());
        // Decode must reject the same layouts — a valid message
        // decoded against a corrupt manifest, not just a bad encode.
        let good = vec![seg("g", 64, 0, Some(8))];
        let msg = c.encode(&v, &good).unwrap();
        assert!(c.decode(&msg, &zero_rows).is_err());
        assert!(c.decode(&msg, &ragged).is_err());
        // The error is typed, not a bare panic/parse failure.
        match c.encode(&v, &zero_rows) {
            Err(crate::error::Error::Invalid(m)) => {
                assert!(m.contains("quant layout"), "{m}");
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn truncated_payload_rejected() {
        let c = AffineCodec::new(4);
        let segs = vec![seg("a", 64, 0, Some(8))];
        let v = randv(64, 4);
        let mut msg = c.encode(&v, &segs).unwrap();
        msg.payload.truncate(msg.payload.len() - 3);
        assert!(c.decode(&msg, &segs).is_err());
        msg.payload.extend_from_slice(&[0; 10]);
        assert!(c.decode(&msg, &segs).is_err());
    }
}
