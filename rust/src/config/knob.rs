//! The knob protocol: one parse/label contract for every enum-valued
//! config key.
//!
//! Each selection knob (`executor`, `sampler`, `aggregator`,
//! `time_model`, `codec`, …) used to hand-roll its own
//! `parse`/`label` pair plus a bespoke `ok_or_else` error at every
//! call site. [`Knob`] pins the contract in one place: a knob is
//! `FromStr + Display` with the round-trip law `parse(display(k)) ==
//! k` (checked for every implementor by this module's shared
//! property test), and [`parse_knob`] renders the one canonical error
//! shape — ``unknown <key> `<value>` (<choices>)`` — that
//! `config::set`, the TOML loader and the CLI all surface (the loader
//! and CLI route through [`FlConfig::set`](super::FlConfig::set),
//! presets construct the enums directly, so every entry point shares
//! this code path).
//!
//! The historical inherent `parse`/`label` methods remain the
//! implementation; the trait impls delegate, so existing callers keep
//! compiling while new code can be generic over knobs.

use std::fmt;
use std::str::FromStr;

use crate::compression::CodecKind;
use crate::coordinator::aggregator::AggregatorKind;
use crate::coordinator::executor::ExecutorKind;
use crate::coordinator::sampler::SamplerKind;
use crate::error::{Error, Result};
use crate::transport::{NetworkKind, OverlapKind, ProfileKind, Sharing,
                       TimeModelKind, WireFaultPolicy};

/// An enum-valued config knob: parseable, printable, and round-trip
/// stable (`parse(display(k)) == k` per variant).
pub trait Knob: Sized + FromStr + fmt::Display {
    /// Config key this knob answers to (used in error messages).
    const NAME: &'static str;
    /// Human-readable choices list (used in error messages).
    const CHOICES: &'static str;
    /// Representative variants for the shared round-trip test — every
    /// unit variant, plus parameterized ones at non-default values.
    fn variants() -> Vec<Self>;
}

/// Parse a knob value with the canonical config-error shape:
/// ``unknown <key> `<value>` (<choices>)``.
pub fn parse_knob<K: Knob>(value: &str) -> Result<K> {
    value.parse().map_err(|_| {
        Error::parse(format!(
            "unknown {} `{value}` ({})",
            K::NAME,
            K::CHOICES
        ))
    })
}

/// Wire one kind up to the knob protocol by delegating to its
/// inherent `parse`/`label`.
macro_rules! impl_knob {
    ($ty:ty, $name:literal, $choices:literal, [$($variant:expr),+ $(,)?]) => {
        impl FromStr for $ty {
            type Err = ();
            fn from_str(s: &str) -> std::result::Result<Self, ()> {
                <$ty>::parse(s).ok_or(())
            }
        }

        impl fmt::Display for $ty {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str(&self.label())
            }
        }

        impl Knob for $ty {
            const NAME: &'static str = $name;
            const CHOICES: &'static str = $choices;
            fn variants() -> Vec<Self> {
                vec![$($variant),+]
            }
        }
    };
}

impl_knob!(ExecutorKind, "executor", "serial|parallel",
           [ExecutorKind::Serial, ExecutorKind::Parallel]);
impl_knob!(SamplerKind, "sampler", "uniform|latency_biased|oversample_k",
           [SamplerKind::Uniform, SamplerKind::LatencyBiased,
            SamplerKind::OversampleK]);
impl_knob!(AggregatorKind, "aggregator", "fedavg|svt|exact",
           [AggregatorKind::FedAvg, AggregatorKind::Svt,
            AggregatorKind::Exact]);
impl_knob!(TimeModelKind, "time_model", "closed|event",
           [TimeModelKind::Closed, TimeModelKind::Event]);
impl_knob!(NetworkKind, "network", "edge_lte|wifi",
           [NetworkKind::EdgeLte, NetworkKind::Wifi]);
impl_knob!(Sharing, "net_sharing", "dedicated|shared",
           [Sharing::Dedicated, Sharing::Shared]);
impl_knob!(OverlapKind, "overlap", "none|transfer",
           [OverlapKind::None, OverlapKind::Transfer]);
impl_knob!(CodecKind, "codec",
           "fp32|q8|q4|q2|topk:<keep>|zerofl:<sp>:<mr>|sparse_ef:<keep>",
           [CodecKind::Fp32, CodecKind::Affine(8), CodecKind::Affine(4),
            CodecKind::Affine(2), CodecKind::TopK(0.5),
            CodecKind::ZeroFl(0.9, 0.2), CodecKind::SparseEf(0.5)]);
impl_knob!(WireFaultPolicy, "wire_on_timeout", "drop|abort",
           [WireFaultPolicy::Drop, WireFaultPolicy::Abort]);

// `ProfileKind::File` labels as bare "file" for display tables, but
// `Display` owes the round-trip law the parseable `file:PATH` form;
// the macro delegates `Display` to `label()`, so this knob is wired
// by hand.
impl FromStr for ProfileKind {
    type Err = ();
    fn from_str(s: &str) -> std::result::Result<Self, ()> {
        ProfileKind::parse(s).ok_or(())
    }
}

impl fmt::Display for ProfileKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // Full parseable spec — `label()` stays the bare "file"
            // for display tables, but `Display` owes round-trippable
            // output.
            ProfileKind::File(path) => write!(f, "file:{path}"),
            other => f.write_str(other.label()),
        }
    }
}

impl Knob for ProfileKind {
    const NAME: &'static str = "client_profiles";
    const CHOICES: &'static str = "uniform|tiered|file:PATH";
    fn variants() -> Vec<Self> {
        vec![
            ProfileKind::Uniform,
            ProfileKind::Tiered,
            ProfileKind::File("fleet.toml".into()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The shared round-trip law every knob must satisfy.
    fn round_trips<K: Knob + PartialEq + fmt::Debug>() {
        let variants = K::variants();
        assert!(!variants.is_empty(), "{} lists no variants", K::NAME);
        for k in variants {
            let shown = k.to_string();
            let back: K = parse_knob(&shown).unwrap_or_else(|e| {
                panic!("{} `{shown}` failed to re-parse: {e}", K::NAME)
            });
            assert_eq!(back, k, "{} round-trip via `{shown}`", K::NAME);
        }
        assert!(parse_knob::<K>("definitely-not-a-choice").is_err());
    }

    #[test]
    fn every_knob_round_trips() {
        round_trips::<ExecutorKind>();
        round_trips::<SamplerKind>();
        round_trips::<AggregatorKind>();
        round_trips::<TimeModelKind>();
        round_trips::<NetworkKind>();
        round_trips::<Sharing>();
        round_trips::<OverlapKind>();
        round_trips::<CodecKind>();
        round_trips::<ProfileKind>();
        round_trips::<WireFaultPolicy>();
    }

    #[test]
    fn parse_errors_carry_key_and_choices() {
        let err = parse_knob::<ExecutorKind>("turbo")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown executor `turbo`"), "{err}");
        assert!(err.contains("serial|parallel"), "{err}");
        let err = parse_knob::<CodecKind>("q3").unwrap_err().to_string();
        assert!(err.contains("unknown codec `q3`"), "{err}");
    }

    #[test]
    fn file_profile_displays_its_full_spec() {
        let k = ProfileKind::File("fleet.toml".into());
        assert_eq!(k.to_string(), "file:fleet.toml");
        // The bare display label stays "file" for tables.
        assert_eq!(k.label(), "file");
    }
}
