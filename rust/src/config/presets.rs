//! Named experiment presets.
//!
//! `paper_*` presets reproduce the paper's §IV setup verbatim (100
//! clients, 10% sampling, batch 32, lr 0.01, momentum 0.9) — they are
//! what the analytic tables use, and can be run end-to-end given enough
//! CPU-hours. `scaled_*` presets keep every ratio (10% sampling, same
//! optimizer, same LDA) at a size this testbed trains in minutes; the
//! benches use them for the accuracy columns (DESIGN.md §2).

use crate::compression::CodecKind;
use crate::config::FlConfig;
use crate::coordinator::aggregator::AggregatorKind;
use crate::coordinator::executor::ExecutorKind;
use crate::coordinator::sampler::SamplerKind;
use crate::transport::{ProfileKind, TimeModelKind};

/// Paper §IV main setup: ResNet-8, CIFAR-10-scale, LDA 0.5, 100 rounds.
pub fn paper_resnet8(rank: usize, codec: CodecKind) -> FlConfig {
    FlConfig {
        tag: if rank == 0 {
            "resnet8_full".into()
        } else {
            format!("resnet8_lora_fc_r{rank}")
        },
        num_clients: 100,
        clients_per_round: 10,
        rounds: 100,
        local_epochs: 5,
        lr: 0.01,
        lora_alpha: 16.0 * rank.max(1) as f32, // paper: alpha = 16 r (512 at r=32)
        codec,
        lda_alpha: 0.5,
        samples_per_client: 500, // 50k CIFAR train / 100 clients
        test_samples: 10_000,
        seed: 42,
        eval_every: 5,
        dropout: 0.0,
        lr_decay: 1.0,
        // 10 clients/round is exactly the fan-out regime the parallel
        // engine exists for; results are bit-identical to serial.
        executor: ExecutorKind::Parallel,
        threads: 0,
        ..FlConfig::default()
    }
}

/// Paper Table IV setup: ResNet-18, 700 rounds, 1 local epoch, LDA 1.0.
pub fn paper_resnet18(rank: usize, codec: CodecKind) -> FlConfig {
    let mut cfg = paper_resnet8(rank, codec);
    cfg.tag = if rank == 0 {
        "resnet18_full".into()
    } else {
        format!("resnet18_lora_fc_r{rank}")
    };
    cfg.rounds = 700;
    cfg.local_epochs = 1;
    cfg.lda_alpha = 1.0;
    cfg
}

/// Scaled profile on micro8 (16x16 images): minutes on this CPU.
/// Keeps the paper's ratios: 25% sampling is raised from 10% so each
/// round still averages >= 4 clients at the small federation size.
pub fn scaled_micro(variant_tag: &str, rank: usize, codec: CodecKind) -> FlConfig {
    FlConfig {
        tag: variant_tag.into(),
        num_clients: 16,
        clients_per_round: 4,
        rounds: 24,
        local_epochs: 2,
        lr: 0.02,
        lora_alpha: 16.0 * rank.max(1) as f32,
        codec,
        lda_alpha: 0.5,
        samples_per_client: 48,
        test_samples: 240,
        seed: 42,
        eval_every: 2,
        dropout: 0.0,
        lr_decay: 1.0,
        // Scaled profiles keep the serial reference: rounds are seconds
        // long and the benches that use them time the executor itself.
        executor: ExecutorKind::Serial,
        threads: 0,
        ..FlConfig::default()
    }
}

/// Scaled profile on tiny8 (32x32 images, ~0.2 s/step): tens of minutes.
pub fn scaled_tiny(variant_tag: &str, rank: usize, codec: CodecKind) -> FlConfig {
    let mut cfg = scaled_micro(variant_tag, rank, codec);
    cfg.tag = variant_tag.into();
    cfg.num_clients = 12;
    cfg.clients_per_round = 3;
    cfg.rounds = 16;
    cfg.samples_per_client = 64;
    cfg.test_samples = 200;
    cfg
}

/// Heterogeneous-rank federation on micro8: the server holds r=8
/// adapters while clients split round-robin across r2/r4/r8 device
/// classes — the regime of the paper's §V future-work sketch (and of
/// the heterogeneous-client federated-LoRA line in PAPERS.md). The
/// r=2 tier's messages are ~4x smaller than the r=8 tier's, so
/// heterogeneity doubles as a communication knob.
pub fn hetero_micro() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r8".into(),
        num_clients: 12,
        clients_per_round: 4,
        rounds: 40,
        local_epochs: 2,
        lr: 0.02,
        lora_alpha: 64.0, // fixed alpha; per-tier scale = alpha / r_tier
        samples_per_client: 64,
        test_samples: 240,
        eval_every: 8,
        hetero_ranks: vec![2, 4, 8],
        ..FlConfig::default()
    }
}

/// Straggler regime on micro8: tiered link/compute profiles (5 of 16
/// clients are ~8× slow) with oversampled participation — each round
/// draws `K·(1+β)` clients and the server cancels the expected
/// stragglers once K uploads are in. The preset is the measurable
/// form of the ROADMAP's "straggler-aware sampling" item:
/// `sim_net_parallel_s` under `oversample_k` must beat `uniform` on
/// the same seed (pinned in `tests/executor.rs`).
pub fn straggler_micro() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 16,
        clients_per_round: 4,
        rounds: 24,
        local_epochs: 2,
        lr: 0.02,
        lora_alpha: 64.0,
        samples_per_client: 48,
        test_samples: 240,
        eval_every: 4,
        sampler: SamplerKind::OversampleK,
        oversample_beta: 0.5,
        client_profiles: ProfileKind::Tiered,
        // Straggler cost is a fan-out phenomenon; keep the engine that
        // models it (results stay bit-identical to serial).
        executor: ExecutorKind::Parallel,
        ..FlConfig::default()
    }
}

/// The straggler regime priced by the discrete-event time model
/// instead of the closed envelopes: fine-grained chunks through tight
/// stage queues, so the `sim_net_event_s` column lands strictly
/// between the pipelined and parallel estimates (queueing made
/// visible). Training, sampling and every other column are
/// bit-identical to `straggler_micro` — the time model only prices
/// rounds.
pub fn event_micro() -> FlConfig {
    FlConfig {
        time_model: TimeModelKind::Event,
        chunk_kb: 1,
        stage_queue: 2,
        ..straggler_micro()
    }
}

/// SVT aggregation regime on micro8: the server stacks the uploaded
/// LoRA factors per adapter pair, truncates the exact weighted-mean
/// product at 90% retained spectral energy, and broadcasts the
/// re-factored adapter (FLoRIST-style singular-value thresholding; see
/// PAPERS.md). A 10% dropout keeps the contributor set ragged so the
/// truncation actually has variance to trim, and the `eff_rank`
/// column records what survives each round.
pub fn svt_micro() -> FlConfig {
    let mut cfg = scaled_micro("micro8_lora_fc_r8", 8, CodecKind::Fp32);
    cfg.aggregator = AggregatorKind::Svt;
    cfg.svt_energy = 0.9;
    cfg.dropout = 0.1;
    cfg.rounds = 24;
    cfg
}

/// Sparse error-feedback regime on micro8: uploads keep the top 25%
/// of coordinates by magnitude and bank the rest in a per-client
/// residual that is replayed (and re-ranked) next time the client is
/// sampled — nothing is silently dropped, it is only deferred. The
/// residual-conservation invariant is pinned in `tests/aggregation.rs`.
pub fn sparse_ef_micro() -> FlConfig {
    scaled_micro("micro8_lora_fc_r4", 4, CodecKind::SparseEf(0.25))
}

/// Registration-scale throughput regime: 1M registered clients, 10k
/// sampled per round, 8 aggregator shards. This is the coordinator
/// scaling benchmark behind `BENCH_scale.json` (rounds/sec at
/// 10k/100k/1M registered clients), not a training experiment — one
/// round, one local epoch, a handful of samples per client, and the
/// uniform sampler (the latency-biased one prices every registered
/// client per draw, which is O(n·k) at this scale). The federation
/// sits above [`crate::data::LAZY_THRESHOLD`], so client datasets
/// materialize on demand from fork seeds instead of 1M upfront
/// allocations.
pub fn scale_bench() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 1_000_000,
        clients_per_round: 10_000,
        rounds: 1,
        local_epochs: 1,
        lr: 0.02,
        lora_alpha: 64.0,
        samples_per_client: 8,
        test_samples: 64,
        eval_every: 1,
        executor: ExecutorKind::Parallel,
        shards: 8,
        ..FlConfig::default()
    }
}

/// Look a preset up by CLI name (`flocora train --preset NAME`).
pub fn by_name(name: &str) -> Option<FlConfig> {
    match name {
        "paper_resnet8" => Some(paper_resnet8(32, CodecKind::Affine(8))),
        "paper_resnet18" => Some(paper_resnet18(16, CodecKind::Affine(8))),
        "scaled_micro" => {
            Some(scaled_micro("micro8_lora_fc_r4", 4, CodecKind::Fp32))
        }
        "scaled_tiny" => {
            Some(scaled_tiny("tiny8_lora_fc_r8", 8, CodecKind::Fp32))
        }
        "hetero_micro" => Some(hetero_micro()),
        "straggler_micro" => Some(straggler_micro()),
        "event_micro" => Some(event_micro()),
        "svt_micro" => Some(svt_micro()),
        "sparse_ef_micro" => Some(sparse_ef_micro()),
        "scale_bench" => Some(scale_bench()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_presets_match_section_iv() {
        let cfg = paper_resnet8(32, CodecKind::Fp32);
        assert_eq!(cfg.num_clients, 100);
        assert_eq!(cfg.clients_per_round, 10);
        assert_eq!(cfg.rounds, 100);
        assert_eq!(cfg.local_epochs, 5);
        assert_eq!(cfg.lr, 0.01);
        assert_eq!(cfg.lora_alpha, 512.0); // alpha = 16 r at r = 32
        assert_eq!(cfg.lora_scale(32), 16.0);
        cfg.validate().unwrap();

        let t4 = paper_resnet18(16, CodecKind::Affine(8));
        assert_eq!(t4.rounds, 700);
        assert_eq!(t4.local_epochs, 1);
        assert_eq!(t4.lda_alpha, 1.0);
        assert_eq!(t4.executor, ExecutorKind::Parallel);
        t4.validate().unwrap();
    }

    #[test]
    fn scaled_presets_valid() {
        scaled_micro("micro8_lora_fc_r4", 4, CodecKind::Fp32)
            .validate()
            .unwrap();
        scaled_tiny("tiny8_lora_fc_r8", 8, CodecKind::Affine(4))
            .validate()
            .unwrap();
    }

    #[test]
    fn hetero_preset_valid_and_tiered() {
        let cfg = hetero_micro();
        cfg.validate().unwrap();
        assert_eq!(cfg.hetero_ranks, vec![2, 4, 8]);
        assert_eq!(cfg.tag, "micro8_lora_fc_r8");
        // 12 clients round-robin over 3 tiers => 4 per device class.
        assert_eq!(cfg.num_clients % cfg.hetero_ranks.len(), 0);
    }

    #[test]
    fn straggler_preset_oversamples_tiered_clients() {
        let cfg = straggler_micro();
        cfg.validate().unwrap();
        assert_eq!(cfg.sampler, SamplerKind::OversampleK);
        assert_eq!(cfg.client_profiles, ProfileKind::Tiered);
        assert!(cfg.oversample_beta > 0.0);
        // K·(1+β) must fit in the pool with room to cancel.
        let draw = (cfg.clients_per_round as f64
            * (1.0 + cfg.oversample_beta)).ceil() as usize;
        assert!(draw > cfg.clients_per_round);
        assert!(draw <= cfg.num_clients);
    }

    #[test]
    fn event_preset_prices_rounds_with_the_simulator() {
        let cfg = event_micro();
        cfg.validate().unwrap();
        assert_eq!(cfg.time_model, TimeModelKind::Event);
        assert!(cfg.chunk_kb >= 1);
        // Everything that reaches training matches straggler_micro.
        let base = straggler_micro();
        assert_eq!(cfg.tag, base.tag);
        assert_eq!(cfg.seed, base.seed);
        assert_eq!(cfg.rounds, base.rounds);
        assert_eq!(cfg.sampler, base.sampler);
        assert_eq!(cfg.client_profiles, base.client_profiles);
    }

    #[test]
    fn zoo_presets_select_their_aggregation_paths() {
        let svt = svt_micro();
        svt.validate().unwrap();
        assert_eq!(svt.aggregator, AggregatorKind::Svt);
        assert_eq!(svt.svt_energy, 0.9);
        assert!(svt.dropout > 0.0, "SVT preset wants ragged rounds");
        assert_eq!(svt.tag, "micro8_lora_fc_r8");

        let ef = sparse_ef_micro();
        ef.validate().unwrap();
        assert_eq!(ef.aggregator, AggregatorKind::FedAvg);
        assert_eq!(ef.codec, CodecKind::SparseEf(0.25));
    }

    #[test]
    fn scale_bench_is_sharded_and_lazy() {
        let cfg = scale_bench();
        cfg.validate().unwrap();
        assert!(cfg.shards > 1);
        assert!(cfg.num_clients >= crate::data::LAZY_THRESHOLD);
        assert_eq!(cfg.sampler, SamplerKind::Uniform,
                   "latency-biased sampling is O(n·k) at this scale");
        assert_eq!(cfg.rounds, 1, "a throughput probe, not a run");
    }

    #[test]
    fn presets_resolve_by_name() {
        for name in ["paper_resnet8", "paper_resnet18", "scaled_micro",
                     "scaled_tiny", "hetero_micro", "straggler_micro",
                     "event_micro", "svt_micro", "sparse_ef_micro",
                     "scale_bench"] {
            let cfg = by_name(name).unwrap_or_else(|| {
                panic!("preset {name} missing")
            });
            cfg.validate().unwrap();
        }
        assert!(by_name("nope").is_none());
    }
}
