//! TOML-subset config loader: flat `key = value` lines, `#` comments,
//! optional quoting for strings. Section headers are accepted and
//! flattened (`[section]` is ignored — the config namespace is flat).

use std::path::Path;

use crate::config::FlConfig;
use crate::error::{Error, Result};

/// Strip a trailing `# comment`, honouring double quotes: a `#`
/// inside a quoted value (`tag = "run#3"`) is data, not a comment.
/// (The pre-fix loader cut at the first `#` anywhere, truncating the
/// value to `"run`.) An unterminated quote swallows the rest of the
/// line as value text — `cfg.set` rejects it downstream if malformed.
fn strip_comment(raw: &str) -> &str {
    let mut in_quotes = false;
    for (i, c) in raw.char_indices() {
        match c {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &raw[..i],
            _ => {}
        }
    }
    raw
}

/// Parse `key = value` lines into an existing config.
pub fn apply_str(cfg: &mut FlConfig, text: &str) -> Result<()> {
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| {
            Error::parse(format!("line {}: expected key = value", lineno + 1))
        })?;
        let key = key.trim();
        let value = value.trim().trim_matches('"');
        cfg.set(key, value).map_err(|e| {
            Error::parse(format!("line {}: {e}", lineno + 1))
        })?;
    }
    Ok(())
}

/// Apply a config file on top of an existing config (preset or
/// defaults); the caller validates once every override is in.
pub fn apply_file(cfg: &mut FlConfig, path: impl AsRef<Path>) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    apply_str(cfg, &text)
}

/// Load a config file on top of defaults.
pub fn load(path: impl AsRef<Path>) -> Result<FlConfig> {
    let mut cfg = FlConfig::default();
    apply_file(&mut cfg, path)?;
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::CodecKind;

    #[test]
    fn parses_typical_file() {
        let mut cfg = FlConfig::default();
        apply_str(
            &mut cfg,
            r#"
            # FLoCoRA scaled run
            [federation]
            tag = "tiny8_lora_fc_r8"
            rounds = 30
            codec = q8          # quantized uplink+downlink
            lora_alpha = 128.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.tag, "tiny8_lora_fc_r8");
        assert_eq!(cfg.rounds, 30);
        assert_eq!(cfg.codec, CodecKind::Affine(8));
        assert_eq!(cfg.lora_alpha, 128.0);
    }

    #[test]
    fn hash_inside_quotes_is_data_not_comment() {
        let mut cfg = FlConfig::default();
        apply_str(
            &mut cfg,
            "tag = \"run#3\"          # trailing comment still stripped\n\
             rounds = 9 # plain comments too\n\
             # and full-line comments\n",
        )
        .unwrap();
        // Pre-fix, the `#` cut first and the tag truncated to `run`.
        assert_eq!(cfg.tag, "run#3");
        assert_eq!(cfg.rounds, 9);
        // Round trip: a written value with `#` survives re-parsing.
        let mut again = FlConfig::default();
        apply_str(&mut again, &format!("tag = \"{}\"", cfg.tag)).unwrap();
        assert_eq!(again.tag, cfg.tag);
    }

    #[test]
    fn rejects_malformed() {
        let mut cfg = FlConfig::default();
        assert!(apply_str(&mut cfg, "rounds 30").is_err());
        assert!(apply_str(&mut cfg, "unknown = 1").is_err());
        let err = apply_str(&mut cfg, "\n\nrounds = x").unwrap_err();
        assert!(err.to_string().contains("line 3"), "{err}");
    }
}
