//! TOML-subset config loader: flat `key = value` lines, `#` comments,
//! optional quoting for strings. Section headers are accepted and
//! flattened (`[section]` is ignored — the config namespace is flat).

use std::path::Path;

use crate::config::FlConfig;
use crate::error::{Error, Result};

/// Parse `key = value` lines into an existing config.
pub fn apply_str(cfg: &mut FlConfig, text: &str) -> Result<()> {
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| {
            Error::parse(format!("line {}: expected key = value", lineno + 1))
        })?;
        let key = key.trim();
        let value = value.trim().trim_matches('"');
        cfg.set(key, value).map_err(|e| {
            Error::parse(format!("line {}: {e}", lineno + 1))
        })?;
    }
    Ok(())
}

/// Apply a config file on top of an existing config (preset or
/// defaults); the caller validates once every override is in.
pub fn apply_file(cfg: &mut FlConfig, path: impl AsRef<Path>) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    apply_str(cfg, &text)
}

/// Load a config file on top of defaults.
pub fn load(path: impl AsRef<Path>) -> Result<FlConfig> {
    let mut cfg = FlConfig::default();
    apply_file(&mut cfg, path)?;
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::CodecKind;

    #[test]
    fn parses_typical_file() {
        let mut cfg = FlConfig::default();
        apply_str(
            &mut cfg,
            r#"
            # FLoCoRA scaled run
            [federation]
            tag = "tiny8_lora_fc_r8"
            rounds = 30
            codec = q8          # quantized uplink+downlink
            lora_alpha = 128.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.tag, "tiny8_lora_fc_r8");
        assert_eq!(cfg.rounds, 30);
        assert_eq!(cfg.codec, CodecKind::Affine(8));
        assert_eq!(cfg.lora_alpha, 128.0);
    }

    #[test]
    fn rejects_malformed() {
        let mut cfg = FlConfig::default();
        assert!(apply_str(&mut cfg, "rounds 30").is_err());
        assert!(apply_str(&mut cfg, "unknown = 1").is_err());
        let err = apply_str(&mut cfg, "\n\nrounds = x").unwrap_err();
        assert!(err.to_string().contains("line 3"), "{err}");
    }
}
