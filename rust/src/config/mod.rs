//! Experiment configuration: typed config struct, named presets
//! (paper-scale and scaled profiles), and a TOML-subset file loader so
//! runs are launchable as `flocora train --config exp.toml` with CLI
//! overrides on top.

pub mod knob;
pub mod loader;
pub mod presets;

use crate::compression::CodecKind;
use crate::coordinator::aggregator::AggregatorKind;
use crate::coordinator::executor::ExecutorKind;
use crate::coordinator::sampler::SamplerKind;
use crate::error::{Error, Result};
use crate::transport::{NetworkKind, OverlapKind, ProfileKind, Sharing,
                       TimeModelKind, DEFAULT_COMPUTE_BASE_S};

pub use knob::{parse_knob, Knob};

/// Full description of one FL run.
#[derive(Debug, Clone)]
pub struct FlConfig {
    /// Manifest tag, e.g. `tiny8_lora_fc_r8`.
    pub tag: String,
    pub num_clients: usize,
    /// Clients sampled per round (paper: 10% of 100).
    pub clients_per_round: usize,
    pub rounds: usize,
    pub local_epochs: usize,
    /// Client SGD learning rate (paper: 0.01).
    pub lr: f32,
    /// LoRA alpha; the runtime scale is `alpha / rank`. Ignored by
    /// `full` variants. Paper main setting: alpha = 16 r.
    pub lora_alpha: f32,
    pub codec: CodecKind,
    /// Dirichlet concentration for the LDA partitioner.
    pub lda_alpha: f64,
    pub samples_per_client: usize,
    pub test_samples: usize,
    pub seed: u64,
    /// Evaluate every k rounds (always evaluates the final round).
    pub eval_every: usize,
    /// Per-round probability that a sampled client fails before
    /// uploading (straggler/failure injection; FedAvg simply averages
    /// the survivors). 0.0 disables.
    pub dropout: f64,
    /// Multiplicative per-round learning-rate decay (1.0 = constant;
    /// e.g. 0.99 halves the lr every ~69 rounds).
    pub lr_decay: f32,
    /// How a round's sampled clients execute: the serial reference or
    /// the thread-pool engine. Bit-identical results either way (the
    /// per-client RNG depends only on `(seed, round, cid)`).
    pub executor: ExecutorKind,
    /// Worker threads for the parallel executor (0 = one per available
    /// core). Ignored by the serial executor.
    pub threads: usize,
    /// Out-of-order result window of the streaming round merge (0 =
    /// twice the worker count). Bounds how many decoded client updates
    /// can be buffered at once; any value is bit-identical. Ignored by
    /// the serial executor.
    pub window: usize,
    /// Aggregator shards: the round's sampled clients split into this
    /// many contiguous, block-aligned partitions, each folding into
    /// its own aggregator/ledger/stage-log on its own thread; the
    /// coordinator merges partials through the canonical block tree
    /// (see `coordinator::shard`). Any value is bit-identical — 1
    /// (the default) is the historical single-sink round.
    pub shards: usize,
    /// Link profile behind the simulated time-on-wire report
    /// (`edge_lte | wifi`).
    pub network: NetworkKind,
    /// Link-sharing regime for the concurrent-clients wire time
    /// (`dedicated | shared`).
    pub net_sharing: Sharing,
    /// Transfer/compute overlap (`none | transfer`). `transfer` runs
    /// the parallel executor's decode/encode stages on dedicated
    /// transport threads (client A's upload overlaps client B's
    /// training); results and every simulated estimate stay
    /// bit-identical to `none` — only wall clock changes. Ignored by
    /// the serial executor.
    pub overlap: OverlapKind,
    /// Per-round client selection strategy
    /// (`uniform | latency_biased | oversample_k`). `uniform` is
    /// bit-identical to the pre-strategy sampler.
    pub sampler: SamplerKind,
    /// Oversampling fraction for `sampler = oversample_k`: each round
    /// draws `ceil(K·(1+β))` clients, accepts the first K expected
    /// uploads and cancels the stragglers. `0.0` reproduces `uniform`
    /// bit-for-bit. Ignored by the other strategies.
    pub oversample_beta: f64,
    /// Per-client link/compute profile table
    /// (`uniform | tiered | file:PATH`). `uniform` keeps every client
    /// on the base `network` link (pre-profile behaviour); `tiered`
    /// splits clients round-robin over fast/mid/slow device classes
    /// with seeded jitter; `file:PATH` pins an exact cid-range →
    /// multipliers table from a config file.
    pub client_profiles: ProfileKind,
    /// Seconds of simulated client compute per round at profile
    /// multiplier 1.0 (scaled tables only; `uniform` stays at zero
    /// compute). Default 0.25 — the former hardcoded baseline, so
    /// existing presets are bit-identical.
    pub compute_base_s: f64,
    /// Which backend computes the `sim_net_event_s` round time
    /// (`closed | event`). `closed` reports the ideal pipelined
    /// envelope; `event` replays the round through the discrete-event
    /// simulator (`transport::sim`) at chunk granularity. Never
    /// affects training, sampling or the other simulated columns.
    pub time_model: TimeModelKind,
    /// Event-simulator transfer chunk size in KiB (>= 1).
    pub chunk_kb: usize,
    /// Event-simulator inter-stage queue capacity in chunks
    /// (0 = unbounded).
    pub stage_queue: usize,
    /// Rank tiers for a heterogeneous federation, e.g. `[2, 4, 8]`
    /// (clients are assigned round-robin by id). Empty = homogeneous.
    /// The server tag must be a LoRA variant; each tier needs the
    /// matching `_r{rank}` artifact and `rank <= server rank` (the
    /// up-projection pads exactly; the reverse would truncate).
    pub hetero_ranks: Vec<usize>,
    /// Per-tier wire codecs, parallel to `hetero_ranks`. Empty = every
    /// tier uses `codec`.
    pub hetero_codecs: Vec<CodecKind>,
    /// Server-side merge strategy (`fedavg | svt | exact`). The
    /// factor-aware modes act on the layout's adapter pairs and fall
    /// back to plain FedAvg on layouts without any (full models).
    pub aggregator: AggregatorKind,
    /// Retained-energy threshold τ ∈ (0, 1] for `aggregator = svt`:
    /// keep the smallest head of singular directions whose Σσ² reaches
    /// τ of the total. τ = 1.0 is bit-for-bit FedAvg. Ignored by the
    /// other aggregators.
    pub svt_energy: f64,
    /// Deterministic failure injection: `(round, cid)` coordinates at
    /// which a sampled client drops after its download, before its
    /// upload (`drop_plan = "1:3,4:0"`). Checked after the `dropout`
    /// coin so the RNG stream is untouched; the wire parity tests use
    /// it as the in-process reference for a killed remote client.
    /// Empty (the default) disables. Incompatible with
    /// `sampler = oversample_k` (the cancellation planner does not
    /// replay planned drops).
    pub drop_plan: Vec<(usize, usize)>,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            tag: "micro8_lora_fc_r4".into(),
            num_clients: 16,
            clients_per_round: 4,
            rounds: 20,
            local_epochs: 2,
            lr: 0.02,
            lora_alpha: 64.0, // 16 * r for r = 4
            codec: CodecKind::Fp32,
            lda_alpha: 0.5,
            samples_per_client: 48,
            test_samples: 240,
            seed: 42,
            eval_every: 2,
            dropout: 0.0,
            lr_decay: 1.0,
            executor: ExecutorKind::Serial,
            threads: 0,
            window: 0,
            shards: 1,
            network: NetworkKind::EdgeLte,
            net_sharing: Sharing::Dedicated,
            overlap: OverlapKind::None,
            sampler: SamplerKind::Uniform,
            oversample_beta: 0.0,
            client_profiles: ProfileKind::Uniform,
            compute_base_s: DEFAULT_COMPUTE_BASE_S,
            time_model: TimeModelKind::Closed,
            chunk_kb: 64,
            stage_queue: 4,
            hetero_ranks: Vec::new(),
            hetero_codecs: Vec::new(),
            aggregator: AggregatorKind::FedAvg,
            svt_energy: 0.9,
            drop_plan: Vec::new(),
        }
    }
}

/// Parse a comma-separated list (`"2,4,8"`); empty or `none` clears.
fn parse_list<T>(
    key: &str,
    value: &str,
    parse_one: impl Fn(&str) -> Option<T>,
) -> Result<Vec<T>> {
    let value = value.trim();
    if value.is_empty() || value == "none" {
        return Ok(Vec::new());
    }
    value
        .split(',')
        .map(|part| {
            let part = part.trim();
            parse_one(part).ok_or_else(|| {
                Error::parse(format!("bad entry `{part}` in `{key}`"))
            })
        })
        .collect()
}

impl FlConfig {
    /// Effective `alpha / r` scale for a given rank (1.0 for full).
    pub fn lora_scale(&self, rank: usize) -> f32 {
        if rank == 0 {
            1.0
        } else {
            self.lora_alpha / rank as f32
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.clients_per_round == 0 || self.clients_per_round > self.num_clients {
            return Err(Error::invalid(format!(
                "clients_per_round {} must be in [1, {}]",
                self.clients_per_round, self.num_clients
            )));
        }
        if self.rounds == 0 || self.local_epochs == 0 {
            return Err(Error::invalid("rounds/local_epochs must be > 0"));
        }
        if self.samples_per_client == 0 || self.test_samples == 0 {
            return Err(Error::invalid("dataset sizes must be > 0"));
        }
        if self.eval_every == 0 {
            return Err(Error::invalid("eval_every must be > 0"));
        }
        if !(self.lr > 0.0) || !(self.lda_alpha > 0.0) {
            return Err(Error::invalid("lr and lda_alpha must be > 0"));
        }
        if !(0.0..1.0).contains(&self.dropout) {
            return Err(Error::invalid("dropout must be in [0, 1)"));
        }
        if !(self.lr_decay > 0.0 && self.lr_decay <= 1.0) {
            return Err(Error::invalid("lr_decay must be in (0, 1]"));
        }
        if !(self.oversample_beta >= 0.0 && self.oversample_beta.is_finite())
        {
            return Err(Error::invalid("oversample_beta must be >= 0"));
        }
        if !(self.compute_base_s >= 0.0 && self.compute_base_s.is_finite()) {
            return Err(Error::invalid("compute_base_s must be >= 0"));
        }
        if self.chunk_kb == 0 {
            return Err(Error::invalid("chunk_kb must be > 0"));
        }
        if self.shards == 0 {
            return Err(Error::invalid("shards must be >= 1"));
        }
        if self.hetero_ranks.iter().any(|&r| r == 0) {
            return Err(Error::invalid("hetero_ranks entries must be > 0"));
        }
        if !self.hetero_codecs.is_empty()
            && self.hetero_codecs.len() != self.hetero_ranks.len()
        {
            return Err(Error::invalid(format!(
                "hetero_codecs has {} entries for {} rank tiers",
                self.hetero_codecs.len(),
                self.hetero_ranks.len()
            )));
        }
        if !(self.svt_energy > 0.0
            && self.svt_energy <= 1.0
            && self.svt_energy.is_finite())
        {
            return Err(Error::invalid("svt_energy must be in (0, 1]"));
        }
        if !self.drop_plan.is_empty()
            && self.sampler == SamplerKind::OversampleK
        {
            // The oversampling cancellation planner predicts expected
            // survivors by replaying the dropout coin only; a planned
            // drop it cannot see would skew the cut.
            return Err(Error::invalid(
                "drop_plan is incompatible with sampler = oversample_k",
            ));
        }
        if self.drop_plan.iter().any(|&(r, c)| {
            r >= self.rounds || c >= self.num_clients
        }) {
            return Err(Error::invalid(format!(
                "drop_plan entries must be round:cid within \
                 [0, {})×[0, {})",
                self.rounds, self.num_clients
            )));
        }
        Ok(())
    }

    /// Apply one `key = value` setting (config file or CLI override).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T> {
            v.parse().map_err(|_| {
                Error::parse(format!("bad value `{v}` for `{k}`"))
            })
        }
        match key {
            "tag" => self.tag = value.to_string(),
            "num_clients" => self.num_clients = p(key, value)?,
            "clients_per_round" => self.clients_per_round = p(key, value)?,
            "rounds" => self.rounds = p(key, value)?,
            "local_epochs" => self.local_epochs = p(key, value)?,
            "lr" => self.lr = p(key, value)?,
            "lora_alpha" => self.lora_alpha = p(key, value)?,
            "lda_alpha" => self.lda_alpha = p(key, value)?,
            "samples_per_client" => self.samples_per_client = p(key, value)?,
            "test_samples" => self.test_samples = p(key, value)?,
            "seed" => self.seed = p(key, value)?,
            "eval_every" => self.eval_every = p(key, value)?,
            "dropout" => self.dropout = p(key, value)?,
            "lr_decay" => self.lr_decay = p(key, value)?,
            "threads" => self.threads = p(key, value)?,
            "window" => self.window = p(key, value)?,
            "shards" => self.shards = p(key, value)?,
            // Enum-valued keys all route through the knob protocol —
            // one parse path and one error shape for the loader, the
            // CLI and direct `set` callers (see `config::knob`).
            "network" => self.network = parse_knob(value)?,
            "net_sharing" => self.net_sharing = parse_knob(value)?,
            "overlap" => self.overlap = parse_knob(value)?,
            "sampler" => self.sampler = parse_knob(value)?,
            "oversample_beta" => self.oversample_beta = p(key, value)?,
            "client_profiles" => self.client_profiles = parse_knob(value)?,
            "compute_base_s" => self.compute_base_s = p(key, value)?,
            "time_model" => self.time_model = parse_knob(value)?,
            "chunk_kb" => self.chunk_kb = p(key, value)?,
            "stage_queue" => self.stage_queue = p(key, value)?,
            "hetero_ranks" => {
                self.hetero_ranks = parse_list(key, value, |v| {
                    v.parse::<usize>().ok()
                })?
            }
            "hetero_codecs" => {
                self.hetero_codecs =
                    parse_list(key, value, CodecKind::parse)?
            }
            "executor" => self.executor = parse_knob(value)?,
            "codec" => self.codec = parse_knob(value)?,
            "aggregator" => self.aggregator = parse_knob(value)?,
            "svt_energy" => self.svt_energy = p(key, value)?,
            "drop_plan" => {
                self.drop_plan = parse_list(key, value, |v| {
                    let (r, c) = v.split_once(':')?;
                    Some((r.trim().parse().ok()?, c.trim().parse().ok()?))
                })?
            }
            _ => return Err(Error::parse(format!("unknown config key `{key}`"))),
        }
        Ok(())
    }

    /// Serialize every field as loader-format `key = value` lines —
    /// the config blob the wire server hands to clients at `Hello`.
    /// The round-trip law (`apply_str(default, to_blob(cfg)) == cfg`)
    /// is what lets a remote client rebuild the *exact* federation —
    /// same LDA partition, same RNG coordinates, same codec — from the
    /// blob alone; the config-blob test pins it field by field.
    pub fn to_blob(&self) -> String {
        let mut out = String::new();
        let mut kv = |k: &str, v: String| {
            out.push_str(k);
            out.push_str(" = ");
            out.push_str(&v);
            out.push('\n');
        };
        // Float fields rely on Display's shortest-round-trip rendering
        // (`0.1_f32` prints as `0.1` and re-parses to the same bits).
        kv("tag", format!("\"{}\"", self.tag));
        kv("num_clients", self.num_clients.to_string());
        kv("clients_per_round", self.clients_per_round.to_string());
        kv("rounds", self.rounds.to_string());
        kv("local_epochs", self.local_epochs.to_string());
        kv("lr", self.lr.to_string());
        kv("lora_alpha", self.lora_alpha.to_string());
        kv("codec", self.codec.to_string());
        kv("lda_alpha", self.lda_alpha.to_string());
        kv("samples_per_client", self.samples_per_client.to_string());
        kv("test_samples", self.test_samples.to_string());
        kv("seed", self.seed.to_string());
        kv("eval_every", self.eval_every.to_string());
        kv("dropout", self.dropout.to_string());
        kv("lr_decay", self.lr_decay.to_string());
        kv("executor", self.executor.to_string());
        kv("threads", self.threads.to_string());
        kv("window", self.window.to_string());
        kv("shards", self.shards.to_string());
        kv("network", self.network.to_string());
        kv("net_sharing", self.net_sharing.to_string());
        kv("overlap", self.overlap.to_string());
        kv("sampler", self.sampler.to_string());
        kv("oversample_beta", self.oversample_beta.to_string());
        kv("client_profiles", self.client_profiles.to_string());
        kv("compute_base_s", self.compute_base_s.to_string());
        kv("time_model", self.time_model.to_string());
        kv("chunk_kb", self.chunk_kb.to_string());
        kv("stage_queue", self.stage_queue.to_string());
        kv(
            "hetero_ranks",
            join_or_none(self.hetero_ranks.iter().map(usize::to_string)),
        );
        kv(
            "hetero_codecs",
            join_or_none(self.hetero_codecs.iter()
                .map(CodecKind::to_string)),
        );
        kv("aggregator", self.aggregator.to_string());
        kv("svt_energy", self.svt_energy.to_string());
        kv(
            "drop_plan",
            join_or_none(self.drop_plan.iter()
                .map(|(r, c)| format!("{r}:{c}"))),
        );
        out
    }
}

/// Comma-join for list-valued keys; the loader reads `none` as empty.
fn join_or_none(items: impl Iterator<Item = String>) -> String {
    let joined = items.collect::<Vec<_>>().join(",");
    if joined.is_empty() { "none".into() } else { joined }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        FlConfig::default().validate().unwrap();
    }

    #[test]
    fn set_and_validate() {
        let mut c = FlConfig::default();
        c.set("rounds", "7").unwrap();
        c.set("codec", "q4").unwrap();
        c.set("lr", "0.5").unwrap();
        assert_eq!(c.rounds, 7);
        assert_eq!(c.codec, CodecKind::Affine(4));
        assert!(c.set("codec", "bogus").is_err());
        assert!(c.set("nope", "1").is_err());
        c.set("clients_per_round", "100").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn executor_knobs_parse_and_default_serial() {
        let mut c = FlConfig::default();
        assert_eq!(c.executor, ExecutorKind::Serial);
        assert_eq!(c.threads, 0);
        c.set("executor", "parallel").unwrap();
        c.set("threads", "8").unwrap();
        assert_eq!(c.executor, ExecutorKind::Parallel);
        assert_eq!(c.threads, 8);
        c.validate().unwrap();
        assert!(c.set("executor", "turbo").is_err());
        assert!(c.set("threads", "-1").is_err());
    }

    #[test]
    fn network_and_window_knobs_parse() {
        let mut c = FlConfig::default();
        assert_eq!(c.network, NetworkKind::EdgeLte);
        assert_eq!(c.net_sharing, Sharing::Dedicated);
        assert_eq!(c.window, 0);
        c.set("network", "wifi").unwrap();
        c.set("net_sharing", "shared").unwrap();
        c.set("window", "3").unwrap();
        assert_eq!(c.network, NetworkKind::Wifi);
        assert_eq!(c.net_sharing, Sharing::Shared);
        assert_eq!(c.window, 3);
        c.validate().unwrap();
        assert!(c.set("network", "5g").is_err());
        assert!(c.set("net_sharing", "split").is_err());
    }

    #[test]
    fn overlap_knob_parses_and_defaults_to_none() {
        let mut c = FlConfig::default();
        assert_eq!(c.overlap, OverlapKind::None);
        c.set("overlap", "transfer").unwrap();
        assert_eq!(c.overlap, OverlapKind::Transfer);
        c.validate().unwrap();
        c.set("overlap", "none").unwrap();
        assert_eq!(c.overlap, OverlapKind::None);
        assert!(c.set("overlap", "both").is_err());
    }

    #[test]
    fn sampler_and_profile_knobs_parse_and_validate() {
        let mut c = FlConfig::default();
        assert_eq!(c.sampler, SamplerKind::Uniform);
        assert_eq!(c.oversample_beta, 0.0);
        assert_eq!(c.client_profiles, ProfileKind::Uniform);
        c.set("sampler", "oversample_k").unwrap();
        c.set("oversample_beta", "0.5").unwrap();
        c.set("client_profiles", "tiered").unwrap();
        assert_eq!(c.sampler, SamplerKind::OversampleK);
        assert_eq!(c.oversample_beta, 0.5);
        assert_eq!(c.client_profiles, ProfileKind::Tiered);
        c.validate().unwrap();
        c.set("sampler", "latency_biased").unwrap();
        c.validate().unwrap();
        assert!(c.set("sampler", "fastest").is_err());
        assert!(c.set("client_profiles", "chaos").is_err());
        assert!(c.set("oversample_beta", "x").is_err());
        // Negative beta survives parsing but fails validation.
        c.set("oversample_beta", "-0.1").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn time_model_knobs_parse_and_validate() {
        let mut c = FlConfig::default();
        assert_eq!(c.time_model, TimeModelKind::Closed);
        assert_eq!(c.chunk_kb, 64);
        assert_eq!(c.stage_queue, 4);
        c.set("time_model", "event").unwrap();
        c.set("chunk_kb", "16").unwrap();
        c.set("stage_queue", "0").unwrap();
        assert_eq!(c.time_model, TimeModelKind::Event);
        assert_eq!(c.chunk_kb, 16);
        assert_eq!(c.stage_queue, 0);
        c.validate().unwrap();
        assert!(c.set("time_model", "fluid").is_err());
        assert!(c.set("chunk_kb", "x").is_err());
        // chunk_kb = 0 survives parsing but fails validation.
        c.set("chunk_kb", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn compute_base_and_file_profile_knobs_parse() {
        let mut c = FlConfig::default();
        assert_eq!(c.compute_base_s, 0.25);
        c.set("compute_base_s", "0.75").unwrap();
        assert_eq!(c.compute_base_s, 0.75);
        c.validate().unwrap();
        c.set("client_profiles", "file:fleet.toml").unwrap();
        assert_eq!(
            c.client_profiles,
            ProfileKind::File("fleet.toml".into())
        );
        c.validate().unwrap();
        assert!(c.set("client_profiles", "file:").is_err());
        assert!(c.set("compute_base_s", "x").is_err());
        c.set("compute_base_s", "-0.5").unwrap();
        assert!(c.validate().is_err());
        c.set("compute_base_s", "nan").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn hetero_knobs_parse_and_validate() {
        let mut c = FlConfig::default();
        assert!(c.hetero_ranks.is_empty());
        c.set("hetero_ranks", "2, 4,8").unwrap();
        assert_eq!(c.hetero_ranks, vec![2, 4, 8]);
        c.validate().unwrap();
        c.set("hetero_codecs", "q4,q8,fp32").unwrap();
        assert_eq!(
            c.hetero_codecs,
            vec![CodecKind::Affine(4), CodecKind::Affine(8), CodecKind::Fp32]
        );
        c.validate().unwrap();
        // Tier/codec arity mismatch is a config error.
        c.set("hetero_ranks", "2,4").unwrap();
        assert!(c.validate().is_err());
        // `none` clears.
        c.set("hetero_codecs", "none").unwrap();
        c.validate().unwrap();
        c.set("hetero_ranks", "none").unwrap();
        assert!(c.hetero_ranks.is_empty());
        assert!(c.set("hetero_ranks", "2,x").is_err());
        // A zero rank survives parsing but fails validation.
        c.set("hetero_ranks", "0,4").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn aggregator_knobs_parse_and_validate() {
        let mut c = FlConfig::default();
        assert_eq!(c.aggregator, AggregatorKind::FedAvg);
        assert_eq!(c.svt_energy, 0.9);
        c.set("aggregator", "svt").unwrap();
        c.set("svt_energy", "0.8").unwrap();
        assert_eq!(c.aggregator, AggregatorKind::Svt);
        assert_eq!(c.svt_energy, 0.8);
        c.validate().unwrap();
        c.set("aggregator", "exact").unwrap();
        c.validate().unwrap();
        c.set("aggregator", "fedavg").unwrap();
        c.validate().unwrap();
        assert!(c.set("aggregator", "median").is_err());
        assert!(c.set("svt_energy", "x").is_err());
        // Out-of-range thresholds survive parsing, fail validation.
        for bad in ["0", "-0.5", "1.5", "nan"] {
            c.set("svt_energy", bad).unwrap();
            assert!(c.validate().is_err(), "svt_energy = {bad}");
        }
        c.set("svt_energy", "1.0").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn shards_knob_parses_and_validates() {
        let mut c = FlConfig::default();
        assert_eq!(c.shards, 1);
        c.set("shards", "4").unwrap();
        assert_eq!(c.shards, 4);
        c.validate().unwrap();
        assert!(c.set("shards", "x").is_err());
        // shards = 0 survives parsing but fails validation.
        c.set("shards", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn drop_plan_parses_and_validates() {
        let mut c = FlConfig::default();
        assert!(c.drop_plan.is_empty());
        c.set("drop_plan", "1:3, 4:0").unwrap();
        assert_eq!(c.drop_plan, vec![(1, 3), (4, 0)]);
        c.validate().unwrap();
        // `none` clears.
        c.set("drop_plan", "none").unwrap();
        assert!(c.drop_plan.is_empty());
        assert!(c.set("drop_plan", "1:x").is_err());
        assert!(c.set("drop_plan", "7").is_err());
        // Out-of-range coordinates survive parsing, fail validation.
        c.set("drop_plan", "99:0").unwrap();
        assert!(c.validate().is_err());
        c.set("drop_plan", "0:99").unwrap();
        assert!(c.validate().is_err());
        // Planned drops cannot mix with the oversampling planner.
        c.set("drop_plan", "1:1").unwrap();
        c.set("sampler", "oversample_k").unwrap();
        assert!(c.validate().is_err());
        c.set("sampler", "uniform").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn blob_round_trips_every_field() {
        // A config with every field off its default; the blob applied
        // to a default must reproduce it exactly (the wire client
        // rebuilds its federation from nothing but this blob).
        let mut cfg = FlConfig::default();
        for (k, v) in [
            ("tag", "micro8_lora_fc_r8"),
            ("num_clients", "24"),
            ("clients_per_round", "6"),
            ("rounds", "9"),
            ("local_epochs", "3"),
            ("lr", "0.013"),
            ("lora_alpha", "48.5"),
            ("codec", "sparse_ef:0.25"),
            ("lda_alpha", "0.31"),
            ("samples_per_client", "20"),
            ("test_samples", "50"),
            ("seed", "977"),
            ("eval_every", "3"),
            ("dropout", "0.12"),
            ("lr_decay", "0.97"),
            ("executor", "parallel"),
            ("threads", "3"),
            ("window", "5"),
            ("shards", "2"),
            ("network", "wifi"),
            ("net_sharing", "shared"),
            ("overlap", "transfer"),
            ("sampler", "latency_biased"),
            ("oversample_beta", "0.4"),
            ("client_profiles", "tiered"),
            ("compute_base_s", "0.75"),
            ("time_model", "event"),
            ("chunk_kb", "32"),
            ("stage_queue", "7"),
            ("hetero_ranks", "2,4"),
            ("hetero_codecs", "q4,q8"),
            ("aggregator", "svt"),
            ("svt_energy", "0.85"),
            ("drop_plan", "1:3,4:0"),
        ] {
            cfg.set(k, v).unwrap();
        }
        let mut back = FlConfig::default();
        loader::apply_str(&mut back, &cfg.to_blob()).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
        // And the default round-trips too (list fields emit `none`).
        let mut dflt = FlConfig::default();
        loader::apply_str(&mut dflt, &FlConfig::default().to_blob())
            .unwrap();
        assert_eq!(format!("{dflt:?}"), format!("{:?}", FlConfig::default()));
    }

    #[test]
    fn lora_scale_math() {
        let mut c = FlConfig::default();
        c.lora_alpha = 512.0;
        assert_eq!(c.lora_scale(32), 16.0);
        assert_eq!(c.lora_scale(0), 1.0);
    }
}
