//! CIFAR-S: a synthetic, class-conditional image distribution.
//!
//! Each class owns a *spectral signature*: per channel, two spatial
//! sinusoidal gratings with class-specific frequencies/orientations and
//! a class-specific color bias. An individual sample draws
//! instance-specific phases, amplitude jitter, a random affine
//! brightness gradient and pixel noise — so the class is recoverable
//! from frequency/color statistics (what conv layers excel at) while
//! single samples vary substantially.
//!
//! Everything is a pure function of `(class, instance rng)`; the class
//! signature derives from a SplitMix-style hash so train and test draw
//! from the identical class-conditional distribution.

use crate::util::rng::Rng;

/// Pixel noise level; chosen so a ResNet-8-class model reaches high but
/// not saturated accuracy at the scaled experiment sizes.
const NOISE: f32 = 0.18;

/// Class signature: two gratings + color bias per channel.
struct ClassSig {
    // per channel: (fx1, fy1, fx2, fy2) in cycles per image
    freqs: [[f32; 4]; 3],
    color: [f32; 3],
}

fn class_sig(class: usize) -> ClassSig {
    // Deterministic per class, independent of image size.
    let mut rng = Rng::new(0xC1FA_0000 + class as u64);
    let mut freqs = [[0.0f32; 4]; 3];
    for ch in freqs.iter_mut() {
        // Frequencies in [1, 6] cycles; orientation via independent x/y
        // components. Distinct per class/channel with high probability.
        for f in ch.iter_mut() {
            *f = (1.0 + 5.0 * rng.f32()) * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
    }
    let mut color = [0.0f32; 3];
    for c in color.iter_mut() {
        *c = 0.35 + 0.3 * rng.f32();
    }
    ClassSig { freqs, color }
}

/// Generate one `size x size x 3` image (NHWC, row-major, values ~[0,1]).
pub fn gen_image(class: usize, size: usize, rng: &mut Rng, out: &mut [f32]) {
    assert_eq!(out.len(), size * size * 3);
    let sig = class_sig(class);
    // Instance parameters.
    let phase1 = (rng.f32() * std::f32::consts::TAU, rng.f32() * std::f32::consts::TAU,
                  rng.f32() * std::f32::consts::TAU);
    let phase2 = (rng.f32() * std::f32::consts::TAU, rng.f32() * std::f32::consts::TAU,
                  rng.f32() * std::f32::consts::TAU);
    let amp1 = 0.7 + 0.6 * rng.f32();
    let amp2 = 0.7 + 0.6 * rng.f32();
    // Random brightness gradient (nuisance factor shared by channels).
    let gx = (rng.f32() - 0.5) * 0.3;
    let gy = (rng.f32() - 0.5) * 0.3;

    let inv = 1.0 / size as f32;
    for y in 0..size {
        for x in 0..size {
            let fx = x as f32 * inv;
            let fy = y as f32 * inv;
            let grad = gx * (fx - 0.5) + gy * (fy - 0.5);
            for ch in 0..3 {
                let f = &sig.freqs[ch];
                let ph1 = match ch {
                    0 => phase1.0,
                    1 => phase1.1,
                    _ => phase1.2,
                };
                let ph2 = match ch {
                    0 => phase2.0,
                    1 => phase2.1,
                    _ => phase2.2,
                };
                let s1 = (std::f32::consts::TAU * (f[0] * fx + f[1] * fy) + ph1).sin();
                let s2 = (std::f32::consts::TAU * (f[2] * fx + f[3] * fy) + ph2).sin();
                let v = sig.color[ch]
                    + 0.22 * amp1 * s1
                    + 0.13 * amp2 * s2
                    + grad
                    + NOISE * rng.normal() as f32;
                out[(y * size + x) * 3 + ch] = v.clamp(0.0, 1.0);
            }
        }
    }
}

/// Held-out IID balanced test set.
pub struct TestSet {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub image_size: usize,
}

impl TestSet {
    /// `n` samples, classes round-robin (exactly balanced), disjoint RNG
    /// stream from all training data.
    pub fn generate(n: usize, size: usize, classes: usize, seed: u64) -> TestSet {
        let mut rng = Rng::new(seed ^ 0x7E57_5E7);
        let mut images = vec![0.0f32; n * size * size * 3];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % classes;
            gen_image(class, size,
                      &mut rng.fork(i as u64),
                      &mut images[i * size * size * 3..(i + 1) * size * size * 3]);
            labels.push(class as i32);
        }
        TestSet { images, labels, n, image_size: size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let mut a = vec![0.0; 16 * 16 * 3];
        let mut b = vec![0.0; 16 * 16 * 3];
        gen_image(3, 16, &mut Rng::new(9), &mut a);
        gen_image(3, 16, &mut Rng::new(9), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn instances_differ_within_class() {
        let mut a = vec![0.0; 16 * 16 * 3];
        let mut b = vec![0.0; 16 * 16 * 3];
        gen_image(3, 16, &mut Rng::new(1), &mut a);
        gen_image(3, 16, &mut Rng::new(2), &mut b);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff / a.len() as f32 > 0.05, "instances too similar");
    }

    #[test]
    fn values_in_range() {
        let mut img = vec![0.0; 32 * 32 * 3];
        for class in 0..10 {
            gen_image(class, 32, &mut Rng::new(class as u64), &mut img);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn classes_are_spectrally_separable() {
        // Nearest-centroid in raw pixel space should beat chance on a
        // small sample — weak but fast proxy for learnability.
        let size = 16;
        let dim = size * size * 3;
        let classes = 4;
        let per = 24;
        let mut centroids = vec![vec![0.0f64; dim]; classes];
        let mut train: Vec<(usize, Vec<f32>)> = Vec::new();
        let mut rng = Rng::new(77);
        for c in 0..classes {
            for i in 0..per {
                let mut img = vec![0.0f32; dim];
                gen_image(c, size, &mut rng.fork((c * 1000 + i) as u64), &mut img);
                for (acc, &v) in centroids[c].iter_mut().zip(&img) {
                    *acc += v as f64 / per as f64;
                }
                train.push((c, img));
            }
        }
        let mut correct = 0;
        let mut total = 0;
        for c in 0..classes {
            for i in 0..8 {
                let mut img = vec![0.0f32; dim];
                gen_image(c, size, &mut rng.fork((90_000 + c * 100 + i) as u64),
                          &mut img);
                let best = (0..classes)
                    .min_by(|&a, &b| {
                        let da: f64 = centroids[a].iter().zip(&img)
                            .map(|(m, &v)| (m - v as f64).powi(2)).sum();
                        let db: f64 = centroids[b].iter().zip(&img)
                            .map(|(m, &v)| (m - v as f64).powi(2)).sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                correct += (best == c) as usize;
                total += 1;
            }
        }
        // Chance is 25%; spectral classes should give centroids real pull.
        assert!(correct as f64 / total as f64 > 0.5,
                "{correct}/{total} — classes not separable enough");
        let _ = train;
    }

    #[test]
    fn test_set_balanced() {
        let ts = TestSet::generate(40, 16, 10, 5);
        let mut hist = [0usize; 10];
        for &l in &ts.labels {
            hist[l as usize] += 1;
        }
        assert!(hist.iter().all(|&c| c == 4));
    }
}
