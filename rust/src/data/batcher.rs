//! Minibatch iteration over a local dataset: per-epoch Fisher-Yates
//! shuffle, fixed batch size (artifacts are lowered for a static batch),
//! and three tail policies for the ragged final batch:
//!
//! * [`Tail::Drop`]    — training default: partial batches are skipped.
//! * [`Tail::PadWrap`] — training on shards smaller than one batch: pad
//!   by wrapping around the shard (the train artifact has no mask input,
//!   so zero-padding would bias gradients toward class 0 / black images).
//! * [`Tail::PadZero`] — eval: zero-pad + mask, exact counts.

use crate::runtime::Batch;
use crate::util::rng::Rng;

/// Ragged-final-batch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tail {
    Drop,
    PadZero,
    PadWrap,
}

/// Iterator producing fixed-size [`Batch`]es over (images, labels).
pub struct BatchIter<'a> {
    images: &'a [f32],
    labels: &'a [i32],
    px: usize,
    batch_size: usize,
    order: Vec<usize>,
    pos: usize,
    tail: Tail,
}

impl<'a> BatchIter<'a> {
    pub fn new(
        images: &'a [f32],
        labels: &'a [i32],
        image_size: usize,
        batch_size: usize,
        shuffle_rng: Option<&mut Rng>,
        tail: Tail,
    ) -> Self {
        let px = image_size * image_size * 3;
        assert_eq!(images.len(), labels.len() * px, "image/label mismatch");
        assert!(!labels.is_empty(), "empty dataset");
        let mut order: Vec<usize> = (0..labels.len()).collect();
        if let Some(rng) = shuffle_rng {
            rng.shuffle(&mut order);
        }
        BatchIter { images, labels, px, batch_size, order, pos: 0, tail }
    }

    pub fn num_batches(&self) -> usize {
        match self.tail {
            Tail::Drop => self.order.len() / self.batch_size,
            _ => self.order.len().div_ceil(self.batch_size),
        }
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let remaining = self.order.len() - self.pos;
        if remaining == 0 {
            return None;
        }
        if remaining < self.batch_size && self.tail == Tail::Drop {
            return None;
        }
        let n = remaining.min(self.batch_size);
        let mut x = vec![0.0f32; self.batch_size * self.px];
        let mut y = vec![0i32; self.batch_size];
        let mut mask = vec![0.0f32; self.batch_size];
        for j in 0..self.batch_size {
            let idx = match (j < n, self.tail) {
                (true, _) => self.order[self.pos + j],
                (false, Tail::PadWrap) => self.order[(self.pos + j) % self.order.len()],
                (false, _) => {
                    continue; // PadZero: leave zeros, mask stays 0
                }
            };
            x[j * self.px..(j + 1) * self.px]
                .copy_from_slice(&self.images[idx * self.px..(idx + 1) * self.px]);
            y[j] = self.labels[idx];
            mask[j] = 1.0;
        }
        self.pos += n;
        Some(Batch { x, y, mask, n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_data(n: usize, size: usize) -> (Vec<f32>, Vec<i32>) {
        let px = size * size * 3;
        let images: Vec<f32> = (0..n * px).map(|i| i as f32).collect();
        let labels: Vec<i32> = (0..n as i32).collect();
        (images, labels)
    }

    #[test]
    fn covers_everything_once_with_pad_zero() {
        let (im, lb) = mk_data(10, 2);
        let it = BatchIter::new(&im, &lb, 2, 4, None, Tail::PadZero);
        assert_eq!(it.num_batches(), 3);
        let mut seen = Vec::new();
        for b in it {
            for j in 0..4 {
                if b.mask[j] > 0.0 {
                    seen.push(b.y[j]);
                }
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn drop_skips_ragged() {
        let (im, lb) = mk_data(10, 2);
        let it = BatchIter::new(&im, &lb, 2, 4, None, Tail::Drop);
        assert_eq!(it.num_batches(), 2);
        assert_eq!(it.count(), 2);
    }

    #[test]
    fn pad_zero_masks_and_zeroes() {
        let (im, lb) = mk_data(5, 2);
        let batches: Vec<Batch> =
            BatchIter::new(&im, &lb, 2, 4, None, Tail::PadZero).collect();
        assert_eq!(batches.len(), 2);
        let last = &batches[1];
        assert_eq!(last.n, 1);
        assert_eq!(last.mask, vec![1.0, 0.0, 0.0, 0.0]);
        assert!(last.x[12..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pad_wrap_reuses_real_samples() {
        let (im, lb) = mk_data(3, 2);
        let batches: Vec<Batch> =
            BatchIter::new(&im, &lb, 2, 8, None, Tail::PadWrap).collect();
        assert_eq!(batches.len(), 1);
        let b = &batches[0];
        assert_eq!(b.n, 3);
        // All 8 slots hold real examples (wrapped), all marked valid.
        assert_eq!(b.y, vec![0, 1, 2, 0, 1, 2, 0, 1]);
        assert!(b.mask.iter().all(|&m| m == 1.0));
        // Slot 3 is a copy of sample 0.
        assert_eq!(&b.x[3 * 12..4 * 12], &b.x[0..12]);
    }

    #[test]
    fn shuffle_changes_order_but_not_pairing() {
        let (im, lb) = mk_data(8, 2);
        let mut rng = Rng::new(3);
        let batches: Vec<Batch> =
            BatchIter::new(&im, &lb, 2, 8, Some(&mut rng), Tail::PadZero)
                .collect();
        let b = &batches[0];
        let px = 12;
        for j in 0..8 {
            assert_eq!(b.x[j * px], (b.y[j] as usize * px) as f32);
        }
        assert_ne!(b.y, (0..8).collect::<Vec<_>>());
    }
}
