//! Data substrate: the CIFAR-S synthetic dataset, the LDA (Dirichlet)
//! non-IID partitioner, and the minibatch loader.
//!
//! **Substitution note (DESIGN.md §2).** The paper trains on CIFAR-10,
//! which cannot be downloaded in this offline environment. CIFAR-S is a
//! deterministic, class-conditional 3-channel image distribution with
//! the properties the experiments actually exercise: (a) learnable by
//! small CNNs but not linearly trivial, (b) controllable intra-class
//! variance, (c) label-driven so LDA partitioning produces the same
//! client-skew structure as Hsu et al. [20].

pub mod batcher;
pub mod cifar_s;
pub mod partition;

pub use batcher::BatchIter;
pub use cifar_s::{gen_image, TestSet};
pub use partition::{lda_partition, ClientData, Federation, LAZY_THRESHOLD};
