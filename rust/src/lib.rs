//! # FLoCoRA — Federated Learning Compression with Low-Rank Adaptation
//!
//! Production-style reproduction of *"FLoCoRA: Federated learning
//! compression with low-rank adaptation"* (Grativol et al., EUSIPCO
//! 2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the federated-learning coordinator:
//!   round scheduling with pluggable client executors (serial reference
//!   or bit-identical thread-pool fan-out, [`coordinator::executor`]),
//!   client sampling, FedAvg aggregation over flat parameter vectors,
//!   wire codecs (fp32 / affine-quantized 8-4-2 bit / magnitude-pruning
//!   sparse / ZeroFL sparse), total-communication-cost accounting, LDA
//!   data partitioning, the synthetic CIFAR-S dataset, metrics, config
//!   and CLI.
//! * **Layer 2 (python, build time)** — JAX ResNet-8/18 forward/backward
//!   with LoRA adapters, lowered once to HLO text (`make artifacts`).
//! * **Layer 1 (python, build time)** — Pallas kernels for the fused
//!   low-rank matmul and affine quantization, verified against pure-jnp
//!   oracles and lowered into the same HLO.
//!
//! At runtime the rust binary loads `artifacts/*.hlo.txt` through the
//! PJRT C API (`xla` crate) and drives everything itself — python never
//! appears on the request path.
//!
//! Entry points: [`coordinator::Simulation`] for programmatic use (see
//! `examples/quickstart.rs`), the `flocora` binary for the CLI. Crate
//! how-to lives in `rust/README.md`; the system map in
//! `ARCHITECTURE.md` at the repo root.

pub mod cli;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sync;
pub mod tensor;
pub mod transport;
pub mod util;

pub use error::{Error, Result};
