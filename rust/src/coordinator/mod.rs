//! The FL coordinator — the paper's system contribution at Layer 3.
//!
//! FLoCoRA's protocol (paper §III, Fig. 1) per round `t`:
//!
//! 1. the server **downloads** the global adapter vector `Δ̄_t L`
//!    (encoded by the active wire codec — fp32 or affine-quantized) to
//!    the sampled subset `K` of clients;
//! 2. each client trains **only** the adapter vector locally (the frozen
//!    base `W_initial` never moves and is never re-transmitted);
//! 3. clients **upload** their updated adapter vectors `Δ_{t+1}^k L`
//!    (same codec);
//! 4. the server **aggregates** with FedAvg's `n_k / n` weighted mean —
//!    or a factor-aware mode from the aggregation zoo (`aggregator =
//!    svt|exact`, see [`aggregator`]).
//!
//! The aggregator never inspects what the vector means — full model
//! (FedAvg baseline), adapters (FLoCoRA), or a sparsified variant
//! (pruning/ZeroFL baselines) all flow through the identical loop,
//! which is exactly the paper's "implementable in any FL optimization
//! method" claim, here enforced by the type system: [`server::Server`]
//! only sees `&[f32]` + a [`crate::compression::Codec`].

//! Within a round the protocol is embarrassingly parallel — clients
//! only meet at step 4 — so per-client execution is pluggable
//! ([`executor::ClientExecutor`]): the serial reference, the windowed
//! thread-pool executor and the staged transfer-overlap pipeline
//! (`overlap = transfer`, transfer stages on dedicated transport
//! threads) produce bit-identical runs by construction, streaming each
//! result into the server's in-place merge ([`sink::RoundSink`]) in
//! sampling order; the merge narrates each client's round to the
//! transport stage (`transport::stage`), which owns all wire-time
//! accounting. A [`hetero::ClientPlan`] extends the same loop to
//! rank-heterogeneous federations (per-client rank tiers and codecs).

pub mod aggregator;
pub mod executor;
pub mod hetero;
pub mod sampler;
pub mod server;
pub mod shard;
pub mod sink;
pub mod trainer;
pub mod window;

pub use aggregator::{adapter_pairs, AdapterPair, AggOutcome, AggPartial,
                     Aggregator, AggregatorKind, ClientUpdate,
                     ExactAggregator, FedAvg, SvtAggregator};
pub use executor::{run_client, ClientExecutor, ExecutorKind,
                   ParallelExecutor, PipelinedExecutor, SerialExecutor};
pub use hetero::{ClientPlan, PlanTier};
pub use sampler::{LatencyBiasedSampler, OversampleSampler, Sampler,
                  SamplerKind, UniformSampler};
pub use server::{RoundPlan, RunSummary, Simulation};
pub use shard::{shard_slices, SHARD_BLOCK};
pub use sink::{collect_round, RoundSink, VecSink};
pub use trainer::LocalTrainer;
