//! The streaming half of the round engine: where client results *go*.
//!
//! [`super::executor::ClientExecutor::execute`] does not return a
//! `Vec` of results — it pushes each [`ClientResult`] into a
//! [`RoundSink`] as soon as that client's slot comes up in sampling
//! order. The server's merge (ledger entries, FedAvg adds, dropout
//! counts, network-load accounting) therefore runs *incrementally*,
//! and a round's peak memory is O(params + out-of-order window)
//! instead of O(clients_per_round × params).
//!
//! **Sink contract.** For a round over `clients` (the sampling-order
//! id slice):
//!
//! 1. `push(index, result)` is called exactly once per index, with
//!    `index` strictly increasing from 0 to `clients.len() - 1`;
//! 2. `result.cid == clients[index]` — results arrive in sampling
//!    order no matter how the executor scheduled the work;
//! 3. every call happens on the thread that called `execute` (the
//!    coordinator thread), so a sink needs no synchronization;
//! 4. an `Err` from `push` aborts the round: the executor stops
//!    draining, winds down its workers, and propagates the error.
//!
//! Implementations: the server's in-place merge
//! (`coordinator::server`), [`VecSink`] for tests and callers that
//! genuinely want the batch-collect behaviour back.
//!
//! The server's merge additionally narrates each drained result to the
//! simulated transport stage as
//! [`StageEvent`](crate::transport::StageEvent)s (download → train →
//! upload / dropped / cancelled) — wire-time charging lives in
//! `transport::stage`, not in sinks. Because pushes are single-threaded
//! and in sampling order, that event stream is deterministic no matter
//! which executor (serial, windowed-parallel, or the staged
//! `overlap = transfer` pipeline) produced the results.
//!
//! The single-threaded guarantee (point 3) is not taken on faith: the
//! claim/drain protocol that funnels concurrent worker results into the
//! one draining thread lives in [`super::window`] and is model-checked
//! under loom (`tests/loom.rs`), including panic/abort interleavings.
//! Sinks therefore stay lock-free by construction, and the determinism
//! lint (`cargo xtask lint-determinism`) keeps `std::sync` out of them.

use crate::coordinator::executor::{ClientExecutor, ClientResult,
                                   RoundContext};
use crate::error::Result;

/// Receives one round's client results, in sampling order.
pub trait RoundSink {
    /// Accept the result for `clients[index]`. See the module docs for
    /// the exact ordering/threading contract.
    fn push(&mut self, index: usize, result: ClientResult) -> Result<()>;
}

/// The batch-collect behaviour as a sink: buffers every result.
///
/// This is what the pre-streaming engine did implicitly; keep it for
/// tests and tools that want the whole round in hand. Production
/// merges should stream instead.
#[derive(Debug, Default)]
pub struct VecSink {
    pub results: Vec<ClientResult>,
}

impl VecSink {
    pub fn new() -> VecSink {
        VecSink::default()
    }
}

impl RoundSink for VecSink {
    fn push(&mut self, index: usize, result: ClientResult) -> Result<()> {
        debug_assert_eq!(index, self.results.len(),
                         "sink contract: indices arrive in order");
        self.results.push(result);
        Ok(())
    }
}

/// Run a round and collect every result into a `Vec` — the old
/// batch-collect `execute` signature as a helper.
pub fn collect_round(
    executor: &dyn ClientExecutor,
    ctx: &RoundContext<'_>,
    clients: &[usize],
) -> Result<Vec<ClientResult>> {
    let mut sink = VecSink::new();
    executor.execute(ctx, clients, &mut sink)?;
    Ok(sink.results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_sink_buffers_in_order() {
        let mut sink = VecSink::new();
        for i in 0..3 {
            sink.push(i, ClientResult {
                cid: 10 + i,
                down_bytes: 4,
                update: None,
                cancelled: false,
            })
            .unwrap();
        }
        assert_eq!(sink.results.len(), 3);
        assert!(sink.results.iter().enumerate()
                    .all(|(i, r)| r.cid == 10 + i));
    }
}
