//! The streaming half of the round engine: where client results *go*.
//!
//! [`super::executor::ClientExecutor::execute`] does not return a
//! `Vec` of results — it pushes each [`ClientResult`] into a
//! [`RoundSink`] as soon as that client's slot comes up in sampling
//! order. The server's merge (ledger entries, aggregator folds,
//! dropout counts, network-load accounting) therefore runs
//! *incrementally*, and a round's peak memory is O(params +
//! out-of-order window) instead of O(clients_per_round × params).
//!
//! **Sink contract.** For one `execute` over `clients` (a
//! sampling-order id slice):
//!
//! 1. `push(index, result)` is called exactly once per index, with
//!    `index` strictly increasing from 0 to `clients.len() - 1`;
//! 2. `result.cid == clients[index]` — results arrive in sampling
//!    order no matter how the executor scheduled the work;
//! 3. every call happens on the thread that called `execute`, so a
//!    sink needs no synchronization;
//! 4. an `Err` from `push` aborts the round: the executor stops
//!    draining, winds down its workers, and propagates the error.
//!
//! **Sharding contract.** Under the sharded coordinator
//! (`shards > 1`; see `coordinator::shard`) a round runs one sink
//! *per shard*: each shard's executor call covers one contiguous,
//! block-aligned partition of the sampled clients, its sink sees
//! *shard-local* indices `0..partition.len()` (point 1 applies per
//! shard), and possibly a different thread per shard — but still
//! exactly one thread per sink, so sinks stay lock-free. The
//! coordinator owns the cross-shard merge, in canonical shard order;
//! a sink must never aggregate across shards itself. [`collect_round`]
//! is the reference implementation of that ownership rule: callers
//! hand it one boxed sink per shard and it partitions the clients
//! with [`shard_slices`](crate::coordinator::shard::shard_slices).
//!
//! Implementations: the server's in-place shard merge
//! (`coordinator::server`), [`VecSink`] for tests and callers that
//! genuinely want the batch-collect behaviour back.
//!
//! The server's merge additionally narrates each drained result as
//! [`StageEvent`](crate::transport::StageEvent)s (download → train →
//! upload / dropped / cancelled), replayed into the simulated
//! transport stage on the coordinator thread — wire-time charging
//! lives in `transport::stage`, not in sinks. Because pushes are
//! single-threaded per shard and in sampling order, that event stream
//! is deterministic no matter which executor (serial,
//! windowed-parallel, or the staged `overlap = transfer` pipeline)
//! produced the results.
//!
//! The one-thread-per-sink guarantee (point 3) is not taken on faith:
//! the claim/drain protocol that funnels concurrent worker results
//! into the one draining thread lives in [`super::window`] and is
//! model-checked under loom (`tests/loom.rs`) — as is the shard
//! claim/merge handshake (`coordinator::shard::run_partitioned`) —
//! including panic/abort interleavings. Sinks therefore stay
//! lock-free by construction, and the determinism lint
//! (`cargo xtask lint-determinism`) keeps `std::sync` out of them.

use crate::coordinator::executor::{ClientExecutor, ClientResult,
                                   RoundContext};
use crate::coordinator::shard::shard_slices;
use crate::error::Result;

/// Receives one shard's client results, in sampling order.
pub trait RoundSink {
    /// Accept the result for `clients[index]` (`index` is shard-local
    /// under the sharded coordinator). See the module docs for the
    /// exact ordering/threading contract.
    fn push(&mut self, index: usize, result: ClientResult) -> Result<()>;
}

/// Forwarding impl so callers can lend a sink to the boxed-slice APIs
/// (`Box::new(&mut my_sink)`) and keep reading it afterwards.
impl<S: RoundSink + ?Sized> RoundSink for &mut S {
    fn push(&mut self, index: usize, result: ClientResult) -> Result<()> {
        (**self).push(index, result)
    }
}

/// The batch-collect behaviour as a sink: buffers every result.
///
/// This is what the pre-streaming engine did implicitly; keep it for
/// tests and tools that want the whole round (or shard) in hand.
/// Production merges should stream instead.
#[derive(Debug, Default)]
pub struct VecSink {
    pub results: Vec<ClientResult>,
}

impl VecSink {
    pub fn new() -> VecSink {
        VecSink::default()
    }
}

impl RoundSink for VecSink {
    fn push(&mut self, index: usize, result: ClientResult) -> Result<()> {
        debug_assert_eq!(index, self.results.len(),
                         "sink contract: indices arrive in order");
        self.results.push(result);
        Ok(())
    }
}

/// Run a round under the sharded ownership rule: one sink per shard.
///
/// The sampled `clients` are partitioned into `sinks.len()`
/// contiguous block-aligned ranges
/// ([`shard_slices`](crate::coordinator::shard::shard_slices)) and
/// each partition executes into its own sink with shard-local
/// indices. This helper runs the shards serially — it enforces and
/// documents the *ownership* contract (shard-local indices, no
/// cross-shard aggregation in sinks); the threaded fan-out lives in
/// `coordinator::shard::run_partitioned`, which the server composes
/// with per-shard merges. One sink degrades to exactly the historical
/// single-sink round.
pub fn collect_round(
    executor: &dyn ClientExecutor,
    ctx: &RoundContext<'_>,
    clients: &[usize],
    sinks: &mut [Box<dyn RoundSink + '_>],
) -> Result<()> {
    assert!(!sinks.is_empty(), "collect_round needs at least one sink");
    let ranges = shard_slices(clients.len(), sinks.len());
    for (range, sink) in ranges.into_iter().zip(sinks.iter_mut()) {
        executor.execute(ctx, &clients[range], sink.as_mut())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_sink_buffers_in_order() {
        let mut sink = VecSink::new();
        for i in 0..3 {
            sink.push(i, ClientResult {
                cid: 10 + i,
                down_bytes: 4,
                update: None,
                cancelled: false,
            })
            .unwrap();
        }
        assert_eq!(sink.results.len(), 3);
        assert!(sink.results.iter().enumerate()
                    .all(|(i, r)| r.cid == 10 + i));
    }

    #[test]
    fn borrowed_sinks_forward_and_survive_the_box() {
        let mut sink = VecSink::new();
        {
            let mut boxed: Box<dyn RoundSink + '_> =
                Box::new(&mut sink);
            boxed
                .push(0, ClientResult {
                    cid: 42,
                    down_bytes: 1,
                    update: None,
                    cancelled: false,
                })
                .unwrap();
        }
        assert_eq!(sink.results.len(), 1);
        assert_eq!(sink.results[0].cid, 42);
    }
}
