//! Pluggable per-round client execution — the streaming round engine.
//!
//! The FLoCoRA protocol is embarrassingly parallel within a round: each
//! sampled client decodes its download message, trains on its own
//! shard, and encodes its upload; clients only meet again at FedAvg
//! aggregation. [`ClientExecutor`] captures exactly that per-client
//! unit of work, with three implementations:
//!
//! * [`SerialExecutor`] — clients run one after another on the calling
//!   thread, each result pushed into the sink immediately. The
//!   reference implementation.
//! * [`ParallelExecutor`] — clients fan out across a pool of scoped OS
//!   threads that fill a **bounded out-of-order window** of result
//!   slots, while the calling thread drains the window in sampling
//!   order (Condvar-gated). Peak simultaneously-buffered results never
//!   exceed the window, so a round's memory is O(params + window)
//!   rather than O(clients_per_round × params).
//! * [`PipelinedExecutor`] — the `overlap = transfer` engine: the
//!   per-client unit of work is split into its three stages
//!   (download/decode → train → encode/upload) and the *transfer*
//!   stages run on dedicated transport threads separate from the
//!   compute workers, so client A's upload is encoded while client B
//!   still trains. Same window bound, same sink contract, same bits —
//!   only the wall-clock shape changes (and the simulated
//!   `sim_net_pipelined_s` column models exactly this regime).
//!
//! Results flow into a [`RoundSink`](super::sink::RoundSink) instead of
//! a returned `Vec` — see `coordinator::sink` for the ordering and
//! threading contract.
//!
//! **Determinism contract.** Both executors push one [`ClientResult`]
//! per sampled client *in sampling order*, and every source of
//! randomness a client touches (dropout draw, batch shuffling) comes
//! from [`Rng::for_client`], which depends only on `(seed, round, cid)`
//! — never on execution order, thread count, or window size. The server
//! merges in that stable order, so a run's output is bit-identical
//! under either executor at any window (asserted by
//! `tests/executor.rs`).
//!
//! **Heterogeneous ranks.** A [`RoundContext`] may carry a
//! [`ClientPlan`](crate::coordinator::hetero::ClientPlan): each client
//! then trains at its own rank tier with its tier's codec, and
//! `run_client` projects the upload back into the server's rank space
//! before it reaches the sink — the merge never sees anything but
//! server-shaped vectors.

use crate::compression::{Codec, Message};
use crate::config::FlConfig;
use crate::coordinator::hetero::{project_ranks, ClientPlan};
use crate::coordinator::sink::RoundSink;
use crate::coordinator::trainer::{LocalOutcome, LocalTrainer};
use crate::coordinator::window::{BoundedWindow, StageRing};
use crate::data::Federation;
use crate::error::{Error, Result};
use crate::model::Segment;
use crate::runtime::ModelSession;
use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::thread;
use crate::transport::OverlapKind;
use crate::util::rng::Rng;

/// Executor selection, parseable from CLI/config strings (mirrors
/// [`crate::compression::CodecKind`] for codecs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Clients run sequentially on the coordinator thread.
    Serial,
    /// Clients fan out across a thread pool feeding a bounded
    /// out-of-order merge window (bit-identical results).
    Parallel,
}

impl ExecutorKind {
    /// Parse `serial | parallel`.
    pub fn parse(s: &str) -> Option<ExecutorKind> {
        match s {
            "serial" => Some(ExecutorKind::Serial),
            "parallel" => Some(ExecutorKind::Parallel),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ExecutorKind::Serial => "serial",
            ExecutorKind::Parallel => "parallel",
        }
    }

    /// Instantiate the executor. `threads` and `window` only affect
    /// [`ExecutorKind::Parallel`]; 0 means one worker per available
    /// core / a window of twice the worker count respectively.
    /// `overlap = transfer` swaps the parallel engine for the staged
    /// [`PipelinedExecutor`] (dedicated transport threads); the serial
    /// reference has a single lane, so the knob is a no-op there.
    pub fn build(&self, threads: usize, window: usize,
                 overlap: OverlapKind) -> Box<dyn ClientExecutor> {
        match (self, overlap) {
            (ExecutorKind::Serial, _) => Box::new(SerialExecutor),
            (ExecutorKind::Parallel, OverlapKind::None) => {
                Box::new(ParallelExecutor::new(threads).with_window(window))
            }
            (ExecutorKind::Parallel, OverlapKind::Transfer) => {
                Box::new(PipelinedExecutor::new(threads).with_window(window))
            }
        }
    }
}

/// What every sampled client downloads this round.
pub enum Downloads<'a> {
    /// One shared message, pulled by every client (homogeneous round).
    Homogeneous(&'a Message),
    /// One message per rank tier, indexed by
    /// [`ClientPlan::tier_of`](crate::coordinator::hetero::ClientPlan::tier_of).
    Tiered(&'a [Message]),
}

/// Everything one round of client work reads. All fields are shared
/// immutably across executor threads ([`ModelSession`], `dyn Codec`
/// and [`ClientPlan`] are `Sync` by construction).
pub struct RoundContext<'a> {
    /// The server-tier session; in a tiered round it also names the
    /// rank space every upload is projected back into.
    pub session: &'a ModelSession,
    /// The server-tier wire codec (tiers may override per client).
    pub codec: &'a dyn Codec,
    pub federation: &'a Federation,
    /// Frozen `W_initial` (never moves, never re-encoded, shared by
    /// every tier).
    pub frozen: &'a [f32],
    /// The encoded global vector(s) clients pull this round.
    pub downloads: Downloads<'a>,
    pub trainer: LocalTrainer,
    pub cfg: &'a FlConfig,
    /// Round index, part of the per-client RNG coordinates.
    pub round: usize,
    /// Per-client rank-tier plan; `None` = homogeneous round. Must be
    /// `Some` exactly when `downloads` is [`Downloads::Tiered`].
    pub plan: Option<&'a ClientPlan>,
    /// Sorted client ids the server already decided to cancel this
    /// round (oversampled rounds end at the K-th accepted upload; the
    /// cut is planned on the coordinator from expected round trips, so
    /// it is deterministic under any executor). Empty = nobody.
    pub cancelled: &'a [usize],
}

/// What one sampled client hands to the round sink.
#[derive(Debug, Clone)]
pub struct ClientResult {
    pub cid: usize,
    /// Bytes this client pulled (its tier's download message).
    pub down_bytes: usize,
    /// `None` if the client failed before uploading (dropout
    /// injection), or if the server cancelled it (`cancelled`).
    pub update: Option<ClientUpdate>,
    /// The server cut this client mid-round (oversampled round already
    /// had its K uploads). Distinct from a dropout: the client was
    /// healthy, the round just ended without it.
    pub cancelled: bool,
}

/// The payload of a surviving client's upload, as handed to the merge.
///
/// Homogeneous rounds keep the upload *encoded* all the way to the
/// coordinator: the merge folds it straight into the aggregator via
/// [`crate::compression::Codec::decode_into`] (the zero-copy path —
/// the message is 4–18× smaller than the dense vector it decodes to,
/// and the dense form never materializes). Tiered rounds decode on
/// the worker because the rank projection needs the dense vector.
/// Both forms produce bit-identical merges: the fused fold runs the
/// same per-element arithmetic in the same order.
#[derive(Debug, Clone)]
pub enum UpdateVector {
    /// Decoded dense vector in the server's rank space (tiered
    /// clients: already projected).
    Dense(Vec<f32>),
    /// Still-encoded upload; decoded into the merge accumulator.
    Encoded(Message),
}

impl UpdateVector {
    /// Materialize the dense server-space vector (tests, inspection).
    pub fn to_dense(
        &self,
        codec: &dyn Codec,
        segments: &[Segment],
    ) -> Result<Vec<f32>> {
        match self {
            UpdateVector::Dense(v) => Ok(v.clone()),
            UpdateVector::Encoded(msg) => codec.decode(msg, segments),
        }
    }
}

/// A surviving client's contribution.
#[derive(Debug, Clone)]
pub struct ClientUpdate {
    /// The update as the *server* will see it — after the uplink
    /// encode and (for tiered clients) the decode + projection back
    /// into the server's rank space, ready for the merge fold.
    pub params: UpdateVector,
    /// FedAvg weight `n_k` (local sample count).
    pub weight: f64,
    pub up_bytes: usize,
    pub mean_loss: f64,
    pub mean_acc: f64,
}

/// Resolve one client's gear: the server tier, or its plan tier.
fn client_gear<'a>(
    ctx: &RoundContext<'a>,
    cid: usize,
) -> Result<(&'a ModelSession, &'a dyn Codec, &'a Message, f32)> {
    match (ctx.plan, &ctx.downloads) {
        (None, Downloads::Homogeneous(msg)) => {
            Ok((ctx.session, ctx.codec, *msg, ctx.trainer.lora_scale))
        }
        (Some(plan), Downloads::Tiered(msgs)) => {
            let t = plan.tier_of(cid);
            let tier = &plan.tiers()[t];
            Ok((&tier.session, tier.codec.as_ref(), &msgs[t],
                tier.lora_scale))
        }
        _ => Err(Error::invalid(
            "round context: plan and downloads disagree",
        )),
    }
}

/// What the download/decode stage hands downstream.
enum Fetched {
    /// Cancelled by the server before training: the download happened
    /// (the round was in flight), but no compute or upload is spent —
    /// cancellation is a real wall-clock win, not just bookkeeping.
    Cancelled,
    /// Decoded start parameters for the train stage.
    Start(Vec<f32>),
}

/// Stage 1 — download/decode: pull the client's tier message and
/// decode it into start parameters (or short-circuit a planned
/// cancellation). Pure in `(ctx, cid)`; runs on a transport thread
/// under `overlap = transfer`.
fn stage_download(ctx: &RoundContext<'_>, cid: usize)
                  -> Result<(usize, Fetched)> {
    let (session, codec, down_msg, _) = client_gear(ctx, cid)?;
    let down_bytes = down_msg.size_bytes();
    if ctx.cancelled.binary_search(&cid).is_ok() {
        return Ok((down_bytes, Fetched::Cancelled));
    }
    let start = codec.decode(down_msg, &session.spec.trainable_segments)?;
    Ok((down_bytes, Fetched::Start(start)))
}

/// What the train stage hands to the upload stage.
enum Trained {
    /// Failure injection: the client downloaded the model but fails
    /// before uploading (crash/network loss). FedAvg proceeds with the
    /// survivors — the aggregation-agnostic loop needs no special
    /// casing.
    Dropped,
    Outcome(LocalOutcome),
}

/// Stage 2 — local training: the dropout coin and the local epochs.
/// All client randomness flows from `(seed, round, cid)` — stable
/// under any execution order or stage placement (see module docs).
fn stage_train(ctx: &RoundContext<'_>, cid: usize, start: Vec<f32>)
               -> Result<Trained> {
    let (session, _, _, lora_scale) = client_gear(ctx, cid)?;
    let mut crng =
        Rng::for_client(ctx.cfg.seed, ctx.round as u64, cid as u64);
    if ctx.cfg.dropout > 0.0 && crng.f64() < ctx.cfg.dropout {
        return Ok(Trained::Dropped);
    }
    // Deterministic failure injection (`drop_plan = round:cid,...`):
    // checked *after* the dropout coin so the RNG stream is untouched
    // — a planned drop is bit-identical to the same client crashing
    // after its download, which is exactly what a killed wire client
    // looks like to the server (the parity tests lean on this).
    if ctx.cfg.drop_plan.iter().any(|&(r, c)| r == ctx.round && c == cid) {
        return Ok(Trained::Dropped);
    }
    let trainer = LocalTrainer { lora_scale, ..ctx.trainer };
    let outcome = trainer.run(
        session,
        &ctx.federation.client(cid),
        ctx.frozen,
        start,
        &mut crng,
    )?;
    Ok(Trained::Outcome(outcome))
}

/// Stage 3 — encode/upload: encode → count bytes → hand the encoded
/// message to the merge (homogeneous rounds), or decode + rank-project
/// on the worker (tiered rounds, where the projection needs the dense
/// vector). Runs on a transport thread under `overlap = transfer`.
fn stage_upload(ctx: &RoundContext<'_>, cid: usize, outcome: LocalOutcome)
                -> Result<ClientUpdate> {
    let (session, codec, _, _) = client_gear(ctx, cid)?;
    let segments = &session.spec.trainable_segments;
    // The client-keyed path lets stateful codecs (sparse_ef's error
    // feedback) tie their residuals to the client id; stateless codecs
    // fall through to the plain encode.
    let up_msg = codec.encode_client(cid, &outcome.params, segments)?;
    let up_bytes = up_msg.size_bytes();

    let params = match ctx.plan {
        // Homogeneous round: keep the upload encoded — the merge
        // folds it straight into the aggregator (zero-copy), and the
        // worker never materializes the decoded vector at all.
        None => UpdateVector::Encoded(up_msg),
        // Tiered clients hand back a vector in their own rank space;
        // embed it into the server's before the sink ever sees it
        // (zero-padding is exact on the B·A product — see
        // `coordinator::hetero`).
        Some(_) => {
            let received = codec.decode(&up_msg, segments)?;
            UpdateVector::Dense(project_ranks(
                &received,
                segments,
                &ctx.session.spec.trainable_segments,
            )?)
        }
    };

    Ok(ClientUpdate {
        params,
        weight: outcome.samples as f64,
        up_bytes,
        mean_loss: outcome.mean_loss,
        mean_acc: outcome.mean_acc,
    })
}

/// The complete per-client unit of work — the three stages composed
/// inline: download-decode → (maybe drop) → local train →
/// encode-upload. Shared verbatim by the serial and parallel executors
/// so they cannot diverge behaviorally; the pipelined executor runs
/// the *same* stage functions, just on different threads. Public
/// because the wire client (`transport::wire`) runs this exact
/// function against a context rebuilt from the announced round plan —
/// one client-work path, whether the result crosses a socket or not.
pub fn run_client(ctx: &RoundContext<'_>, cid: usize) -> Result<ClientResult> {
    let (down_bytes, fetched) = stage_download(ctx, cid)?;
    let start = match fetched {
        Fetched::Cancelled => {
            return Ok(ClientResult {
                cid,
                down_bytes,
                update: None,
                cancelled: true,
            })
        }
        Fetched::Start(start) => start,
    };
    match stage_train(ctx, cid, start)? {
        Trained::Dropped => Ok(ClientResult {
            cid,
            down_bytes,
            update: None,
            cancelled: false,
        }),
        Trained::Outcome(outcome) => Ok(ClientResult {
            cid,
            down_bytes,
            update: Some(stage_upload(ctx, cid, outcome)?),
            cancelled: false,
        }),
    }
}

/// Strategy for executing a round's sampled clients.
///
/// Contract: `execute` pushes exactly one result per entry of
/// `clients` into `sink`, at indices 0..n in order, on the calling
/// thread, and is deterministic in `(ctx, clients)` — implementations
/// may reorder *work* but never *results* (see `coordinator::sink`).
///
/// Memory note: at most `window` results (parallel) or one result
/// (serial) are buffered between production and the sink — a round
/// peaks at O(params + window), not O(clients_per_round × params), so
/// full-model baselines at large fan-out stay flat.
pub trait ClientExecutor: Send + Sync {
    fn name(&self) -> &'static str;

    fn execute(
        &self,
        ctx: &RoundContext<'_>,
        clients: &[usize],
        sink: &mut dyn RoundSink,
    ) -> Result<()>;
}

/// Clients run strictly one after another — the reference executor.
/// Each result is pushed before the next client starts, so nothing is
/// ever buffered.
pub struct SerialExecutor;

impl ClientExecutor for SerialExecutor {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn execute(
        &self,
        ctx: &RoundContext<'_>,
        clients: &[usize],
        sink: &mut dyn RoundSink,
    ) -> Result<()> {
        for (i, &cid) in clients.iter().enumerate() {
            sink.push(i, run_client(ctx, cid)?)?;
        }
        Ok(())
    }
}

/// Clients fan out across scoped worker threads; workers may run ahead
/// of the in-order merge only as far as the out-of-order window, then
/// block on a Condvar until the coordinator thread drains the oldest
/// slot into the sink. The claim/deposit/drain protocol itself lives
/// in [`BoundedWindow`] (`coordinator::window`), where the loom suite
/// model-checks it exhaustively — this type adds only the client work
/// and the thread pool.
pub struct ParallelExecutor {
    threads: usize,
    window: usize,
    /// High-water mark of simultaneously buffered results in the last
    /// `execute` (diagnostics; the streaming-memory test pins it to
    /// the window). Meaningless while an `execute` is in flight.
    peak_buffered: AtomicUsize,
}

impl ParallelExecutor {
    /// `threads == 0` sizes the pool to the available cores.
    pub fn new(threads: usize) -> ParallelExecutor {
        ParallelExecutor {
            threads,
            window: 0,
            peak_buffered: AtomicUsize::new(0),
        }
    }

    /// Cap the out-of-order result window (`0` = twice the worker
    /// count). Smaller windows bound memory tighter; `1` forces fully
    /// in-order production (workers serialize at the merge).
    pub fn with_window(mut self, window: usize) -> ParallelExecutor {
        self.window = window;
        self
    }

    /// High-water mark of simultaneously buffered (produced, undrained)
    /// results during the most recent `execute`.
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered.load(Ordering::Relaxed)
    }

    fn pool_size(&self, work: usize) -> usize {
        pool_size(self.threads, work)
    }

    fn effective_window(&self, workers: usize) -> usize {
        effective_window(self.window, workers)
    }
}

/// Worker-pool sizing shared by the fan-out executors (and the shard
/// fan-out in `coordinator::server`): `threads == 0` means one worker
/// per available core, and the pool never collapses to zero workers
/// nor exceeds the work items available.
pub(crate) fn pool_size(threads: usize, work: usize) -> usize {
    let auto = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let requested = if threads == 0 { auto } else { threads };
    requested.min(work.max(1))
}

/// Out-of-order window sizing shared by the fan-out executors
/// (`0` = twice the worker count).
fn effective_window(window: usize, workers: usize) -> usize {
    if window == 0 {
        (2 * workers).max(1)
    } else {
        window
    }
}

impl ClientExecutor for ParallelExecutor {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn execute(
        &self,
        ctx: &RoundContext<'_>,
        clients: &[usize],
        sink: &mut dyn RoundSink,
    ) -> Result<()> {
        let n = clients.len();
        let workers = self.pool_size(n);
        self.peak_buffered.store(0, Ordering::Relaxed);
        if workers <= 1 {
            // One lane: skip thread setup, identical results by the
            // determinism contract (and nothing ever buffers).
            return SerialExecutor.execute(ctx, clients, sink);
        }
        // A window beyond the round size buys nothing (claims are
        // bounded by `n` anyway) but would allocate that many slots —
        // clamp so an absurd configured window can't blow the ring
        // allocation.
        let window = self.effective_window(workers).min(n);
        let win: BoundedWindow<Result<ClientResult>> =
            BoundedWindow::new(n, window);

        let out = thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    // If this worker unwinds inside `run_client` (a
                    // bug — client work returns `Result`), its slot
                    // would never fill and the drainer would wait
                    // forever; the sentry aborts the window on the way
                    // out and `thread::scope` re-raises the panic at
                    // the join.
                    let _sentry = win.sentry();
                    // Claim the next index, but never run further
                    // ahead of the merge than the window allows —
                    // that bound is what keeps the round's memory
                    // O(window).
                    while let Some(i) = win.claim() {
                        let res = run_client(ctx, clients[i]);
                        if !win.deposit(i, res) {
                            return;
                        }
                    }
                });
            }

            // The drain side gets the same guard: a sink that panics
            // (rather than returning `Err`) would otherwise leave
            // workers parked on the claim gate forever and the scope
            // join would deadlock instead of propagating the panic.
            let _sentry = win.sentry();

            // In-order drain on the coordinator thread: the sink sees
            // sampling order regardless of which worker finished when.
            let mut out = Ok(());
            for i in 0..n {
                let res = win.drain(i).unwrap_or_else(|_| {
                    // A worker died without delivering; stop draining
                    // so the scope join can re-raise its panic.
                    Err(Error::invalid("round aborted: a worker failed"))
                });
                if let Err(e) = res.and_then(|r| sink.push(i, r)) {
                    win.abort();
                    out = Err(e);
                    break;
                }
            }
            out
        });
        self.peak_buffered
            .store(win.peak_buffered(), Ordering::Relaxed);
        out
    }
}

/// One ring slot of the staged pipeline: the client's progress through
/// download → train → upload, ending in the drainable result.
#[derive(Default)]
enum PipeSlot {
    #[default]
    Empty,
    /// Decoded download waiting for a compute worker.
    Fetched { down_bytes: usize, start: Vec<f32> },
    /// A compute worker owns it.
    Training,
    /// Trained update waiting for the transport-out thread.
    TrainedUp { down_bytes: usize, outcome: LocalOutcome },
    /// The transport-out thread owns it.
    Uploading,
    /// Result ready for the in-order drain.
    Done(Result<ClientResult>),
}

/// The `overlap = transfer` engine: three-stage pipeline with the
/// transfer stages on dedicated transport threads.
///
/// * one **transport-in** thread claims client indices (window-gated,
///   like the parallel executor's workers) and runs
///   download/decode;
/// * `threads` **compute workers** pick up decoded clients and run the
///   dropout coin + local epochs — nothing else, so a worker is never
///   blocked on codec work;
/// * one **transport-out** thread encodes/uploads trained outcomes —
///   client A's upload overlaps client B's training by construction;
/// * the calling thread drains results in sampling order into the
///   sink, exactly like the other executors.
///
/// Work items live in the same bounded ring the parallel executor
/// uses, so at most `window` clients are in flight and peak buffered
/// results never exceed the window. Every stage function is pure in
/// `(ctx, cid)`, so results are bit-identical to [`SerialExecutor`].
/// The ring protocol itself lives in [`StageRing`]
/// (`coordinator::window`), where the loom suite model-checks it —
/// this type adds only the stage work and the thread layout.
pub struct PipelinedExecutor {
    threads: usize,
    window: usize,
    /// High-water mark of simultaneously buffered (produced,
    /// undrained) results in the last `execute` — diagnostics, pinned
    /// `<= window` by the streaming-memory tests.
    peak_buffered: AtomicUsize,
}

impl PipelinedExecutor {
    /// `threads == 0` sizes the compute pool to the available cores
    /// (the two transport threads come on top).
    pub fn new(threads: usize) -> PipelinedExecutor {
        PipelinedExecutor {
            threads,
            window: 0,
            peak_buffered: AtomicUsize::new(0),
        }
    }

    /// Cap the in-flight window (`0` = twice the compute workers).
    pub fn with_window(mut self, window: usize) -> PipelinedExecutor {
        self.window = window;
        self
    }

    /// High-water mark of simultaneously buffered results during the
    /// most recent `execute`.
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered.load(Ordering::Relaxed)
    }
}

/// Pull the payload out of a [`PipeSlot::Done`] slot, resetting it to
/// [`PipeSlot::Empty`] — the drain-side extractor for [`StageRing`].
fn take_done(slot: &mut PipeSlot) -> Option<Result<ClientResult>> {
    match slot {
        PipeSlot::Done(_) => {
            let PipeSlot::Done(r) =
                std::mem::replace(slot, PipeSlot::Empty)
            else {
                unreachable!("slot matched above")
            };
            Some(r)
        }
        _ => None,
    }
}

impl ClientExecutor for PipelinedExecutor {
    fn name(&self) -> &'static str {
        "pipelined"
    }

    fn execute(
        &self,
        ctx: &RoundContext<'_>,
        clients: &[usize],
        sink: &mut dyn RoundSink,
    ) -> Result<()> {
        let n = clients.len();
        let workers = pool_size(self.threads, n);
        self.peak_buffered.store(0, Ordering::Relaxed);
        if workers <= 1 && n <= 1 {
            // Nothing to overlap: skip thread setup, identical results
            // by the determinism contract.
            return SerialExecutor.execute(ctx, clients, sink);
        }
        let window = effective_window(self.window, workers).min(n);
        let ring: StageRing<PipeSlot> = StageRing::new(n, window);

        let out = thread::scope(|scope| {
            // Transport-in: claim indices in order, decode downloads.
            // Every participant holds a ring sentry — a panicking
            // stage (a bug: stage work returns `Result`) must wind the
            // whole pipeline down instead of leaving siblings parked.
            scope.spawn(|| {
                let _sentry = ring.sentry();
                while let Some(i) = ring.claim() {
                    let slot = match stage_download(ctx, clients[i]) {
                        Err(e) => PipeSlot::Done(Err(e)),
                        Ok((down_bytes, Fetched::Cancelled)) => {
                            PipeSlot::Done(Ok(ClientResult {
                                cid: clients[i],
                                down_bytes,
                                update: None,
                                cancelled: true,
                            }))
                        }
                        Ok((down_bytes, Fetched::Start(start))) => {
                            PipeSlot::Fetched { down_bytes, start }
                        }
                    };
                    let done = matches!(slot, PipeSlot::Done(_));
                    if !ring.put(i, slot, done) {
                        return;
                    }
                }
            });

            // Compute workers: dropout coin + local epochs only.
            for _ in 0..workers {
                scope.spawn(|| {
                    let _sentry = ring.sentry();
                    while let Some((i, (down_bytes, start))) =
                        ring.take_matching(|s| match s {
                            PipeSlot::Fetched { .. } => {
                                let PipeSlot::Fetched { down_bytes, start } =
                                    std::mem::replace(s, PipeSlot::Training)
                                else {
                                    unreachable!("slot matched above")
                                };
                                Some((down_bytes, start))
                            }
                            _ => None,
                        })
                    {
                        let slot = match stage_train(ctx, clients[i], start)
                        {
                            Err(e) => PipeSlot::Done(Err(e)),
                            Ok(Trained::Dropped) => {
                                PipeSlot::Done(Ok(ClientResult {
                                    cid: clients[i],
                                    down_bytes,
                                    update: None,
                                    cancelled: false,
                                }))
                            }
                            Ok(Trained::Outcome(outcome)) => {
                                PipeSlot::TrainedUp { down_bytes, outcome }
                            }
                        };
                        let done = matches!(slot, PipeSlot::Done(_));
                        if !ring.put(i, slot, done) {
                            return;
                        }
                    }
                });
            }

            // Transport-out: encode/upload trained outcomes.
            scope.spawn(|| {
                let _sentry = ring.sentry();
                while let Some((i, (down_bytes, outcome))) =
                    ring.take_matching(|s| match s {
                        PipeSlot::TrainedUp { .. } => {
                            let PipeSlot::TrainedUp { down_bytes, outcome } =
                                std::mem::replace(s, PipeSlot::Uploading)
                            else {
                                unreachable!("slot matched above")
                            };
                            Some((down_bytes, outcome))
                        }
                        _ => None,
                    })
                {
                    let res = stage_upload(ctx, clients[i], outcome).map(
                        |update| ClientResult {
                            cid: clients[i],
                            down_bytes,
                            update: Some(update),
                            cancelled: false,
                        },
                    );
                    if !ring.put(i, PipeSlot::Done(res), true) {
                        return;
                    }
                }
            });

            // In-order drain on the coordinator thread — the sink sees
            // sampling order regardless of stage scheduling.
            let _sentry = ring.sentry();
            let mut out = Ok(());
            for i in 0..n {
                let res = ring.drain(i, take_done).unwrap_or_else(|_| {
                    Err(Error::invalid(
                        "round aborted: a pipeline stage failed",
                    ))
                });
                if let Err(e) = res.and_then(|r| sink.push(i, r)) {
                    ring.abort();
                    out = Err(e);
                    break;
                }
            }
            out
        });
        self.peak_buffered
            .store(ring.peak_buffered(), Ordering::Relaxed);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_labels() {
        assert_eq!(ExecutorKind::parse("serial"), Some(ExecutorKind::Serial));
        assert_eq!(
            ExecutorKind::parse("parallel"),
            Some(ExecutorKind::Parallel)
        );
        assert_eq!(ExecutorKind::parse("threads:4"), None);
        assert_eq!(ExecutorKind::Serial.label(), "serial");
        assert_eq!(ExecutorKind::Parallel.label(), "parallel");
        assert_eq!(
            ExecutorKind::Serial.build(0, 0, OverlapKind::None).name(),
            "serial"
        );
        assert_eq!(
            ExecutorKind::Parallel.build(3, 2, OverlapKind::None).name(),
            "parallel"
        );
        // The overlap knob swaps the parallel engine for the staged
        // pipeline; the serial reference has nothing to overlap.
        assert_eq!(
            ExecutorKind::Parallel.build(3, 2, OverlapKind::Transfer).name(),
            "pipelined"
        );
        assert_eq!(
            ExecutorKind::Serial.build(0, 0, OverlapKind::Transfer).name(),
            "serial"
        );
    }

    #[test]
    fn pool_size_clamps_to_work_and_floor() {
        let auto = ParallelExecutor::new(0);
        assert!(auto.pool_size(8) >= 1);
        assert!(auto.pool_size(8) <= 8);
        assert_eq!(ParallelExecutor::new(16).pool_size(4), 4);
        assert_eq!(ParallelExecutor::new(2).pool_size(100), 2);
        assert_eq!(ParallelExecutor::new(5).pool_size(0), 1);
    }

    #[test]
    fn window_defaults_and_pins() {
        let auto = ParallelExecutor::new(4);
        assert_eq!(auto.effective_window(4), 8);
        let pinned = ParallelExecutor::new(4).with_window(3);
        assert_eq!(pinned.effective_window(4), 3);
        let one = ParallelExecutor::new(4).with_window(1);
        assert_eq!(one.effective_window(4), 1);
    }
}
