//! Pluggable per-round client execution — the parallel round engine.
//!
//! The FLoCoRA protocol is embarrassingly parallel within a round: each
//! sampled client decodes the (shared) download message, trains on its
//! own shard, and encodes its upload; clients only meet again at FedAvg
//! aggregation. [`ClientExecutor`] captures exactly that per-client unit
//! of work, with two implementations:
//!
//! * [`SerialExecutor`] — clients run one after another on the calling
//!   thread. The reference implementation.
//! * [`ParallelExecutor`] — clients fan out across a pool of scoped OS
//!   threads pulling from a shared work queue.
//!
//! **Determinism contract.** Both executors return one [`ClientResult`]
//! per sampled client *in sampling order*, and every source of
//! randomness a client touches (dropout draw, batch shuffling) comes
//! from [`Rng::for_client`], which depends only on `(seed, round, cid)`
//! — never on execution order or thread count. The server merges results
//! in that stable order, so a run's output is bit-identical under either
//! executor (asserted by `tests/executor.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::compression::{Codec, Message};
use crate::config::FlConfig;
use crate::coordinator::trainer::LocalTrainer;
use crate::data::Federation;
use crate::error::Result;
use crate::runtime::ModelSession;
use crate::util::rng::Rng;

/// Executor selection, parseable from CLI/config strings (mirrors
/// [`crate::compression::CodecKind`] for codecs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Clients run sequentially on the coordinator thread.
    Serial,
    /// Clients fan out across a thread pool (bit-identical results).
    Parallel,
}

impl ExecutorKind {
    /// Parse `serial | parallel`.
    pub fn parse(s: &str) -> Option<ExecutorKind> {
        match s {
            "serial" => Some(ExecutorKind::Serial),
            "parallel" => Some(ExecutorKind::Parallel),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ExecutorKind::Serial => "serial",
            ExecutorKind::Parallel => "parallel",
        }
    }

    /// Instantiate the executor. `threads` only affects
    /// [`ExecutorKind::Parallel`]; 0 means one worker per available
    /// core.
    pub fn build(&self, threads: usize) -> Box<dyn ClientExecutor> {
        match self {
            ExecutorKind::Serial => Box::new(SerialExecutor),
            ExecutorKind::Parallel => Box::new(ParallelExecutor::new(threads)),
        }
    }
}

/// Everything one round of client work reads. All fields are shared
/// immutably across executor threads ([`ModelSession`] and `dyn Codec`
/// are `Sync` by construction).
pub struct RoundContext<'a> {
    pub session: &'a ModelSession,
    pub codec: &'a dyn Codec,
    pub federation: &'a Federation,
    /// Frozen `W_initial` (never moves, never re-encoded).
    pub frozen: &'a [f32],
    /// The server's encoded global vector — one message, downloaded by
    /// every sampled client.
    pub down_msg: &'a Message,
    pub trainer: LocalTrainer,
    pub cfg: &'a FlConfig,
    /// Round index, part of the per-client RNG coordinates.
    pub round: usize,
}

/// What one sampled client hands back to the server.
#[derive(Debug, Clone)]
pub struct ClientResult {
    pub cid: usize,
    /// Bytes this client pulled (the shared download message).
    pub down_bytes: usize,
    /// `None` if the client failed before uploading (dropout injection).
    pub update: Option<ClientUpdate>,
}

/// A surviving client's contribution.
#[derive(Debug, Clone)]
pub struct ClientUpdate {
    /// The update as the *server* sees it — after the uplink codec
    /// round trip, ready for FedAvg.
    pub params: Vec<f32>,
    /// FedAvg weight `n_k` (local sample count).
    pub weight: f64,
    pub up_bytes: usize,
    pub mean_loss: f64,
    pub mean_acc: f64,
}

/// The complete per-client unit of work: download-decode → (maybe drop)
/// → local train → encode-upload → server-side decode. Shared verbatim
/// by both executors so they cannot diverge behaviorally.
fn run_client(ctx: &RoundContext<'_>, cid: usize) -> Result<ClientResult> {
    let segments = &ctx.session.spec.trainable_segments;
    let down_bytes = ctx.down_msg.size_bytes();
    let start = ctx.codec.decode(ctx.down_msg, segments)?;

    // All client randomness flows from (seed, round, cid) — stable under
    // any execution order (see module docs).
    let mut crng =
        Rng::for_client(ctx.cfg.seed, ctx.round as u64, cid as u64);

    // Failure injection: the client downloaded the model but fails
    // before uploading (crash/network loss). FedAvg proceeds with the
    // survivors — the aggregation-agnostic loop needs no special casing.
    if ctx.cfg.dropout > 0.0 && crng.f64() < ctx.cfg.dropout {
        return Ok(ClientResult { cid, down_bytes, update: None });
    }

    let outcome = ctx.trainer.run(
        ctx.session,
        &ctx.federation.clients[cid],
        ctx.frozen,
        start,
        &mut crng,
    )?;

    // Upload: encode → count bytes → decode as the server would.
    let up_msg = ctx.codec.encode(&outcome.params, segments)?;
    let up_bytes = up_msg.size_bytes();
    let received = ctx.codec.decode(&up_msg, segments)?;

    Ok(ClientResult {
        cid,
        down_bytes,
        update: Some(ClientUpdate {
            params: received,
            weight: outcome.samples as f64,
            up_bytes,
            mean_loss: outcome.mean_loss,
            mean_acc: outcome.mean_acc,
        }),
    })
}

/// Strategy for executing a round's sampled clients.
///
/// Contract: `execute` returns exactly one result per entry of
/// `clients`, in the same order, and is deterministic in `(ctx,
/// clients)` — implementations may reorder *work* but never *results*.
///
/// Memory note: the collected `Vec` holds every surviving client's
/// decoded update simultaneously, so a round peaks at
/// O(`clients_per_round` × params) — inherent for in-flight parallel
/// work, and the cost of keeping one merge path for all executors.
/// Negligible for FLoCoRA adapters (tens of kB each); for full-model
/// baselines at large fan-out, budget accordingly (a streaming
/// in-order merge is a ROADMAP follow-on).
pub trait ClientExecutor: Send + Sync {
    fn name(&self) -> &'static str;

    fn execute(
        &self,
        ctx: &RoundContext<'_>,
        clients: &[usize],
    ) -> Result<Vec<ClientResult>>;
}

/// Clients run strictly one after another — the reference executor.
pub struct SerialExecutor;

impl ClientExecutor for SerialExecutor {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn execute(
        &self,
        ctx: &RoundContext<'_>,
        clients: &[usize],
    ) -> Result<Vec<ClientResult>> {
        clients.iter().map(|&cid| run_client(ctx, cid)).collect()
    }
}

/// Clients fan out across scoped worker threads pulling indices from a
/// shared atomic queue; results land in per-index slots so the returned
/// order is the sampling order regardless of which worker finished when.
pub struct ParallelExecutor {
    threads: usize,
}

impl ParallelExecutor {
    /// `threads == 0` sizes the pool to the available cores.
    pub fn new(threads: usize) -> ParallelExecutor {
        ParallelExecutor { threads }
    }

    fn pool_size(&self, work: usize) -> usize {
        let auto = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        // `auto` is always >= 1, so the pool never collapses to zero
        // workers; it also never exceeds the work items available.
        let requested = if self.threads == 0 { auto } else { self.threads };
        requested.min(work.max(1))
    }
}

impl ClientExecutor for ParallelExecutor {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn execute(
        &self,
        ctx: &RoundContext<'_>,
        clients: &[usize],
    ) -> Result<Vec<ClientResult>> {
        let n = clients.len();
        let workers = self.pool_size(n);
        if workers <= 1 {
            // One lane: skip thread setup, identical results by the
            // determinism contract.
            return SerialExecutor.execute(ctx, clients);
        }

        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<Result<ClientResult>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let res = run_client(ctx, clients[i]);
                    slots.lock().unwrap()[i] = Some(res);
                });
            }
        });

        // Worker panics propagate: `thread::scope` re-raises them at
        // the join above, so reaching this point means every index was
        // claimed and its slot written — `None` is impossible.
        let slots = slots.into_inner().unwrap();
        let mut out = Vec::with_capacity(n);
        for slot in slots {
            match slot {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => return Err(e),
                None => unreachable!(
                    "scope joined all workers; every slot is filled"
                ),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_labels() {
        assert_eq!(ExecutorKind::parse("serial"), Some(ExecutorKind::Serial));
        assert_eq!(
            ExecutorKind::parse("parallel"),
            Some(ExecutorKind::Parallel)
        );
        assert_eq!(ExecutorKind::parse("threads:4"), None);
        assert_eq!(ExecutorKind::Serial.label(), "serial");
        assert_eq!(ExecutorKind::Parallel.label(), "parallel");
        assert_eq!(ExecutorKind::Serial.build(0).name(), "serial");
        assert_eq!(ExecutorKind::Parallel.build(3).name(), "parallel");
    }

    #[test]
    fn pool_size_clamps_to_work_and_floor() {
        let auto = ParallelExecutor::new(0);
        assert!(auto.pool_size(8) >= 1);
        assert!(auto.pool_size(8) <= 8);
        assert_eq!(ParallelExecutor::new(16).pool_size(4), 4);
        assert_eq!(ParallelExecutor::new(2).pool_size(100), 2);
        assert_eq!(ParallelExecutor::new(5).pool_size(0), 1);
    }
}
