//! Local client training: the paper's step (2) — `E` local epochs of
//! SGD-with-momentum over the client's shard, driving the AOT-compiled
//! PJRT train step.
//!
//! The optimizer state (momentum) is *local and ephemeral*: it is
//! reinitialized at the start of every round (standard FedAvg client
//! behaviour — only parameters travel).

use crate::data::batcher::Tail;
use crate::data::{BatchIter, ClientData};
use crate::error::Result;
use crate::runtime::ModelSession;
use crate::util::rng::Rng;

/// Outcome of one client round.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    pub params: Vec<f32>,
    /// Mean train loss over all steps this round.
    pub mean_loss: f64,
    /// Mean train accuracy over all steps this round.
    pub mean_acc: f64,
    pub steps: usize,
    pub samples: usize,
}

/// Runs local epochs for sampled clients.
///
/// Plain-data and `Copy`: one instance is built per round (it carries
/// the round's decayed learning rate) and shared read-only by every
/// client-executor thread via `executor::RoundContext`.
#[derive(Debug, Clone, Copy)]
pub struct LocalTrainer {
    pub local_epochs: usize,
    pub lr: f32,
    pub lora_scale: f32,
}

impl LocalTrainer {
    /// Train `start_params` on `data`, returning the updated vector.
    pub fn run(
        &self,
        session: &ModelSession,
        data: &ClientData,
        frozen: &[f32],
        start_params: Vec<f32>,
        rng: &mut Rng,
    ) -> Result<LocalOutcome> {
        let mut params = start_params;
        let mut momentum = vec![0.0f32; params.len()];
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut steps = 0usize;
        for _epoch in 0..self.local_epochs {
            let batches = BatchIter::new(
                &data.images,
                &data.labels,
                session.spec.image_size,
                session.spec.batch_size,
                Some(rng),
                // Shards >= one batch drop the ragged tail (the train
                // artifact has no mask input); smaller shards wrap-pad
                // so every client still produces at least one step.
                if data.n < session.spec.batch_size {
                    Tail::PadWrap
                } else {
                    Tail::Drop
                },
            );
            for batch in batches {
                let stats = session.train_step(
                    &mut params,
                    &mut momentum,
                    frozen,
                    &batch,
                    self.lr,
                    self.lora_scale,
                )?;
                loss_sum += stats.loss as f64;
                acc_sum += stats.acc as f64;
                steps += 1;
            }
        }
        Ok(LocalOutcome {
            params,
            mean_loss: if steps > 0 { loss_sum / steps as f64 } else { 0.0 },
            mean_acc: if steps > 0 { acc_sum / steps as f64 } else { 0.0 },
            steps,
            samples: data.n,
        })
    }
}
