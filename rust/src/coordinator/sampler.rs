//! Client sampling strategies.
//!
//! FedAvg samples a subset `K` of the client pool `C` each round
//! (paper §II-A). With per-client link/compute profiles
//! ([`crate::transport::ClientProfiles`]) the *strategy* becomes a
//! lever against stragglers, so sampling is a trait ([`Sampler`]) with
//! three implementations (the `sampler` config knob):
//!
//! * [`UniformSampler`] — uniform without replacement; the reference,
//!   bit-identical to the pre-trait behaviour.
//! * [`LatencyBiasedSampler`] — weight ∝ inverse expected round trip,
//!   so slow clients are sampled less often but never starved (every
//!   weight stays positive).
//! * [`OversampleSampler`] — draws `K · (1 + β)` clients uniformly;
//!   the server accepts the first `K` expected uploads and cancels the
//!   stragglers (see `coordinator::server`). Shares the uniform
//!   sampler's RNG stream, so `β = 0` is bit-identical to
//!   [`UniformSampler`].
//!
//! Sampling runs on the coordinator thread *before* the executor fans
//! work out, so a sampler's mutable stream never races — and the
//! sorted order it returns is exactly the order the round sink drains
//! results in (the streaming merge's `push(index, ..)` contract is
//! defined against this slice, see `coordinator::sink`).

use crate::util::rng::Rng;

/// Stream-salt shared by [`UniformSampler`] and [`OversampleSampler`]
/// so the latter at `β = 0` replays the former's draws exactly.
const UNIFORM_SALT: u64 = 0x5A4D_7E3A;

/// Per-round client selection strategy.
///
/// Contract: `sample(k)` returns distinct in-range client ids, sorted
/// ascending, at least `k` of them when the pool allows (oversampling
/// strategies may return more — the server then accepts the first `k`
/// uploads and cancels the rest). Implementations own their RNG
/// stream, so a run's sampling sequence depends only on the seed.
pub trait Sampler: Send {
    fn name(&self) -> &'static str;

    /// Sample one round's client ids (sorted, distinct).
    fn sample(&mut self, k: usize) -> Vec<usize>;
}

/// Sampler selection, parseable from CLI/config strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplerKind {
    /// Uniform without replacement (the reference).
    #[default]
    Uniform,
    /// Weight ∝ inverse expected round trip on the client's profile.
    LatencyBiased,
    /// Uniformly oversample `K·(1+β)`; late clients are cancelled.
    OversampleK,
}

impl SamplerKind {
    /// Parse `uniform | latency_biased | oversample_k`.
    pub fn parse(s: &str) -> Option<SamplerKind> {
        match s {
            "uniform" => Some(SamplerKind::Uniform),
            "latency_biased" => Some(SamplerKind::LatencyBiased),
            "oversample_k" => Some(SamplerKind::OversampleK),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SamplerKind::Uniform => "uniform",
            SamplerKind::LatencyBiased => "latency_biased",
            SamplerKind::OversampleK => "oversample_k",
        }
    }
}

/// Uniform-without-replacement sampler with its own RNG stream.
pub struct UniformSampler {
    rng: Rng,
    num_clients: usize,
}

impl UniformSampler {
    pub fn new(num_clients: usize, seed: u64) -> UniformSampler {
        UniformSampler { rng: Rng::new(seed ^ UNIFORM_SALT), num_clients }
    }

    /// Sample `k` distinct client ids for one round (sorted for
    /// deterministic iteration order downstream).
    pub fn sample(&mut self, k: usize) -> Vec<usize> {
        let mut ids = self.rng.choose_k(self.num_clients, k);
        ids.sort_unstable();
        ids
    }
}

impl Sampler for UniformSampler {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn sample(&mut self, k: usize) -> Vec<usize> {
        UniformSampler::sample(self, k)
    }
}

/// Weighted sampler: per-client weight ∝ inverse expected round trip,
/// drawn without replacement.
///
/// Slow clients keep a positive weight, so over enough rounds every
/// client still participates (no starvation — asserted by the property
/// tests); they just stop dominating the straggler max every round.
pub struct LatencyBiasedSampler {
    rng: Rng,
    weights: Vec<f64>,
}

impl LatencyBiasedSampler {
    /// `weights[cid]` is client `cid`'s sampling weight (the server
    /// passes inverse expected round trips). Panics if any weight is
    /// not finite and positive — a zero weight would silently starve a
    /// client, which is a construction bug, not a runtime condition.
    pub fn new(weights: Vec<f64>, seed: u64) -> LatencyBiasedSampler {
        assert!(
            !weights.is_empty()
                && weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "latency-biased sampling needs finite positive weights"
        );
        LatencyBiasedSampler { rng: Rng::new(seed ^ 0x17B9_C3D5), weights }
    }
}

impl Sampler for LatencyBiasedSampler {
    fn name(&self) -> &'static str {
        "latency_biased"
    }

    fn sample(&mut self, k: usize) -> Vec<usize> {
        let n = self.weights.len();
        assert!(k <= n, "cannot sample {k} of {n} clients");
        // K passes of roulette selection over a scratch copy, zeroing
        // picked entries: O(n·k) with both small (n = pool size).
        let mut w = self.weights.clone();
        let mut ids = Vec::with_capacity(k);
        for _ in 0..k {
            let total: f64 = w.iter().sum();
            let mut x = self.rng.f64() * total;
            let mut pick = None;
            for (i, &wi) in w.iter().enumerate() {
                if wi <= 0.0 {
                    continue;
                }
                pick = Some(i);
                if x < wi {
                    break;
                }
                x -= wi;
            }
            // The loop always sees >= n - k + 1 > 0 positive entries;
            // a floating-point tail lands on the last one.
            let pick = pick.expect("no positive weight left");
            ids.push(pick);
            w[pick] = 0.0;
        }
        ids.sort_unstable();
        ids
    }
}

/// Uniformly oversamples `ceil(K·(1+β))` clients (capped at the pool
/// size); the server completes the round at the K-th accepted upload
/// and cancels the rest.
pub struct OversampleSampler {
    rng: Rng,
    num_clients: usize,
    beta: f64,
}

impl OversampleSampler {
    /// `beta >= 0` is the oversampling fraction (`0` reproduces
    /// [`UniformSampler`] bit-for-bit: same stream salt, same draws).
    pub fn new(num_clients: usize, seed: u64, beta: f64)
               -> OversampleSampler {
        assert!(beta >= 0.0 && beta.is_finite(), "beta must be >= 0");
        OversampleSampler {
            rng: Rng::new(seed ^ UNIFORM_SALT),
            num_clients,
            beta,
        }
    }

    /// How many ids one round draws for a target of `k` uploads.
    pub fn draw_count(&self, k: usize) -> usize {
        let extra = (k as f64 * self.beta).ceil() as usize;
        (k + extra).min(self.num_clients)
    }
}

impl Sampler for OversampleSampler {
    fn name(&self) -> &'static str {
        "oversample_k"
    }

    fn sample(&mut self, k: usize) -> Vec<usize> {
        let mut ids = self.rng.choose_k(self.num_clients, self.draw_count(k));
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_labels() {
        assert_eq!(SamplerKind::parse("uniform"), Some(SamplerKind::Uniform));
        assert_eq!(
            SamplerKind::parse("latency_biased"),
            Some(SamplerKind::LatencyBiased)
        );
        assert_eq!(
            SamplerKind::parse("oversample_k"),
            Some(SamplerKind::OversampleK)
        );
        assert_eq!(SamplerKind::parse("fastest"), None);
        assert_eq!(SamplerKind::Uniform.label(), "uniform");
        assert_eq!(SamplerKind::LatencyBiased.label(), "latency_biased");
        assert_eq!(SamplerKind::OversampleK.label(), "oversample_k");
        assert_eq!(SamplerKind::default(), SamplerKind::Uniform);
    }

    #[test]
    fn distinct_sorted_in_range() {
        let mut s = UniformSampler::new(100, 1);
        for _ in 0..50 {
            let ids = s.sample(10);
            assert_eq!(ids.len(), 10);
            assert!(ids.windows(2).all(|w| w[0] < w[1]));
            assert!(ids.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = UniformSampler::new(50, 7);
        let mut b = UniformSampler::new(50, 7);
        let mut c = UniformSampler::new(50, 8);
        assert_eq!(a.sample(5), b.sample(5));
        // Different seeds diverge on some draw within a few rounds.
        let mut diverged = false;
        for _ in 0..5 {
            if a.sample(5) != c.sample(5) {
                diverged = true;
            }
        }
        assert!(diverged);
    }

    #[test]
    fn covers_all_clients_over_time() {
        let mut s = UniformSampler::new(20, 3);
        let mut seen = vec![false; 20];
        for _ in 0..60 {
            for id in s.sample(4) {
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "sampler starved some client");
    }

    #[test]
    fn oversample_beta_zero_replays_uniform_exactly() {
        let mut uni = UniformSampler::new(40, 11);
        let mut over = OversampleSampler::new(40, 11, 0.0);
        for round in 0..50 {
            assert_eq!(
                UniformSampler::sample(&mut uni, 6),
                Sampler::sample(&mut over, 6),
                "round {round}"
            );
        }
    }

    #[test]
    fn oversample_draw_counts() {
        let s = OversampleSampler::new(16, 1, 0.5);
        assert_eq!(s.draw_count(4), 6);
        assert_eq!(s.draw_count(3), 5); // ceil(1.5) extra
        assert_eq!(s.draw_count(16), 16); // capped at the pool
        let s0 = OversampleSampler::new(16, 1, 0.0);
        assert_eq!(s0.draw_count(4), 4);
    }

    #[test]
    fn oversample_ids_distinct_sorted_in_range() {
        let mut s = OversampleSampler::new(30, 5, 0.4);
        for _ in 0..40 {
            let ids = Sampler::sample(&mut s, 5);
            assert_eq!(ids.len(), 7);
            assert!(ids.windows(2).all(|w| w[0] < w[1]));
            assert!(ids.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn latency_biased_prefers_fast_but_never_starves() {
        // Client 0 is 10x faster (weight 10); clients 1..9 equal.
        let mut weights = vec![1.0; 10];
        weights[0] = 10.0;
        let mut s = LatencyBiasedSampler::new(weights, 9);
        let mut counts = vec![0usize; 10];
        for _ in 0..400 {
            for id in Sampler::sample(&mut s, 3) {
                counts[id] += 1;
            }
        }
        // The fast client appears far more often than any slow one...
        let max_slow = counts[1..].iter().copied().max().unwrap();
        assert!(counts[0] > 2 * max_slow, "{counts:?}");
        // ...but every slow client still participates.
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn latency_biased_sorted_distinct_and_deterministic() {
        let w = vec![3.0, 1.0, 1.0, 0.5, 2.0, 1.0];
        let mut a = LatencyBiasedSampler::new(w.clone(), 4);
        let mut b = LatencyBiasedSampler::new(w, 4);
        for _ in 0..30 {
            let ids = Sampler::sample(&mut a, 3);
            assert_eq!(ids, Sampler::sample(&mut b, 3));
            assert_eq!(ids.len(), 3);
            assert!(ids.windows(2).all(|p| p[0] < p[1]));
        }
    }

    #[test]
    #[should_panic(expected = "positive weights")]
    fn latency_biased_rejects_zero_weights() {
        LatencyBiasedSampler::new(vec![1.0, 0.0], 1);
    }
}
