//! Client sampling: uniform without replacement (FedAvg's subset `K`
//! of the client pool `C`, paper §II-A).

use crate::util::rng::Rng;

/// Uniform-without-replacement sampler with its own RNG stream.
pub struct UniformSampler {
    rng: Rng,
    num_clients: usize,
}

impl UniformSampler {
    pub fn new(num_clients: usize, seed: u64) -> UniformSampler {
        UniformSampler { rng: Rng::new(seed ^ 0x5A4D_7E3A), num_clients }
    }

    /// Sample `k` distinct client ids for one round (sorted for
    /// deterministic iteration order downstream). Sampling runs on the
    /// coordinator thread *before* the executor fans work out, so the
    /// sampler's mutable stream never races — and the sorted order is
    /// exactly the order the round sink drains results in (the
    /// streaming merge's `push(index, ..)` contract is defined against
    /// this slice, see `coordinator::sink`).
    pub fn sample(&mut self, k: usize) -> Vec<usize> {
        let mut ids = self.rng.choose_k(self.num_clients, k);
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_sorted_in_range() {
        let mut s = UniformSampler::new(100, 1);
        for _ in 0..50 {
            let ids = s.sample(10);
            assert_eq!(ids.len(), 10);
            assert!(ids.windows(2).all(|w| w[0] < w[1]));
            assert!(ids.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = UniformSampler::new(50, 7);
        let mut b = UniformSampler::new(50, 7);
        let mut c = UniformSampler::new(50, 8);
        assert_eq!(a.sample(5), b.sample(5));
        // Different seeds diverge on some draw within a few rounds.
        let mut diverged = false;
        for _ in 0..5 {
            if a.sample(5) != c.sample(5) {
                diverged = true;
            }
        }
        assert!(diverged);
    }

    #[test]
    fn covers_all_clients_over_time() {
        let mut s = UniformSampler::new(20, 3);
        let mut seen = vec![false; 20];
        for _ in 0..60 {
            for id in s.sample(4) {
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "sampler starved some client");
    }
}
