//! Rank-heterogeneous federation — the extension the paper's conclusion
//! sketches ("explore … rank heterogeneity to further reduce the
//! communication cost"), in the spirit of HLoRA [8]: each client trains
//! adapters at its own rank `r_k <= r_server`, and the server projects
//! between rank spaces by zero-padding / truncating the rank dimension
//! of each adapter pair.
//!
//! Projection rules per segment kind (matching the adapter shapes of
//! paper §III):
//! * `lora_b (r, I, K, K)`      — pad/truncate leading `r` rows;
//! * `lora_a (O, r, 1, 1)`      — pad/truncate the per-output-row `r`
//!   columns;
//! * `fc_lora_b (d, r)`         — per-row columns;
//! * `fc_lora_a (r, c)`         — leading rows;
//! * everything else (norm, fc) — shapes match, copied verbatim.
//!
//! Zero-padding is exact for the B·A product: extra rank slots
//! contribute `0 · x = 0`, so an `r`-rank adapter embedded in an
//! `r' > r` space computes the identical function.
//!
//! [`ClientPlan`] turns the projection into an engine feature: it maps
//! every client id to a rank *tier* (a compiled session + wire codec +
//! LoRA scale), and the round engine consults it per client — weak
//! devices train and transmit small adapters inside the one standard
//! `Simulation` loop (`hetero_ranks = "2,4,8"` in the config).

use crate::compression::{Codec, Message};
use crate::error::{Error, Result};
use crate::model::{ParamKind, Segment};
use crate::runtime::ModelSession;

/// Adapter-rank geometry of a segment: `(rank, other_dim,
/// rank_is_leading)`, or `None` for non-adapter segments.
///
/// `rank_is_leading` => memory is rank-major (`[r][inner]`, the right
/// factor of the adapter product); otherwise the segment is per-row
/// rank columns (`[outer][r]`, the left factor). The aggregation zoo
/// ([`adapter_pairs`](crate::coordinator::aggregator::adapter_pairs))
/// uses this to locate each ΔW = L·R factor pair in the flat vector.
pub fn rank_geometry(seg: &Segment) -> Option<(usize, usize, bool)> {
    match seg.kind {
        ParamKind::LoraB => {
            // (r, I, K, K): rank-major.
            let r = seg.shape[0];
            Some((r, seg.numel / r, true))
        }
        ParamKind::FcLoraA => {
            // (r, c): rank-major.
            let r = seg.shape[0];
            Some((r, seg.numel / r, true))
        }
        ParamKind::LoraA => {
            // (O, r, 1, 1): rank-minor.
            let r = seg.shape[1];
            Some((r, seg.shape[0], false))
        }
        ParamKind::FcLoraB => {
            // (d, r): rank-minor.
            let r = seg.shape[1];
            Some((r, seg.shape[0], false))
        }
        _ => None,
    }
}

/// Project a trainable vector from `src` segment layout to `dst`
/// (zero-padding or truncating every adapter's rank dimension).
pub fn project_ranks(
    v: &[f32],
    src: &[Segment],
    dst: &[Segment],
) -> Result<Vec<f32>> {
    if src.len() != dst.len() {
        return Err(Error::invalid(format!(
            "segment count mismatch: {} vs {}",
            src.len(),
            dst.len()
        )));
    }
    let dst_total: usize = dst.iter().map(|s| s.numel).sum();
    let mut out = vec![0.0f32; dst_total];
    for (s, d) in src.iter().zip(dst.iter()) {
        if s.name != d.name || s.kind != d.kind {
            return Err(Error::invalid(format!(
                "segment mismatch: {} vs {}",
                s.name, d.name
            )));
        }
        let sv = &v[s.offset..s.offset + s.numel];
        let dv = &mut out[d.offset..d.offset + d.numel];
        match (rank_geometry(s), rank_geometry(d)) {
            (None, None) => {
                if s.numel != d.numel {
                    return Err(Error::invalid(format!(
                        "non-adapter segment {} changed size",
                        s.name
                    )));
                }
                dv.copy_from_slice(sv);
            }
            (Some((rs, inner_s, lead_s)), Some((rd, inner_d, lead_d))) => {
                if inner_s != inner_d || lead_s != lead_d {
                    return Err(Error::invalid(format!(
                        "adapter {} inner geometry mismatch",
                        s.name
                    )));
                }
                let r = rs.min(rd);
                if lead_s {
                    // rank-major: copy the first r blocks of `inner`.
                    dv[..r * inner_s].copy_from_slice(&sv[..r * inner_s]);
                } else {
                    // rank-minor: per outer row, copy first r columns
                    // (strided row gather, see `kernels`).
                    crate::kernels::gather_rows(sv, rs, dv, rd, r);
                }
            }
            _ => {
                return Err(Error::invalid(format!(
                    "segment {} is an adapter on one side only",
                    s.name
                )))
            }
        }
    }
    Ok(out)
}

/// One rank tier (device class) of a heterogeneous federation: the
/// compiled session at that rank, the tier's wire codec, and the
/// effective `alpha / r_tier` LoRA scale.
pub struct PlanTier {
    pub rank: usize,
    pub session: ModelSession,
    pub codec: Box<dyn Codec>,
    pub lora_scale: f32,
}

/// Per-client rank-tier assignment for one federation.
///
/// The assignment is static round-robin by client id (`cid %
/// num_tiers`) — device classes don't change between rounds — so the
/// plan is immutable, `Sync`, and shareable across executor threads.
pub struct ClientPlan {
    tiers: Vec<PlanTier>,
}

impl ClientPlan {
    /// Panics if `tiers` is empty (a plan with no tiers is a config
    /// bug, caught by `FlConfig::validate` long before this).
    pub fn new(tiers: Vec<PlanTier>) -> ClientPlan {
        assert!(!tiers.is_empty(), "a client plan needs at least one tier");
        ClientPlan { tiers }
    }

    /// Which tier client `cid` belongs to.
    pub fn tier_of(&self, cid: usize) -> usize {
        cid % self.tiers.len()
    }

    pub fn tiers(&self) -> &[PlanTier] {
        &self.tiers
    }

    /// Build one round's tier downloads: project the server-space
    /// global vector down into each tier's rank space and encode it
    /// with that tier's codec. Indexed like [`ClientPlan::tier_of`].
    pub fn encode_downloads(
        &self,
        global: &[f32],
        server_segments: &[Segment],
    ) -> Result<Vec<Message>> {
        self.tiers
            .iter()
            .map(|tier| {
                let segs = &tier.session.spec.trainable_segments;
                let projected =
                    project_ranks(global, server_segments, segs)?;
                tier.codec.encode(&projected, segs)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_spec, ModelCfg, Variant};
    use crate::util::rng::Rng;

    fn specs(r: usize) -> Vec<Segment> {
        build_spec(ModelCfg::by_name("micro8").unwrap(), Variant::LoraFc, r)
            .trainable
    }

    fn randv(n: usize) -> Vec<f32> {
        let mut rng = Rng::new(4);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn down_then_up_is_identity_on_shared_rank_slots() {
        let s4 = specs(4);
        let s8 = specs(8);
        let n4: usize = s4.iter().map(|s| s.numel).sum();
        let v4 = randv(n4);
        let v8 = project_ranks(&v4, &s4, &s8).unwrap();
        let back = project_ranks(&v8, &s8, &s4).unwrap();
        assert_eq!(back, v4);
    }

    #[test]
    fn up_projection_pads_with_zeros() {
        let s4 = specs(4);
        let s8 = specs(8);
        let n4: usize = s4.iter().map(|s| s.numel).sum();
        let v8 = project_ranks(&randv(n4), &s4, &s8).unwrap();
        // Every lora_b segment: rows 4..8 must be zero.
        for seg in &s8 {
            if seg.kind == ParamKind::LoraB {
                let inner = seg.numel / seg.shape[0];
                let sl = &v8[seg.offset..seg.offset + seg.numel];
                assert!(sl[4 * inner..].iter().all(|&x| x == 0.0),
                        "{}", seg.name);
            }
        }
    }

    #[test]
    fn same_rank_is_identity() {
        let s4 = specs(4);
        let n4: usize = s4.iter().map(|s| s.numel).sum();
        let v = randv(n4);
        assert_eq!(project_ranks(&v, &s4, &s4).unwrap(), v);
    }

    #[test]
    fn norm_and_fc_segments_survive_projection() {
        let s4 = specs(4);
        let s8 = specs(8);
        let n4: usize = s4.iter().map(|s| s.numel).sum();
        let v4 = randv(n4);
        let v8 = project_ranks(&v4, &s4, &s8).unwrap();
        for (a, b) in s4.iter().zip(s8.iter()) {
            if matches!(a.kind, ParamKind::NormW | ParamKind::NormB
                        | ParamKind::FcW | ParamKind::FcB) {
                assert_eq!(&v4[a.offset..a.offset + a.numel],
                           &v8[b.offset..b.offset + b.numel], "{}", a.name);
            }
        }
    }

    #[test]
    fn rejects_mismatched_layouts() {
        let s4 = specs(4);
        let full = build_spec(ModelCfg::by_name("micro8").unwrap(),
                              Variant::Full, 0).trainable;
        let n4: usize = s4.iter().map(|s| s.numel).sum();
        assert!(project_ranks(&randv(n4), &s4, &full).is_err());
    }
}
