//! The simulation driver: server state + round loop (paper Fig. 1).
//!
//! [`Simulation::run`] executes the full federated protocol against the
//! AOT artifacts: every byte that would cross the network goes through
//! the configured wire codec in **both** directions (the paper
//! quantizes server→client and client→server messages alike) and is
//! recorded in the [`CommLedger`]; the frozen base `W_initial` is
//! distributed once at round 0 and never re-sent — exactly the FLoCoRA
//! protocol (and, with a `full` variant + fp32 codec, exactly FedAvg).

use std::time::Instant;

use crate::compression::Codec;
use crate::config::FlConfig;
use crate::coordinator::aggregator::FedAvg;
use crate::coordinator::sampler::UniformSampler;
use crate::coordinator::trainer::LocalTrainer;
use crate::data::batcher::Tail;
use crate::data::{lda_partition, BatchIter, Federation, TestSet};
use crate::error::Result;
use crate::metrics::{Recorder, RoundRecord};
use crate::runtime::{Engine, ModelSession};
use crate::transport::{CommLedger, Direction};
use crate::util::rng::Rng;

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub final_acc: f64,
    pub tail_acc: f64,
    pub total_bytes: u64,
    pub mean_up_msg_bytes: f64,
    pub per_client_tcc_bytes: f64,
    pub rounds: usize,
    pub wall_s: f64,
}

/// One federated-learning simulation.
pub struct Simulation {
    cfg: FlConfig,
    session: ModelSession,
    federation: Federation,
    test: TestSet,
    codec: Box<dyn Codec>,
    sampler: UniformSampler,
    rng: Rng,
    /// Global trainable vector (`Δ̄_t L` for LoRA variants; the whole
    /// model for `full`).
    pub global: Vec<f32>,
    /// Frozen `W_initial` — broadcast once, never updated (paper §III).
    pub frozen: Vec<f32>,
    pub ledger: CommLedger,
    lora_scale: f32,
    rounds_done: usize,
    /// Clients that failed mid-round (failure injection diagnostics).
    pub dropped_clients: u64,
}

impl Simulation {
    pub fn new(engine: &Engine, cfg: FlConfig) -> Result<Simulation> {
        cfg.validate()?;
        let session = engine.session(&cfg.tag)?;
        let spec = &session.spec;
        let federation = lda_partition(
            cfg.num_clients,
            cfg.samples_per_client,
            spec.num_classes,
            spec.image_size,
            cfg.lda_alpha,
            cfg.seed,
        );
        let test = TestSet::generate(
            cfg.test_samples,
            spec.image_size,
            spec.num_classes,
            cfg.seed.wrapping_add(0x7E57),
        );
        // W_initial: both sides of the split come from the init artifact
        // with the run seed — every client starts from the same frozen
        // base, like the paper's single initial broadcast.
        let (global, frozen) = session.init(cfg.seed)?;
        let lora_scale = cfg.lora_scale(spec.rank);
        Ok(Simulation {
            sampler: UniformSampler::new(cfg.num_clients, cfg.seed),
            rng: Rng::new(cfg.seed ^ 0xF1F1),
            codec: cfg.codec.build(),
            cfg,
            session,
            federation,
            test,
            global,
            frozen,
            ledger: CommLedger::new(),
            lora_scale,
            rounds_done: 0,
            dropped_clients: 0,
        })
    }

    pub fn config(&self) -> &FlConfig {
        &self.cfg
    }

    pub fn spec_rank(&self) -> usize {
        self.session.spec.rank
    }

    /// Evaluate the current global model on the held-out test set.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let batches = BatchIter::new(
            &self.test.images,
            &self.test.labels,
            self.session.spec.image_size,
            self.session.spec.batch_size,
            None,
            Tail::PadZero,
        );
        for batch in batches {
            let (l, c) = self.session.eval_step(
                &self.global,
                &self.frozen,
                &batch,
                self.lora_scale,
            )?;
            loss_sum += l;
            correct += c;
        }
        let n = self.test.n as f64;
        Ok((loss_sum / n, correct / n))
    }

    /// Execute one communication round; returns the mean client train
    /// loss/acc for the round.
    pub fn round(&mut self) -> Result<(f64, f64)> {
        self.ledger.begin_round();
        let segments = &self.session.spec.trainable_segments;

        // (1) server encodes the global vector once; each sampled client
        //     downloads (and decodes) it.
        let down_msg = self.codec.encode(&self.global, segments)?;
        let client_ids = self.sampler.sample(self.cfg.clients_per_round);
        let mut agg = FedAvg::new(self.global.len());
        let mut loss_sum = 0.0;
        let mut acc_sum = 0.0;

        // Per-round learning rate under the multiplicative schedule.
        let lr = self.cfg.lr
            * self.cfg.lr_decay.powi(self.rounds_done as i32);
        let trainer = LocalTrainer {
            local_epochs: self.cfg.local_epochs,
            lr,
            lora_scale: self.lora_scale,
        };

        let mut survivors = 0usize;
        for &cid in &client_ids {
            self.ledger.record(Direction::Down, down_msg.size_bytes());
            let start = self.codec.decode(&down_msg, segments)?;

            // Failure injection: the client downloaded the model but
            // fails before uploading (crash/network loss). FedAvg
            // proceeds with the survivors — the aggregation-agnostic
            // loop needs no special casing.
            if self.cfg.dropout > 0.0 && self.rng.f64() < self.cfg.dropout {
                self.dropped_clients += 1;
                continue;
            }
            survivors += 1;

            // (2) local training on the client's shard.
            let mut crng = self.rng.fork(cid as u64);
            let outcome = trainer.run(
                &self.session,
                &self.federation.clients[cid],
                &self.frozen,
                start,
                &mut crng,
            )?;
            loss_sum += outcome.mean_loss;
            acc_sum += outcome.mean_acc;

            // (3) upload: encode → count bytes → server decodes.
            let up_msg = self.codec.encode(&outcome.params, segments)?;
            self.ledger.record(Direction::Up, up_msg.size_bytes());
            let received = self.codec.decode(&up_msg, segments)?;

            // (4) FedAvg weighted accumulation (weight n_k).
            agg.add(&received, outcome.samples as f64)?;
        }

        self.rounds_done += 1;
        if survivors == 0 {
            // Every sampled client failed: the round is lost but the
            // federation survives — global state is unchanged.
            return Ok((f64::NAN, f64::NAN));
        }
        self.global = agg.finish()?;
        let k = survivors as f64;
        Ok((loss_sum / k, acc_sum / k))
    }

    /// Run the full schedule, recording evaluated rounds.
    pub fn run(&mut self, recorder: &mut Recorder) -> Result<RunSummary> {
        let t0 = Instant::now();
        let mut last_train_loss = f64::NAN;
        for r in 0..self.cfg.rounds {
            let (train_loss, _train_acc) = self.round()?;
            last_train_loss = train_loss;
            let is_last = r + 1 == self.cfg.rounds;
            if (r + 1) % self.cfg.eval_every == 0 || is_last {
                let (test_loss, test_acc) = self.evaluate()?;
                recorder.push(RoundRecord {
                    round: r + 1,
                    test_acc,
                    test_loss,
                    train_loss,
                    cum_bytes: self.ledger.total_bytes(),
                    wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
            }
        }
        let _ = last_train_loss;
        Ok(RunSummary {
            final_acc: recorder.final_acc(),
            tail_acc: recorder.tail_acc(3),
            total_bytes: self.ledger.total_bytes(),
            mean_up_msg_bytes: self.ledger.mean_up_msg(),
            per_client_tcc_bytes: self.ledger.per_client_tcc(self.cfg.rounds),
            rounds: self.cfg.rounds,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}
