//! The simulation driver: server state + round loop (paper Fig. 1).
//!
//! [`Simulation::run`] executes the full federated protocol against the
//! AOT artifacts: every byte that would cross the network goes through
//! the configured wire codec in **both** directions (the paper
//! quantizes server→client and client→server messages alike) and is
//! recorded in the [`CommLedger`]; the frozen base `W_initial` is
//! distributed once at round 0 and never re-sent — exactly the FLoCoRA
//! protocol (and, with a `full` variant + fp32 codec, exactly FedAvg).
//!
//! Per-client work is delegated to the configured
//! [`ClientExecutor`](crate::coordinator::executor::ClientExecutor)
//! (serial reference or thread-pool parallel); the server merges the
//! results in sampling order, so the two executors are bit-identical.

use std::time::Instant;

use crate::compression::Codec;
use crate::config::FlConfig;
use crate::coordinator::aggregator::FedAvg;
use crate::coordinator::executor::{ClientExecutor, RoundContext};
use crate::coordinator::sampler::UniformSampler;
use crate::coordinator::trainer::LocalTrainer;
use crate::data::batcher::Tail;
use crate::data::{lda_partition, BatchIter, Federation, TestSet};
use crate::error::Result;
use crate::metrics::{Recorder, RoundRecord};
use crate::runtime::{Engine, ModelSession};
use crate::transport::{CommLedger, Direction, NetworkModel};

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub final_acc: f64,
    pub tail_acc: f64,
    /// Mean client train loss of the last round (NaN if every sampled
    /// client dropped in that round).
    pub final_train_loss: f64,
    pub total_bytes: u64,
    pub mean_up_msg_bytes: f64,
    pub per_client_tcc_bytes: f64,
    pub rounds: usize,
    pub wall_s: f64,
    /// Simulated time-on-wire for the whole run if every round's
    /// clients used the link one after another (sum of round trips).
    pub sim_net_serial_s: f64,
    /// Simulated time-on-wire with each round's clients in flight
    /// concurrently — the server waits for the slowest straggler per
    /// round (max, not sum).
    pub sim_net_parallel_s: f64,
}

/// One federated-learning simulation.
///
/// ```no_run
/// use flocora::config::FlConfig;
/// use flocora::coordinator::Simulation;
/// use flocora::coordinator::executor::ExecutorKind;
/// use flocora::metrics::Recorder;
/// use flocora::runtime::Engine;
///
/// # fn main() -> flocora::Result<()> {
/// let engine = Engine::new("artifacts")?; // run `make artifacts` first
/// let cfg = FlConfig {
///     executor: ExecutorKind::Parallel, // bit-identical to Serial
///     threads: 0,                       // 0 = one worker per core
///     ..FlConfig::default()
/// };
/// let mut sim = Simulation::new(&engine, cfg)?;
/// let mut rec = Recorder::new("quickstart");
/// let summary = sim.run(&mut rec)?;
/// println!(
///     "acc {:.3} after {} rounds, {} bytes moved, wire time {:.1}s \
///      (parallel clients) vs {:.1}s (serial clients)",
///     summary.final_acc, summary.rounds, summary.total_bytes,
///     summary.sim_net_parallel_s, summary.sim_net_serial_s,
/// );
/// # Ok(())
/// # }
/// ```
pub struct Simulation {
    cfg: FlConfig,
    session: ModelSession,
    federation: Federation,
    test: TestSet,
    codec: Box<dyn Codec>,
    executor: Box<dyn ClientExecutor>,
    sampler: UniformSampler,
    /// Link profile behind the simulated round-time report.
    net: NetworkModel,
    /// Global trainable vector (`Δ̄_t L` for LoRA variants; the whole
    /// model for `full`).
    pub global: Vec<f32>,
    /// Frozen `W_initial` — broadcast once, never updated (paper §III).
    pub frozen: Vec<f32>,
    pub ledger: CommLedger,
    lora_scale: f32,
    rounds_done: usize,
    last_train_loss: f64,
    sim_net_serial_s: f64,
    sim_net_parallel_s: f64,
    /// Clients that failed mid-round (failure injection diagnostics).
    pub dropped_clients: u64,
}

impl Simulation {
    pub fn new(engine: &Engine, cfg: FlConfig) -> Result<Simulation> {
        cfg.validate()?;
        let session = engine.session(&cfg.tag)?;
        let spec = &session.spec;
        let federation = lda_partition(
            cfg.num_clients,
            cfg.samples_per_client,
            spec.num_classes,
            spec.image_size,
            cfg.lda_alpha,
            cfg.seed,
        );
        let test = TestSet::generate(
            cfg.test_samples,
            spec.image_size,
            spec.num_classes,
            cfg.seed.wrapping_add(0x7E57),
        );
        // W_initial: both sides of the split come from the init artifact
        // with the run seed — every client starts from the same frozen
        // base, like the paper's single initial broadcast.
        let (global, frozen) = session.init(cfg.seed)?;
        let lora_scale = cfg.lora_scale(spec.rank);
        Ok(Simulation {
            sampler: UniformSampler::new(cfg.num_clients, cfg.seed),
            codec: cfg.codec.build(),
            executor: cfg.executor.build(cfg.threads),
            net: NetworkModel::edge_lte(),
            cfg,
            session,
            federation,
            test,
            global,
            frozen,
            ledger: CommLedger::new(),
            lora_scale,
            rounds_done: 0,
            last_train_loss: f64::NAN,
            sim_net_serial_s: 0.0,
            sim_net_parallel_s: 0.0,
            dropped_clients: 0,
        })
    }

    pub fn config(&self) -> &FlConfig {
        &self.cfg
    }

    pub fn spec_rank(&self) -> usize {
        self.session.spec.rank
    }

    /// Swap the link profile used for the simulated round-time report
    /// (default: [`NetworkModel::edge_lte`]). Call before the first
    /// [`Simulation::round`]: the per-run accumulators don't segment by
    /// profile, so switching mid-run mixes times from different links.
    pub fn set_network(&mut self, net: NetworkModel) {
        self.net = net;
    }

    /// Evaluate the current global model on the held-out test set.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let batches = BatchIter::new(
            &self.test.images,
            &self.test.labels,
            self.session.spec.image_size,
            self.session.spec.batch_size,
            None,
            Tail::PadZero,
        );
        for batch in batches {
            let (l, c) = self.session.eval_step(
                &self.global,
                &self.frozen,
                &batch,
                self.lora_scale,
            )?;
            loss_sum += l;
            correct += c;
        }
        let n = self.test.n as f64;
        Ok((loss_sum / n, correct / n))
    }

    /// Execute one communication round; returns the mean client train
    /// loss/acc for the round (NaN/NaN if every sampled client failed —
    /// the round is lost but the federation survives with its global
    /// state unchanged).
    pub fn round(&mut self) -> Result<(f64, f64)> {
        self.ledger.begin_round();
        let segments = &self.session.spec.trainable_segments;

        // (1) server encodes the global vector once; each sampled client
        //     downloads (and decodes) it.
        let down_msg = self.codec.encode(&self.global, segments)?;
        let client_ids = self.sampler.sample(self.cfg.clients_per_round);

        // Per-round learning rate under the multiplicative schedule.
        let lr = self.cfg.lr
            * self.cfg.lr_decay.powi(self.rounds_done as i32);

        // (2)+(3) per-client work — download-decode, local train,
        // encode-upload — runs under the configured executor.
        let results = {
            let ctx = RoundContext {
                session: &self.session,
                codec: self.codec.as_ref(),
                federation: &self.federation,
                frozen: &self.frozen,
                down_msg: &down_msg,
                trainer: LocalTrainer {
                    local_epochs: self.cfg.local_epochs,
                    lr,
                    lora_scale: self.lora_scale,
                },
                cfg: &self.cfg,
                round: self.rounds_done,
            };
            self.executor.execute(&ctx, &client_ids)?
        };

        // (4) deterministic merge in sampling (client-id) order: ledger
        // entries, FedAvg contributions and dropout counts are byte-for-
        // byte the same whichever executor produced the results.
        let mut agg = FedAvg::new(self.global.len());
        let mut loss_sum = 0.0;
        let mut acc_sum = 0.0;
        let mut survivors = 0usize;
        let mut loads = Vec::with_capacity(client_ids.len());
        // Consuming iteration: each client's decoded update buffer is
        // freed as soon as it is folded into the accumulator rather
        // than living until the whole merge ends.
        for (i, res) in results.into_iter().enumerate() {
            // The merge relies on positional order == sampling order;
            // an executor violating the contract must fail loud — in
            // release builds too — not silently mis-attribute FedAvg
            // weights. One integer compare per client per round.
            assert_eq!(res.cid, client_ids[i],
                       "executor broke the result-order contract");
            self.ledger.record(Direction::Down, res.down_bytes);
            match res.update {
                None => {
                    self.dropped_clients += 1;
                    loads.push((res.down_bytes, 0));
                }
                Some(up) => {
                    survivors += 1;
                    self.ledger.record(Direction::Up, up.up_bytes);
                    loss_sum += up.mean_loss;
                    acc_sum += up.mean_acc;
                    agg.add(&up.params, up.weight)?;
                    loads.push((res.down_bytes, up.up_bytes));
                }
            }
        }
        self.sim_net_serial_s += self.net.round_time_serial(&loads);
        self.sim_net_parallel_s += self.net.round_time_parallel(&loads);

        self.rounds_done += 1;
        if survivors == 0 {
            // Every sampled client failed: the round is lost but the
            // federation survives — global state is unchanged.
            return Ok((f64::NAN, f64::NAN));
        }
        self.global = agg.finish()?;
        let k = survivors as f64;
        Ok((loss_sum / k, acc_sum / k))
    }

    /// Run the full schedule, recording evaluated rounds.
    pub fn run(&mut self, recorder: &mut Recorder) -> Result<RunSummary> {
        let t0 = Instant::now();
        for r in 0..self.cfg.rounds {
            let (train_loss, _train_acc) = self.round()?;
            self.last_train_loss = train_loss;
            let is_last = r + 1 == self.cfg.rounds;
            if (r + 1) % self.cfg.eval_every == 0 || is_last {
                let (test_loss, test_acc) = self.evaluate()?;
                recorder.push(RoundRecord {
                    round: r + 1,
                    test_acc,
                    test_loss,
                    train_loss,
                    cum_bytes: self.ledger.total_bytes(),
                    wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
            }
        }
        Ok(RunSummary {
            final_acc: recorder.final_acc(),
            tail_acc: recorder.tail_acc(3),
            final_train_loss: self.last_train_loss,
            total_bytes: self.ledger.total_bytes(),
            mean_up_msg_bytes: self.ledger.mean_up_msg(),
            per_client_tcc_bytes: self.ledger.per_client_tcc(self.cfg.rounds),
            rounds: self.cfg.rounds,
            wall_s: t0.elapsed().as_secs_f64(),
            sim_net_serial_s: self.sim_net_serial_s,
            sim_net_parallel_s: self.sim_net_parallel_s,
        })
    }
}
