//! The simulation driver: server state + round loop (paper Fig. 1).
//!
//! [`Simulation::run`] executes the full federated protocol against the
//! AOT artifacts: every byte that would cross the network goes through
//! the configured wire codec in **both** directions (the paper
//! quantizes server→client and client→server messages alike) and is
//! recorded in the [`CommLedger`]; the frozen base `W_initial` is
//! distributed once at round 0 and never re-sent — exactly the FLoCoRA
//! protocol (and, with a `full` variant + fp32 codec, exactly FedAvg).
//!
//! Per-client work is delegated to the configured
//! [`ClientExecutor`](crate::coordinator::executor::ClientExecutor)
//! (serial reference or windowed thread-pool), which **streams** each
//! result into an in-place shard merge
//! ([`RoundSink`](crate::coordinator::sink::RoundSink)) in sampling
//! order: ledger entries, aggregator folds (`aggregator =
//! fedavg|svt|exact`), dropout counts and stage events fold in as
//! each client's slot drains, so a round's peak memory is
//! O(shards × params + window) and the executors stay bit-identical.
//!
//! With `shards = N` the sampled clients split into N contiguous,
//! block-aligned partitions (see [`crate::coordinator::shard`]); each
//! shard runs its own sink — own aggregator, own ledger bucket, own
//! stage-event log — on its own thread, and the coordinator merges
//! the partials in canonical shard order: event logs replay into one
//! transport stage in sampling order, integer ledgers absorb
//! order-free, and the aggregator block partials reduce through the
//! canonical merge tree. `shards = 1` and `shards = N` are
//! byte-identical by construction.
//!
//! With `hetero_ranks` configured, the round runs a
//! [`ClientPlan`](crate::coordinator::hetero::ClientPlan): each client
//! trains at its own rank tier with its tier's codec, and uploads are
//! projected back into the server's rank space before aggregation.

// det-lint: allow(wall-clock) — `run()` reports real wall-clock time in
// `RunSummary::wall_secs`, a diagnostic column that is stripped before
// the bit-identity diffs in sim-smoke; no simulated quantity reads it.
use std::time::Instant;

use crate::compression::{Codec, Message};
use crate::config::FlConfig;
use crate::coordinator::aggregator::{adapter_pairs, AdapterPair,
                                     Aggregator, ClientUpdate as AggUpdate};
use crate::coordinator::executor::{pool_size, ClientExecutor, ClientResult,
                                   Downloads, RoundContext, UpdateVector};
use crate::coordinator::hetero::{ClientPlan, PlanTier};
use crate::coordinator::shard::{run_partitioned, shard_slices, stat_fold,
                                stat_merge, StatBlock};
use crate::coordinator::sampler::{LatencyBiasedSampler, OversampleSampler,
                                  Sampler, SamplerKind, UniformSampler};
use crate::coordinator::sink::RoundSink;
use crate::coordinator::trainer::LocalTrainer;
use crate::data::batcher::Tail;
use crate::data::{lda_partition, BatchIter, Federation, TestSet};
use crate::error::{Error, Result};
use crate::metrics::{p50, Recorder, RoundRecord};
use crate::model::Segment;
use crate::runtime::{Engine, ModelSession};
use crate::transport::{ClientProfiles, CommLedger, Direction, NetworkModel,
                       StageEvent, TimeModel, TransferStage};
use crate::util::rng::Rng;

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub final_acc: f64,
    pub tail_acc: f64,
    /// Mean client train loss of the last round (NaN if every sampled
    /// client dropped in that round).
    pub final_train_loss: f64,
    pub total_bytes: u64,
    pub mean_up_msg_bytes: f64,
    pub per_client_tcc_bytes: f64,
    pub rounds: usize,
    pub wall_s: f64,
    /// Simulated time-on-wire for the whole run if every round's
    /// clients used the link one after another (sum of round trips).
    pub sim_net_serial_s: f64,
    /// Simulated time-on-wire with each round's clients in flight
    /// concurrently — slowest straggler per round on dedicated links,
    /// total-bits-over-capacity on a shared pipe (see
    /// [`crate::transport::Sharing`]).
    pub sim_net_parallel_s: f64,
    /// Simulated time-on-wire under the transport-stage overlap regime
    /// (`overlap = transfer`): transfer streamed off the client task,
    /// so each round is bounded by its slowest single stage (and, on a
    /// shared pipe, the busier direction). Never above
    /// `sim_net_parallel_s`.
    pub sim_net_pipelined_s: f64,
    /// Total simulated transfer wait across the run (downloads +
    /// uploads, cancelled downloads included) — the wire time the
    /// pipelined regime hides behind compute.
    pub transfer_wait_s: f64,
    /// The active `time_model`'s simulated round time, summed over the
    /// run: bit-identical to `sim_net_pipelined_s` under `closed`, the
    /// chunk-granularity discrete-event result under `event` (always
    /// within `[pipelined, parallel]` on dedicated links).
    pub sim_net_event_s: f64,
    /// Peak inter-stage queue occupancy (chunks) any round's event
    /// simulation observed; 0 under `time_model = closed`.
    pub queue_peak: usize,
    /// Total simulated producer-blocked time on full stage queues
    /// across the run; 0 under `time_model = closed`.
    pub queue_block_s: f64,
    /// Sampled clients the server cancelled across the run
    /// (`sampler = oversample_k` ends each round at the K-th accepted
    /// upload; 0 for the other strategies).
    pub cancelled_clients: u64,
    /// Median simulated client round-trip (profiled wire + compute)
    /// over every client the server waited on, whole run.
    pub sim_client_p50_s: f64,
    /// Slowest simulated client round-trip seen in the run.
    pub sim_client_max_s: f64,
    /// Mean effective adapter rank the server broadcast, averaged over
    /// every aggregated round (rounds every client lost are excluded).
    /// The static server rank under `aggregator = fedavg`; what the
    /// energy threshold kept under `svt`; 0.0 for layouts with no
    /// adapter pairs.
    pub mean_eff_rank: f64,
    /// Deepest canonical block-merge tree any aggregated round needed
    /// (0 when every round's survivors fit one fold block, i.e. the
    /// historical serial fold). Shard-invariant by construction: the
    /// tree shape depends only on the non-empty block list, never on
    /// the `shards` knob — so it survives the sim-smoke bit-identity
    /// diffs.
    pub merge_depth: usize,
}

/// One planned communication round: every decision the coordinator
/// makes *before* any client work runs — the ledger bucket is opened,
/// the broadcast is encoded, the clients are sampled and the
/// cancellations are planned. Produced by [`Simulation::plan_round`],
/// consumed by [`Simulation::merge_round`]; [`Simulation::round`] is
/// literally that composition. The wire server
/// ([`crate::transport::wire`]) announces exactly this plan to remote
/// clients, so in-process and networked rounds share one decision
/// path — the core of the wire mode's byte-identity argument.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Round index (`rounds_done` at planning time).
    pub round: usize,
    /// This round's learning rate under the multiplicative schedule.
    pub lr: f32,
    /// Sampled client ids, sorted ascending — the merge's slot order.
    pub client_ids: Vec<usize>,
    /// Sorted ids the server pre-decided to cancel (oversampling
    /// strategies return more ids than the round accepts).
    pub cancelled_ids: Vec<usize>,
    /// The one shared encoded download (homogeneous rounds).
    pub shared_msg: Option<Message>,
    /// Per-tier encoded downloads (heterogeneous rounds); empty
    /// otherwise. Exactly one of `shared_msg` / `tier_msgs` is set.
    pub tier_msgs: Vec<Message>,
}

/// One federated-learning simulation.
///
/// ```no_run
/// use flocora::config::FlConfig;
/// use flocora::coordinator::Simulation;
/// use flocora::coordinator::executor::ExecutorKind;
/// use flocora::metrics::Recorder;
/// use flocora::runtime::Engine;
///
/// # fn main() -> flocora::Result<()> {
/// let engine = Engine::new("artifacts")?; // run `make artifacts` first
/// let cfg = FlConfig {
///     executor: ExecutorKind::Parallel, // bit-identical to Serial
///     threads: 0,                       // 0 = one worker per core
///     window: 0,                        // 0 = 2x workers; any value
///                                       //     is bit-identical too
///     ..FlConfig::default()
/// };
/// let mut sim = Simulation::new(&engine, cfg)?;
/// let mut rec = Recorder::new("quickstart");
/// let summary = sim.run(&mut rec)?;
/// println!(
///     "acc {:.3} after {} rounds, {} bytes moved, wire time {:.1}s \
///      (parallel clients) vs {:.1}s (serial clients)",
///     summary.final_acc, summary.rounds, summary.total_bytes,
///     summary.sim_net_parallel_s, summary.sim_net_serial_s,
/// );
/// # Ok(())
/// # }
/// ```
pub struct Simulation {
    cfg: FlConfig,
    session: ModelSession,
    federation: Federation,
    test: TestSet,
    codec: Box<dyn Codec>,
    executor: Box<dyn ClientExecutor>,
    sampler: Box<dyn Sampler>,
    /// Base link profile behind the simulated round-time report.
    net: NetworkModel,
    /// Per-client link/compute deviations from the base link.
    profiles: ClientProfiles,
    /// Round-time backend (`time_model` knob): closed envelopes or the
    /// chunk-granularity discrete-event simulator.
    time_model: Box<dyn TimeModel>,
    /// Rank-tier plan (`hetero_ranks`); `None` = homogeneous.
    plan: Option<ClientPlan>,
    /// Bytes moved per tier (down + up), indexed like the plan's
    /// tiers. Empty for homogeneous runs.
    tier_bytes: Vec<u64>,
    /// Global trainable vector (`Δ̄_t L` for LoRA variants; the whole
    /// model for `full`).
    pub global: Vec<f32>,
    /// Frozen `W_initial` — broadcast once, never updated (paper §III).
    pub frozen: Vec<f32>,
    pub ledger: CommLedger,
    lora_scale: f32,
    rounds_done: usize,
    last_train_loss: f64,
    last_round_dropped: u64,
    last_round_cancelled: u64,
    /// Simulated round-trip of every client the server waited on in
    /// the most recent round (bounded by clients-per-round).
    last_round_times: Vec<f64>,
    sim_net_serial_s: f64,
    sim_net_parallel_s: f64,
    sim_net_pipelined_s: f64,
    transfer_wait_s: f64,
    sim_net_event_s: f64,
    queue_peak: usize,
    queue_block_s: f64,
    last_round_queue_peak: usize,
    /// Adapter factor pairs of the server layout, precomputed once for
    /// the per-round aggregator builds (`aggregator = svt|exact`).
    agg_pairs: Vec<AdapterPair>,
    /// Effective rank the most recent aggregated round broadcast (NaN
    /// while no round has aggregated, and after a lost round).
    last_round_eff_rank: f64,
    /// Deepest block-merge tree over the run (see `coordinator::shard`).
    merge_depth: usize,
    /// Wall-clock seconds each shard spent settling its partial in the
    /// most recent round, in shard order — a stdout diagnostic, never
    /// exported (it would break the bit-identity diffs).
    last_round_shard_settle_s: Vec<f64>,
    /// Clients that failed mid-round (failure injection diagnostics).
    pub dropped_clients: u64,
    /// Clients the server cancelled after their round already had K
    /// uploads (`sampler = oversample_k` only).
    pub cancelled_clients: u64,
}

impl Simulation {
    pub fn new(engine: &Engine, cfg: FlConfig) -> Result<Simulation> {
        cfg.validate()?;
        let session = engine.session(&cfg.tag)?;
        let spec = &session.spec;
        let federation = lda_partition(
            cfg.num_clients,
            cfg.samples_per_client,
            spec.num_classes,
            spec.image_size,
            cfg.lda_alpha,
            cfg.seed,
        );
        let test = TestSet::generate(
            cfg.test_samples,
            spec.image_size,
            spec.num_classes,
            cfg.seed.wrapping_add(0x7E57),
        );
        // W_initial: both sides of the split come from the init artifact
        // with the run seed — every client starts from the same frozen
        // base, like the paper's single initial broadcast.
        let (global, frozen) = session.init(cfg.seed)?;
        let lora_scale = cfg.lora_scale(spec.rank);
        // Rank-tier plan: one compiled session + codec per tier, tags
        // derived from the server tag's (model, variant) coordinates.
        let plan = if cfg.hetero_ranks.is_empty() {
            None
        } else {
            if !spec.variant.is_lora() {
                return Err(Error::invalid(
                    "hetero_ranks needs a LoRA server tag (full models \
                     have no rank dimension)",
                ));
            }
            let mut tiers = Vec::with_capacity(cfg.hetero_ranks.len());
            for (i, &rank) in cfg.hetero_ranks.iter().enumerate() {
                if rank > spec.rank {
                    // Up-projection pads exactly; the reverse would
                    // silently truncate rank slots r_server..r_tier of
                    // every update the client trains. Refuse instead.
                    return Err(Error::invalid(format!(
                        "hetero tier r{rank} exceeds the server rank \
                         r{} — its updates would be truncated every \
                         round",
                        spec.rank
                    )));
                }
                let tag =
                    format!("{}_{}_r{}", spec.model, spec.variant, rank);
                let kind = cfg.hetero_codecs.get(i).copied()
                    .unwrap_or(cfg.codec);
                tiers.push(PlanTier {
                    rank,
                    session: engine.session(&tag)?,
                    codec: kind.build(),
                    lora_scale: cfg.lora_scale(rank),
                });
            }
            Some(ClientPlan::new(tiers))
        };
        let tier_bytes = vec![0u64; plan.as_ref()
            .map_or(0, |p| p.tiers().len())];
        // Factor pairs for the aggregation zoo — located once in the
        // server layout; hetero uploads are already projected into it
        // before the sink sees them.
        let agg_pairs = adapter_pairs(&spec.trainable_segments);
        let net = cfg.network.build().with_sharing(cfg.net_sharing);
        let profiles = cfg.client_profiles.build(
            cfg.num_clients,
            cfg.seed,
            cfg.compute_base_s,
        )?;
        let time_model = cfg.time_model.build(cfg.chunk_kb, cfg.stage_queue);
        let sampler: Box<dyn Sampler> = match cfg.sampler {
            SamplerKind::Uniform => {
                Box::new(UniformSampler::new(cfg.num_clients, cfg.seed))
            }
            SamplerKind::LatencyBiased => {
                // Weight ∝ inverse expected round trip on a nominal
                // 1 MB message each way — the bias only needs relative
                // speeds, not the exact payload.
                const NOMINAL: usize = 1_000_000;
                let weights = (0..cfg.num_clients)
                    .map(|cid| {
                        1.0 / profiles.client_time(&net, cid, NOMINAL,
                                                   NOMINAL)
                    })
                    .collect();
                Box::new(LatencyBiasedSampler::new(weights, cfg.seed))
            }
            SamplerKind::OversampleK => Box::new(OversampleSampler::new(
                cfg.num_clients,
                cfg.seed,
                cfg.oversample_beta,
            )),
        };
        Ok(Simulation {
            sampler,
            codec: cfg.codec.build(),
            executor: cfg.executor.build(cfg.threads, cfg.window,
                                         cfg.overlap),
            net,
            profiles,
            time_model,
            plan,
            tier_bytes,
            cfg,
            session,
            federation,
            test,
            global,
            frozen,
            ledger: CommLedger::new(),
            lora_scale,
            rounds_done: 0,
            last_train_loss: f64::NAN,
            last_round_dropped: 0,
            last_round_cancelled: 0,
            last_round_times: Vec::new(),
            sim_net_serial_s: 0.0,
            sim_net_parallel_s: 0.0,
            sim_net_pipelined_s: 0.0,
            transfer_wait_s: 0.0,
            sim_net_event_s: 0.0,
            queue_peak: 0,
            queue_block_s: 0.0,
            last_round_queue_peak: 0,
            agg_pairs,
            last_round_eff_rank: f64::NAN,
            merge_depth: 0,
            last_round_shard_settle_s: Vec::new(),
            dropped_clients: 0,
            cancelled_clients: 0,
        })
    }

    pub fn config(&self) -> &FlConfig {
        &self.cfg
    }

    pub fn spec_rank(&self) -> usize {
        self.session.spec.rank
    }

    /// The rank-tier plan, if this is a heterogeneous run.
    pub fn plan(&self) -> Option<&ClientPlan> {
        self.plan.as_ref()
    }

    /// Bytes moved per rank tier (down + up), indexed like
    /// [`ClientPlan::tiers`]. Empty for homogeneous runs.
    pub fn tier_bytes(&self) -> &[u64] {
        &self.tier_bytes
    }

    /// Clients dropped in the most recent round.
    pub fn last_round_dropped(&self) -> u64 {
        self.last_round_dropped
    }

    /// Clients the server cancelled in the most recent round.
    pub fn last_round_cancelled(&self) -> u64 {
        self.last_round_cancelled
    }

    /// The per-client profile table of this federation.
    pub fn profiles(&self) -> &ClientProfiles {
        &self.profiles
    }

    /// Wall-clock seconds each shard spent settling its partial in the
    /// most recent round, in canonical shard order. Diagnostic only —
    /// it never feeds a simulated quantity or an exported column.
    pub fn last_round_shard_settle_s(&self) -> &[f64] {
        &self.last_round_shard_settle_s
    }

    /// Swap the link profile used for the simulated round-time report
    /// (default: from `FlConfig::network` / `net_sharing`). Call before
    /// the first [`Simulation::round`]: the per-run accumulators don't
    /// segment by profile, so switching mid-run mixes times from
    /// different links.
    pub fn set_network(&mut self, net: NetworkModel) {
        self.net = net;
    }

    /// Evaluate the current global model on the held-out test set.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let batches = BatchIter::new(
            &self.test.images,
            &self.test.labels,
            self.session.spec.image_size,
            self.session.spec.batch_size,
            None,
            Tail::PadZero,
        );
        for batch in batches {
            let (l, c) = self.session.eval_step(
                &self.global,
                &self.frozen,
                &batch,
                self.lora_scale,
            )?;
            loss_sum += l;
            correct += c;
        }
        let n = self.test.n as f64;
        Ok((loss_sum / n, correct / n))
    }

    /// Execute one communication round; returns the mean client train
    /// loss/acc for the round (NaN/NaN if every sampled client failed —
    /// the round is lost but the federation survives with its global
    /// state unchanged).
    pub fn round(&mut self) -> Result<(f64, f64)> {
        let rp = self.plan_round()?;
        self.merge_round(&rp, None)
    }

    /// Open the next round on the coordinator: begin the ledger
    /// bucket, encode the download(s), sample the clients and plan the
    /// cancellations. Advances the sampler stream exactly once, so
    /// `plan_round` + [`Simulation::merge_round`] is bit-identical to
    /// [`Simulation::round`] — which is literally that composition.
    pub fn plan_round(&mut self) -> Result<RoundPlan> {
        self.ledger.begin_round();
        let segments = &self.session.spec.trainable_segments;

        // (1) the server encodes this round's download(s): one shared
        //     message, or one per rank tier (projected, tier-encoded).
        let (shared_msg, tier_msgs): (Option<Message>, Vec<Message>) =
            match &self.plan {
                None => (
                    Some(self.codec.encode(&self.global, segments)?),
                    Vec::new(),
                ),
                Some(plan) => {
                    (None, plan.encode_downloads(&self.global, segments)?)
                }
            };
        let client_ids = self.sampler.sample(self.cfg.clients_per_round);
        // Oversampling strategies return more ids than the round
        // needs; plan which stragglers to cancel *now*, from expected
        // round trips — deterministic under any executor.
        let cancelled_ids = if client_ids.len()
            > self.cfg.clients_per_round
        {
            self.plan_cancellations(&client_ids, shared_msg.as_ref(),
                                    &tier_msgs)
        } else {
            Vec::new()
        };

        // Per-round learning rate under the multiplicative schedule.
        let lr = self.cfg.lr
            * self.cfg.lr_decay.powi(self.rounds_done as i32);
        Ok(RoundPlan {
            round: self.rounds_done,
            lr,
            client_ids,
            cancelled_ids,
            shared_msg,
            tier_msgs,
        })
    }

    /// Merge one planned round: fan the per-client work out through an
    /// executor, stream the results into the per-shard merges, charge
    /// the transport stage and aggregate the survivors. `external`
    /// overrides the configured executor for this round — the wire
    /// server hands in a replay executor fed from socket-delivered
    /// uploads, so remote results flow through the *same* shard merge,
    /// ledger and aggregator code as in-process ones; `None` runs the
    /// configured executor.
    pub fn merge_round(
        &mut self,
        rp: &RoundPlan,
        external: Option<&dyn ClientExecutor>,
    ) -> Result<(f64, f64)> {
        if rp.round != self.rounds_done {
            return Err(Error::invalid(format!(
                "merge_round got a plan for round {} but the simulation \
                 is at round {}",
                rp.round, self.rounds_done
            )));
        }
        let segments = &self.session.spec.trainable_segments;
        let downloads = match &rp.shared_msg {
            Some(msg) => Downloads::Homogeneous(msg),
            None => Downloads::Tiered(&rp.tier_msgs),
        };
        let client_ids = &rp.client_ids;
        let lr = rp.lr;

        // (2)+(3)+(4) per-client work streams into per-shard in-place
        // merges: ledger entries, aggregator folds, dropout counts and
        // stage events fold in as each client's slot drains, in
        // sampling order — byte-for-byte the same whichever executor
        // (or window, or shard count) produced the results, and never
        // a buffered Vec of updates. Each shard owns its sink, its
        // aggregator, its ledger bucket and its event log on its own
        // thread; wire time is charged afterwards by the one
        // coordinator-side transport stage, which owns the link clock
        // and the round's load accumulator.
        let ctx = RoundContext {
            session: &self.session,
            codec: self.codec.as_ref(),
            federation: &self.federation,
            frozen: &self.frozen,
            downloads,
            trainer: LocalTrainer {
                local_epochs: self.cfg.local_epochs,
                lr,
                lora_scale: self.lora_scale,
            },
            cfg: &self.cfg,
            round: rp.round,
            plan: self.plan.as_ref(),
            cancelled: &rp.cancelled_ids,
        };
        let shards = self.cfg.shards;
        let ranges = shard_slices(client_ids.len(), shards);
        let executor = external.unwrap_or(self.executor.as_ref());
        let plan = self.plan.as_ref();
        let codec = self.codec.as_ref();
        let n_tiers = self.tier_bytes.len();
        let agg_kind = self.cfg.aggregator;
        let svt_energy = self.cfg.svt_energy;
        let dim = self.global.len();
        let agg_pairs = &self.agg_pairs;
        let shard_merges =
            run_partitioned(shards, pool_size(0, shards), |j| {
                let slice = &client_ids[ranges[j].clone()];
                let mut merge = ShardMerge {
                    expected: slice,
                    base_slot: ranges[j].start,
                    plan,
                    codec,
                    segments,
                    ledger: {
                        let mut l = CommLedger::new();
                        l.begin_round();
                        l
                    },
                    tier_bytes: vec![0; n_tiers],
                    events: Vec::new(),
                    agg: agg_kind.build(dim, agg_pairs, svt_energy),
                    stats: Vec::new(),
                    survivors: 0,
                    dropped: 0,
                    cancelled: 0,
                    settle_s: 0.0,
                };
                // det-lint: allow(wall-clock) — per-shard settle
                // stopwatch; a stdout-only diagnostic, no simulated
                // quantity or exported column reads it.
                let t = Instant::now();
                executor.execute(&ctx, slice, &mut merge)?;
                merge.settle_s = t.elapsed().as_secs_f64();
                Ok(merge)
            })?;

        // Coordinator-side merge, in canonical shard order. Shard
        // partitions are contiguous in sampling order, so replaying
        // the shard event logs back-to-back feeds the one transport
        // stage the exact unsharded event stream; the integer ledgers
        // absorb order-free; aggregator partials and stat blocks
        // concatenate into the global ascending block list for the
        // canonical tree merge.
        let mut stage = TransferStage::begin_round(&self.net, &self.profiles,
                                                   &*self.time_model);
        let mut partials = Vec::with_capacity(shard_merges.len());
        let mut stats: Vec<StatBlock> = Vec::new();
        let mut settle_s = Vec::with_capacity(shard_merges.len());
        let (mut survivors, mut dropped, mut cancelled) =
            (0usize, 0u64, 0u64);
        for shard in shard_merges {
            for ev in &shard.events {
                stage.push(*ev);
            }
            self.ledger.absorb_round(&shard.ledger);
            for (total, part) in
                self.tier_bytes.iter_mut().zip(&shard.tier_bytes)
            {
                *total += part;
            }
            survivors += shard.survivors;
            dropped += shard.dropped;
            cancelled += shard.cancelled;
            stats.extend(shard.stats);
            partials.push(shard.agg.into_partial());
            settle_s.push(shard.settle_s);
        }
        let transport = stage.finish();
        self.sim_net_serial_s += transport.serial_s;
        self.sim_net_parallel_s += transport.parallel_s;
        self.sim_net_pipelined_s += transport.pipelined_s;
        self.transfer_wait_s += transport.transfer_wait_s;
        self.sim_net_event_s += transport.event_s;
        self.queue_peak = self.queue_peak.max(transport.queue_peak);
        self.queue_block_s += transport.queue_block_s;
        self.last_round_queue_peak = transport.queue_peak;
        self.dropped_clients += dropped;
        self.last_round_dropped = dropped;
        self.cancelled_clients += cancelled;
        self.last_round_cancelled = cancelled;
        self.last_round_times = transport.times;
        self.last_round_shard_settle_s = settle_s;

        self.rounds_done += 1;
        if survivors == 0 {
            // Every sampled client failed: the round is lost but the
            // federation survives — global state is unchanged (and no
            // effective rank was broadcast).
            self.last_round_eff_rank = f64::NAN;
            return Ok((f64::NAN, f64::NAN));
        }
        let (outcome, depth) = self.cfg.aggregator.finish_partials(
            dim,
            &self.agg_pairs,
            self.cfg.svt_energy,
            partials,
        )?;
        self.merge_depth = self.merge_depth.max(depth);
        self.global = outcome.global;
        self.last_round_eff_rank = outcome.eff_rank;
        let (loss_sum, acc_sum) = stat_merge(stats);
        let k = survivors as f64;
        Ok((loss_sum / k, acc_sum / k))
    }

    /// Decide which of an oversampled round's clients to cancel: rank
    /// the round's *expected* survivors by expected simulated round
    /// trip (profiled wire + compute, with the upload estimated at the
    /// download size — exact for the layout-determined fp32/affine
    /// codecs, an approximation for the sparse ones) and cut everyone
    /// after the first `clients_per_round` expected uploads. Ties
    /// break on sampling index, and the dropout check replays the same
    /// per-client coin `run_client` draws — so the plan is a pure
    /// function of the round coordinates and the executors stay
    /// bit-identical.
    fn plan_cancellations(
        &self,
        sampled: &[usize],
        shared_msg: Option<&Message>,
        tier_msgs: &[Message],
    ) -> Vec<usize> {
        let k = self.cfg.clients_per_round;
        let mut expected: Vec<(f64, usize)> = Vec::new();
        for (i, &cid) in sampled.iter().enumerate() {
            if self.cfg.dropout > 0.0 {
                let coin = Rng::for_client(
                    self.cfg.seed,
                    self.rounds_done as u64,
                    cid as u64,
                )
                .f64();
                if coin < self.cfg.dropout {
                    // Will drop before uploading: never a candidate
                    // for one of the K accepted uploads.
                    continue;
                }
            }
            let down = match (&self.plan, shared_msg) {
                (Some(plan), _) => {
                    tier_msgs[plan.tier_of(cid)].size_bytes()
                }
                (None, Some(msg)) => msg.size_bytes(),
                (None, None) => 0,
            };
            let t = self.profiles.client_time(&self.net, cid, down,
                                              down.max(1));
            expected.push((t, i));
        }
        if expected.len() <= k {
            // Dropouts already thinned the round below K uploads:
            // every expected survivor is accepted.
            return Vec::new();
        }
        expected.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut cancelled: Vec<usize> =
            expected[k..].iter().map(|&(_, i)| sampled[i]).collect();
        cancelled.sort_unstable();
        cancelled
    }

    /// Run the full schedule, recording evaluated rounds.
    pub fn run(&mut self, recorder: &mut Recorder) -> Result<RunSummary> {
        self.run_with(recorder, |sim| sim.round())
    }

    /// Run the full schedule with a caller-supplied round driver. The
    /// driver is called once per scheduled round and must leave the
    /// simulation exactly one round further (the obvious driver is
    /// `|sim| sim.round()`, which is what [`Simulation::run`] passes);
    /// the wire server's driver plans the round, gathers the remote
    /// results and calls [`Simulation::merge_round`]. Everything else
    /// — evaluation cadence, record windows, the summary — is this one
    /// code path, so a wire run's records are byte-identical to an
    /// in-process run's by construction.
    pub fn run_with(
        &mut self,
        recorder: &mut Recorder,
        mut round_fn: impl FnMut(&mut Simulation) -> Result<(f64, f64)>,
    ) -> Result<RunSummary> {
        // det-lint: allow(wall-clock) — start of the wall_secs stopwatch;
        // feeds only the diagnostic `RunSummary::wall_secs` column.
        let t0 = Instant::now();
        // Drops/cancellations and client times are tallied *between*
        // records so the exported columns cover every round (and the
        // counts sum to `dropped_clients`/`cancelled_clients`) even
        // when `eval_every > 1` skips rounds.
        let mut drops_since_record = 0u64;
        let mut cancelled_since_record = 0u64;
        let mut pipelined_at_record = 0.0f64;
        let mut wait_at_record = 0.0f64;
        let mut event_at_record = 0.0f64;
        let mut block_at_record = 0.0f64;
        let mut window_queue_peak = 0usize;
        let mut window_times: Vec<f64> = Vec::new();
        // Whole-run client times for the summary percentiles; bounded
        // by rounds × clients_per_round f64s.
        let mut all_times: Vec<f64> = Vec::new();
        // Effective-rank means, per record window and whole-run; lost
        // rounds (NaN) broadcast nothing and are excluded.
        let (mut eff_sum_window, mut eff_rounds_window) = (0.0f64, 0u64);
        let (mut eff_sum_run, mut eff_rounds_run) = (0.0f64, 0u64);
        for r in 0..self.cfg.rounds {
            let (train_loss, _train_acc) = round_fn(self)?;
            self.last_train_loss = train_loss;
            drops_since_record += self.last_round_dropped;
            cancelled_since_record += self.last_round_cancelled;
            window_queue_peak =
                window_queue_peak.max(self.last_round_queue_peak);
            window_times.extend_from_slice(&self.last_round_times);
            all_times.extend_from_slice(&self.last_round_times);
            if self.last_round_eff_rank.is_finite() {
                eff_sum_window += self.last_round_eff_rank;
                eff_rounds_window += 1;
                eff_sum_run += self.last_round_eff_rank;
                eff_rounds_run += 1;
            }
            let is_last = r + 1 == self.cfg.rounds;
            if (r + 1) % self.cfg.eval_every == 0 || is_last {
                let (test_loss, test_acc) = self.evaluate()?;
                recorder.push(RoundRecord {
                    round: r + 1,
                    test_acc,
                    test_loss,
                    train_loss,
                    cum_bytes: self.ledger.total_bytes(),
                    dropped: drops_since_record,
                    cancelled: cancelled_since_record,
                    client_p50_s: p50(&window_times),
                    client_max_s: window_times.iter().copied()
                        .fold(0.0, f64::max),
                    sim_net_pipelined_s: self.sim_net_pipelined_s
                        - pipelined_at_record,
                    transfer_wait_s: self.transfer_wait_s - wait_at_record,
                    sim_net_event_s: self.sim_net_event_s - event_at_record,
                    queue_peak: window_queue_peak,
                    queue_block_s: self.queue_block_s - block_at_record,
                    eff_rank: if eff_rounds_window > 0 {
                        eff_sum_window / eff_rounds_window as f64
                    } else {
                        0.0
                    },
                    wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
                drops_since_record = 0;
                cancelled_since_record = 0;
                eff_sum_window = 0.0;
                eff_rounds_window = 0;
                pipelined_at_record = self.sim_net_pipelined_s;
                wait_at_record = self.transfer_wait_s;
                event_at_record = self.sim_net_event_s;
                block_at_record = self.queue_block_s;
                window_queue_peak = 0;
                window_times.clear();
            }
        }
        Ok(RunSummary {
            final_acc: recorder.final_acc(),
            tail_acc: recorder.tail_acc(3),
            final_train_loss: self.last_train_loss,
            total_bytes: self.ledger.total_bytes(),
            mean_up_msg_bytes: self.ledger.mean_up_msg(),
            per_client_tcc_bytes: self.ledger.per_client_tcc(self.cfg.rounds),
            rounds: self.cfg.rounds,
            wall_s: t0.elapsed().as_secs_f64(),
            sim_net_serial_s: self.sim_net_serial_s,
            sim_net_parallel_s: self.sim_net_parallel_s,
            sim_net_pipelined_s: self.sim_net_pipelined_s,
            transfer_wait_s: self.transfer_wait_s,
            sim_net_event_s: self.sim_net_event_s,
            queue_peak: self.queue_peak,
            queue_block_s: self.queue_block_s,
            cancelled_clients: self.cancelled_clients,
            sim_client_p50_s: p50(&all_times),
            sim_client_max_s: all_times.iter().copied().fold(0.0, f64::max),
            mean_eff_rank: if eff_rounds_run > 0 {
                eff_sum_run / eff_rounds_run as f64
            } else {
                0.0
            },
            merge_depth: self.merge_depth,
        })
    }
}

/// One shard's in-place merge: the [`RoundSink`] holding that shard's
/// accumulators. Every push folds one client straight into the shard's
/// ledger bucket and its [`Aggregator`] (`fedavg|svt|exact`), and logs
/// the client's round as [`StageEvent`]s for the coordinator to replay
/// into the one transport stage — wire-time charging lives there, not
/// in the merge. The decoded update is freed as soon as its
/// `agg.fold` returns; factor-aware aggregators do their refactor
/// work inside `finish_partials`, on the coordinator thread, after
/// every shard settles. A shard merge owns all its state (no `&mut`
/// into the server), so shards run on their own threads behind
/// `coordinator::shard::run_partitioned`.
struct ShardMerge<'a> {
    /// This shard's slice of the sampled ids (sampling order).
    expected: &'a [usize],
    /// Global sampling slot of shard-local index 0 — block-aligned by
    /// [`shard_slices`], so `base_slot + index` routes every fold to
    /// its partition-invariant block.
    base_slot: usize,
    plan: Option<&'a ClientPlan>,
    /// Server-rank codec + segment layout, for folding still-encoded
    /// uploads straight into the aggregator (zero-copy `decode_into`).
    codec: &'a dyn Codec,
    segments: &'a [Segment],
    /// Shard-local ledger (one round bucket); the coordinator absorbs
    /// it via [`CommLedger::absorb_round`].
    ledger: CommLedger,
    /// Shard-local per-tier byte counters, summed into the server's.
    tier_bytes: Vec<u64>,
    /// The shard's transport narration, replayed by the coordinator in
    /// shard order (see `transport::stage`).
    events: Vec<StageEvent>,
    agg: Box<dyn Aggregator>,
    /// Per-block train loss/acc partials (`shard::stat_fold`).
    stats: Vec<StatBlock>,
    survivors: usize,
    dropped: u64,
    cancelled: u64,
    /// Wall-clock settle time, filled after `execute` returns.
    settle_s: f64,
}

impl RoundSink for ShardMerge<'_> {
    fn push(&mut self, index: usize, res: ClientResult) -> Result<()> {
        // The merge relies on positional order == sampling order; an
        // executor violating the contract must fail loud — in release
        // builds too — not silently mis-attribute FedAvg weights. One
        // integer compare per client per round.
        if self.expected.get(index) != Some(&res.cid) {
            return Err(Error::invalid(format!(
                "executor broke the result-order contract: slot {index} \
                 got client {}, expected {:?}",
                res.cid,
                self.expected.get(index),
            )));
        }
        self.ledger.record(Direction::Down, res.down_bytes);
        self.events.push(StageEvent::Download {
            cid: res.cid,
            bytes: res.down_bytes,
        });
        let up_bytes = if res.cancelled {
            // The server cut this client after the round had its K
            // uploads: the download still moved (bytes + serial time),
            // but the round never waits for it — under `overlap =
            // transfer` the cut lands mid-transfer.
            self.cancelled += 1;
            self.events.push(StageEvent::Cancelled { cid: res.cid });
            0
        } else {
            match res.update {
                None => {
                    self.dropped += 1;
                    self.events.push(StageEvent::Dropped { cid: res.cid });
                    0
                }
                Some(up) => {
                    let slot = self.base_slot + index;
                    self.survivors += 1;
                    self.ledger.record(Direction::Up, up.up_bytes);
                    stat_fold(&mut self.stats, slot, up.mean_loss,
                              up.mean_acc);
                    let update = match &up.params {
                        UpdateVector::Dense(v) => AggUpdate::Dense(v),
                        UpdateVector::Encoded(msg) => AggUpdate::Encoded {
                            codec: self.codec,
                            msg,
                            segments: self.segments,
                        },
                    };
                    self.agg.fold(slot, update, up.weight)?;
                    self.events.push(StageEvent::Train { cid: res.cid });
                    self.events.push(StageEvent::Upload {
                        cid: res.cid,
                        bytes: up.up_bytes,
                    });
                    up.up_bytes
                }
            }
        };
        if let Some(plan) = self.plan {
            self.tier_bytes[plan.tier_of(res.cid)] +=
                (res.down_bytes + up_bytes) as u64;
        }
        Ok(())
    }
}
