//! The simulation driver: server state + round loop (paper Fig. 1).
//!
//! [`Simulation::run`] executes the full federated protocol against the
//! AOT artifacts: every byte that would cross the network goes through
//! the configured wire codec in **both** directions (the paper
//! quantizes server→client and client→server messages alike) and is
//! recorded in the [`CommLedger`]; the frozen base `W_initial` is
//! distributed once at round 0 and never re-sent — exactly the FLoCoRA
//! protocol (and, with a `full` variant + fp32 codec, exactly FedAvg).
//!
//! Per-client work is delegated to the configured
//! [`ClientExecutor`](crate::coordinator::executor::ClientExecutor)
//! (serial reference or windowed thread-pool), which **streams** each
//! result into the server's in-place merge
//! ([`RoundSink`](crate::coordinator::sink::RoundSink)) in sampling
//! order: ledger entries, FedAvg adds, dropout counts and network
//! loads fold in as each client's slot drains, so a round's peak
//! memory is O(params + window) and the executors stay bit-identical.
//!
//! With `hetero_ranks` configured, the round runs a
//! [`ClientPlan`](crate::coordinator::hetero::ClientPlan): each client
//! trains at its own rank tier with its tier's codec, and uploads are
//! projected back into the server's rank space before aggregation.

use std::time::Instant;

use crate::compression::{Codec, Message};
use crate::config::FlConfig;
use crate::coordinator::aggregator::FedAvg;
use crate::coordinator::executor::{ClientExecutor, ClientResult,
                                   Downloads, RoundContext};
use crate::coordinator::hetero::{ClientPlan, PlanTier};
use crate::coordinator::sampler::UniformSampler;
use crate::coordinator::sink::RoundSink;
use crate::coordinator::trainer::LocalTrainer;
use crate::data::batcher::Tail;
use crate::data::{lda_partition, BatchIter, Federation, TestSet};
use crate::error::{Error, Result};
use crate::metrics::{Recorder, RoundRecord};
use crate::runtime::{Engine, ModelSession};
use crate::transport::{CommLedger, Direction, NetworkModel, RoundLoad};

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub final_acc: f64,
    pub tail_acc: f64,
    /// Mean client train loss of the last round (NaN if every sampled
    /// client dropped in that round).
    pub final_train_loss: f64,
    pub total_bytes: u64,
    pub mean_up_msg_bytes: f64,
    pub per_client_tcc_bytes: f64,
    pub rounds: usize,
    pub wall_s: f64,
    /// Simulated time-on-wire for the whole run if every round's
    /// clients used the link one after another (sum of round trips).
    pub sim_net_serial_s: f64,
    /// Simulated time-on-wire with each round's clients in flight
    /// concurrently — slowest straggler per round on dedicated links,
    /// total-bits-over-capacity on a shared pipe (see
    /// [`crate::transport::Sharing`]).
    pub sim_net_parallel_s: f64,
}

/// One federated-learning simulation.
///
/// ```no_run
/// use flocora::config::FlConfig;
/// use flocora::coordinator::Simulation;
/// use flocora::coordinator::executor::ExecutorKind;
/// use flocora::metrics::Recorder;
/// use flocora::runtime::Engine;
///
/// # fn main() -> flocora::Result<()> {
/// let engine = Engine::new("artifacts")?; // run `make artifacts` first
/// let cfg = FlConfig {
///     executor: ExecutorKind::Parallel, // bit-identical to Serial
///     threads: 0,                       // 0 = one worker per core
///     window: 0,                        // 0 = 2x workers; any value
///                                       //     is bit-identical too
///     ..FlConfig::default()
/// };
/// let mut sim = Simulation::new(&engine, cfg)?;
/// let mut rec = Recorder::new("quickstart");
/// let summary = sim.run(&mut rec)?;
/// println!(
///     "acc {:.3} after {} rounds, {} bytes moved, wire time {:.1}s \
///      (parallel clients) vs {:.1}s (serial clients)",
///     summary.final_acc, summary.rounds, summary.total_bytes,
///     summary.sim_net_parallel_s, summary.sim_net_serial_s,
/// );
/// # Ok(())
/// # }
/// ```
pub struct Simulation {
    cfg: FlConfig,
    session: ModelSession,
    federation: Federation,
    test: TestSet,
    codec: Box<dyn Codec>,
    executor: Box<dyn ClientExecutor>,
    sampler: UniformSampler,
    /// Link profile behind the simulated round-time report.
    net: NetworkModel,
    /// Rank-tier plan (`hetero_ranks`); `None` = homogeneous.
    plan: Option<ClientPlan>,
    /// Bytes moved per tier (down + up), indexed like the plan's
    /// tiers. Empty for homogeneous runs.
    tier_bytes: Vec<u64>,
    /// Global trainable vector (`Δ̄_t L` for LoRA variants; the whole
    /// model for `full`).
    pub global: Vec<f32>,
    /// Frozen `W_initial` — broadcast once, never updated (paper §III).
    pub frozen: Vec<f32>,
    pub ledger: CommLedger,
    lora_scale: f32,
    rounds_done: usize,
    last_train_loss: f64,
    last_round_dropped: u64,
    sim_net_serial_s: f64,
    sim_net_parallel_s: f64,
    /// Clients that failed mid-round (failure injection diagnostics).
    pub dropped_clients: u64,
}

impl Simulation {
    pub fn new(engine: &Engine, cfg: FlConfig) -> Result<Simulation> {
        cfg.validate()?;
        let session = engine.session(&cfg.tag)?;
        let spec = &session.spec;
        let federation = lda_partition(
            cfg.num_clients,
            cfg.samples_per_client,
            spec.num_classes,
            spec.image_size,
            cfg.lda_alpha,
            cfg.seed,
        );
        let test = TestSet::generate(
            cfg.test_samples,
            spec.image_size,
            spec.num_classes,
            cfg.seed.wrapping_add(0x7E57),
        );
        // W_initial: both sides of the split come from the init artifact
        // with the run seed — every client starts from the same frozen
        // base, like the paper's single initial broadcast.
        let (global, frozen) = session.init(cfg.seed)?;
        let lora_scale = cfg.lora_scale(spec.rank);
        // Rank-tier plan: one compiled session + codec per tier, tags
        // derived from the server tag's (model, variant) coordinates.
        let plan = if cfg.hetero_ranks.is_empty() {
            None
        } else {
            if !spec.variant.is_lora() {
                return Err(Error::invalid(
                    "hetero_ranks needs a LoRA server tag (full models \
                     have no rank dimension)",
                ));
            }
            let mut tiers = Vec::with_capacity(cfg.hetero_ranks.len());
            for (i, &rank) in cfg.hetero_ranks.iter().enumerate() {
                if rank > spec.rank {
                    // Up-projection pads exactly; the reverse would
                    // silently truncate rank slots r_server..r_tier of
                    // every update the client trains. Refuse instead.
                    return Err(Error::invalid(format!(
                        "hetero tier r{rank} exceeds the server rank \
                         r{} — its updates would be truncated every \
                         round",
                        spec.rank
                    )));
                }
                let tag =
                    format!("{}_{}_r{}", spec.model, spec.variant, rank);
                let kind = cfg.hetero_codecs.get(i).copied()
                    .unwrap_or(cfg.codec);
                tiers.push(PlanTier {
                    rank,
                    session: engine.session(&tag)?,
                    codec: kind.build(),
                    lora_scale: cfg.lora_scale(rank),
                });
            }
            Some(ClientPlan::new(tiers))
        };
        let tier_bytes = vec![0u64; plan.as_ref()
            .map_or(0, |p| p.tiers().len())];
        Ok(Simulation {
            sampler: UniformSampler::new(cfg.num_clients, cfg.seed),
            codec: cfg.codec.build(),
            executor: cfg.executor.build(cfg.threads, cfg.window),
            net: cfg.network.build().with_sharing(cfg.net_sharing),
            plan,
            tier_bytes,
            cfg,
            session,
            federation,
            test,
            global,
            frozen,
            ledger: CommLedger::new(),
            lora_scale,
            rounds_done: 0,
            last_train_loss: f64::NAN,
            last_round_dropped: 0,
            sim_net_serial_s: 0.0,
            sim_net_parallel_s: 0.0,
            dropped_clients: 0,
        })
    }

    pub fn config(&self) -> &FlConfig {
        &self.cfg
    }

    pub fn spec_rank(&self) -> usize {
        self.session.spec.rank
    }

    /// The rank-tier plan, if this is a heterogeneous run.
    pub fn plan(&self) -> Option<&ClientPlan> {
        self.plan.as_ref()
    }

    /// Bytes moved per rank tier (down + up), indexed like
    /// [`ClientPlan::tiers`]. Empty for homogeneous runs.
    pub fn tier_bytes(&self) -> &[u64] {
        &self.tier_bytes
    }

    /// Clients dropped in the most recent round.
    pub fn last_round_dropped(&self) -> u64 {
        self.last_round_dropped
    }

    /// Swap the link profile used for the simulated round-time report
    /// (default: from `FlConfig::network` / `net_sharing`). Call before
    /// the first [`Simulation::round`]: the per-run accumulators don't
    /// segment by profile, so switching mid-run mixes times from
    /// different links.
    pub fn set_network(&mut self, net: NetworkModel) {
        self.net = net;
    }

    /// Evaluate the current global model on the held-out test set.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let batches = BatchIter::new(
            &self.test.images,
            &self.test.labels,
            self.session.spec.image_size,
            self.session.spec.batch_size,
            None,
            Tail::PadZero,
        );
        for batch in batches {
            let (l, c) = self.session.eval_step(
                &self.global,
                &self.frozen,
                &batch,
                self.lora_scale,
            )?;
            loss_sum += l;
            correct += c;
        }
        let n = self.test.n as f64;
        Ok((loss_sum / n, correct / n))
    }

    /// Execute one communication round; returns the mean client train
    /// loss/acc for the round (NaN/NaN if every sampled client failed —
    /// the round is lost but the federation survives with its global
    /// state unchanged).
    pub fn round(&mut self) -> Result<(f64, f64)> {
        self.ledger.begin_round();
        let segments = &self.session.spec.trainable_segments;

        // (1) the server encodes this round's download(s): one shared
        //     message, or one per rank tier (projected, tier-encoded).
        let (shared_msg, tier_msgs): (Option<Message>, Vec<Message>) =
            match &self.plan {
                None => (
                    Some(self.codec.encode(&self.global, segments)?),
                    Vec::new(),
                ),
                Some(plan) => {
                    (None, plan.encode_downloads(&self.global, segments)?)
                }
            };
        let downloads = match &shared_msg {
            Some(msg) => Downloads::Homogeneous(msg),
            None => Downloads::Tiered(&tier_msgs),
        };
        let client_ids = self.sampler.sample(self.cfg.clients_per_round);

        // Per-round learning rate under the multiplicative schedule.
        let lr = self.cfg.lr
            * self.cfg.lr_decay.powi(self.rounds_done as i32);

        // (2)+(3)+(4) per-client work streams into the in-place merge:
        // ledger entries, FedAvg adds, dropout counts and network loads
        // fold in as each client's slot drains, in sampling order —
        // byte-for-byte the same whichever executor (or window)
        // produced the results, and never a buffered Vec of updates.
        let mut merge = RoundMerge {
            expected: &client_ids,
            plan: self.plan.as_ref(),
            ledger: &mut self.ledger,
            tier_bytes: &mut self.tier_bytes,
            net: &self.net,
            agg: FedAvg::new(self.global.len()),
            load: RoundLoad::new(),
            loss_sum: 0.0,
            acc_sum: 0.0,
            survivors: 0,
            dropped: 0,
        };
        let ctx = RoundContext {
            session: &self.session,
            codec: self.codec.as_ref(),
            federation: &self.federation,
            frozen: &self.frozen,
            downloads,
            trainer: LocalTrainer {
                local_epochs: self.cfg.local_epochs,
                lr,
                lora_scale: self.lora_scale,
            },
            cfg: &self.cfg,
            round: self.rounds_done,
            plan: self.plan.as_ref(),
        };
        self.executor.execute(&ctx, &client_ids, &mut merge)?;

        let RoundMerge {
            agg, load, loss_sum, acc_sum, survivors, dropped, ..
        } = merge;
        self.sim_net_serial_s += load.serial_s();
        self.sim_net_parallel_s += load.parallel_s(&self.net);
        self.dropped_clients += dropped;
        self.last_round_dropped = dropped;

        self.rounds_done += 1;
        if survivors == 0 {
            // Every sampled client failed: the round is lost but the
            // federation survives — global state is unchanged.
            return Ok((f64::NAN, f64::NAN));
        }
        self.global = agg.finish()?;
        let k = survivors as f64;
        Ok((loss_sum / k, acc_sum / k))
    }

    /// Run the full schedule, recording evaluated rounds.
    pub fn run(&mut self, recorder: &mut Recorder) -> Result<RunSummary> {
        let t0 = Instant::now();
        // Drops are tallied *between* records so the exported column
        // covers every round (and sums to `dropped_clients`) even when
        // `eval_every > 1` skips rounds.
        let mut drops_since_record = 0u64;
        for r in 0..self.cfg.rounds {
            let (train_loss, _train_acc) = self.round()?;
            self.last_train_loss = train_loss;
            drops_since_record += self.last_round_dropped;
            let is_last = r + 1 == self.cfg.rounds;
            if (r + 1) % self.cfg.eval_every == 0 || is_last {
                let (test_loss, test_acc) = self.evaluate()?;
                recorder.push(RoundRecord {
                    round: r + 1,
                    test_acc,
                    test_loss,
                    train_loss,
                    cum_bytes: self.ledger.total_bytes(),
                    dropped: drops_since_record,
                    wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
                drops_since_record = 0;
            }
        }
        Ok(RunSummary {
            final_acc: recorder.final_acc(),
            tail_acc: recorder.tail_acc(3),
            final_train_loss: self.last_train_loss,
            total_bytes: self.ledger.total_bytes(),
            mean_up_msg_bytes: self.ledger.mean_up_msg(),
            per_client_tcc_bytes: self.ledger.per_client_tcc(self.cfg.rounds),
            rounds: self.cfg.rounds,
            wall_s: t0.elapsed().as_secs_f64(),
            sim_net_serial_s: self.sim_net_serial_s,
            sim_net_parallel_s: self.sim_net_parallel_s,
        })
    }
}

/// The server's in-place round merge: one [`RoundSink`] holding the
/// round's accumulators. Every push folds one client straight into the
/// ledger, the FedAvg accumulator and the network-load tally — the
/// decoded update is freed as soon as its `agg.add` returns.
struct RoundMerge<'a> {
    expected: &'a [usize],
    plan: Option<&'a ClientPlan>,
    ledger: &'a mut CommLedger,
    tier_bytes: &'a mut [u64],
    net: &'a NetworkModel,
    agg: FedAvg,
    load: RoundLoad,
    loss_sum: f64,
    acc_sum: f64,
    survivors: usize,
    dropped: u64,
}

impl RoundSink for RoundMerge<'_> {
    fn push(&mut self, index: usize, res: ClientResult) -> Result<()> {
        // The merge relies on positional order == sampling order; an
        // executor violating the contract must fail loud — in release
        // builds too — not silently mis-attribute FedAvg weights. One
        // integer compare per client per round.
        if self.expected.get(index) != Some(&res.cid) {
            return Err(Error::invalid(format!(
                "executor broke the result-order contract: slot {index} \
                 got client {}, expected {:?}",
                res.cid,
                self.expected.get(index),
            )));
        }
        self.ledger.record(Direction::Down, res.down_bytes);
        let up_bytes = match res.update {
            None => {
                self.dropped += 1;
                self.load.add(self.net, res.down_bytes, 0);
                0
            }
            Some(up) => {
                self.survivors += 1;
                self.ledger.record(Direction::Up, up.up_bytes);
                self.loss_sum += up.mean_loss;
                self.acc_sum += up.mean_acc;
                self.agg.add(&up.params, up.weight)?;
                self.load.add(self.net, res.down_bytes, up.up_bytes);
                up.up_bytes
            }
        };
        if let Some(plan) = self.plan {
            self.tier_bytes[plan.tier_of(res.cid)] +=
                (res.down_bytes + up_bytes) as u64;
        }
        Ok(())
    }
}
