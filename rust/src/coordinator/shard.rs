//! Shard plumbing for the hierarchical coordinator: block-aligned
//! cid-partitions, the canonical tree merge, and the shard thread pool.
//!
//! One `RoundSink` on one coordinator thread was the last serial
//! bottleneck (ROADMAP: "Sharded hierarchical coordinator"). The
//! `shards` knob splits a round's sampled clients into N contiguous
//! partitions; each shard folds its clients into its own aggregator,
//! ledger bucket and stage-event log on its own thread (behind the
//! `flocora::sync` shim), and the coordinator merges the shard
//! partials in canonical shard order.
//!
//! **Why the merge is exact.** Sum-of-sums is exact for the integer
//! ledger counters, but f32/f64 addition is *not* associative, so a
//! naive per-shard partial sum would drift bitwise as the shard count
//! changes. The fix is a fixed *fold-block* structure that exists
//! independently of the partition: sampling slots are grouped into
//! blocks of [`SHARD_BLOCK`] slots, every accumulator folds serially
//! *within* a block (in sampling order), and block partials merge
//! pairwise in a canonical tree over the ascending non-empty block
//! list. Shard boundaries are always block-aligned
//! ([`shard_slices`]), so the set of block partials — and therefore
//! the merge tree and every rounding step in it — is identical for
//! any shard count. `shards = 1` vs `shards = N` is byte-identical by
//! construction, and rounds of at most `SHARD_BLOCK` clients occupy a
//! single block, making the whole scheme bit-for-bit the historical
//! serial fold.
//!
//! Factor-aware aggregators (`svt | exact`) ride the same seam by
//! concatenating shard-local factor stacks in shard order — shard
//! partitions are contiguous in sampling order, so the concatenation
//! *is* the global sampling-order stack and the single
//! coordinator-side SVD sees identical input (see
//! `coordinator::aggregator`).
//!
//! NOTE for `lint-determinism`: merge loops in this module iterate
//! `Vec`s in index order only — never hash maps — because the merge
//! order is part of the bit-identity contract. The map-iter lint rule
//! covers this file (it scopes to `coordinator/` + `transport/`).

use std::ops::Range;

use crate::coordinator::window::BoundedWindow;
use crate::error::{Error, Result};
use crate::sync::thread;

/// Sampling slots per fold block. Rounds with at most this many
/// sampled clients fold in a single block — zero merge arithmetic —
/// which keeps every historical preset bit-for-bit identical to the
/// pre-shard serial fold.
pub const SHARD_BLOCK: usize = 64;

/// The fold block a global sampling slot belongs to.
pub fn block_of(slot: usize) -> usize {
    slot / SHARD_BLOCK
}

/// Partition `n_slots` sampling slots into `shards` contiguous,
/// block-aligned ranges (trailing shards may be empty when there are
/// fewer blocks than shards). The union covers `0..n_slots` exactly
/// and every boundary is a multiple of [`SHARD_BLOCK`], so the
/// per-block fold state is independent of the shard count.
pub fn shard_slices(n_slots: usize, shards: usize) -> Vec<Range<usize>> {
    assert!(shards >= 1, "shards must be >= 1");
    let nblocks = (n_slots + SHARD_BLOCK - 1) / SHARD_BLOCK;
    (0..shards)
        .map(|j| {
            let b0 = j * nblocks / shards;
            let b1 = (j + 1) * nblocks / shards;
            (b0 * SHARD_BLOCK).min(n_slots)..(b1 * SHARD_BLOCK).min(n_slots)
        })
        .collect()
}

/// Pairwise tree reduction in canonical order: each round merges
/// adjacent pairs `(0,1), (2,3), …` (an odd tail carries up
/// unmerged) until one item remains. Returns the merged item and the
/// tree depth (number of merge rounds; 0 for zero or one item). The
/// tree shape depends only on the item count, so callers that feed it
/// the ascending non-empty block list get a partition-invariant
/// reduction.
pub fn tree_reduce<T>(
    items: Vec<T>,
    mut merge: impl FnMut(&mut T, T),
) -> (Option<T>, usize) {
    let mut items = items;
    let mut depth = 0;
    while items.len() > 1 {
        depth += 1;
        let mut next = Vec::with_capacity((items.len() + 1) / 2);
        let mut it = items.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                merge(&mut a, b);
            }
            next.push(a);
        }
        items = next;
    }
    (items.pop(), depth)
}

/// Per-block partial of the round's f64 client statistics (train
/// loss/accuracy sums). Same block structure as the aggregator's fold
/// blocks, same canonical tree — so the round means are byte-identical
/// at any shard count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StatBlock {
    pub index: usize,
    pub loss_sum: f64,
    pub acc_sum: f64,
}

/// Fold one surviving client's stats into an ascending block list
/// (slots arrive in sampling order within a shard, so blocks append
/// in ascending index order).
pub fn stat_fold(
    blocks: &mut Vec<StatBlock>,
    slot: usize,
    loss: f64,
    acc: f64,
) {
    let index = block_of(slot);
    match blocks.last_mut() {
        Some(b) if b.index == index => {
            b.loss_sum += loss;
            b.acc_sum += acc;
        }
        _ => {
            debug_assert!(
                blocks.last().map_or(true, |b| b.index < index),
                "stat blocks must fold in ascending slot order"
            );
            blocks.push(StatBlock { index, loss_sum: loss, acc_sum: acc });
        }
    }
}

/// Tree-merge concatenated per-shard stat blocks (already in ascending
/// global block order) into the round's `(loss_sum, acc_sum)`.
pub fn stat_merge(blocks: Vec<StatBlock>) -> (f64, f64) {
    let (merged, _depth) = tree_reduce(blocks, |a, b| {
        a.loss_sum += b.loss_sum;
        a.acc_sum += b.acc_sum;
    });
    merged.map_or((0.0, 0.0), |b| (b.loss_sum, b.acc_sum))
}

/// Run `work(j)` for every shard `j in 0..shards` and return the
/// results in shard order. With more than one shard and more than one
/// worker, shards fan out across scoped threads behind the
/// `flocora::sync` shim using the same claim/deposit/drain handshake
/// as the parallel executor ([`BoundedWindow`] with `window = shards`:
/// every shard may be in flight at once); the calling thread drains
/// partials in canonical shard order. Worker count never affects the
/// returned values — each shard's work is independent and results are
/// keyed by shard index — so `shards = N` is bit-identical whether it
/// ran inline or threaded (the loom suite model-checks the handshake).
pub fn run_partitioned<T: Send>(
    shards: usize,
    workers: usize,
    work: impl Fn(usize) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    assert!(shards >= 1, "shards must be >= 1");
    if shards == 1 || workers <= 1 {
        let mut out = Vec::with_capacity(shards);
        for j in 0..shards {
            out.push(work(j)?);
        }
        return Ok(out);
    }
    let workers = workers.min(shards);
    let win: BoundedWindow<Result<T>> = BoundedWindow::new(shards, shards);
    thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // A panicking shard (a bug — shard work returns
                // `Result`) must abort the window so the drain side
                // can stop waiting and the scope join re-raises.
                let _sentry = win.sentry();
                while let Some(j) = win.claim() {
                    let res = work(j);
                    if !win.deposit(j, res) {
                        return;
                    }
                }
            });
        }
        // Drain partials in canonical shard order on the coordinator
        // thread — the merge order is part of the bit-identity
        // contract.
        let _sentry = win.sentry();
        let mut out = Vec::with_capacity(shards);
        for j in 0..shards {
            let res = win.drain(j).unwrap_or_else(|_| {
                Err(Error::invalid("round aborted: a shard worker failed"))
            });
            match res {
                Ok(t) => out.push(t),
                Err(e) => {
                    win.abort();
                    return Err(e);
                }
            }
        }
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slices_cover_and_align() {
        for &(n, shards) in &[
            (0usize, 1usize),
            (0, 3),
            (8, 1),
            (8, 3),
            (64, 2),
            (65, 2),
            (100, 3),
            (1000, 7),
            (10_000, 8),
        ] {
            let slices = shard_slices(n, shards);
            assert_eq!(slices.len(), shards);
            let mut cursor = 0;
            for r in &slices {
                assert_eq!(r.start, cursor, "contiguous ({n}, {shards})");
                assert!(r.start % SHARD_BLOCK == 0 || r.start == n);
                cursor = r.end;
            }
            assert_eq!(cursor, n, "union covers 0..n ({n}, {shards})");
            // Every interior boundary is block-aligned.
            for r in &slices {
                if r.end != n {
                    assert_eq!(r.end % SHARD_BLOCK, 0);
                }
            }
        }
    }

    #[test]
    fn slices_are_partition_invariant_on_blocks() {
        // The multiset of blocks each slot maps to never depends on
        // the shard count: concatenating shard-local block lists in
        // shard order reproduces the global ascending block list.
        let n = 333;
        let global: Vec<usize> = (0..n).map(block_of).collect();
        for shards in [1, 2, 3, 7] {
            let mut concat = Vec::new();
            for r in shard_slices(n, shards) {
                concat.extend(r.map(block_of));
            }
            assert_eq!(concat, global, "shards = {shards}");
        }
    }

    #[test]
    fn tree_reduce_shape_and_depth() {
        let (one, d) = tree_reduce(vec![5i64], |a, b| *a += b);
        assert_eq!((one, d), (Some(5), 0));
        let (none, d) = tree_reduce(Vec::<i64>::new(), |a, b| *a += b);
        assert_eq!((none, d), (None, 0));
        // Merge order is observable through a non-commutative op.
        let items: Vec<Vec<u32>> = (0..5).map(|i| vec![i]).collect();
        let (merged, depth) = tree_reduce(items, |a, b| a.extend(b));
        // Rounds: [01, 23, 4] -> [0123, 4] -> [01234]: depth 3.
        assert_eq!(merged.unwrap(), vec![0, 1, 2, 3, 4]);
        assert_eq!(depth, 3);
        let (_, d8) = tree_reduce(vec![0u8; 8], |_a, _b| {});
        assert_eq!(d8, 3);
    }

    #[test]
    fn stat_blocks_match_any_partition() {
        // Folding stats per shard and tree-merging the concatenation
        // gives the same bits for every shard count.
        let n = 200;
        let stats: Vec<(f64, f64)> = (0..n)
            .map(|i| {
                let x = (i as f64).sin();
                (x * 0.1, x.abs())
            })
            .collect();
        let reference = {
            let mut blocks = Vec::new();
            for (slot, &(l, a)) in stats.iter().enumerate() {
                stat_fold(&mut blocks, slot, l, a);
            }
            stat_merge(blocks)
        };
        for shards in [1, 2, 3, 7] {
            let mut concat = Vec::new();
            for r in shard_slices(n, shards) {
                let mut local = Vec::new();
                for slot in r {
                    let (l, a) = stats[slot];
                    stat_fold(&mut local, slot, l, a);
                }
                concat.extend(local);
            }
            let merged = stat_merge(concat);
            assert_eq!(merged.0.to_bits(), reference.0.to_bits());
            assert_eq!(merged.1.to_bits(), reference.1.to_bits());
        }
    }

    #[test]
    fn run_partitioned_orders_and_propagates_errors() {
        for workers in [1, 2, 4] {
            let got =
                run_partitioned(5, workers, |j| Ok(j * 10)).unwrap();
            assert_eq!(got, vec![0, 10, 20, 30, 40]);
        }
        let err = run_partitioned::<usize>(3, 2, |j| {
            if j == 1 {
                Err(Error::invalid("shard 1 failed"))
            } else {
                Ok(j)
            }
        });
        assert!(err.is_err());
    }
}
