//! The round engine's concurrency protocols, extracted as standalone
//! objects so the loom suite model-checks the *real* code.
//!
//! Two protocols live here, payload-generic so `tests/loom.rs` can
//! drive them with cheap values while the executors drive them with
//! full client results:
//!
//! * [`BoundedWindow`] — the parallel executor's claim/deposit/drain
//!   window: workers claim strictly increasing indices but never run
//!   further ahead of the in-order drain than `window` slots, deposit
//!   results out of order into a ring, and a single drainer takes them
//!   back out in order. Two condvars: `may_claim` (workers wait for a
//!   slot to free) and `may_drain` (the drainer waits for the oldest
//!   slot to fill).
//! * [`StageRing`] — the pipelined executor's in/compute/out ring: the
//!   same claim gate and in-order drain, but slots carry a caller-owned
//!   stage enum and intermediate stages hand work to each other by
//!   predicate ([`StageRing::take_matching`]). One condvar, broadcast
//!   on every transition; waiters re-check their own predicate.
//!
//! Model-checked invariants (exhaustive within the preemption bound,
//! windows 1–3 — see `tests/loom.rs`): no lost wakeups (every schedule
//! terminates), at most `window` results buffered at once, and the
//! panic sentry ([`BoundedWindow::sentry`] / [`StageRing::sentry`])
//! unblocks every waiter when any participant unwinds.
//!
//! The executors add nothing on top but the client work itself, so
//! what the checker proves here is what production runs.

use crate::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Error returned by the drain side when the round was aborted — a
/// participant panicked (sentry) or the caller called `abort` (sink
/// error). The executor maps it to its own error type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Aborted;

/// Lock even when poisoned: the abort path must always get through —
/// it runs while a sibling thread is unwinding, possibly having
/// poisoned the state mutex on its way down, and skipping the abort
/// flag then would leave waiters parked forever.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct WindowState<T> {
    /// Ring buffer; index `i`'s slot is `i % window`. `Some` =
    /// deposited but not yet drained.
    slots: Vec<Option<T>>,
    /// Next index a producer may claim.
    next: usize,
    /// Results handed out in order so far (== next index to drain).
    drained: usize,
    /// Set on error/panic: producers wind down without claiming.
    abort: bool,
    /// Deposited-but-undrained count and its high-water mark — the
    /// O(window) memory claim, tracked under the same mutex as the
    /// protocol so the model checker sees it too.
    buffered: usize,
    peak_buffered: usize,
}

/// Bounded out-of-order production window with in-order drain — the
/// [`ParallelExecutor`](super::executor::ParallelExecutor) protocol.
///
/// Roles: any number of producers loop `claim` → work → `deposit`;
/// exactly one drainer calls `drain(0..n)` in order. Either side may
/// `abort`; a [`sentry`](BoundedWindow::sentry) guard does so
/// automatically on panic.
pub struct BoundedWindow<T> {
    state: Mutex<WindowState<T>>,
    /// Producers wait here when the window is full; the drainer
    /// notifies after freeing a slot.
    may_claim: Condvar,
    /// The drainer waits here for the oldest slot to fill; producers
    /// notify after depositing.
    may_drain: Condvar,
    n: usize,
    window: usize,
}

impl<T> BoundedWindow<T> {
    /// A window over indices `0..n` with `window` in-flight slots.
    pub fn new(n: usize, window: usize) -> BoundedWindow<T> {
        assert!(window >= 1, "window must hold at least one slot");
        BoundedWindow {
            state: Mutex::new(WindowState {
                slots: (0..window).map(|_| None).collect(),
                next: 0,
                drained: 0,
                abort: false,
                buffered: 0,
                peak_buffered: 0,
            }),
            may_claim: Condvar::new(),
            may_drain: Condvar::new(),
            n,
            window,
        }
    }

    /// Claim the next index, blocking while the window is full.
    /// `None` = wind down (all indices claimed, or the round aborted).
    pub fn claim(&self) -> Option<usize> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.abort || st.next >= self.n {
                return None;
            }
            if st.next < st.drained + self.window {
                st.next += 1;
                return Some(st.next - 1);
            }
            st = self.may_claim.wait(st).unwrap();
        }
    }

    /// Deposit index `i`'s result. `false` = the round aborted while
    /// the producer was working; the value is dropped and the producer
    /// should wind down.
    pub fn deposit(&self, i: usize, value: T) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.abort {
            return false;
        }
        let slot = i % self.window;
        debug_assert!(st.slots[slot].is_none(), "slot {i} deposited twice");
        st.slots[slot] = Some(value);
        st.buffered += 1;
        st.peak_buffered = st.peak_buffered.max(st.buffered);
        drop(st);
        self.may_drain.notify_one();
        true
    }

    /// Take index `i`'s result, in order, blocking until a producer
    /// deposits it. Frees the slot (and wakes blocked producers) on
    /// the way out. `Err(Aborted)` = a producer died without
    /// delivering.
    pub fn drain(&self, i: usize) -> Result<T, Aborted> {
        let out = {
            let mut st = self.state.lock().unwrap();
            loop {
                if let Some(v) = st.slots[i % self.window].take() {
                    st.drained += 1;
                    st.buffered -= 1;
                    break Ok(v);
                }
                if st.abort {
                    break Err(Aborted);
                }
                st = self.may_drain.wait(st).unwrap();
            }
        };
        // A slot may just have freed: more indices claimable.
        self.may_claim.notify_all();
        out
    }

    /// Flag the round as aborted and wake every waiter, poisoned or
    /// not. Idempotent; callable from any thread, including mid-panic.
    pub fn abort(&self) {
        lock_unpoisoned(&self.state).abort = true;
        self.may_claim.notify_all();
        self.may_drain.notify_all();
    }

    /// Guard that [`abort`](BoundedWindow::abort)s if dropped during a
    /// panic — without it, a producer unwinding inside its work item
    /// (a bug: work returns `Result`) would leave its slot forever
    /// empty and the drainer parked, and the scope join would deadlock
    /// instead of propagating the panic. Every participant holds one.
    pub fn sentry(&self) -> WindowSentry<'_, T> {
        WindowSentry { window: self }
    }

    /// High-water mark of simultaneously buffered (deposited,
    /// undrained) results so far.
    pub fn peak_buffered(&self) -> usize {
        lock_unpoisoned(&self.state).peak_buffered
    }
}

/// See [`BoundedWindow::sentry`].
pub struct WindowSentry<'w, T> {
    window: &'w BoundedWindow<T>,
}

impl<T> Drop for WindowSentry<'_, T> {
    fn drop(&mut self) {
        if crate::sync::thread::panicking() {
            self.window.abort();
        }
    }
}

struct RingState<S> {
    slots: Vec<S>,
    next: usize,
    drained: usize,
    abort: bool,
    buffered: usize,
    peak_buffered: usize,
}

/// Staged pipeline ring — the
/// [`PipelinedExecutor`](super::executor::PipelinedExecutor) protocol.
///
/// Same claim gate and in-order drain as [`BoundedWindow`], but each
/// slot is a caller-owned stage enum (`S`): the claiming stage fills a
/// slot with [`put`](StageRing::put), intermediate stages steal work
/// matching their predicate with [`take_matching`](StageRing::take_matching)
/// and put the advanced state back, and the drainer extracts terminal
/// slots in index order. One condvar: every transition broadcasts,
/// every waiter re-checks its own predicate (rounds are tens of
/// clients, so spurious-wakeup cost is noise next to a train step).
pub struct StageRing<S> {
    state: Mutex<RingState<S>>,
    cv: Condvar,
    n: usize,
    window: usize,
}

impl<S: Default> StageRing<S> {
    /// A ring over indices `0..n` with `window` slots, each starting
    /// at `S::default()` (the empty stage).
    pub fn new(n: usize, window: usize) -> StageRing<S> {
        assert!(window >= 1, "window must hold at least one slot");
        StageRing {
            state: Mutex::new(RingState {
                slots: (0..window).map(|_| S::default()).collect(),
                next: 0,
                drained: 0,
                abort: false,
                buffered: 0,
                peak_buffered: 0,
            }),
            cv: Condvar::new(),
            n,
            window,
        }
    }
}

impl<S> StageRing<S> {
    /// Claim the next index (the pipeline's entry stage), blocking
    /// while the window is full. `None` = wind down.
    pub fn claim(&self) -> Option<usize> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.abort || st.next >= self.n {
                return None;
            }
            if st.next < st.drained + self.window {
                st.next += 1;
                return Some(st.next - 1);
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Store index `i`'s advanced stage. `done` marks a terminal slot
    /// (counts toward the buffered high-water mark the memory claim is
    /// about). `false` = round aborted; wind down.
    pub fn put(&self, i: usize, slot: S, done: bool) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.abort {
            return false;
        }
        if done {
            st.buffered += 1;
            st.peak_buffered = st.peak_buffered.max(st.buffered);
        }
        let idx = i % self.window;
        st.slots[idx] = slot;
        drop(st);
        self.cv.notify_all();
        true
    }

    /// Steal the lowest in-flight slot the extractor accepts, blocking
    /// until one appears. The extractor, called under the lock, should
    /// swap a claim marker into the slot and return the stage payload
    /// (`None` = not my stage, keep scanning). Returns the index with
    /// the payload; `None` = wind down (abort, or every index already
    /// drained).
    pub fn take_matching<R>(
        &self,
        mut extract: impl FnMut(&mut S) -> Option<R>,
    ) -> Option<(usize, R)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.abort || st.drained >= self.n {
                return None;
            }
            let mut found = None;
            for j in st.drained..st.next {
                let idx = j % self.window;
                if let Some(r) = extract(&mut st.slots[idx]) {
                    found = Some((j, r));
                    break;
                }
            }
            if found.is_some() {
                return found;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Take index `i`'s terminal payload, in order, blocking until the
    /// extractor accepts the slot (which it should reset to empty).
    /// `Err(Aborted)` = a stage died without delivering.
    pub fn drain<R>(
        &self,
        i: usize,
        mut extract: impl FnMut(&mut S) -> Option<R>,
    ) -> Result<R, Aborted> {
        let out = {
            let mut st = self.state.lock().unwrap();
            loop {
                let idx = i % self.window;
                if let Some(r) = extract(&mut st.slots[idx]) {
                    st.drained += 1;
                    st.buffered -= 1;
                    break Ok(r);
                }
                if st.abort {
                    break Err(Aborted);
                }
                st = self.cv.wait(st).unwrap();
            }
        };
        // A slot just freed (or the round ended): wake claims.
        self.cv.notify_all();
        out
    }

    /// Flag the round as aborted and wake every waiter, poisoned or
    /// not. Idempotent; callable from any thread, including mid-panic.
    pub fn abort(&self) {
        lock_unpoisoned(&self.state).abort = true;
        self.cv.notify_all();
    }

    /// Panic guard — same role as [`BoundedWindow::sentry`].
    pub fn sentry(&self) -> RingSentry<'_, S> {
        RingSentry { ring: self }
    }

    /// High-water mark of simultaneously buffered terminal results.
    pub fn peak_buffered(&self) -> usize {
        lock_unpoisoned(&self.state).peak_buffered
    }
}

/// See [`StageRing::sentry`].
pub struct RingSentry<'r, S> {
    ring: &'r StageRing<S>,
}

impl<S> Drop for RingSentry<'_, S> {
    fn drop(&mut self) {
        if crate::sync::thread::panicking() {
            self.ring.abort();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_serial_roundtrip() {
        let w: BoundedWindow<usize> = BoundedWindow::new(5, 2);
        // Single-threaded drive: claim/deposit/drain in lockstep.
        for i in 0..5 {
            assert_eq!(w.claim(), Some(i));
            assert!(w.deposit(i, 10 * i));
            assert_eq!(w.drain(i), Ok(10 * i));
        }
        assert_eq!(w.claim(), None, "all indices claimed");
        assert_eq!(w.peak_buffered(), 1);
    }

    #[test]
    fn window_claim_gate_is_the_window() {
        let w: BoundedWindow<()> = BoundedWindow::new(10, 3);
        assert_eq!(w.claim(), Some(0));
        assert_eq!(w.claim(), Some(1));
        assert_eq!(w.claim(), Some(2));
        // Fourth claim would block (drained=0, window=3) — drain one
        // first. Deposit out of order to exercise the ring.
        for i in [2, 0, 1] {
            assert!(w.deposit(i, ()));
        }
        assert_eq!(w.peak_buffered(), 3);
        assert_eq!(w.drain(0), Ok(()));
        assert_eq!(w.claim(), Some(3));
    }

    #[test]
    fn window_abort_unblocks_everything() {
        let w: BoundedWindow<u8> = BoundedWindow::new(4, 2);
        assert_eq!(w.claim(), Some(0));
        w.abort();
        assert_eq!(w.claim(), None);
        assert!(!w.deposit(0, 7), "deposit after abort is rejected");
        assert_eq!(w.drain(0), Err(Aborted));
    }

    #[derive(Default, PartialEq, Debug)]
    enum Slot {
        #[default]
        Empty,
        Fetched(u32),
        Done(u32),
    }

    #[test]
    fn ring_stages_hand_off_by_predicate() {
        let r: StageRing<Slot> = StageRing::new(3, 2);
        assert_eq!(r.claim(), Some(0));
        assert!(r.put(0, Slot::Fetched(5), false));
        let (i, v) = r
            .take_matching(|s| match s {
                Slot::Fetched(v) => {
                    let v = *v;
                    *s = Slot::Empty;
                    Some(v)
                }
                _ => None,
            })
            .unwrap();
        assert_eq!((i, v), (0, 5));
        assert!(r.put(0, Slot::Done(v * 2), true));
        let got = r.drain(0, |s| match std::mem::take(s) {
            Slot::Done(v) => Some(v),
            other => {
                *s = other;
                None
            }
        });
        assert_eq!(got, Ok(10));
        assert_eq!(r.peak_buffered(), 1);
    }

    #[test]
    fn ring_abort_unblocks_everything() {
        let r: StageRing<Slot> = StageRing::new(3, 2);
        assert_eq!(r.claim(), Some(0));
        r.abort();
        assert_eq!(r.claim(), None);
        assert!(!r.put(0, Slot::Done(1), true));
        assert!(r.take_matching(|_| Some(())).is_none());
        let got: Result<u32, Aborted> = r.drain(0, |s| match s {
            Slot::Done(v) => Some(*v),
            _ => None,
        });
        assert_eq!(got, Err(Aborted));
    }
}
