//! FedAvg aggregation over opaque flat vectors.
//!
//! `w_{t+1} = Σ_k (n_k / n) w_k` (paper Eq. 1's minimizer step). The
//! accumulator is f64-free by design — the paper's method aggregates in
//! the same precision the messages arrive in (f32), and the weighted
//! accumulation is the per-round O(K·P) hot loop (DESIGN.md §7).

use crate::error::{Error, Result};
use crate::tensor;

/// Streaming weighted-average accumulator.
pub struct FedAvg {
    acc: Vec<f32>,
    total_weight: f64,
}

impl FedAvg {
    pub fn new(dim: usize) -> FedAvg {
        FedAvg { acc: vec![0.0; dim], total_weight: 0.0 }
    }

    /// Add one client's vector with sample-count weight `n_k`.
    pub fn add(&mut self, v: &[f32], weight: f64) -> Result<()> {
        if v.len() != self.acc.len() {
            return Err(Error::invalid(format!(
                "aggregator dim {} vs contribution {}",
                self.acc.len(),
                v.len()
            )));
        }
        if !(weight > 0.0) {
            return Err(Error::invalid(format!("bad weight {weight}")));
        }
        tensor::axpy_weighted(&mut self.acc, v, weight as f32);
        self.total_weight += weight;
        Ok(())
    }

    pub fn contributions(&self) -> f64 {
        self.total_weight
    }

    /// Finish: divide by total weight.
    pub fn finish(mut self) -> Result<Vec<f32>> {
        if self.total_weight <= 0.0 {
            return Err(Error::invalid("aggregating zero contributions"));
        }
        tensor::scale(&mut self.acc, (1.0 / self.total_weight) as f32);
        Ok(self.acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_mean() {
        let mut agg = FedAvg::new(2);
        agg.add(&[1.0, 0.0], 1.0).unwrap();
        agg.add(&[4.0, 3.0], 3.0).unwrap();
        let out = agg.finish().unwrap();
        assert_eq!(out, vec![3.25, 2.25]);
    }

    #[test]
    fn identity_on_identical_inputs() {
        let v = vec![0.5f32, -1.5, 2.0];
        let mut agg = FedAvg::new(3);
        for w in [1.0, 2.0, 5.0] {
            agg.add(&v, w).unwrap();
        }
        let out = agg.finish().unwrap();
        for (a, b) in out.iter().zip(&v) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rejects_errors() {
        let mut agg = FedAvg::new(2);
        assert!(agg.add(&[1.0], 1.0).is_err());
        assert!(agg.add(&[1.0, 2.0], 0.0).is_err());
        assert!(FedAvg::new(2).finish().is_err());
    }
}
