//! The aggregation zoo: FedAvg plus factor-aware LoRA aggregators.
//!
//! `w_{t+1} = Σ_k (n_k / n) w_k` (paper Eq. 1's minimizer step) is the
//! baseline [`FedAvg`]; the weighted accumulation is the per-round
//! O(K·P) hot loop (DESIGN.md §7).
//!
//! **One upload entry point.** Every aggregator consumes uploads
//! through [`Aggregator::fold`] taking a [`ClientUpdate`] — dense or
//! still-encoded — so call sites (the server's shard merge, the
//! executors) never choose a decode path themselves. Dense-mean
//! aggregation keeps the zero-copy
//! [`Codec::decode_into`](crate::compression::Codec::decode_into)
//! fast path internally; factor-aware modes materialize the vector
//! (they slice adapter factors out of it).
//!
//! **Shard-ready folding.** Each fold carries its client's global
//! *sampling slot*; the accumulator groups slots into fixed blocks of
//! [`SHARD_BLOCK`](crate::coordinator::shard::SHARD_BLOCK) and keeps
//! one serial (sampling-order) partial sum per block. Finishing
//! merges block partials pairwise in the canonical tree
//! ([`tree_reduce`](crate::coordinator::shard::tree_reduce)) over the
//! ascending non-empty block list. Because shard partitions are
//! block-aligned, concatenating shard-local partials in shard order
//! reproduces exactly the block list a single aggregator would hold —
//! so [`AggregatorKind::finish_partials`] is byte-identical at any
//! shard count, and rounds of ≤ `SHARD_BLOCK` clients (every
//! historical preset) are bit-for-bit the pre-shard serial fold.
//! (The slot parameter is why `fold` takes three arguments where the
//! obvious API takes two: dropped clients never fold, so the block a
//! contribution lands in cannot be recovered from the fold count.)
//!
//! Averaging LoRA factors independently is *biased*: the mean of the
//! products `Σ w_k L_k R_k / W` is not the product of the means
//! `L̄ · R̄`. Two factor-aware modes correct for that behind the one
//! [`Aggregator`] seam (the `aggregator = fedavg|svt|exact` knob):
//!
//! * [`SvtAggregator`] — FLoRIST-style server-side singular-value
//!   thresholding: stack every client's scaled factors per adapter
//!   pair, refactor the exact weighted-mean product through a thin QR +
//!   core-SVD, and keep the smallest head of singular directions whose
//!   energy (Σσ²) reaches the `svt_energy` threshold. Reports the
//!   per-round effective rank. `svt_energy >= 1.0` skips the refactor
//!   entirely and is bit-for-bit FedAvg.
//! * [`ExactAggregator`] — the same stacked refactor with no energy
//!   cut: the broadcast factors reproduce the true mean product up to
//!   the server rank budget (the optimal rank-r correction of the
//!   A·B averaging bias). A single-contributor round is bit-for-bit
//!   FedAvg — the mean of one product *is* the product of one mean.
//!
//! Both stack factors in sampling order per shard; shard stacks
//! concatenate in shard order (= global sampling order, partitions
//! are contiguous) before the *single* coordinator-side SVD, so the
//! refactor is independent of the shard count, the executor, and the
//! window size. Non-adapter segments (norms, fc head) always take the
//! plain FedAvg path.

use crate::compression::{Codec, Message};
use crate::coordinator::hetero::rank_geometry;
use crate::coordinator::shard::{block_of, tree_reduce};
use crate::error::{Error, Result};
use crate::model::Segment;
use crate::tensor;

/// One client's upload, as handed to [`Aggregator::fold`]: either the
/// dense server-space vector or the still-encoded wire message plus
/// what's needed to decode it. The aggregator picks the decode
/// strategy (zero-copy fold vs materialize), not the call site.
pub enum ClientUpdate<'a> {
    /// Decoded dense vector in the server's rank space.
    Dense(&'a [f32]),
    /// Still-encoded upload; dense-mean aggregators fold it zero-copy
    /// via [`Codec::decode_into`], factor-aware ones materialize.
    Encoded {
        codec: &'a dyn Codec,
        msg: &'a Message,
        segments: &'a [Segment],
    },
}

/// One fold block's accumulator: the serial weighted partial sum of
/// the contributions whose sampling slots fall in block `index`.
struct FoldBlock {
    index: usize,
    acc: Vec<f32>,
    weight: f64,
}

/// Streaming weighted-average accumulator, block-structured for the
/// sharded coordinator (see the module docs): one serial f32 partial
/// per fold block, merged pairwise in canonical block order at
/// finish. A single-block accumulator (≤ 64 sequential slots) is
/// bit-for-bit the historical flat fold.
pub struct FedAvg {
    dim: usize,
    /// Non-empty block partials, ascending by block index.
    blocks: Vec<FoldBlock>,
    /// Next sampling slot for the sequential [`FedAvg::add`] path.
    next_slot: usize,
}

impl FedAvg {
    pub fn new(dim: usize) -> FedAvg {
        FedAvg { dim, blocks: Vec::new(), next_slot: 0 }
    }

    fn check_weight(weight: f64) -> Result<()> {
        if !(weight > 0.0) {
            return Err(Error::invalid(format!("bad weight {weight}")));
        }
        Ok(())
    }

    /// The accumulator for `slot`'s block, created zeroed on first
    /// touch. Slots arrive in sampling order within a shard, so in
    /// practice this appends; the binary search keeps the list
    /// correct (and ascending) for arbitrary fold orders too.
    fn block_mut(&mut self, slot: usize) -> &mut FoldBlock {
        let index = block_of(slot);
        let pos = match self
            .blocks
            .binary_search_by(|b| b.index.cmp(&index))
        {
            Ok(pos) => pos,
            Err(pos) => {
                self.blocks.insert(
                    pos,
                    FoldBlock {
                        index,
                        acc: vec![0.0; self.dim],
                        weight: 0.0,
                    },
                );
                pos
            }
        };
        &mut self.blocks[pos]
    }

    /// Fold one client's dense vector at its global sampling slot
    /// with sample-count weight `n_k`.
    pub fn fold_dense(
        &mut self,
        slot: usize,
        v: &[f32],
        weight: f64,
    ) -> Result<()> {
        if v.len() != self.dim {
            return Err(Error::invalid(format!(
                "aggregator dim {} vs contribution {}",
                self.dim,
                v.len()
            )));
        }
        Self::check_weight(weight)?;
        let block = self.block_mut(slot);
        tensor::axpy_weighted(&mut block.acc, v, weight as f32);
        block.weight += weight;
        Ok(())
    }

    /// Zero-copy fold of a still-encoded upload: the codec's
    /// [`Codec::decode_into`] streams `weight * decoded` straight into
    /// the slot's block accumulator. Same validations, same
    /// arithmetic, no intermediate vector.
    pub fn fold_encoded(
        &mut self,
        slot: usize,
        codec: &dyn Codec,
        msg: &Message,
        segments: &[Segment],
        weight: f64,
    ) -> Result<()> {
        Self::check_weight(weight)?;
        let block = self.block_mut(slot);
        codec.decode_into(msg, segments, &mut block.acc, weight as f32)?;
        block.weight += weight;
        Ok(())
    }

    /// Sequential convenience fold: slots assigned 0, 1, 2, … in call
    /// order (benches, property tests, reference loops). Identical to
    /// the historical flat accumulator for up to `SHARD_BLOCK` adds.
    pub fn add(&mut self, v: &[f32], weight: f64) -> Result<()> {
        let slot = self.next_slot;
        self.fold_dense(slot, v, weight)?;
        self.next_slot = slot + 1;
        Ok(())
    }

    /// Total weight contributed so far: per-block serial weight sums,
    /// tree-merged in canonical block order (the same reduction
    /// [`FedAvg::finish`] divides by).
    pub fn contributions(&self) -> f64 {
        let weights: Vec<f64> =
            self.blocks.iter().map(|b| b.weight).collect();
        let (total, _depth) = tree_reduce(weights, |a, b| *a += b);
        total.unwrap_or(0.0)
    }

    /// Consume the block partials into `(Σ w_k v_k, Σ w_k, depth)`
    /// via the canonical pairwise tree; `depth` is the merge-tree
    /// depth (0 for a single block).
    fn merge_blocks(self) -> Result<(Vec<f32>, f64, usize)> {
        let (merged, depth) = tree_reduce(self.blocks, |a, b| {
            tensor::axpy_weighted(&mut a.acc, &b.acc, 1.0);
            a.weight += b.weight;
        });
        match merged {
            None => Err(Error::invalid("aggregating zero contributions")),
            Some(b) => Ok((b.acc, b.weight, depth)),
        }
    }

    /// Finish: tree-merge the block partials, divide by total weight.
    pub fn finish(self) -> Result<Vec<f32>> {
        let (mut acc, total_weight, _depth) = self.merge_blocks()?;
        if total_weight <= 0.0 {
            return Err(Error::invalid("aggregating zero contributions"));
        }
        tensor::scale(&mut acc, (1.0 / total_weight) as f32);
        Ok(acc)
    }
}

/// What one round of aggregation produced.
pub struct AggOutcome {
    /// The new global trainable vector.
    pub global: Vec<f32>,
    /// Mean effective adapter rank the server broadcasts this round
    /// (mean over adapter pairs of the rank slots actually carrying
    /// signal; the static server rank for FedAvg, 0.0 for layouts with
    /// no adapter pairs).
    pub eff_rank: f64,
}

/// One shard's aggregation partial, extracted by
/// [`Aggregator::into_partial`] and merged on the coordinator thread
/// by [`AggregatorKind::finish_partials`]. Opaque: the block partials
/// and factor stacks inside are meaningful only to the kind that
/// produced them.
pub struct AggPartial {
    blocks: Vec<FoldBlock>,
    /// Per-pair factor stacks in shard-local sampling order (empty
    /// for plain FedAvg and the svt τ ≥ 1.0 passthrough).
    stacks: Vec<PairStack>,
    /// Contributors this shard folded (factor-aware modes only; the
    /// global single-contributor passthrough needs the sum).
    clients: usize,
}

/// One round's server-side merge strategy, behind a common seam so the
/// round engine can swap FedAvg for factor-aware modes
/// (`aggregator = fedavg|svt|exact`).
pub trait Aggregator: Send {
    /// Fold one client's upload — dense or still-encoded — at its
    /// global sampling `slot`, with sample-count weight `n_k`. The
    /// implementation picks the decode strategy: dense-mean modes
    /// fold encoded uploads zero-copy via
    /// [`Codec::decode_into`](crate::compression::Codec::decode_into)
    /// (bit-identical to decode-then-fold — same per-element ops,
    /// same order — the decoded vector just never exists);
    /// factor-aware modes materialize, because they slice adapter
    /// factors out of the dense vector.
    fn fold(
        &mut self,
        slot: usize,
        update: ClientUpdate<'_>,
        weight: f64,
    ) -> Result<()>;
    /// Total weight contributed so far.
    fn contributions(&self) -> f64;
    /// Consume the accumulator and produce the new global vector plus
    /// the round's effective-rank report. Equivalent to extracting
    /// this aggregator's single partial and finishing it — kept for
    /// unsharded callers (tests, benches).
    fn finish(self: Box<Self>) -> Result<AggOutcome>;
    /// Extract this shard's partial for the coordinator-side merge
    /// ([`AggregatorKind::finish_partials`]).
    fn into_partial(self: Box<Self>) -> AggPartial;
}

/// One LoRA adapter pair located inside the flat trainable vector:
/// `ΔW = L · R` with `L` the rank-minor factor (`outer × rank`,
/// row-major at `left_offset`) and `R` the rank-major factor
/// (`rank × inner`, row-major at `right_offset`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdapterPair {
    pub left_offset: usize,
    pub outer: usize,
    pub right_offset: usize,
    pub inner: usize,
    pub rank: usize,
}

/// Locate every adapter factor pair in a trainable layout: two
/// consecutive segments that are both adapters at the same rank, one
/// rank-minor (the left factor) and one rank-major (the right). Matches
/// both orderings the spec emits (`lora_b` then `lora_a` for convs,
/// `fc.lora_b` then `fc.lora_a` for the head).
pub fn adapter_pairs(segments: &[Segment]) -> Vec<AdapterPair> {
    let mut pairs = Vec::new();
    let mut i = 0;
    while i + 1 < segments.len() {
        let (a, b) = (&segments[i], &segments[i + 1]);
        if let (Some((ra, da, lead_a)), Some((rb, db, lead_b))) =
            (rank_geometry(a), rank_geometry(b))
        {
            if ra == rb && ra > 0 && lead_a != lead_b {
                let (left, outer, right, inner) = if lead_a {
                    // a is rank-major (right factor), b is the left.
                    (b, db, a, da)
                } else {
                    (a, da, b, db)
                };
                pairs.push(AdapterPair {
                    left_offset: left.offset,
                    outer,
                    right_offset: right.offset,
                    inner,
                    rank: ra,
                });
                i += 2;
                continue;
            }
        }
        i += 1;
    }
    pairs
}

/// Aggregation-mode selection, parseable from CLI/config strings (the
/// `aggregator = fedavg | svt | exact` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AggregatorKind {
    /// Plain factor-wise weighted mean (the paper's method).
    #[default]
    FedAvg,
    /// Stacked-factor refactor with energy-threshold truncation
    /// (FLoRIST-style SVT; the `svt_energy` knob).
    Svt,
    /// Stacked-factor refactor with no energy cut — the optimal
    /// rank-budget correction of the A·B averaging bias.
    Exact,
}

impl AggregatorKind {
    /// Parse `fedavg | svt | exact`.
    pub fn parse(s: &str) -> Option<AggregatorKind> {
        match s {
            "fedavg" => Some(AggregatorKind::FedAvg),
            "svt" => Some(AggregatorKind::Svt),
            "exact" => Some(AggregatorKind::Exact),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AggregatorKind::FedAvg => "fedavg",
            AggregatorKind::Svt => "svt",
            AggregatorKind::Exact => "exact",
        }
    }

    /// Build a fresh per-shard aggregator for a `dim`-element trainable
    /// vector whose adapter pairs are `pairs` (precomputed once per
    /// run via [`adapter_pairs`]). `svt_energy` is only read by
    /// [`AggregatorKind::Svt`].
    pub fn build(
        &self,
        dim: usize,
        pairs: &[AdapterPair],
        svt_energy: f64,
    ) -> Box<dyn Aggregator> {
        match self {
            AggregatorKind::FedAvg => Box::new(FedAvgAggregator {
                inner: FedAvg::new(dim),
                eff_rank: static_rank(pairs),
            }),
            AggregatorKind::Svt => Box::new(SvtAggregator::new(
                dim,
                pairs.to_vec(),
                svt_energy,
            )),
            AggregatorKind::Exact => {
                Box::new(ExactAggregator::new(dim, pairs.to_vec()))
            }
        }
    }

    /// Merge per-shard partials (in canonical shard order) into the
    /// round outcome: concatenate the shards' block partials and
    /// factor stacks — block-aligned contiguous partitions make the
    /// concatenation exactly the list a single aggregator would hold
    /// — then run one tree merge and (for `svt | exact`) one SVD
    /// refactor on the coordinator thread. Returns the outcome and
    /// the block merge-tree depth. Byte-identical to boxing one
    /// aggregator over the whole round, at any shard count.
    pub fn finish_partials(
        &self,
        dim: usize,
        pairs: &[AdapterPair],
        svt_energy: f64,
        partials: Vec<AggPartial>,
    ) -> Result<(AggOutcome, usize)> {
        let mut fed = FedAvg::new(dim);
        let mut stacks: Vec<PairStack> =
            pairs.iter().map(|_| PairStack::default()).collect();
        let mut clients = 0usize;
        for partial in partials {
            debug_assert!(
                fed.blocks.last().map_or(true, |prev| {
                    partial
                        .blocks
                        .first()
                        .map_or(true, |next| prev.index < next.index)
                }),
                "shard partials must merge in canonical shard order"
            );
            fed.blocks.extend(partial.blocks);
            clients += partial.clients;
            for (dst, src) in stacks.iter_mut().zip(partial.stacks) {
                dst.left_cols.extend(src.left_cols);
                dst.right_rows.extend(src.right_rows);
            }
        }
        let total_weight = fed.contributions();
        let (mut acc, _w, depth) = fed.merge_blocks()?;
        if total_weight <= 0.0 {
            return Err(Error::invalid("aggregating zero contributions"));
        }
        tensor::scale(&mut acc, (1.0 / total_weight) as f32);
        let passthrough = match self {
            AggregatorKind::FedAvg => true,
            AggregatorKind::Svt => svt_energy >= 1.0,
            AggregatorKind::Exact => false,
        };
        let outcome = finish_stacked(
            acc,
            pairs,
            stacks,
            clients,
            total_weight,
            match self {
                AggregatorKind::Svt => Some(svt_energy.min(1.0)),
                _ => None,
            },
            passthrough,
        )?;
        Ok((outcome, depth))
    }
}

/// Mean server rank over adapter pairs — what a FedAvg round
/// effectively broadcasts (0.0 when the layout has no adapter pairs,
/// i.e. full-model variants).
fn static_rank(pairs: &[AdapterPair]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    pairs.iter().map(|p| p.rank as f64).sum::<f64>() / pairs.len() as f64
}

/// [`FedAvg`] behind the [`Aggregator`] seam, reporting the static
/// server rank as its effective rank.
struct FedAvgAggregator {
    inner: FedAvg,
    eff_rank: f64,
}

impl Aggregator for FedAvgAggregator {
    fn fold(
        &mut self,
        slot: usize,
        update: ClientUpdate<'_>,
        weight: f64,
    ) -> Result<()> {
        match update {
            ClientUpdate::Dense(v) => {
                self.inner.fold_dense(slot, v, weight)
            }
            ClientUpdate::Encoded { codec, msg, segments } => self
                .inner
                .fold_encoded(slot, codec, msg, segments, weight),
        }
    }

    fn contributions(&self) -> f64 {
        self.inner.contributions()
    }

    fn finish(self: Box<Self>) -> Result<AggOutcome> {
        Ok(AggOutcome {
            global: self.inner.finish()?,
            eff_rank: self.eff_rank,
        })
    }

    fn into_partial(self: Box<Self>) -> AggPartial {
        AggPartial {
            blocks: self.inner.blocks,
            stacks: Vec::new(),
            clients: 0,
        }
    }
}

/// Per-pair stack of scaled client factors: left columns (`outer`-long,
/// pre-scaled by the client weight) and matching right rows
/// (`inner`-long). Column `j` of the conceptual `outer × m` left stack
/// pairs with row `j` of the `m × inner` right stack, so
/// `Σ_k w_k L_k R_k = L_stack · R_stack` exactly. Stacking is pure
/// appends in sampling order, which is what lets shard-local stacks
/// concatenate into the global stack.
#[derive(Default)]
struct PairStack {
    left_cols: Vec<Vec<f64>>,
    right_rows: Vec<Vec<f64>>,
}

/// Shared core of the factor-aware modes: a full-vector [`FedAvg`]
/// (non-adapter segments, and the τ ≥ 1.0 passthrough) plus per-pair
/// factor stacks refactored at finish.
struct StackedAggregator {
    mean: FedAvg,
    pairs: Vec<AdapterPair>,
    stacks: Vec<PairStack>,
    clients: usize,
    /// Retained-energy threshold in (0, 1]; `None` means keep every
    /// numerically nonzero direction (the exact mode).
    energy: Option<f64>,
    /// Skip stacking and refactoring entirely — the svt τ ≥ 1.0 mode,
    /// defined as bit-for-bit FedAvg.
    passthrough: bool,
}

/// FLoRIST-style server-side singular-value thresholding
/// (`aggregator = svt`): see the module docs for the refactor.
pub struct SvtAggregator(StackedAggregator);

impl SvtAggregator {
    /// `energy` is the retained-energy threshold τ ∈ (0, 1]; τ ≥ 1.0
    /// degrades to bit-for-bit FedAvg (no stacking, no refactor).
    pub fn new(dim: usize, pairs: Vec<AdapterPair>, energy: f64) -> Self {
        let mut inner =
            StackedAggregator::new(dim, pairs, Some(energy.min(1.0)));
        inner.passthrough = energy >= 1.0;
        SvtAggregator(inner)
    }
}

impl Aggregator for SvtAggregator {
    fn fold(
        &mut self,
        slot: usize,
        update: ClientUpdate<'_>,
        weight: f64,
    ) -> Result<()> {
        self.0.fold(slot, update, weight)
    }

    fn contributions(&self) -> f64 {
        self.0.mean.contributions()
    }

    fn finish(self: Box<Self>) -> Result<AggOutcome> {
        self.0.finish()
    }

    fn into_partial(self: Box<Self>) -> AggPartial {
        self.0.into_partial()
    }
}

/// Exact-aggregation correction of the A·B averaging bias
/// (`aggregator = exact`): the broadcast factors reproduce the true
/// weighted-mean product up to the server rank budget.
pub struct ExactAggregator(StackedAggregator);

impl ExactAggregator {
    pub fn new(dim: usize, pairs: Vec<AdapterPair>) -> Self {
        ExactAggregator(StackedAggregator::new(dim, pairs, None))
    }
}

impl Aggregator for ExactAggregator {
    fn fold(
        &mut self,
        slot: usize,
        update: ClientUpdate<'_>,
        weight: f64,
    ) -> Result<()> {
        self.0.fold(slot, update, weight)
    }

    fn contributions(&self) -> f64 {
        self.0.mean.contributions()
    }

    fn finish(self: Box<Self>) -> Result<AggOutcome> {
        self.0.finish()
    }

    fn into_partial(self: Box<Self>) -> AggPartial {
        self.0.into_partial()
    }
}

impl StackedAggregator {
    fn new(
        dim: usize,
        pairs: Vec<AdapterPair>,
        energy: Option<f64>,
    ) -> StackedAggregator {
        let stacks = pairs.iter().map(|_| PairStack::default()).collect();
        StackedAggregator {
            mean: FedAvg::new(dim),
            pairs,
            stacks,
            clients: 0,
            energy,
            passthrough: false,
        }
    }

    fn fold(
        &mut self,
        slot: usize,
        update: ClientUpdate<'_>,
        weight: f64,
    ) -> Result<()> {
        if self.passthrough {
            // No stacking: the zero-copy encoded fold is safe (and
            // bitwise-FedAvg is the passthrough's definition).
            self.clients += 1;
            return match update {
                ClientUpdate::Dense(v) => {
                    self.mean.fold_dense(slot, v, weight)
                }
                ClientUpdate::Encoded { codec, msg, segments } => self
                    .mean
                    .fold_encoded(slot, codec, msg, segments, weight),
            };
        }
        // Factor stacking needs the dense vector; materialize encoded
        // uploads here, once, behind the seam.
        let materialized;
        let v: &[f32] = match update {
            ClientUpdate::Dense(v) => v,
            ClientUpdate::Encoded { codec, msg, segments } => {
                materialized = codec.decode(msg, segments)?;
                &materialized
            }
        };
        self.mean.fold_dense(slot, v, weight)?;
        self.clients += 1;
        for (pair, stack) in self.pairs.iter().zip(self.stacks.iter_mut()) {
            let r = pair.rank;
            for j in 0..r {
                // Left column j (scaled by the weight) and right row j;
                // a slot whose column or row is all-zero contributes
                // nothing to the product — skip it (hetero clients
                // zero-pad their unused rank slots).
                let col: Vec<f64> = (0..pair.outer)
                    .map(|o| v[pair.left_offset + o * r + j] as f64 * weight)
                    .collect();
                let row: Vec<f64> = (0..pair.inner)
                    .map(|t| v[pair.right_offset + j * pair.inner + t] as f64)
                    .collect();
                if col.iter().all(|&x| x == 0.0)
                    || row.iter().all(|&x| x == 0.0)
                {
                    continue;
                }
                stack.left_cols.push(col);
                stack.right_rows.push(row);
            }
        }
        Ok(())
    }

    fn finish(self: StackedAggregator) -> Result<AggOutcome> {
        let total_weight = self.mean.contributions();
        let global = self.mean.finish()?;
        finish_stacked(
            global,
            &self.pairs,
            self.stacks,
            self.clients,
            total_weight,
            self.energy,
            self.passthrough,
        )
    }

    fn into_partial(self) -> AggPartial {
        AggPartial {
            blocks: self.mean.blocks,
            stacks: self.stacks,
            clients: self.clients,
        }
    }
}

/// The factor-refactor tail shared by the unsharded `finish` and the
/// coordinator-side [`AggregatorKind::finish_partials`]: takes the
/// already-divided mean vector and the (possibly concatenated) factor
/// stacks, and either passes the mean through or refactors each pair.
fn finish_stacked(
    mut global: Vec<f32>,
    pairs: &[AdapterPair],
    stacks: Vec<PairStack>,
    clients: usize,
    total_weight: f64,
    energy: Option<f64>,
    passthrough: bool,
) -> Result<AggOutcome> {
    // Passthrough cases are bit-for-bit FedAvg: τ ≥ 1.0, a
    // non-adapter layout, or a single contributor (the mean of one
    // product is the product of one mean). The rank report still
    // covers the pairs — it is the static server rank then.
    if passthrough || pairs.is_empty() || clients <= 1 {
        return Ok(AggOutcome { global, eff_rank: static_rank(pairs) });
    }
    let mut rank_sum = 0.0;
    for (pair, stack) in pairs.iter().zip(stacks.into_iter()) {
        rank_sum += refactor_pair(
            &mut global,
            pair,
            stack,
            total_weight,
            energy,
        ) as f64;
    }
    Ok(AggOutcome { global, eff_rank: rank_sum / pairs.len() as f64 })
}

/// Refactor one adapter pair's stacked contribution into at most
/// `pair.rank` broadcast slots and write the result into `global`.
/// Returns the number of slots kept (the pair's effective rank).
///
/// The exact weighted-mean product is `P̄ = L_s · R_s / W` with
/// `L_s` `outer × m` and `R_s` `m × inner` (m = Σ stacked slots). Thin
/// QR of both sides (`L_s = Q_l T_l`, `R_sᵀ = Q_r T_r`) reduces the
/// SVD to the small `m × m` core `M = T_l T_rᵀ = U Σ Vᵀ`, giving
/// `P̄ = (Q_l U) (Σ/W) (Q_r V)ᵀ` — computed entirely in f64 on the
/// coordinator thread, so the result is independent of executor mode
/// and shard count.
fn refactor_pair(
    global: &mut [f32],
    pair: &AdapterPair,
    stack: PairStack,
    total_weight: f64,
    energy: Option<f64>,
) -> usize {
    let m = stack.left_cols.len();
    let r = pair.rank;
    // Zero the pair's broadcast slots first; kept directions are
    // written below and an all-zero stack stays all-zero.
    for o in 0..pair.outer {
        for j in 0..r {
            global[pair.left_offset + o * r + j] = 0.0;
        }
    }
    for x in global
        .iter_mut()
        .skip(pair.right_offset)
        .take(r * pair.inner)
    {
        *x = 0.0;
    }
    if m == 0 {
        return 0;
    }
    let (ql, tl) = mgs_qr(&stack.left_cols);
    let (qr, tr) = mgs_qr(&stack.right_rows);
    // Core M = T_l · T_rᵀ (m × m).
    let mut core = vec![vec![0.0f64; m]; m];
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0;
            for (a, b) in tl[i].iter().zip(tr[j].iter()) {
                s += a * b;
            }
            core[i][j] = s;
        }
    }
    let (u_sigma, v) = jacobi_svd(&mut core);
    // σ_j = ‖column j of UΣ‖; order indices by σ descending
    // (index-ascending tie-break keeps the sort deterministic).
    let mut sigmas: Vec<(usize, f64)> = (0..m)
        .map(|j| {
            let s = (0..m)
                .map(|i| u_sigma[i][j] * u_sigma[i][j])
                .sum::<f64>()
                .sqrt();
            (j, s)
        })
        .collect();
    sigmas.sort_by(|a, b| {
        b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
    });
    let sigma_max = sigmas.first().map(|&(_, s)| s).unwrap_or(0.0);
    if sigma_max <= 0.0 {
        return 0;
    }
    let nonzero = sigmas
        .iter()
        .take_while(|&&(_, s)| s > sigma_max * 1e-9)
        .count();
    let keep = match energy {
        None => nonzero.min(r),
        Some(tau) => {
            let total: f64 = sigmas.iter().map(|&(_, s)| s * s).sum();
            let mut acc = 0.0;
            let mut k = 0;
            for &(_, s) in sigmas.iter().take(nonzero) {
                k += 1;
                acc += s * s;
                if acc >= tau * total {
                    break;
                }
            }
            k.min(r)
        }
    };
    // Write kept directions: left slot j gets (Q_l u_j) · σ_j / W,
    // right slot j gets (Q_r v_j)ᵀ.
    for (slot, &(jj, sigma)) in sigmas.iter().take(keep).enumerate() {
        let scale = sigma / total_weight;
        for o in 0..pair.outer {
            let mut val = 0.0;
            for i in 0..m {
                val += ql[i][o] * u_sigma[i][jj] / sigma;
            }
            global[pair.left_offset + o * r + slot] = (val * scale) as f32;
        }
        for t in 0..pair.inner {
            let mut val = 0.0;
            for i in 0..m {
                val += qr[i][t] * v[i][jj];
            }
            global[pair.right_offset + slot * pair.inner + t] = val as f32;
        }
    }
    keep
}

/// Modified Gram-Schmidt QR of the matrix whose columns are `cols`
/// (each a length-`d` vector). Returns `(q, t)` with `q[i]` the i-th
/// orthonormal column (all-zero when the input column was linearly
/// dependent) and `t[i][j]` upper-triangular such that
/// `cols[j] = Σ_i q[i] · t[i][j]`.
fn mgs_qr(cols: &[Vec<f64>]) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let m = cols.len();
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut t = vec![vec![0.0f64; m]; m];
    for j in 0..m {
        let mut v = cols[j].clone();
        for i in 0..j {
            let dot: f64 = q[i].iter().zip(v.iter()).map(|(a, b)| a * b).sum();
            t[i][j] = dot;
            for (x, &qx) in v.iter_mut().zip(q[i].iter()) {
                *x -= dot * qx;
            }
        }
        let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        let col_norm: f64 =
            cols[j].iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > col_norm.max(1e-300) * 1e-12 {
            t[j][j] = norm;
            for x in v.iter_mut() {
                *x /= norm;
            }
            q.push(v);
        } else {
            t[j][j] = 0.0;
            q.push(vec![0.0; cols[j].len()]);
        }
    }
    (q, t)
}

/// One-sided Jacobi SVD of the square matrix `a` (row-major `m × m`,
/// consumed): returns `(u_sigma, v)` where `u_sigma`'s columns are
/// `u_j σ_j` and `v` is orthogonal, with `a = (UΣ) Vᵀ`. Fixed sweep
/// order and a pure-f64 inner loop keep the decomposition
/// deterministic across platforms and executors.
fn jacobi_svd(a: &mut [Vec<f64>]) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let m = a.len();
    let mut v = vec![vec![0.0f64; m]; m];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    for _sweep in 0..60 {
        let mut rotated = false;
        for p in 0..m {
            for q in (p + 1)..m {
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for row in a.iter() {
                    app += row[p] * row[p];
                    aqq += row[q] * row[q];
                    apq += row[p] * row[q];
                }
                if apq.abs() <= 1e-15 * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                rotated = true;
                let theta = 0.5 * (2.0 * apq).atan2(app - aqq);
                let (s, c) = theta.sin_cos();
                for row in a.iter_mut() {
                    let (xp, xq) = (row[p], row[q]);
                    row[p] = c * xp + s * xq;
                    row[q] = -s * xp + c * xq;
                }
                for row in v.iter_mut() {
                    let (xp, xq) = (row[p], row[q]);
                    row[p] = c * xp + s * xq;
                    row[q] = -s * xp + c * xq;
                }
            }
        }
        if !rotated {
            break;
        }
    }
    (a.to_vec(), v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::{shard_slices, SHARD_BLOCK};
    use crate::model::{build_spec, ModelCfg, ParamKind, Variant};
    use crate::util::rng::Rng;

    fn lora_segments(rank: usize) -> Vec<Segment> {
        build_spec(
            ModelCfg::by_name("micro8").unwrap(),
            Variant::LoraFc,
            rank,
        )
        .trainable
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
    }

    /// Dense product of one pair's factors read from a flat vector.
    fn pair_product(v: &[f32], p: &AdapterPair) -> Vec<f64> {
        let mut out = vec![0.0f64; p.outer * p.inner];
        for o in 0..p.outer {
            for t in 0..p.inner {
                let mut s = 0.0;
                for j in 0..p.rank {
                    s += v[p.left_offset + o * p.rank + j] as f64
                        * v[p.right_offset + j * p.inner + t] as f64;
                }
                out[o * p.inner + t] = s;
            }
        }
        out
    }

    #[test]
    fn kind_parses_and_labels() {
        assert_eq!(AggregatorKind::parse("fedavg"),
                   Some(AggregatorKind::FedAvg));
        assert_eq!(AggregatorKind::parse("svt"), Some(AggregatorKind::Svt));
        assert_eq!(AggregatorKind::parse("exact"),
                   Some(AggregatorKind::Exact));
        assert_eq!(AggregatorKind::parse("trimmed_mean"), None);
        assert_eq!(AggregatorKind::FedAvg.label(), "fedavg");
        assert_eq!(AggregatorKind::Svt.label(), "svt");
        assert_eq!(AggregatorKind::Exact.label(), "exact");
        assert_eq!(AggregatorKind::default(), AggregatorKind::FedAvg);
    }

    #[test]
    fn weighted_mean() {
        let mut agg = FedAvg::new(2);
        agg.add(&[1.0, 0.0], 1.0).unwrap();
        agg.add(&[4.0, 3.0], 3.0).unwrap();
        assert_eq!(agg.contributions(), 4.0);
        let out = agg.finish().unwrap();
        assert_eq!(out, vec![3.25, 2.25]);
    }

    #[test]
    fn identity_on_identical_inputs() {
        let v = vec![0.5f32, -1.5, 2.0];
        let mut agg = FedAvg::new(3);
        for w in [1.0, 2.0, 5.0] {
            agg.add(&v, w).unwrap();
        }
        let out = agg.finish().unwrap();
        for (a, b) in out.iter().zip(&v) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rejects_errors() {
        let mut agg = FedAvg::new(2);
        assert!(agg.add(&[1.0], 1.0).is_err());
        assert!(agg.add(&[1.0, 2.0], 0.0).is_err());
        assert!(FedAvg::new(2).finish().is_err());
        // The boxed seam surfaces the same errors.
        for kind in
            [AggregatorKind::FedAvg, AggregatorKind::Svt, AggregatorKind::Exact]
        {
            let mut agg = kind.build(2, &[], 0.9);
            assert!(
                agg.fold(0, ClientUpdate::Dense(&[1.0]), 1.0).is_err(),
                "{kind:?}"
            );
            assert!(
                agg.fold(0, ClientUpdate::Dense(&[1.0, 2.0]), -1.0)
                    .is_err(),
                "{kind:?}"
            );
            assert!(kind.build(2, &[], 0.9).finish().is_err(), "{kind:?}");
            // And the sharded merge rejects an all-empty round too.
            assert!(kind
                .finish_partials(2, &[], 0.9, vec![
                    kind.build(2, &[], 0.9).into_partial()
                ])
                .is_err());
        }
    }

    /// Sharding the fold stream over block-aligned partitions and
    /// merging the partials is byte-identical to one aggregator — for
    /// every kind, including streams longer than one fold block.
    #[test]
    fn finish_partials_is_bitwise_identical_to_single_fold() {
        let segs = lora_segments(4);
        let pairs = adapter_pairs(&segs);
        let n: usize = segs.iter().map(|s| s.numel).sum();
        // 3 blocks' worth of clients, some slots skipped (dropouts).
        let total_slots = 2 * SHARD_BLOCK + 17;
        let updates: Vec<Option<(Vec<f32>, f64)>> = (0..total_slots)
            .map(|slot| {
                if slot % 11 == 3 {
                    None // dropped: no fold at this slot
                } else {
                    Some((
                        randv(n, 100 + slot as u64),
                        1.0 + (slot % 5) as f64,
                    ))
                }
            })
            .collect();
        for kind in
            [AggregatorKind::FedAvg, AggregatorKind::Svt, AggregatorKind::Exact]
        {
            let tau = 0.8;
            let reference = {
                let mut agg = kind.build(n, &pairs, tau);
                for (slot, u) in updates.iter().enumerate() {
                    if let Some((v, w)) = u {
                        agg.fold(slot, ClientUpdate::Dense(v), *w)
                            .unwrap();
                    }
                }
                kind.finish_partials(
                    n,
                    &pairs,
                    tau,
                    vec![agg.into_partial()],
                )
                .unwrap()
            };
            for shards in [2usize, 3, 7] {
                let mut partials = Vec::new();
                for range in shard_slices(total_slots, shards) {
                    let mut agg = kind.build(n, &pairs, tau);
                    for slot in range {
                        if let Some((v, w)) = &updates[slot] {
                            agg.fold(slot, ClientUpdate::Dense(v), *w)
                                .unwrap();
                        }
                    }
                    partials.push(agg.into_partial());
                }
                let got = kind
                    .finish_partials(n, &pairs, tau, partials)
                    .unwrap();
                assert_eq!(
                    reference.0.global, got.0.global,
                    "{kind:?} shards={shards}"
                );
                assert_eq!(reference.0.eff_rank, got.0.eff_rank);
                assert_eq!(
                    reference.1, got.1,
                    "merge depth must be shard-invariant"
                );
            }
        }
    }

    /// The unsharded trait `finish` and `finish_partials` over one
    /// partial agree bitwise, and single-block streams reproduce the
    /// historical flat fold (left-fold in slot order).
    #[test]
    fn single_block_fold_matches_flat_reference() {
        let n = 64;
        let vs: Vec<Vec<f32>> =
            (0..8).map(|i| randv(n, 40 + i as u64)).collect();
        // Flat reference: the pre-block serial fold.
        let mut acc = vec![0.0f32; n];
        let mut total = 0.0f64;
        for (i, v) in vs.iter().enumerate() {
            let w = 1.0 + i as f64;
            tensor::axpy_weighted(&mut acc, v, w as f32);
            total += w;
        }
        tensor::scale(&mut acc, (1.0 / total) as f32);
        let mut agg = AggregatorKind::FedAvg.build(n, &[], 0.9);
        for (i, v) in vs.iter().enumerate() {
            agg.fold(i, ClientUpdate::Dense(v), 1.0 + i as f64).unwrap();
        }
        let out = agg.finish().unwrap();
        assert_eq!(out.global, acc);
    }

    #[test]
    fn adapter_pairs_cover_every_adapter_segment() {
        let segs = lora_segments(4);
        let pairs = adapter_pairs(&segs);
        assert!(!pairs.is_empty());
        // Every adapter segment's elements are covered exactly once.
        let adapter_numel: usize = segs
            .iter()
            .filter(|s| rank_geometry(s).is_some())
            .map(|s| s.numel)
            .sum();
        let paired_numel: usize = pairs
            .iter()
            .map(|p| p.rank * (p.outer + p.inner))
            .sum();
        assert_eq!(adapter_numel, paired_numel);
        for p in &pairs {
            assert_eq!(p.rank, 4);
            assert!(p.outer > 0 && p.inner > 0);
        }
        // Full-model layouts have no pairs.
        let full = build_spec(
            ModelCfg::by_name("micro8").unwrap(),
            Variant::Full,
            0,
        )
        .trainable;
        assert!(adapter_pairs(&full).is_empty());
        assert!(
            full.iter().all(|s| !matches!(
                s.kind,
                ParamKind::LoraA | ParamKind::LoraB
            )),
            "full variant unexpectedly grew adapters"
        );
    }

    #[test]
    fn svt_full_energy_is_bitwise_fedavg() {
        let segs = lora_segments(4);
        let pairs = adapter_pairs(&segs);
        let n: usize = segs.iter().map(|s| s.numel).sum();
        let (a, b) = (randv(n, 1), randv(n, 2));
        let mut fed = AggregatorKind::FedAvg.build(n, &pairs, 0.9);
        let mut svt = AggregatorKind::Svt.build(n, &pairs, 1.0);
        for agg in [&mut fed, &mut svt] {
            agg.fold(0, ClientUpdate::Dense(&a), 2.0).unwrap();
            agg.fold(1, ClientUpdate::Dense(&b), 3.0).unwrap();
        }
        let fed = fed.finish().unwrap();
        let svt = svt.finish().unwrap();
        assert_eq!(fed.global, svt.global, "τ=1.0 must be exact FedAvg");
        assert_eq!(fed.eff_rank, svt.eff_rank);
        assert_eq!(fed.eff_rank, 4.0);
    }

    #[test]
    fn exact_single_client_is_bitwise_fedavg() {
        let segs = lora_segments(4);
        let pairs = adapter_pairs(&segs);
        let n: usize = segs.iter().map(|s| s.numel).sum();
        let v = randv(n, 7);
        let mut fed = AggregatorKind::FedAvg.build(n, &pairs, 0.9);
        let mut exact = AggregatorKind::Exact.build(n, &pairs, 0.9);
        fed.fold(0, ClientUpdate::Dense(&v), 5.0).unwrap();
        exact.fold(0, ClientUpdate::Dense(&v), 5.0).unwrap();
        let fed = fed.finish().unwrap();
        let exact = exact.finish().unwrap();
        assert_eq!(fed.global, exact.global);
        assert_eq!(fed.eff_rank, exact.eff_rank);
    }

    /// Two clients that each use disjoint rank slots: the true mean
    /// product has rank ≤ r, so the exact mode must reproduce it —
    /// while factor-wise FedAvg is biased by construction.
    #[test]
    fn exact_mode_corrects_the_averaging_bias() {
        // One synthetic pair: L is 3×2 rank-minor, R is 2×3 rank-major.
        let pair = AdapterPair {
            left_offset: 0,
            outer: 3,
            right_offset: 6,
            inner: 3,
            rank: 2,
        };
        let n = 15; // 6 + 9
        // Client 1 uses slot 0 only; client 2 uses slot 1 only.
        let mut c1 = vec![0.0f32; n];
        let mut c2 = vec![0.0f32; n];
        for o in 0..3 {
            c1[o * 2] = (o + 1) as f32; // L[:,0] = [1,2,3]
            c2[o * 2 + 1] = (o as f32) - 1.0; // L[:,1] = [-1,0,1]
        }
        for t in 0..3 {
            c1[6 + t] = 1.0 + t as f32; // R[0,:] = [1,2,3]
            c2[6 + 3 + t] = 2.0 - t as f32; // R[1,:] = [2,1,0]
        }
        let expect: Vec<f64> = {
            let p1 = pair_product(&c1, &pair);
            let p2 = pair_product(&c2, &pair);
            p1.iter().zip(&p2).map(|(a, b)| (a + b) / 2.0).collect()
        };
        let pairs = vec![pair];
        let mut exact = AggregatorKind::Exact.build(n, &pairs, 0.9);
        let mut fed = AggregatorKind::FedAvg.build(n, &pairs, 0.9);
        for agg in [&mut exact, &mut fed] {
            agg.fold(0, ClientUpdate::Dense(&c1), 1.0).unwrap();
            agg.fold(1, ClientUpdate::Dense(&c2), 1.0).unwrap();
        }
        let exact = exact.finish().unwrap();
        let got = pair_product(&exact.global, &pair);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-5, "exact: {got:?} vs {expect:?}");
        }
        assert!((exact.eff_rank - 2.0).abs() < 1e-12);
        // FedAvg halves each factor, quartering the product: biased.
        let fed = fed.finish().unwrap();
        let biased = pair_product(&fed.global, &pair);
        let err: f64 = biased
            .iter()
            .zip(&expect)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err > 0.5, "FedAvg should be visibly biased here: {err}");
    }

    #[test]
    fn svt_threshold_truncates_rank() {
        // Same disjoint-slot construction, but slot 0 carries almost
        // all the energy — a low threshold keeps only that direction.
        let pair = AdapterPair {
            left_offset: 0,
            outer: 3,
            right_offset: 6,
            inner: 3,
            rank: 2,
        };
        let n = 15;
        let mut c1 = vec![0.0f32; n];
        let mut c2 = vec![0.0f32; n];
        for o in 0..3 {
            c1[o * 2] = 10.0 * (o + 1) as f32;
            c2[o * 2 + 1] = 0.01 * ((o as f32) - 1.0);
        }
        for t in 0..3 {
            c1[6 + t] = 10.0;
            c2[6 + 3 + t] = 0.01;
        }
        let pairs = vec![pair];
        let run = |tau: f64| {
            let mut agg = AggregatorKind::Svt.build(n, &pairs, tau);
            agg.fold(0, ClientUpdate::Dense(&c1), 1.0).unwrap();
            agg.fold(1, ClientUpdate::Dense(&c2), 1.0).unwrap();
            agg.finish().unwrap()
        };
        let low = run(0.5);
        assert!((low.eff_rank - 1.0).abs() < 1e-12, "{}", low.eff_rank);
        // The kept direction reproduces the dominant client's product.
        let got = pair_product(&low.global, &pair);
        let p1 = pair_product(&c1, &pair);
        for (g, e) in got.iter().zip(&p1) {
            assert!((g - e / 2.0).abs() < 1e-3, "{got:?}");
        }
        let high = run(0.999999);
        assert!(high.eff_rank >= low.eff_rank);
        assert!((high.eff_rank - 2.0).abs() < 1e-12, "{}", high.eff_rank);
    }

    #[test]
    fn factor_modes_match_fedavg_on_nonadapter_segments() {
        let segs = lora_segments(4);
        let pairs = adapter_pairs(&segs);
        let n: usize = segs.iter().map(|s| s.numel).sum();
        let (a, b) = (randv(n, 3), randv(n, 4));
        let mut fed = AggregatorKind::FedAvg.build(n, &pairs, 0.9);
        let mut exact = AggregatorKind::Exact.build(n, &pairs, 0.9);
        for agg in [&mut fed, &mut exact] {
            agg.fold(0, ClientUpdate::Dense(&a), 1.0).unwrap();
            agg.fold(1, ClientUpdate::Dense(&b), 4.0).unwrap();
        }
        let fed = fed.finish().unwrap();
        let exact = exact.finish().unwrap();
        for s in segs.iter().filter(|s| rank_geometry(s).is_none()) {
            assert_eq!(
                &fed.global[s.offset..s.offset + s.numel],
                &exact.global[s.offset..s.offset + s.numel],
                "{} must take the plain FedAvg path",
                s.name
            );
        }
    }

    #[test]
    fn refactor_is_deterministic_in_add_order_of_values() {
        // Same clients, same weights, two separate aggregator
        // instances: bitwise-identical output (the in-round order is
        // fixed by the sampler, but rebuildability matters for replay).
        let segs = lora_segments(8);
        let pairs = adapter_pairs(&segs);
        let n: usize = segs.iter().map(|s| s.numel).sum();
        let vs: Vec<Vec<f32>> =
            (0..3).map(|i| randv(n, 10 + i as u64)).collect();
        let run = || {
            let mut agg = AggregatorKind::Svt.build(n, &pairs, 0.8);
            for (i, v) in vs.iter().enumerate() {
                agg.fold(i, ClientUpdate::Dense(v), 1.0 + i as f64)
                    .unwrap();
            }
            agg.finish().unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.global, b.global);
        assert_eq!(a.eff_rank, b.eff_rank);
    }
}
