//! Communication-cost accounting.
//!
//! The paper's Equation 2 gives the total communication cost *per
//! client* over `R` rounds: `TCC(R) = 2 R Q_p |w|` (bits; download +
//! upload each round). [`tcc_equation2`] reproduces it analytically —
//! this is the formula behind Table III's 982.07 MB FedAvg row — while
//! [`CommLedger`] measures the real encoded bytes the simulation moved,
//! so quantization overhead (scales/zero-points) is counted exactly as
//! the paper says it includes.

/// Message direction, server perspective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Server → client (model download).
    Down,
    /// Client → server (update upload).
    Up,
}

/// Eq. 2: bytes for one client over `rounds` rounds with `bits`-wide
/// elements and `num_params` parameters per message.
pub fn tcc_equation2(rounds: usize, bits: u32, num_params: usize) -> f64 {
    2.0 * rounds as f64 * (bits as f64 / 8.0) * num_params as f64
}

/// Measured byte ledger.
#[derive(Debug, Default, Clone)]
pub struct CommLedger {
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub up_msgs: u64,
    pub down_msgs: u64,
    /// Per-round totals (up + down), for convergence-vs-cost plots.
    pub per_round: Vec<u64>,
}

impl CommLedger {
    pub fn new() -> CommLedger {
        CommLedger::default()
    }

    pub fn record(&mut self, dir: Direction, bytes: usize) {
        match dir {
            Direction::Up => {
                self.up_bytes += bytes as u64;
                self.up_msgs += 1;
            }
            Direction::Down => {
                self.down_bytes += bytes as u64;
                self.down_msgs += 1;
            }
        }
        // A record before the first `begin_round` opens the bucket
        // instead of silently leaking the bytes out of `per_round`
        // (callers driving the ledger by hand don't all announce
        // round boundaries first).
        match self.per_round.last_mut() {
            Some(last) => *last += bytes as u64,
            None => self.per_round.push(bytes as u64),
        }
    }

    /// Open a new per-round bucket.
    pub fn begin_round(&mut self) {
        self.per_round.push(0);
    }

    /// Fold one shard's round ledger into this ledger's current
    /// round bucket. Every counter is an integer sum, so sum-of-sums
    /// is exact here — the coordinator still absorbs shards in
    /// canonical shard order, which keeps the (already
    /// order-insensitive) totals trivially bit-identical to the
    /// unsharded interleaved recording.
    pub fn absorb_round(&mut self, shard: &CommLedger) {
        self.up_bytes += shard.up_bytes;
        self.down_bytes += shard.down_bytes;
        self.up_msgs += shard.up_msgs;
        self.down_msgs += shard.down_msgs;
        let bytes: u64 = shard.per_round.iter().sum();
        match self.per_round.last_mut() {
            Some(last) => *last += bytes,
            None => self.per_round.push(bytes),
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }

    /// Paper-style per-client TCC over `rounds` rounds, from measured
    /// bytes: `rounds × (mean_down + mean_up)` with per-direction
    /// means. Pooling both directions into one mean (the pre-fix
    /// formula) mis-weights the estimate whenever the two directions
    /// carry different message counts (dropouts upload nothing) or
    /// different codecs (hetero tiers with asymmetric wire formats).
    /// With symmetric traffic this still equals Eq. 2 on measured
    /// bytes.
    pub fn per_client_tcc(&self, rounds: usize) -> f64 {
        let mean_down = if self.down_msgs == 0 {
            0.0
        } else {
            self.down_bytes as f64 / self.down_msgs as f64
        };
        let mean_up = if self.up_msgs == 0 {
            0.0
        } else {
            self.up_bytes as f64 / self.up_msgs as f64
        };
        rounds as f64 * (mean_down + mean_up)
    }

    /// Mean upload message bytes (the "Message Size" column of Table IV).
    pub fn mean_up_msg(&self) -> f64 {
        if self.up_msgs == 0 {
            0.0
        } else {
            self.up_bytes as f64 / self.up_msgs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equation2_matches_paper_table3_fedavg_row() {
        // ResNet-8: 1.2276 M params, fp32, 100 rounds => 982.07 MB.
        let bytes = tcc_equation2(100, 32, 1_227_594);
        assert!((bytes / 1e6 - 982.07).abs() < 1.0, "{}", bytes / 1e6);
    }

    #[test]
    fn equation2_matches_paper_table3_flocora_row() {
        // FLoCoRA r=32: 258.0 K trained params => ~205.47 MB / 100 rounds.
        let bytes = tcc_equation2(100, 32, 258_026);
        assert!((bytes / 1e6 - 205.47).abs() < 1.5, "{}", bytes / 1e6);
    }

    #[test]
    fn ledger_accumulates_and_buckets() {
        let mut l = CommLedger::new();
        l.begin_round();
        l.record(Direction::Down, 100);
        l.record(Direction::Up, 50);
        l.begin_round();
        l.record(Direction::Down, 10);
        assert_eq!(l.total_bytes(), 160);
        assert_eq!(l.per_round, vec![150, 10]);
        assert_eq!(l.up_msgs, 1);
        assert_eq!(l.down_msgs, 2);
        assert_eq!(l.mean_up_msg(), 50.0);
    }

    #[test]
    fn per_client_tcc_symmetric_case() {
        let mut l = CommLedger::new();
        l.begin_round();
        for _ in 0..10 {
            l.record(Direction::Down, 1000);
            l.record(Direction::Up, 1000);
        }
        // Every message 1000 B, 5 rounds => per-client 2*5*1000 = 10 kB.
        assert_eq!(l.per_client_tcc(5), 10_000.0);
    }

    #[test]
    fn per_client_tcc_weighs_directions_separately() {
        // Asymmetric regime: every client pulls 3000 B, but only half
        // upload (dropouts) at 1000 B. The paper-style per-client cost
        // is rounds × (mean_down + mean_up) = 5 × 4000 = 20 kB; the
        // old pooled mean smeared the missing uploads across both
        // directions (35 kB total / 15 msgs × 2 × 5 ≈ 23.3 kB).
        let mut l = CommLedger::new();
        l.begin_round();
        for i in 0..10 {
            l.record(Direction::Down, 3000);
            if i % 2 == 0 {
                l.record(Direction::Up, 1000);
            }
        }
        assert_eq!(l.per_client_tcc(5), 20_000.0);
        // Down-only traffic (e.g. a fully dropped run) still counts
        // the downloads instead of dividing by a zero message count.
        let mut d = CommLedger::new();
        d.record(Direction::Down, 100);
        assert_eq!(d.per_client_tcc(2), 200.0);
        assert_eq!(CommLedger::new().per_client_tcc(3), 0.0);
    }

    #[test]
    fn absorb_round_matches_interleaved_recording() {
        // Unsharded reference: one ledger records every message.
        let mut reference = CommLedger::new();
        reference.begin_round();
        for i in 0..10usize {
            reference.record(Direction::Down, 1000 + i);
            if i % 3 != 0 {
                reference.record(Direction::Up, 500 + i);
            }
        }
        // Sharded: two shard ledgers split the clients, absorbed in
        // shard order into a round bucket.
        let mut merged = CommLedger::new();
        merged.begin_round();
        for shard_clients in [0..6usize, 6..10] {
            let mut shard = CommLedger::new();
            shard.begin_round();
            for i in shard_clients {
                shard.record(Direction::Down, 1000 + i);
                if i % 3 != 0 {
                    shard.record(Direction::Up, 500 + i);
                }
            }
            merged.absorb_round(&shard);
        }
        assert_eq!(merged.up_bytes, reference.up_bytes);
        assert_eq!(merged.down_bytes, reference.down_bytes);
        assert_eq!(merged.up_msgs, reference.up_msgs);
        assert_eq!(merged.down_msgs, reference.down_msgs);
        assert_eq!(merged.per_round, reference.per_round);
    }

    #[test]
    fn record_before_begin_round_opens_a_bucket() {
        let mut l = CommLedger::new();
        l.record(Direction::Down, 40);
        l.record(Direction::Up, 2);
        assert_eq!(l.per_round, vec![42]);
        l.begin_round();
        l.record(Direction::Down, 7);
        assert_eq!(l.per_round, vec![42, 7]);
        assert_eq!(l.total_bytes(), 49);
    }
}
