//! Per-client link and compute profiles — the heterogeneity axis the
//! symmetric [`NetworkModel`](crate::transport::NetworkModel) cannot
//! express.
//!
//! The base [`NetworkModel`] describes *one* link (LTE, WiFi); real
//! edge federations put every client behind its own multiple of that
//! link — the regime where straggler-aware sampling pays off. A
//! [`ClientProfile`] scales the base link's per-direction wire times
//! and the client's simulated compute; a [`ClientProfiles`] table maps
//! every client id to its profile, deterministically from the run seed
//! (so profiles are stable across rounds, executors and threads).
//!
//! Two table shapes ([`ProfileKind`], the `client_profiles` knob):
//!
//! * [`ProfileKind::Uniform`] — every client at exactly 1.0× with zero
//!   simulated compute: bit-identical to the pre-profile symmetric
//!   model (multiplying a time by `1.0` and adding `0.0` are exact in
//!   f64).
//! * [`ProfileKind::Tiered`] — clients split round-robin over
//!   fast/mid/slow device classes (the same `cid % 3` assignment the
//!   hetero-rank plan uses), each with a seeded ±10% per-client jitter
//!   so no two clients are perfectly identical.

use crate::transport::NetworkModel;
use crate::util::rng::Rng;

/// One client's deviation from the base link profile.
///
/// Multipliers scale *time*, so `2.0` means "half the rate / twice as
/// slow". `compute_mult` scales the table's per-round compute baseline
/// ([`ClientProfiles::compute_s`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientProfile {
    /// Uplink time multiplier (≥ 0; 1.0 = the base link).
    pub up_mult: f64,
    /// Downlink time multiplier.
    pub down_mult: f64,
    /// Simulated local-compute multiplier.
    pub compute_mult: f64,
}

impl ClientProfile {
    /// The neutral profile: the base link, no simulated compute skew.
    pub const UNIT: ClientProfile =
        ClientProfile { up_mult: 1.0, down_mult: 1.0, compute_mult: 1.0 };

    /// This client's time to pull `bytes` (base link scaled).
    pub fn download_time(&self, net: &NetworkModel, bytes: usize) -> f64 {
        net.download_time(bytes) * self.down_mult
    }

    /// This client's time to push `bytes` (base link scaled).
    pub fn upload_time(&self, net: &NetworkModel, bytes: usize) -> f64 {
        net.upload_time(bytes) * self.up_mult
    }
}

/// Profile-table selection, parseable from CLI/config strings (the
/// `client_profiles = uniform | tiered` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProfileKind {
    /// Every client owns an identical base-rate link (pre-profile
    /// behaviour, bit-identical).
    #[default]
    Uniform,
    /// Fast/mid/slow device classes, round-robin by client id, with
    /// seeded per-client jitter.
    Tiered,
}

impl ProfileKind {
    /// Parse `uniform | tiered`.
    pub fn parse(s: &str) -> Option<ProfileKind> {
        match s {
            "uniform" => Some(ProfileKind::Uniform),
            "tiered" => Some(ProfileKind::Tiered),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ProfileKind::Uniform => "uniform",
            ProfileKind::Tiered => "tiered",
        }
    }

    /// Build the per-client table for a federation of `num_clients`,
    /// deterministically from `seed`.
    pub fn build(&self, num_clients: usize, seed: u64) -> ClientProfiles {
        match self {
            ProfileKind::Uniform => ClientProfiles::uniform(num_clients),
            ProfileKind::Tiered => ClientProfiles::tiered(num_clients, seed),
        }
    }
}

/// The device classes of [`ClientProfiles::tiered`]:
/// `(up_mult, down_mult, compute_mult)` before jitter.
const TIERS: [(f64, f64, f64); 3] = [
    (0.8, 0.8, 0.6),  // fast: fiber-backed, recent silicon
    (1.0, 1.0, 1.0),  // mid: the base link
    (8.0, 8.0, 6.0),  // slow: congested uplink, old device
];

/// Seconds of simulated client compute per round at `compute_mult`
/// 1.0 in a tiered table (uniform tables use 0.0 so legacy arithmetic
/// is untouched).
const TIERED_COMPUTE_BASE_S: f64 = 0.25;

/// Immutable per-client profile table for one federation.
///
/// Built once at `Simulation::new` and shared by the sampler (expected
/// round trips → sampling weights) and the round merge (per-client
/// simulated times). Assignment depends only on `(seed, cid)`, never
/// on execution order.
#[derive(Debug, Clone)]
pub struct ClientProfiles {
    profiles: Vec<ClientProfile>,
    /// Simulated compute seconds per round at multiplier 1.0.
    compute_base_s: f64,
}

impl ClientProfiles {
    /// Every client at [`ClientProfile::UNIT`], zero simulated compute
    /// — arithmetically identical to the pre-profile network model.
    pub fn uniform(num_clients: usize) -> ClientProfiles {
        ClientProfiles {
            profiles: vec![ClientProfile::UNIT; num_clients],
            compute_base_s: 0.0,
        }
    }

    /// Fast/mid/slow tiers round-robin by client id, each multiplier
    /// jittered ±10% by a stream derived purely from `(seed, cid)`.
    pub fn tiered(num_clients: usize, seed: u64) -> ClientProfiles {
        let profiles = (0..num_clients)
            .map(|cid| {
                let (up, down, compute) = TIERS[cid % TIERS.len()];
                let mut rng =
                    Rng::derive(seed ^ 0x70F1_1E5A, &[cid as u64]);
                let mut jitter =
                    |base: f64| base * rng.range_f64(0.9, 1.1);
                ClientProfile {
                    up_mult: jitter(up),
                    down_mult: jitter(down),
                    compute_mult: jitter(compute),
                }
            })
            .collect();
        ClientProfiles { profiles, compute_base_s: TIERED_COMPUTE_BASE_S }
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// Client `cid`'s profile (panics on an out-of-range id, which
    /// would mean the sampler and the table disagree on the federation
    /// size — a construction bug, not a runtime condition).
    pub fn get(&self, cid: usize) -> &ClientProfile {
        &self.profiles[cid]
    }

    /// Client `cid`'s simulated compute seconds for one round.
    pub fn compute_s(&self, cid: usize) -> f64 {
        self.compute_base_s * self.profiles[cid].compute_mult
    }

    /// Client `cid`'s simulated time for one full round trip: profiled
    /// download, plus (when the client uploads, `up_bytes > 0`) its
    /// compute and profiled upload. Dropped clients (`up_bytes == 0`)
    /// are charged the download only, matching the pre-profile model.
    pub fn client_time(
        &self,
        net: &NetworkModel,
        cid: usize,
        down_bytes: usize,
        up_bytes: usize,
    ) -> f64 {
        let (td, tc, tu) = self.stage_times(net, cid, down_bytes, up_bytes);
        td + (tc + tu)
    }

    /// Client `cid`'s round trip split into its three pipeline stages:
    /// `(download, compute, upload)` seconds. A dropped client
    /// (`up_bytes == 0`) has zero compute and upload stages — it never
    /// trained. Summing the stages as `td + (tc + tu)` reproduces
    /// [`ClientProfiles::client_time`] bit-for-bit; the split exists so
    /// the transport stage can model transfer/compute overlap
    /// ([`RoundLoad::add_stages`](crate::transport::RoundLoad::add_stages)).
    pub fn stage_times(
        &self,
        net: &NetworkModel,
        cid: usize,
        down_bytes: usize,
        up_bytes: usize,
    ) -> (f64, f64, f64) {
        let p = self.get(cid);
        let td = p.download_time(net, down_bytes);
        if up_bytes > 0 {
            (td, self.compute_s(cid), p.upload_time(net, up_bytes))
        } else {
            (td, 0.0, 0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_labels() {
        assert_eq!(ProfileKind::parse("uniform"), Some(ProfileKind::Uniform));
        assert_eq!(ProfileKind::parse("tiered"), Some(ProfileKind::Tiered));
        assert_eq!(ProfileKind::parse("fast"), None);
        assert_eq!(ProfileKind::Uniform.label(), "uniform");
        assert_eq!(ProfileKind::Tiered.label(), "tiered");
        assert_eq!(ProfileKind::default(), ProfileKind::Uniform);
    }

    #[test]
    fn uniform_table_matches_bare_network_model() {
        let net = NetworkModel::edge_lte();
        let table = ClientProfiles::uniform(8);
        for cid in 0..8 {
            // Bit-identical, not approximately equal: ×1.0 and +0.0
            // are exact, which is what keeps pre-profile runs stable.
            assert_eq!(
                table.client_time(&net, cid, 1_000_000, 500_000),
                net.round_trip(1_000_000, 500_000)
            );
            assert_eq!(
                table.client_time(&net, cid, 1_000_000, 0),
                net.download_time(1_000_000)
            );
            assert_eq!(table.compute_s(cid), 0.0);
        }
    }

    #[test]
    fn tiered_table_is_deterministic_in_seed_and_cid() {
        let a = ClientProfiles::tiered(12, 9);
        let b = ClientProfiles::tiered(12, 9);
        let c = ClientProfiles::tiered(12, 10);
        for cid in 0..12 {
            assert_eq!(a.get(cid), b.get(cid), "cid {cid}");
        }
        assert!((0..12).any(|cid| a.get(cid) != c.get(cid)),
                "different seeds never diverged");
        // Table size is independent of construction order: a prefix of
        // a larger federation matches exactly.
        let big = ClientProfiles::tiered(24, 9);
        for cid in 0..12 {
            assert_eq!(a.get(cid), big.get(cid), "cid {cid}");
        }
    }

    #[test]
    fn tiered_slow_class_is_slower_than_fast_class() {
        let net = NetworkModel::edge_lte();
        let table = ClientProfiles::tiered(12, 3);
        // cid % 3: 0 = fast, 1 = mid, 2 = slow; jitter is ±10%, far
        // smaller than the 10x class separation.
        let fast = table.client_time(&net, 0, 1_000_000, 1_000_000);
        let mid = table.client_time(&net, 1, 1_000_000, 1_000_000);
        let slow = table.client_time(&net, 2, 1_000_000, 1_000_000);
        assert!(fast < mid, "{fast} vs {mid}");
        assert!(mid < slow, "{mid} vs {slow}");
        assert!(slow > 3.0 * mid, "slow tier not separated: {slow} vs {mid}");
        assert!(table.compute_s(2) > table.compute_s(0));
    }

    #[test]
    fn stage_times_sum_to_client_time_bitwise() {
        let net = NetworkModel::edge_lte();
        let table = ClientProfiles::tiered(9, 5);
        for cid in 0..9 {
            for &(d, u) in &[(1_000_000usize, 500_000usize), (10_000, 0)] {
                let (td, tc, tu) = table.stage_times(&net, cid, d, u);
                assert_eq!(td + (tc + tu), table.client_time(&net, cid, d, u),
                           "cid {cid}");
                if u == 0 {
                    assert_eq!((tc, tu), (0.0, 0.0));
                } else {
                    assert!(tc > 0.0 && tu > 0.0);
                }
            }
        }
    }

    #[test]
    fn dropped_clients_pay_download_only() {
        let net = NetworkModel::wifi();
        let table = ClientProfiles::tiered(6, 1);
        let full = table.client_time(&net, 2, 10_000, 10_000);
        let dropped = table.client_time(&net, 2, 10_000, 0);
        assert!(dropped < full);
        let expect = net.download_time(10_000) * table.get(2).down_mult;
        assert!((dropped - expect).abs() < 1e-12);
    }
}
