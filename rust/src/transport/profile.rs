//! Per-client link and compute profiles — the heterogeneity axis the
//! symmetric [`NetworkModel`](crate::transport::NetworkModel) cannot
//! express.
//!
//! The base [`NetworkModel`] describes *one* link (LTE, WiFi); real
//! edge federations put every client behind its own multiple of that
//! link — the regime where straggler-aware sampling pays off. A
//! [`ClientProfile`] scales the base link's per-direction wire times
//! and the client's simulated compute; a [`ClientProfiles`] table maps
//! every client id to its profile, deterministically from the run seed
//! (so profiles are stable across rounds, executors and threads).
//!
//! Three table shapes ([`ProfileKind`], the `client_profiles` knob):
//!
//! * [`ProfileKind::Uniform`] — every client at exactly 1.0× with zero
//!   simulated compute: bit-identical to the pre-profile symmetric
//!   model (multiplying a time by `1.0` and adding `0.0` are exact in
//!   f64).
//! * [`ProfileKind::Tiered`] — clients split round-robin over
//!   fast/mid/slow device classes (the same `cid % 3` assignment the
//!   hetero-rank plan uses), each with a seeded ±10% per-client jitter
//!   so no two clients are perfectly identical.
//! * [`ProfileKind::File`] (`client_profiles = file:PATH`) — a pinned
//!   tier table loaded from a TOML-ish file: one
//!   `LO-HI = up, down, compute` line per client-id range (see
//!   [`ClientProfiles::parse_table`]). No jitter, no seed — configs
//!   own the exact numbers.
//!
//! The per-round compute baseline the multipliers scale is the
//! `compute_base_s` config knob (default
//! [`DEFAULT_COMPUTE_BASE_S`] = 0.25 s, the former hardcoded value, so
//! existing presets are bit-identical). Uniform tables keep zero
//! compute regardless — that is their bit-identity contract.

use std::path::Path;

use crate::error::{Error, Result};
use crate::transport::NetworkModel;
use crate::util::rng::Rng;

/// One client's deviation from the base link profile.
///
/// Multipliers scale *time*, so `2.0` means "half the rate / twice as
/// slow". `compute_mult` scales the table's per-round compute baseline
/// ([`ClientProfiles::compute_s`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientProfile {
    /// Uplink time multiplier (≥ 0; 1.0 = the base link).
    pub up_mult: f64,
    /// Downlink time multiplier.
    pub down_mult: f64,
    /// Simulated local-compute multiplier.
    pub compute_mult: f64,
}

impl ClientProfile {
    /// The neutral profile: the base link, no simulated compute skew.
    pub const UNIT: ClientProfile =
        ClientProfile { up_mult: 1.0, down_mult: 1.0, compute_mult: 1.0 };

    /// This client's time to pull `bytes` (base link scaled).
    pub fn download_time(&self, net: &NetworkModel, bytes: usize) -> f64 {
        net.download_time(bytes) * self.down_mult
    }

    /// This client's time to push `bytes` (base link scaled).
    pub fn upload_time(&self, net: &NetworkModel, bytes: usize) -> f64 {
        net.upload_time(bytes) * self.up_mult
    }
}

/// Profile-table selection, parseable from CLI/config strings (the
/// `client_profiles = uniform | tiered | file:PATH` knob).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum ProfileKind {
    /// Every client owns an identical base-rate link (pre-profile
    /// behaviour, bit-identical).
    #[default]
    Uniform,
    /// Fast/mid/slow device classes, round-robin by client id, with
    /// seeded per-client jitter.
    Tiered,
    /// A pinned tier table loaded from the given path (see
    /// [`ClientProfiles::parse_table`] for the format).
    File(String),
}

impl ProfileKind {
    /// Parse `uniform | tiered | file:PATH`.
    pub fn parse(s: &str) -> Option<ProfileKind> {
        match s {
            "uniform" => Some(ProfileKind::Uniform),
            "tiered" => Some(ProfileKind::Tiered),
            _ => s
                .strip_prefix("file:")
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(|p| ProfileKind::File(p.to_string())),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ProfileKind::Uniform => "uniform",
            ProfileKind::Tiered => "tiered",
            ProfileKind::File(_) => "file",
        }
    }

    /// Build the per-client table for a federation of `num_clients`,
    /// deterministically from `seed`; scaled tables price one round of
    /// client compute at `compute_base_s × compute_mult` seconds.
    /// Fails on an unreadable or malformed `file:` table.
    pub fn build(&self, num_clients: usize, seed: u64, compute_base_s: f64)
                 -> Result<ClientProfiles> {
        match self {
            ProfileKind::Uniform => Ok(ClientProfiles::uniform(num_clients)),
            ProfileKind::Tiered => Ok(ClientProfiles::tiered(
                num_clients, seed,
            )
            .with_compute_base(compute_base_s)),
            ProfileKind::File(path) => {
                ClientProfiles::from_file(path, num_clients, compute_base_s)
            }
        }
    }
}

/// The device classes of [`ClientProfiles::tiered`]:
/// `(up_mult, down_mult, compute_mult)` before jitter.
const TIERS: [(f64, f64, f64); 3] = [
    (0.8, 0.8, 0.6),  // fast: fiber-backed, recent silicon
    (1.0, 1.0, 1.0),  // mid: the base link
    (8.0, 8.0, 6.0),  // slow: congested uplink, old device
];

/// Default seconds of simulated client compute per round at
/// `compute_mult` 1.0 (the `compute_base_s` config knob's default;
/// uniform tables use 0.0 so legacy arithmetic is untouched).
pub const DEFAULT_COMPUTE_BASE_S: f64 = 0.25;

/// Immutable per-client profile table for one federation.
///
/// Built once at `Simulation::new` and shared by the sampler (expected
/// round trips → sampling weights) and the round merge (per-client
/// simulated times). Assignment depends only on `(seed, cid)`, never
/// on execution order.
#[derive(Debug, Clone)]
pub struct ClientProfiles {
    profiles: Vec<ClientProfile>,
    /// Simulated compute seconds per round at multiplier 1.0.
    compute_base_s: f64,
}

impl ClientProfiles {
    /// Every client at [`ClientProfile::UNIT`], zero simulated compute
    /// — arithmetically identical to the pre-profile network model.
    pub fn uniform(num_clients: usize) -> ClientProfiles {
        ClientProfiles {
            profiles: vec![ClientProfile::UNIT; num_clients],
            compute_base_s: 0.0,
        }
    }

    /// Fast/mid/slow tiers round-robin by client id, each multiplier
    /// jittered ±10% by a stream derived purely from `(seed, cid)`.
    pub fn tiered(num_clients: usize, seed: u64) -> ClientProfiles {
        let profiles = (0..num_clients)
            .map(|cid| {
                let (up, down, compute) = TIERS[cid % TIERS.len()];
                let mut rng =
                    Rng::derive(seed ^ 0x70F1_1E5A, &[cid as u64]);
                let mut jitter =
                    |base: f64| base * rng.range_f64(0.9, 1.1);
                ClientProfile {
                    up_mult: jitter(up),
                    down_mult: jitter(down),
                    compute_mult: jitter(compute),
                }
            })
            .collect();
        ClientProfiles { profiles, compute_base_s: DEFAULT_COMPUTE_BASE_S }
    }

    /// Same table, different per-round compute baseline (the
    /// `compute_base_s` knob; [`DEFAULT_COMPUTE_BASE_S`] keeps the
    /// table bit-identical to the pre-knob arithmetic).
    pub fn with_compute_base(mut self, compute_base_s: f64)
                             -> ClientProfiles {
        self.compute_base_s = compute_base_s;
        self
    }

    /// Load a pinned tier table from a file (`client_profiles =
    /// file:PATH`); see [`ClientProfiles::parse_table`].
    pub fn from_file(path: impl AsRef<Path>, num_clients: usize,
                     compute_base_s: f64) -> Result<ClientProfiles> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::parse(format!(
                "client_profiles file `{}`: {e}",
                path.display()
            ))
        })?;
        Self::parse_table(&text, num_clients, compute_base_s).map_err(|e| {
            Error::parse(format!(
                "client_profiles file `{}`: {e}",
                path.display()
            ))
        })
    }

    /// Parse a tier table: one `RANGE = up, down, compute` line per
    /// client-id range, where `RANGE` is `LO-HI` (inclusive) or a
    /// single `CID`, and the three values are the time multipliers.
    /// `#` comments, blank lines and `[section]` headers are ignored
    /// (same TOML-subset family as the config loader). Clients no line
    /// covers stay at [`ClientProfile::UNIT`]; later lines override
    /// earlier ones. Ranges beyond `num_clients - 1`, non-finite or
    /// negative multipliers, and malformed lines are errors.
    pub fn parse_table(text: &str, num_clients: usize,
                       compute_base_s: f64) -> Result<ClientProfiles> {
        let mut profiles = vec![ClientProfile::UNIT; num_clients];
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty()
                || (line.starts_with('[') && line.ends_with(']'))
            {
                continue;
            }
            let err = |msg: String| {
                Error::parse(format!("line {}: {msg}", lineno + 1))
            };
            let (range, values) = line.split_once('=').ok_or_else(|| {
                err("expected `LO-HI = up, down, compute`".into())
            })?;
            let range = range.trim();
            let (lo, hi) = match range.split_once('-') {
                Some((lo, hi)) => (
                    lo.trim().parse::<usize>(),
                    hi.trim().parse::<usize>(),
                ),
                None => {
                    let cid = range.parse::<usize>();
                    (cid.clone(), cid)
                }
            };
            let (lo, hi) = match (lo, hi) {
                (Ok(lo), Ok(hi)) => (lo, hi),
                _ => {
                    return Err(err(format!(
                        "bad client range `{range}`"
                    )))
                }
            };
            if lo > hi {
                return Err(err(format!(
                    "empty client range `{range}` (lo > hi)"
                )));
            }
            if hi >= num_clients {
                return Err(err(format!(
                    "client {hi} out of range for a {num_clients}-client \
                     federation"
                )));
            }
            let mults: Vec<f64> = values
                .split(',')
                .map(|v| v.trim().parse::<f64>())
                .collect::<std::result::Result<_, _>>()
                .map_err(|_| {
                    err(format!("bad multipliers `{}`", values.trim()))
                })?;
            let &[up, down, compute] = &mults[..] else {
                return Err(err(format!(
                    "expected 3 multipliers (up, down, compute), got {}",
                    mults.len()
                )));
            };
            for m in [up, down, compute] {
                if !m.is_finite() || m < 0.0 {
                    return Err(err(format!(
                        "multiplier {m} must be finite and >= 0"
                    )));
                }
            }
            for p in profiles.iter_mut().take(hi + 1).skip(lo) {
                *p = ClientProfile {
                    up_mult: up,
                    down_mult: down,
                    compute_mult: compute,
                };
            }
        }
        Ok(ClientProfiles { profiles, compute_base_s })
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// Client `cid`'s profile (panics on an out-of-range id, which
    /// would mean the sampler and the table disagree on the federation
    /// size — a construction bug, not a runtime condition).
    pub fn get(&self, cid: usize) -> &ClientProfile {
        &self.profiles[cid]
    }

    /// Client `cid`'s simulated compute seconds for one round.
    pub fn compute_s(&self, cid: usize) -> f64 {
        self.compute_base_s * self.profiles[cid].compute_mult
    }

    /// Client `cid`'s simulated time for one full round trip: profiled
    /// download, plus (when the client uploads, `up_bytes > 0`) its
    /// compute and profiled upload. Dropped clients (`up_bytes == 0`)
    /// are charged the download only, matching the pre-profile model.
    pub fn client_time(
        &self,
        net: &NetworkModel,
        cid: usize,
        down_bytes: usize,
        up_bytes: usize,
    ) -> f64 {
        let (td, tc, tu) = self.stage_times(net, cid, down_bytes, up_bytes);
        td + (tc + tu)
    }

    /// Client `cid`'s round trip split into its three pipeline stages:
    /// `(download, compute, upload)` seconds. A dropped client
    /// (`up_bytes == 0`) has zero compute and upload stages — it never
    /// trained. Summing the stages as `td + (tc + tu)` reproduces
    /// [`ClientProfiles::client_time`] bit-for-bit; the split exists so
    /// the transport stage can model transfer/compute overlap
    /// ([`RoundLoad::add_stages`](crate::transport::RoundLoad::add_stages)).
    pub fn stage_times(
        &self,
        net: &NetworkModel,
        cid: usize,
        down_bytes: usize,
        up_bytes: usize,
    ) -> (f64, f64, f64) {
        let p = self.get(cid);
        let td = p.download_time(net, down_bytes);
        if up_bytes > 0 {
            (td, self.compute_s(cid), p.upload_time(net, up_bytes))
        } else {
            (td, 0.0, 0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_labels() {
        assert_eq!(ProfileKind::parse("uniform"), Some(ProfileKind::Uniform));
        assert_eq!(ProfileKind::parse("tiered"), Some(ProfileKind::Tiered));
        assert_eq!(ProfileKind::parse("fast"), None);
        assert_eq!(
            ProfileKind::parse("file:profiles.toml"),
            Some(ProfileKind::File("profiles.toml".into()))
        );
        assert_eq!(ProfileKind::parse("file:"), None);
        assert_eq!(ProfileKind::Uniform.label(), "uniform");
        assert_eq!(ProfileKind::Tiered.label(), "tiered");
        assert_eq!(ProfileKind::File("x".into()).label(), "file");
        assert_eq!(ProfileKind::default(), ProfileKind::Uniform);
    }

    #[test]
    fn compute_base_knob_scales_tiered_compute() {
        let base = ClientProfiles::tiered(6, 9);
        let doubled =
            ClientProfiles::tiered(6, 9).with_compute_base(0.5);
        for cid in 0..6 {
            // Same multipliers, doubled baseline.
            assert_eq!(base.get(cid), doubled.get(cid));
            assert!(
                (doubled.compute_s(cid) - 2.0 * base.compute_s(cid)).abs()
                    < 1e-12,
                "cid {cid}"
            );
        }
        // The default baseline is the former hardcoded 0.25 — knob off
        // means bit-identical presets.
        let built = ProfileKind::Tiered
            .build(6, 9, DEFAULT_COMPUTE_BASE_S)
            .unwrap();
        for cid in 0..6 {
            assert_eq!(built.compute_s(cid), base.compute_s(cid));
        }
    }

    #[test]
    fn table_files_pin_exact_tiers() {
        let table = ClientProfiles::parse_table(
            "# custom fleet\n\
             [profiles]\n\
             0-3 = 0.8, 0.8, 0.6   # fiber\n\
             4 = 1.0, 1.0, 1.0\n\
             5-7 = 8.0, 6.0, 4.0\n\
             6 = 2.0, 2.0, 2.0     # later lines override\n",
            10,
            0.5,
        )
        .unwrap();
        assert_eq!(table.len(), 10);
        assert_eq!(
            *table.get(0),
            ClientProfile { up_mult: 0.8, down_mult: 0.8, compute_mult: 0.6 }
        );
        assert_eq!(*table.get(4), ClientProfile::UNIT);
        assert_eq!(table.get(5).up_mult, 8.0);
        assert_eq!(table.get(6).up_mult, 2.0, "override line lost");
        // Uncovered cids default to the unit profile.
        assert_eq!(*table.get(9), ClientProfile::UNIT);
        // compute_base_s flows through.
        assert!((table.compute_s(4) - 0.5).abs() < 1e-12);
        let net = NetworkModel::edge_lte();
        assert!(
            table.client_time(&net, 5, 1_000_000, 1_000_000)
                > table.client_time(&net, 0, 1_000_000, 1_000_000)
        );
    }

    #[test]
    fn malformed_table_files_error_with_line_numbers() {
        let cases = [
            ("0-3 = 0.8, 0.8", "expected 3 multipliers"),
            ("0-3 = a, b, c", "bad multipliers"),
            ("x-3 = 1, 1, 1", "bad client range"),
            ("3-1 = 1, 1, 1", "lo > hi"),
            ("0-12 = 1, 1, 1", "out of range"),
            ("0-2 = -1, 1, 1", "must be finite"),
            ("0-2 = inf, 1, 1", "must be finite"),
            ("just words", "expected `LO-HI"),
        ];
        for (line, needle) in cases {
            let text = format!("# header\n{line}\n");
            let err = ClientProfiles::parse_table(&text, 8, 0.25)
                .unwrap_err()
                .to_string();
            assert!(err.contains("line 2"), "{line}: {err}");
            assert!(err.contains(needle), "{line}: {err}");
        }
        // A missing file is a config error, not a panic.
        let err = ClientProfiles::from_file(
            "/nonexistent/profiles.toml", 8, 0.25,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("profiles.toml"), "{err}");
        // And ProfileKind::build surfaces it.
        assert!(ProfileKind::File("/nonexistent/p.toml".into())
            .build(8, 1, 0.25)
            .is_err());
    }

    #[test]
    fn overlapping_ranges_pin_later_wins() {
        // Overlap is legal and documented: later lines override earlier
        // ones for every cid they share, and only for those.
        let table = ClientProfiles::parse_table(
            "0-5 = 2.0, 2.0, 2.0\n\
             3-7 = 9.0, 9.0, 9.0\n\
             5 = 1.0, 1.0, 1.0\n",
            10,
            0.25,
        )
        .unwrap();
        for cid in 0..3 {
            assert_eq!(table.get(cid).up_mult, 2.0, "cid {cid}");
        }
        for cid in [3, 4, 6, 7] {
            assert_eq!(table.get(cid).up_mult, 9.0, "cid {cid}");
        }
        assert_eq!(*table.get(5), ClientProfile::UNIT);
        assert_eq!(*table.get(8), ClientProfile::UNIT);
    }

    #[test]
    fn empty_table_file_is_all_unit_profiles() {
        // An empty file (or one that is only comments/sections) is not
        // an error: every client stays at the unit profile and the
        // compute base still applies.
        for text in ["", "\n\n", "# nothing here\n[profiles]\n"] {
            let table =
                ClientProfiles::parse_table(text, 4, 0.75).unwrap();
            assert_eq!(table.len(), 4);
            for cid in 0..4 {
                assert_eq!(*table.get(cid), ClientProfile::UNIT,
                           "{text:?} cid {cid}");
                assert!((table.compute_s(cid) - 0.75).abs() < 1e-12);
            }
        }
        // Zero clients is degenerate but well-defined.
        assert_eq!(ClientProfiles::parse_table("", 0, 0.25).unwrap().len(),
                   0);
    }

    #[test]
    fn trailing_garbage_after_a_valid_line_is_an_error() {
        // Anything after the three multipliers that is not a `#`
        // comment must fail loudly, with the offending line number —
        // silent acceptance would hide typos like a forgotten comma.
        let cases = [
            ("0-2 = 1, 1, 1 extra", "bad multipliers"),
            ("0-2 = 1, 1, 1,", "bad multipliers"),
            ("0-2 = 1, 1, 1, 1", "expected 3 multipliers"),
            ("0-2 = 1, 1, 1 = 2", "bad multipliers"),
        ];
        for (line, needle) in cases {
            let text = format!("0-1 = 1, 1, 1\n{line}\n");
            let err = ClientProfiles::parse_table(&text, 8, 0.25)
                .unwrap_err()
                .to_string();
            assert!(err.contains("line 2"), "{line}: {err}");
            assert!(err.contains(needle), "{line}: {err}");
        }
        // The same garbage behind a comment marker is fine.
        let ok = ClientProfiles::parse_table(
            "0-2 = 1, 1, 1 # extra\n", 8, 0.25);
        assert!(ok.is_ok());
    }

    #[test]
    fn uniform_table_matches_bare_network_model() {
        let net = NetworkModel::edge_lte();
        let table = ClientProfiles::uniform(8);
        for cid in 0..8 {
            // Bit-identical, not approximately equal: ×1.0 and +0.0
            // are exact, which is what keeps pre-profile runs stable.
            assert_eq!(
                table.client_time(&net, cid, 1_000_000, 500_000),
                net.round_trip(1_000_000, 500_000)
            );
            assert_eq!(
                table.client_time(&net, cid, 1_000_000, 0),
                net.download_time(1_000_000)
            );
            assert_eq!(table.compute_s(cid), 0.0);
        }
    }

    #[test]
    fn tiered_table_is_deterministic_in_seed_and_cid() {
        let a = ClientProfiles::tiered(12, 9);
        let b = ClientProfiles::tiered(12, 9);
        let c = ClientProfiles::tiered(12, 10);
        for cid in 0..12 {
            assert_eq!(a.get(cid), b.get(cid), "cid {cid}");
        }
        assert!((0..12).any(|cid| a.get(cid) != c.get(cid)),
                "different seeds never diverged");
        // Table size is independent of construction order: a prefix of
        // a larger federation matches exactly.
        let big = ClientProfiles::tiered(24, 9);
        for cid in 0..12 {
            assert_eq!(a.get(cid), big.get(cid), "cid {cid}");
        }
    }

    #[test]
    fn tiered_slow_class_is_slower_than_fast_class() {
        let net = NetworkModel::edge_lte();
        let table = ClientProfiles::tiered(12, 3);
        // cid % 3: 0 = fast, 1 = mid, 2 = slow; jitter is ±10%, far
        // smaller than the 10x class separation.
        let fast = table.client_time(&net, 0, 1_000_000, 1_000_000);
        let mid = table.client_time(&net, 1, 1_000_000, 1_000_000);
        let slow = table.client_time(&net, 2, 1_000_000, 1_000_000);
        assert!(fast < mid, "{fast} vs {mid}");
        assert!(mid < slow, "{mid} vs {slow}");
        assert!(slow > 3.0 * mid, "slow tier not separated: {slow} vs {mid}");
        assert!(table.compute_s(2) > table.compute_s(0));
    }

    #[test]
    fn stage_times_sum_to_client_time_bitwise() {
        let net = NetworkModel::edge_lte();
        let table = ClientProfiles::tiered(9, 5);
        for cid in 0..9 {
            for &(d, u) in &[(1_000_000usize, 500_000usize), (10_000, 0)] {
                let (td, tc, tu) = table.stage_times(&net, cid, d, u);
                assert_eq!(td + (tc + tu), table.client_time(&net, cid, d, u),
                           "cid {cid}");
                if u == 0 {
                    assert_eq!((tc, tu), (0.0, 0.0));
                } else {
                    assert!(tc > 0.0 && tu > 0.0);
                }
            }
        }
    }

    #[test]
    fn dropped_clients_pay_download_only() {
        let net = NetworkModel::wifi();
        let table = ClientProfiles::tiered(6, 1);
        let full = table.client_time(&net, 2, 10_000, 10_000);
        let dropped = table.client_time(&net, 2, 10_000, 0);
        assert!(dropped < full);
        let expect = net.download_time(10_000) * table.get(2).down_mult;
        assert!((dropped - expect).abs() < 1e-12);
    }
}
