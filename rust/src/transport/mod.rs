//! Transport substrate: message framing, communication-cost accounting
//! (the paper's Eq. 2, generalised to measured bytes), a simple
//! bandwidth/latency network model for wall-clock estimates, the
//! transport stage that charges wire time from stage events so
//! transfer/compute overlap is modellable (`overlap = transfer`), and
//! the discrete-event simulator that replays those events at chunk
//! granularity (`time_model = event`). [`wire`] is the one module
//! here that moves *real* bytes: a TCP coordinator/client pair
//! byte-identical to the in-process simulator.

pub mod accounting;
pub mod network;
pub mod profile;
pub mod sim;
pub mod stage;
pub mod wire;

pub use accounting::{tcc_equation2, CommLedger, Direction};
pub use network::{NetworkKind, NetworkModel, RoundLoad, Sharing};
pub use profile::{ClientProfile, ClientProfiles, ProfileKind,
                  DEFAULT_COMPUTE_BASE_S};
pub use sim::{simulate_round, ClientLoad, ClosedTimeModel, EventTimeModel,
              SimParams, TimeEstimate, TimeModel, TimeModelKind};
pub use stage::{OverlapKind, RoundTransport, StageEvent, TransferStage};
pub use wire::{run_client_loop, serve_on, ClaimGrant, ClaimTable,
               ClientOpts, ClientReport, Frame, ServeOpts,
               WireFaultPolicy, MAX_FRAME_LEN, STATUS_ACK,
               STATUS_DROPPED, STATUS_FINISHED, WIRE_MAGIC,
               WIRE_VERSION};
