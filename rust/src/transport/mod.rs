//! Transport substrate: message framing, communication-cost accounting
//! (the paper's Eq. 2, generalised to measured bytes), and a simple
//! bandwidth/latency network model for wall-clock estimates.

pub mod accounting;
pub mod network;
pub mod profile;

pub use accounting::{tcc_equation2, CommLedger, Direction};
pub use network::{NetworkKind, NetworkModel, RoundLoad, Sharing};
pub use profile::{ClientProfile, ClientProfiles, ProfileKind};
