//! A simple bandwidth/latency network model.
//!
//! The paper reports communication *cost* (bytes), not wall-clock, but a
//! deployment-oriented framework should translate message sizes into
//! time-on-wire for capacity planning. This model is used by the
//! `examples/` drivers to report estimated round times on edge-like
//! links (e.g. LTE: 10 Mbit/s up, 30 Mbit/s down, 40 ms RTT), and by
//! [`crate::coordinator::Simulation`] to report a round's simulated
//! duration under serial vs concurrent clients.
//!
//! Two link-sharing regimes ([`Sharing`]):
//!
//! * [`Sharing::Dedicated`] — every client owns an independent link at
//!   the full profile rate; a concurrent round costs the slowest
//!   straggler (max of per-client round trips).
//! * [`Sharing::Shared`] — a round's in-flight clients contend for one
//!   uplink and one downlink pipe (the cell-tower / campus-AP regime):
//!   a concurrent round costs total-bits-over-capacity per direction,
//!   so adding clients stops being free.
//!
//! Three concurrency estimators, all computed from the same streamed
//! loads:
//!
//! * `round_time_serial` — clients one after another (sum).
//! * `round_time_parallel` — clients concurrent, but each client's
//!   download → compute → upload chain stays on its own critical path
//!   (transfer charged *inside* the client task — the pre-transport-
//!   stage engine).
//! * `round_time_pipelined` — the transport-stage regime: transfer is
//!   decoupled from the client task and streamed, so a client's wire
//!   time overlaps compute (its own chunked transfers and every other
//!   client's training). A round is then bounded by its slowest single
//!   *stage* and, under a shared pipe, by each direction's busy time —
//!   the ideal-overlap envelope the staged executor approaches.
//!
//! The per-round accumulation is streaming ([`RoundLoad`]): the merge
//! sink feeds each client's `(down, up)` bytes as it drains, nothing
//! is buffered per client.

/// How a round's concurrent clients share the physical link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Sharing {
    /// Independent per-client links at the full profile rate.
    #[default]
    Dedicated,
    /// One shared pipe per direction, split across in-flight clients.
    Shared,
}

impl Sharing {
    /// Parse `dedicated | shared`.
    pub fn parse(s: &str) -> Option<Sharing> {
        match s {
            "dedicated" => Some(Sharing::Dedicated),
            "shared" => Some(Sharing::Shared),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Sharing::Dedicated => "dedicated",
            Sharing::Shared => "shared",
        }
    }
}

/// Link-profile selection, parseable from CLI/config strings (the
/// `network = edge_lte | wifi` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetworkKind {
    EdgeLte,
    Wifi,
}

impl NetworkKind {
    /// Parse `edge_lte | wifi`.
    pub fn parse(s: &str) -> Option<NetworkKind> {
        match s {
            "edge_lte" | "lte" => Some(NetworkKind::EdgeLte),
            "wifi" => Some(NetworkKind::Wifi),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            NetworkKind::EdgeLte => "edge_lte",
            NetworkKind::Wifi => "wifi",
        }
    }

    pub fn build(&self) -> NetworkModel {
        match self {
            NetworkKind::EdgeLte => NetworkModel::edge_lte(),
            NetworkKind::Wifi => NetworkModel::wifi(),
        }
    }
}

/// Bandwidth/latency profile of one *base* link. Per-client deviations
/// (slow devices, congested uplinks) are layered on top by
/// [`crate::transport::ClientProfiles`], which scales these times per
/// client; a bare `NetworkModel` is the symmetric special case.
///
/// ```
/// use flocora::transport::NetworkModel;
///
/// let net = NetworkModel::edge_lte();
/// // Three clients, each pulling 1 MB down and pushing 1 MB up.
/// let loads = [(1_000_000, 1_000_000); 3];
/// let serial = net.round_time_serial(&loads);     // sum of round trips
/// let parallel = net.round_time_parallel(&loads); // slowest straggler
/// assert!((serial - 3.0 * parallel).abs() < 1e-9); // identical clients
/// assert!(parallel < serial);
///
/// // Under shared bandwidth, concurrent clients contend for the pipe.
/// let shared = net.with_sharing(flocora::transport::Sharing::Shared);
/// assert!(shared.round_time_parallel(&loads) > parallel);
///
/// // A transport stage that streams transfer off the client task
/// // (`overlap = transfer`) is bounded by the slowest single stage,
/// // not the download + upload chain.
/// assert!(net.round_time_pipelined(&loads) < parallel);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Uplink bits/second.
    pub up_bps: f64,
    /// Downlink bits/second.
    pub down_bps: f64,
    /// One-way latency, seconds.
    pub latency_s: f64,
    /// How concurrent clients share the link (default: dedicated).
    pub sharing: Sharing,
}

impl NetworkModel {
    /// LTE-ish edge uplink profile.
    pub fn edge_lte() -> NetworkModel {
        NetworkModel {
            up_bps: 10e6,
            down_bps: 30e6,
            latency_s: 0.02,
            sharing: Sharing::Dedicated,
        }
    }

    /// Campus WiFi profile.
    pub fn wifi() -> NetworkModel {
        NetworkModel {
            up_bps: 80e6,
            down_bps: 150e6,
            latency_s: 0.005,
            sharing: Sharing::Dedicated,
        }
    }

    /// Same profile under a different link-sharing regime.
    pub fn with_sharing(mut self, sharing: Sharing) -> NetworkModel {
        self.sharing = sharing;
        self
    }

    pub fn upload_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.up_bps
    }

    pub fn download_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.down_bps
    }

    /// Time for a full round trip of one client (download then upload;
    /// compute time is accounted separately by the caller).
    pub fn round_trip(&self, down_bytes: usize, up_bytes: usize) -> f64 {
        self.download_time(down_bytes) + self.upload_time(up_bytes)
    }

    /// Simulated duration of one round if clients use the link strictly
    /// one after another: the sum of per-client round trips. `loads` is
    /// one `(down_bytes, up_bytes)` pair per sampled client (`up_bytes
    /// == 0` for clients that dropped before uploading).
    pub fn round_time_serial(&self, loads: &[(usize, usize)]) -> f64 {
        self.accumulate(loads).serial_s()
    }

    /// Simulated duration of one round with every client in flight
    /// concurrently. Under [`Sharing::Dedicated`] the server waits for
    /// the slowest straggler (max, not sum) — the regime the parallel
    /// client executor models. Under [`Sharing::Shared`] the round
    /// costs total bits over pipe capacity per direction instead.
    pub fn round_time_parallel(&self, loads: &[(usize, usize)]) -> f64 {
        self.accumulate(loads).parallel_s(self)
    }

    /// Simulated duration of one round under the transport-stage
    /// overlap regime (`overlap = transfer`): wire transfer is
    /// decoupled from the client task, so a client's download/upload
    /// streams concurrently with compute instead of extending its
    /// critical path. The round is bounded by the slowest single stage
    /// of any waited-on client and — under [`Sharing::Shared`] — by
    /// each direction's pipe busy time (the two directions are full
    /// duplex, so they no longer add). Never exceeds
    /// [`NetworkModel::round_time_parallel`], and equals it when every
    /// client has a single non-zero stage.
    pub fn round_time_pipelined(&self, loads: &[(usize, usize)]) -> f64 {
        self.accumulate(loads).pipelined_s(self)
    }

    fn accumulate(&self, loads: &[(usize, usize)]) -> RoundLoad {
        let mut acc = RoundLoad::new();
        for &(down, up) in loads {
            acc.add(self, down, up);
        }
        acc
    }
}

/// Streaming accumulator for one round's network loads.
///
/// The round merge feeds each client's byte counts as its result
/// drains through the sink; nothing per-client is retained, matching
/// the engine's O(params + window) memory contract.
#[derive(Debug, Default, Clone, Copy)]
pub struct RoundLoad {
    serial_s: f64,
    slowest_s: f64,
    /// Slowest single *stage* (download, compute or upload) of any
    /// waited-on client — the dedicated-link bound of the pipelined
    /// regime, where a client's other stages hide behind its largest.
    slowest_stage_s: f64,
    /// Total simulated time-on-wire (downloads + uploads, cancelled
    /// downloads included): the wait the transport stage can overlap
    /// with compute.
    wire_s: f64,
    down_bytes: u64,
    up_bytes: u64,
    uploads: usize,
    clients: usize,
}

impl RoundLoad {
    pub fn new() -> RoundLoad {
        RoundLoad::default()
    }

    /// Fold in one client's `(down, up)` bytes (`up == 0` for a client
    /// that dropped before uploading) at the base link rate.
    pub fn add(&mut self, net: &NetworkModel, down_bytes: usize,
               up_bytes: usize) {
        let td = net.download_time(down_bytes);
        let tu = if up_bytes > 0 { net.upload_time(up_bytes) } else { 0.0 };
        self.add_stages(td, 0.0, tu, down_bytes, up_bytes);
    }

    /// Fold in one client whose simulated time `t` the caller already
    /// computed (e.g. through a per-client
    /// [`ClientProfiles`](crate::transport::ClientProfiles) table,
    /// which may fold compute and per-client link multipliers into
    /// `t`). `up_bytes == 0` still means "dropped before uploading".
    ///
    /// The stage split of `t` is unknown here, so the pipelined
    /// estimator treats the whole `t` as one unsplittable stage
    /// (nothing to overlap — conservative). Callers that know the
    /// split should use [`RoundLoad::add_stages`] instead.
    pub fn add_timed(&mut self, t: f64, down_bytes: usize,
                     up_bytes: usize) {
        self.serial_s += t;
        self.slowest_s = self.slowest_s.max(t);
        self.slowest_stage_s = self.slowest_stage_s.max(t);
        self.wire_s += t;
        self.down_bytes += down_bytes as u64;
        self.up_bytes += up_bytes as u64;
        if up_bytes > 0 {
            self.uploads += 1;
        }
        self.clients += 1;
    }

    /// Fold in one client's simulated round trip split into its three
    /// stages: download `td`, local compute `tc`, upload `tu` (all
    /// seconds; `tc == tu == 0.0` for a client that dropped before
    /// uploading). The serial/parallel estimators see the sum `td +
    /// (tc + tu)` — bit-identical to the pre-stage arithmetic — while
    /// the pipelined estimator keeps the per-stage maxima it needs to
    /// model transfer/compute overlap.
    pub fn add_stages(&mut self, td: f64, tc: f64, tu: f64,
                      down_bytes: usize, up_bytes: usize) {
        let t = td + (tc + tu);
        self.serial_s += t;
        self.slowest_s = self.slowest_s.max(t);
        self.slowest_stage_s = self.slowest_stage_s.max(td.max(tc).max(tu));
        self.wire_s += td + tu;
        self.down_bytes += down_bytes as u64;
        self.up_bytes += up_bytes as u64;
        if up_bytes > 0 {
            self.uploads += 1;
        }
        self.clients += 1;
    }

    /// Fold in a client the server *cancelled* mid-round (oversampled
    /// rounds end at the K-th accepted upload). Its download happened
    /// — the bytes and the serial-regime time `t_down` are charged —
    /// but the concurrent round never waits for it, so it is excluded
    /// from the straggler max (and, under `overlap = transfer`, from
    /// the pipelined stage max: the transport stage cuts it
    /// mid-transfer when the round completes).
    pub fn add_cancelled(&mut self, t_down: f64, down_bytes: usize) {
        self.serial_s += t_down;
        self.wire_s += t_down;
        self.down_bytes += down_bytes as u64;
        self.clients += 1;
    }

    /// Clients one after another: sum of round trips (sharing-agnostic
    /// — a lone client always owns the pipe).
    pub fn serial_s(&self) -> f64 {
        self.serial_s
    }

    /// All clients in flight concurrently, under `net`'s sharing
    /// regime: slowest straggler (dedicated) or total-bits-over-
    /// capacity per direction (shared). Under a shared pipe the round
    /// still cannot finish before its slowest *profiled* client: a
    /// client behind a personal 10× slowdown is rate-limited by its
    /// own link even when the shared pipe is idle, so the shared time
    /// is the max of pipe time and straggler time. (With uniform
    /// profiles the straggler never exceeds the pipe, so this is
    /// bit-identical to the pure pipe model.)
    pub fn parallel_s(&self, net: &NetworkModel) -> f64 {
        match net.sharing {
            Sharing::Dedicated => self.slowest_s,
            Sharing::Shared => {
                if self.clients == 0 {
                    return 0.0;
                }
                let (down, up) = self.pipe_times(net);
                (down + up).max(self.slowest_s)
            }
        }
    }

    /// The transport-stage overlap regime (`overlap = transfer`):
    /// transfer is streamed off the client task, so every stage that is
    /// not a client's single slowest hides behind compute — its own and
    /// other clients'. Under [`Sharing::Dedicated`] the round costs the
    /// slowest single stage of any waited-on client; under
    /// [`Sharing::Shared`] the two directions are full duplex, so the
    /// round additionally floors at each pipe's busy time but the pipes
    /// no longer add. Always `<=` [`RoundLoad::parallel_s`] (stage max
    /// `<=` stage sum, `max(down, up) <= down + up`), and equal to it
    /// when no client has two overlappable stages.
    pub fn pipelined_s(&self, net: &NetworkModel) -> f64 {
        match net.sharing {
            Sharing::Dedicated => self.slowest_stage_s,
            Sharing::Shared => {
                if self.clients == 0 {
                    return 0.0;
                }
                let (down, up) = self.pipe_times(net);
                down.max(up).max(self.slowest_stage_s)
            }
        }
    }

    /// Simulated time-on-wire across the round's clients (downloads
    /// plus uploads, cancelled downloads included; compute excluded) —
    /// the transfer wait a pipelined transport stage can overlap with
    /// compute. Where the stage split is unknown
    /// ([`RoundLoad::add_timed`]) the whole lump is counted.
    pub fn wire_s(&self) -> f64 {
        self.wire_s
    }

    /// Per-direction shared-pipe busy times (total bits over capacity,
    /// one latency each; zero uplink if nobody uploaded).
    fn pipe_times(&self, net: &NetworkModel) -> (f64, f64) {
        let down = net.latency_s
            + self.down_bytes as f64 * 8.0 / net.down_bps;
        let up = if self.uploads > 0 {
            net.latency_s + self.up_bytes as f64 * 8.0 / net.up_bps
        } else {
            0.0
        };
        (down, up)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_scale_with_bytes() {
        let net = NetworkModel::edge_lte();
        let t1 = net.upload_time(1_000_000);
        let t2 = net.upload_time(2_000_000);
        assert!(t2 > t1);
        // 1 MB at 10 Mbit/s = 0.8 s + latency.
        assert!((t1 - (0.02 + 0.8)).abs() < 1e-9);
    }

    #[test]
    fn parallel_round_is_max_and_serial_is_sum() {
        let net = NetworkModel::wifi();
        // Two stragglers of different sizes + one dropped client
        // (download only, no uplink latency charged).
        let loads = [(1_000_000, 2_000_000), (1_000_000, 500_000),
                     (1_000_000, 0)];
        let serial = net.round_time_serial(&loads);
        let parallel = net.round_time_parallel(&loads);
        let slowest = net.round_trip(1_000_000, 2_000_000);
        assert!((parallel - slowest).abs() < 1e-12, "{parallel} vs {slowest}");
        assert!(serial > parallel);
        let dropped = net.download_time(1_000_000);
        let survivor = net.round_trip(1_000_000, 500_000);
        assert!((serial - (slowest + survivor + dropped)).abs() < 1e-12);
    }

    #[test]
    fn empty_round_costs_nothing() {
        let net = NetworkModel::edge_lte();
        assert_eq!(net.round_time_serial(&[]), 0.0);
        assert_eq!(net.round_time_parallel(&[]), 0.0);
        let shared = net.with_sharing(Sharing::Shared);
        assert_eq!(shared.round_time_parallel(&[]), 0.0);
    }

    #[test]
    fn smaller_messages_help_asymmetric_links() {
        let net = NetworkModel::edge_lte();
        // FLoCoRA r=16 q8 message (0.7 MB) vs full ResNet-18 (44.7 MB).
        let flocora = net.round_trip(700_000, 700_000);
        let fedavg = net.round_trip(44_700_000, 44_700_000);
        assert!(fedavg / flocora > 30.0);
    }

    #[test]
    fn streaming_roundload_matches_batch_helpers() {
        let net = NetworkModel::edge_lte();
        let loads = [(5_000, 9_000), (5_000, 0), (5_000, 123_456)];
        let mut acc = RoundLoad::new();
        for &(d, u) in &loads {
            acc.add(&net, d, u);
        }
        assert_eq!(acc.serial_s(), net.round_time_serial(&loads));
        assert_eq!(acc.parallel_s(&net), net.round_time_parallel(&loads));
    }

    #[test]
    fn shared_pipe_charges_total_bits_per_direction() {
        let net = NetworkModel::edge_lte().with_sharing(Sharing::Shared);
        let loads = [(1_000_000, 1_000_000); 4];
        let t = net.round_time_parallel(&loads);
        // 4 MB down at 30 Mbit/s + 4 MB up at 10 Mbit/s + 2 latencies.
        let expect = (0.02 + 4_000_000.0 * 8.0 / 30e6)
            + (0.02 + 4_000_000.0 * 8.0 / 10e6);
        assert!((t - expect).abs() < 1e-9, "{t} vs {expect}");
        // Contention: strictly worse than the dedicated-link max, but
        // never worse than fully serial links (latency is pooled).
        let dedicated = NetworkModel::edge_lte().round_time_parallel(&loads);
        let serial = net.round_time_serial(&loads);
        assert!(t > dedicated);
        assert!(t < serial);
    }

    #[test]
    fn shared_pipe_never_beats_a_profiled_straggler() {
        let net = NetworkModel::edge_lte().with_sharing(Sharing::Shared);
        let mut acc = RoundLoad::new();
        acc.add(&net, 1_000_000, 1_000_000);
        // A client behind a personal 20x slowdown: its own link, not
        // the shared pipe, bounds the round.
        let t_slow = 20.0 * net.round_trip(1_000_000, 1_000_000);
        acc.add_timed(t_slow, 1_000_000, 1_000_000);
        assert_eq!(acc.parallel_s(&net), t_slow);
    }

    #[test]
    fn cancelled_clients_never_stretch_the_straggler_max() {
        let net = NetworkModel::edge_lte();
        let mut acc = RoundLoad::new();
        acc.add(&net, 1_000, 2_000);
        let base = acc.parallel_s(&net);
        // A cancelled straggler charges serial time and bytes but not
        // the concurrent max — the round ended without it.
        acc.add_cancelled(99.0, 50_000_000);
        assert_eq!(acc.parallel_s(&net), base);
        assert!(acc.serial_s() > 99.0);
        let shared = net.with_sharing(Sharing::Shared);
        // Its bytes still contend for a shared pipe, though.
        assert!(acc.parallel_s(&shared) > base);
    }

    #[test]
    fn pipelined_is_slowest_stage_on_dedicated_links() {
        let net = NetworkModel::edge_lte();
        let mut acc = RoundLoad::new();
        // download 0.1s, compute 0.5s, upload 0.3s: the parallel regime
        // charges the chain (0.9s), the pipelined regime the slowest
        // stage (compute, 0.5s).
        acc.add_stages(0.1, 0.5, 0.3, 1_000, 2_000);
        assert_eq!(acc.parallel_s(&net), 0.9);
        assert_eq!(acc.pipelined_s(&net), 0.5);
        assert_eq!(acc.wire_s(), 0.4);
        // A transfer-dominated client: its upload is the stage bound.
        acc.add_stages(0.2, 0.1, 0.6, 1_000, 2_000);
        assert_eq!(acc.pipelined_s(&net), 0.6);
        assert!(acc.pipelined_s(&net) < acc.parallel_s(&net));
        assert!(acc.parallel_s(&net) <= acc.serial_s());
    }

    #[test]
    fn pipelined_shared_pipes_are_full_duplex() {
        let net = NetworkModel::edge_lte().with_sharing(Sharing::Shared);
        let loads = [(1_000_000, 1_000_000); 4];
        let parallel = net.round_time_parallel(&loads);
        let pipelined = net.round_time_pipelined(&loads);
        // The parallel estimator adds the two pipe phases; the
        // transport stage overlaps them (full duplex), so the round is
        // bounded by the busier direction (the 10 Mbit/s uplink).
        let up = 0.02 + 4_000_000.0 * 8.0 / 10e6;
        assert!((pipelined - up).abs() < 1e-9, "{pipelined} vs {up}");
        assert!(pipelined < parallel);
    }

    #[test]
    fn pipelined_equals_parallel_for_single_stage_clients() {
        // Zero-transfer loads leave only the compute stage: nothing to
        // overlap, both estimators see the same max — bit-for-bit.
        let net = NetworkModel {
            up_bps: 10e6,
            down_bps: 30e6,
            latency_s: 0.0,
            sharing: Sharing::Dedicated,
        };
        let mut acc = RoundLoad::new();
        for tc in [0.25, 1.5, 0.6] {
            acc.add_stages(0.0, tc, 0.0, 0, 0);
        }
        assert_eq!(acc.pipelined_s(&net), acc.parallel_s(&net));
        assert_eq!(acc.pipelined_s(&net), 1.5);
        // Dropped clients (download only) are single-stage too.
        let loads = [(5_000, 0), (9_000, 0)];
        assert_eq!(
            net.round_time_pipelined(&loads),
            net.round_time_parallel(&loads)
        );
    }

    #[test]
    fn cancelled_clients_charge_wire_but_not_pipelined_max() {
        let net = NetworkModel::edge_lte();
        let mut acc = RoundLoad::new();
        acc.add_stages(0.1, 0.2, 0.1, 1_000, 1_000);
        let base = acc.pipelined_s(&net);
        acc.add_cancelled(99.0, 50_000_000);
        // Cut mid-transfer: serial and wire time grow, the pipelined
        // round does not wait.
        assert_eq!(acc.pipelined_s(&net), base);
        assert!(acc.wire_s() > 99.0);
    }

    #[test]
    fn kind_and_sharing_parse() {
        assert_eq!(NetworkKind::parse("edge_lte"), Some(NetworkKind::EdgeLte));
        assert_eq!(NetworkKind::parse("lte"), Some(NetworkKind::EdgeLte));
        assert_eq!(NetworkKind::parse("wifi"), Some(NetworkKind::Wifi));
        assert_eq!(NetworkKind::parse("5g"), None);
        assert_eq!(NetworkKind::EdgeLte.label(), "edge_lte");
        assert!(NetworkKind::Wifi.build().up_bps > 10e6);
        assert_eq!(Sharing::parse("dedicated"), Some(Sharing::Dedicated));
        assert_eq!(Sharing::parse("shared"), Some(Sharing::Shared));
        assert_eq!(Sharing::parse("split"), None);
        assert_eq!(Sharing::default(), Sharing::Dedicated);
    }
}
