//! A simple bandwidth/latency network model.
//!
//! The paper reports communication *cost* (bytes), not wall-clock, but a
//! deployment-oriented framework should translate message sizes into
//! time-on-wire for capacity planning. This model is used by the
//! `examples/` drivers to report estimated round times on edge-like
//! links (e.g. LTE: 10 Mbit/s up, 30 Mbit/s down, 40 ms RTT), and by
//! [`crate::coordinator::Simulation`] to report a round's simulated
//! duration under serial vs concurrent clients.

/// Bandwidth/latency profile of one (symmetric across clients) link.
///
/// ```
/// use flocora::transport::NetworkModel;
///
/// let net = NetworkModel::edge_lte();
/// // Three clients, each pulling 1 MB down and pushing 1 MB up.
/// let loads = [(1_000_000, 1_000_000); 3];
/// let serial = net.round_time_serial(&loads);     // sum of round trips
/// let parallel = net.round_time_parallel(&loads); // slowest straggler
/// assert!((serial - 3.0 * parallel).abs() < 1e-9); // identical clients
/// assert!(parallel < serial);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Uplink bits/second.
    pub up_bps: f64,
    /// Downlink bits/second.
    pub down_bps: f64,
    /// One-way latency, seconds.
    pub latency_s: f64,
}

impl NetworkModel {
    /// LTE-ish edge uplink profile.
    pub fn edge_lte() -> NetworkModel {
        NetworkModel { up_bps: 10e6, down_bps: 30e6, latency_s: 0.02 }
    }

    /// Campus WiFi profile.
    pub fn wifi() -> NetworkModel {
        NetworkModel { up_bps: 80e6, down_bps: 150e6, latency_s: 0.005 }
    }

    pub fn upload_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.up_bps
    }

    pub fn download_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.down_bps
    }

    /// Time for a full round trip of one client (download then upload;
    /// compute time is accounted separately by the caller).
    pub fn round_trip(&self, down_bytes: usize, up_bytes: usize) -> f64 {
        self.download_time(down_bytes) + self.upload_time(up_bytes)
    }

    /// One client's time on the wire. `up_bytes == 0` means the client
    /// never uploaded (it dropped mid-round), so no uplink latency is
    /// charged.
    fn client_time(&self, down_bytes: usize, up_bytes: usize) -> f64 {
        let down = self.download_time(down_bytes);
        if up_bytes > 0 {
            down + self.upload_time(up_bytes)
        } else {
            down
        }
    }

    /// Simulated duration of one round if clients use the link strictly
    /// one after another: the sum of per-client round trips. `loads` is
    /// one `(down_bytes, up_bytes)` pair per sampled client (`up_bytes
    /// == 0` for clients that dropped before uploading).
    pub fn round_time_serial(&self, loads: &[(usize, usize)]) -> f64 {
        loads.iter().map(|&(d, u)| self.client_time(d, u)).sum()
    }

    /// Simulated duration of one round with every client in flight
    /// concurrently: the server waits for the slowest straggler, so the
    /// round costs the *max* per-client time, not the sum. This is the
    /// regime the parallel client executor models.
    pub fn round_time_parallel(&self, loads: &[(usize, usize)]) -> f64 {
        loads
            .iter()
            .map(|&(d, u)| self.client_time(d, u))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_scale_with_bytes() {
        let net = NetworkModel::edge_lte();
        let t1 = net.upload_time(1_000_000);
        let t2 = net.upload_time(2_000_000);
        assert!(t2 > t1);
        // 1 MB at 10 Mbit/s = 0.8 s + latency.
        assert!((t1 - (0.02 + 0.8)).abs() < 1e-9);
    }

    #[test]
    fn parallel_round_is_max_and_serial_is_sum() {
        let net = NetworkModel::wifi();
        // Two stragglers of different sizes + one dropped client
        // (download only, no uplink latency charged).
        let loads = [(1_000_000, 2_000_000), (1_000_000, 500_000),
                     (1_000_000, 0)];
        let serial = net.round_time_serial(&loads);
        let parallel = net.round_time_parallel(&loads);
        let slowest = net.round_trip(1_000_000, 2_000_000);
        assert!((parallel - slowest).abs() < 1e-12, "{parallel} vs {slowest}");
        assert!(serial > parallel);
        let dropped = net.download_time(1_000_000);
        let survivor = net.round_trip(1_000_000, 500_000);
        assert!((serial - (slowest + survivor + dropped)).abs() < 1e-12);
    }

    #[test]
    fn empty_round_costs_nothing() {
        let net = NetworkModel::edge_lte();
        assert_eq!(net.round_time_serial(&[]), 0.0);
        assert_eq!(net.round_time_parallel(&[]), 0.0);
    }

    #[test]
    fn smaller_messages_help_asymmetric_links() {
        let net = NetworkModel::edge_lte();
        // FLoCoRA r=16 q8 message (0.7 MB) vs full ResNet-18 (44.7 MB).
        let flocora = net.round_trip(700_000, 700_000);
        let fedavg = net.round_trip(44_700_000, 44_700_000);
        assert!(fedavg / flocora > 30.0);
    }
}
