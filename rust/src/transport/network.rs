//! A simple bandwidth/latency network model.
//!
//! The paper reports communication *cost* (bytes), not wall-clock, but a
//! deployment-oriented framework should translate message sizes into
//! time-on-wire for capacity planning. This model is used by the
//! `examples/` drivers to report estimated round times on edge-like
//! links (e.g. LTE: 10 Mbit/s up, 30 Mbit/s down, 40 ms RTT).

#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Uplink bits/second.
    pub up_bps: f64,
    /// Downlink bits/second.
    pub down_bps: f64,
    /// One-way latency, seconds.
    pub latency_s: f64,
}

impl NetworkModel {
    /// LTE-ish edge uplink profile.
    pub fn edge_lte() -> NetworkModel {
        NetworkModel { up_bps: 10e6, down_bps: 30e6, latency_s: 0.02 }
    }

    /// Campus WiFi profile.
    pub fn wifi() -> NetworkModel {
        NetworkModel { up_bps: 80e6, down_bps: 150e6, latency_s: 0.005 }
    }

    pub fn upload_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.up_bps
    }

    pub fn download_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.down_bps
    }

    /// Time for a full round trip of one client (download then upload;
    /// compute time is accounted separately by the caller).
    pub fn round_trip(&self, down_bytes: usize, up_bytes: usize) -> f64 {
        self.download_time(down_bytes) + self.upload_time(up_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_scale_with_bytes() {
        let net = NetworkModel::edge_lte();
        let t1 = net.upload_time(1_000_000);
        let t2 = net.upload_time(2_000_000);
        assert!(t2 > t1);
        // 1 MB at 10 Mbit/s = 0.8 s + latency.
        assert!((t1 - (0.02 + 0.8)).abs() < 1e-9);
    }

    #[test]
    fn smaller_messages_help_asymmetric_links() {
        let net = NetworkModel::edge_lte();
        // FLoCoRA r=16 q8 message (0.7 MB) vs full ResNet-18 (44.7 MB).
        let flocora = net.round_trip(700_000, 700_000);
        let fedavg = net.round_trip(44_700_000, 44_700_000);
        assert!(fedavg / flocora > 30.0);
    }
}
