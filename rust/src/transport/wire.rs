//! Real networked wire mode: a TCP coordinator and client speaking a
//! versioned, length-prefixed frame protocol — byte-identical to the
//! in-process simulator.
//!
//! **Why this exists.** Everything else in `transport/` *simulates*
//! wire time; this module actually moves the bytes. [`serve_on`] runs
//! the full [`Simulation`] round loop, but instead of executing the
//! sampled clients itself it *announces* each
//! [`RoundPlan`] over TCP, serves the codec-encoded
//! broadcast as a download, gathers the encoded uploads into a
//! [`ClaimTable`], and feeds them through
//! [`Simulation::merge_round`] via a replay executor — so remote
//! results flow through the exact shard merge, ledger, transport-stage
//! and aggregator code an in-process run uses. [`run_client_loop`]
//! is the other half: it rebuilds the federation from the served
//! config blob and runs the *same*
//! [`run_client`] stage composition the executors run.
//!
//! **Byte-identity argument.** A run's bits are a function of (a) the
//! coordinator's round decisions (sampler stream, encoded broadcast,
//! planned cancellations, lr schedule) and (b) each client's result
//! (all randomness from `Rng::for_client(seed, round, cid)`), folded
//! in sampling order. (a) lives in [`Simulation::plan_round`], shared
//! verbatim; (b) is `run_client`, shared verbatim, with the encoded
//! upload bytes crossing the socket untouched and `f64` stats moved
//! bit-exactly (`to_bits`/`from_bits`). The replay executor delivers
//! results in slot order, so the merge cannot tell a socket from a
//! thread. The loopback tests (`tests/wire.rs`) and the CI
//! `wire-smoke` job pin this: wall-stripped JSON from a wire run
//! diffs empty against `Simulation::run`.
//!
//! **Frame grammar.** Every frame is an 8-byte header — magic
//! `F1 0C`, version, type, `u32` little-endian body length (capped at
//! [`MAX_FRAME_LEN`] *before* any allocation) — followed by the body.
//! Integers are little-endian, floats cross as IEEE-754 bits, strings
//! are `u32`-length-prefixed UTF-8, and the final `payload`/text field
//! of a frame is the body remainder. The conversation is strict
//! lockstep: every client frame gets exactly one server reply.
//!
//! **Robustness.** Claims carry a lease: a client that stops
//! heartbeating (or whose connection drops) is settled as a dropout —
//! mapping onto the same `StageEvent::Dropped` accounting the
//! simulator's failure injection uses, so a killed wire client is
//! bit-identical to a `drop_plan` entry. A round that outlives
//! `round_timeout_ms` either force-drops the stragglers
//! ([`WireFaultPolicy::Drop`]) or aborts the run
//! ([`WireFaultPolicy::Abort`]). All server concurrency routes
//! through `crate::sync`, so the claim-table handshake stays inside
//! the loom-checkable surface.

// Wall-clock (`Instant`) is deliberately real in this file — remote
// clients crash in wall-clock time, not simulated time — so it sits on
// the determinism lint's wall-clock exempt list (`cargo xtask
// lint-determinism`). Nothing here feeds a simulated quantity, and the
// exported records are wall-stripped before any bit-identity diff.
use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::compression::Message;
use crate::config::{loader, FlConfig};
use crate::coordinator::executor::{run_client, ClientExecutor, ClientResult,
                                   ClientUpdate, Downloads, RoundContext,
                                   UpdateVector};
use crate::coordinator::sink::RoundSink;
use crate::coordinator::trainer::LocalTrainer;
use crate::coordinator::{RoundPlan, RunSummary, Simulation};
use crate::data::lda_partition;
use crate::error::{Error, Result};
use crate::metrics::Recorder;
use crate::runtime::Engine;
use crate::sync::thread;
use crate::sync::{Arc, Condvar, Mutex, MutexGuard};

/// First two header bytes of every frame.
pub const WIRE_MAGIC: [u8; 2] = [0xF1, 0x0C];
/// Protocol version this build speaks (header byte 3).
pub const WIRE_VERSION: u8 = 1;
/// Fixed frame header size: magic, version, type, `u32` body length.
pub const HEADER_LEN: usize = 8;
/// Hard cap on a frame body, checked against the length prefix
/// *before* any allocation — a hostile or corrupt peer cannot make
/// the receiver reserve gigabytes.
pub const MAX_FRAME_LEN: usize = 256 << 20;

/// `Complete` status: the server accepted the client's frame.
pub const STATUS_ACK: u8 = 0;
/// `Complete` status from a client: it failed before uploading.
pub const STATUS_DROPPED: u8 = 1;
/// `Complete` status from the server: the run is over, disconnect.
pub const STATUS_FINISHED: u8 = 2;

/// One protocol frame. Client→server: `Hello` (empty), `Register`,
/// `Claim`, `Download` (empty payload = request), `Upload`,
/// `Complete(DROPPED)`, `Heartbeat`. Server→client: `Hello` (config
/// blob), `Register` (echo), `Plan`, `Download` (broadcast bytes),
/// `Complete(ACK|FINISHED)`, `Heartbeat` (echo), `Abort`.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Handshake; the server's reply carries the full config blob
    /// ([`FlConfig::to_blob`]) the client rebuilds its federation from.
    Hello { config: String },
    /// The inclusive client-id range this connection hosts.
    Register { lo: u64, hi: u64 },
    /// Ask for this client's slot in a round.
    Claim { round: u64, cid: u64 },
    /// The server's claim verdict: sampled (and, if so, pre-cancelled).
    Plan { round: u64, cid: u64, sampled: bool, cancelled: bool },
    /// Broadcast download; the request form has empty codec/payload.
    Download { round: u64, cid: u64, codec: String, payload: Vec<u8> },
    /// An encoded client update plus its FedAvg stats.
    Upload {
        round: u64,
        cid: u64,
        weight: f64,
        mean_loss: f64,
        mean_acc: f64,
        codec: String,
        payload: Vec<u8>,
    },
    /// Round closure for one client (see the `STATUS_*` constants).
    Complete { round: u64, cid: u64, status: u8 },
    /// Lease keep-alive; the server echoes it.
    Heartbeat { round: u64, cid: u64 },
    /// Fatal: the sender is giving up on this conversation.
    Abort { reason: String },
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked reader over one frame body. Every accessor returns
/// a typed [`Error::Parse`] instead of panicking, so arbitrary bytes
/// are safe to decode (the fuzz tests in `tests/wire.rs` lean on it).
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let have = self.b.len() - self.pos;
        if have < n {
            return Err(Error::parse(format!(
                "wire frame truncated: need {n} byte(s) at offset {}, \
                 have {have}",
                self.pos
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(Error::parse(format!(
                "wire bool must be 0 or 1, got {v}"
            ))),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str_prefixed(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::parse("wire string is not UTF-8"))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.b[self.pos..];
        self.pos = self.b.len();
        s
    }

    fn rest_str(&mut self) -> Result<String> {
        String::from_utf8(self.rest().to_vec())
            .map_err(|_| Error::parse("wire string is not UTF-8"))
    }

    fn finish(self, frame: Frame) -> Result<Frame> {
        if self.pos != self.b.len() {
            return Err(Error::parse(format!(
                "wire frame has {} trailing byte(s) after its {} body",
                self.b.len() - self.pos,
                frame.kind()
            )));
        }
        Ok(frame)
    }
}

/// Validate a frame header; returns `(type, body_len)`. The length cap
/// is enforced here, before the caller allocates anything.
fn check_header(h: &[u8; HEADER_LEN]) -> Result<(u8, usize)> {
    if h[0] != WIRE_MAGIC[0] || h[1] != WIRE_MAGIC[1] {
        return Err(Error::parse(format!(
            "bad wire magic {:02x} {:02x} (want {:02x} {:02x})",
            h[0], h[1], WIRE_MAGIC[0], WIRE_MAGIC[1]
        )));
    }
    if h[2] != WIRE_VERSION {
        return Err(Error::parse(format!(
            "wire protocol version {} (this build speaks {WIRE_VERSION})",
            h[2]
        )));
    }
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::invalid(format!(
            "wire frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap"
        )));
    }
    Ok((h[3], len))
}

fn decode_body(typ: u8, body: &[u8]) -> Result<Frame> {
    let mut c = Cursor { b: body, pos: 0 };
    let frame = match typ {
        1 => Frame::Hello { config: c.rest_str()? },
        2 => Frame::Register { lo: c.u64()?, hi: c.u64()? },
        3 => Frame::Claim { round: c.u64()?, cid: c.u64()? },
        4 => Frame::Plan {
            round: c.u64()?,
            cid: c.u64()?,
            sampled: c.bool()?,
            cancelled: c.bool()?,
        },
        5 => Frame::Download {
            round: c.u64()?,
            cid: c.u64()?,
            codec: c.str_prefixed()?,
            payload: c.rest().to_vec(),
        },
        6 => Frame::Upload {
            round: c.u64()?,
            cid: c.u64()?,
            weight: c.f64()?,
            mean_loss: c.f64()?,
            mean_acc: c.f64()?,
            codec: c.str_prefixed()?,
            payload: c.rest().to_vec(),
        },
        7 => Frame::Complete {
            round: c.u64()?,
            cid: c.u64()?,
            status: c.u8()?,
        },
        8 => Frame::Heartbeat { round: c.u64()?, cid: c.u64()? },
        9 => Frame::Abort { reason: c.rest_str()? },
        t => {
            return Err(Error::parse(format!(
                "unknown wire frame type {t}"
            )))
        }
    };
    c.finish(frame)
}

impl Frame {
    /// Short name for errors and logs (never the payload itself).
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::Register { .. } => "register",
            Frame::Claim { .. } => "claim",
            Frame::Plan { .. } => "plan",
            Frame::Download { .. } => "download",
            Frame::Upload { .. } => "upload",
            Frame::Complete { .. } => "complete",
            Frame::Heartbeat { .. } => "heartbeat",
            Frame::Abort { .. } => "abort",
        }
    }

    fn type_id(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 1,
            Frame::Register { .. } => 2,
            Frame::Claim { .. } => 3,
            Frame::Plan { .. } => 4,
            Frame::Download { .. } => 5,
            Frame::Upload { .. } => 6,
            Frame::Complete { .. } => 7,
            Frame::Heartbeat { .. } => 8,
            Frame::Abort { .. } => 9,
        }
    }

    /// Serialize to header + body bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            Frame::Hello { config } => {
                body.extend_from_slice(config.as_bytes());
            }
            Frame::Register { lo, hi } => {
                put_u64(&mut body, *lo);
                put_u64(&mut body, *hi);
            }
            Frame::Claim { round, cid }
            | Frame::Heartbeat { round, cid } => {
                put_u64(&mut body, *round);
                put_u64(&mut body, *cid);
            }
            Frame::Plan { round, cid, sampled, cancelled } => {
                put_u64(&mut body, *round);
                put_u64(&mut body, *cid);
                body.push(u8::from(*sampled));
                body.push(u8::from(*cancelled));
            }
            Frame::Download { round, cid, codec, payload } => {
                put_u64(&mut body, *round);
                put_u64(&mut body, *cid);
                put_str(&mut body, codec);
                body.extend_from_slice(payload);
            }
            Frame::Upload {
                round,
                cid,
                weight,
                mean_loss,
                mean_acc,
                codec,
                payload,
            } => {
                put_u64(&mut body, *round);
                put_u64(&mut body, *cid);
                put_f64(&mut body, *weight);
                put_f64(&mut body, *mean_loss);
                put_f64(&mut body, *mean_acc);
                put_str(&mut body, codec);
                body.extend_from_slice(payload);
            }
            Frame::Complete { round, cid, status } => {
                put_u64(&mut body, *round);
                put_u64(&mut body, *cid);
                body.push(*status);
            }
            Frame::Abort { reason } => {
                body.extend_from_slice(reason.as_bytes());
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + body.len());
        out.push(WIRE_MAGIC[0]);
        out.push(WIRE_MAGIC[1]);
        out.push(WIRE_VERSION);
        out.push(self.type_id());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Decode one complete frame from a byte slice (header included).
    /// Never panics on arbitrary input: truncation, bad magic/version,
    /// an oversized length prefix, an unknown type, trailing bytes and
    /// malformed strings all come back as typed errors.
    pub fn decode(bytes: &[u8]) -> Result<Frame> {
        if bytes.len() < HEADER_LEN {
            return Err(Error::parse(format!(
                "wire frame shorter than its {HEADER_LEN}-byte header"
            )));
        }
        let mut header = [0u8; HEADER_LEN];
        header.copy_from_slice(&bytes[..HEADER_LEN]);
        let (typ, len) = check_header(&header)?;
        let body = &bytes[HEADER_LEN..];
        if body.len() != len {
            return Err(Error::parse(format!(
                "wire frame length prefix says {len} byte(s), found {}",
                body.len()
            )));
        }
        decode_body(typ, body)
    }
}

/// Serialize and flush one frame.
fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    w.write_all(&frame.encode())?;
    w.flush()?;
    Ok(())
}

/// Fill `buf`, surviving read timeouts: the server's handler sockets
/// poll with a short timeout so they can observe shutdown, and a
/// timeout mid-frame must *keep* the partial bytes and continue (a
/// plain `read_exact` would corrupt the stream framing). Returns
/// `Ok(false)` on a clean EOF (or shutdown) at a frame boundary;
/// mid-frame EOF is a typed `UnexpectedEof`.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    shared: Option<&Shared>,
) -> Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(Error::Io(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                )));
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut
                ) =>
            {
                if let Some(shared) = shared {
                    if lock(&shared.state).shutdown {
                        if got == 0 {
                            return Ok(false);
                        }
                        return Err(Error::Io(std::io::Error::new(
                            ErrorKind::TimedOut,
                            "server shutting down mid-frame",
                        )));
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(true)
}

/// Read one frame off the stream; `Ok(None)` means the peer hung up
/// (or the server is shutting down) at a frame boundary.
fn read_frame_poll(
    stream: &mut TcpStream,
    shared: Option<&Shared>,
) -> Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    if !read_full(stream, &mut header, shared)? {
        return Ok(None);
    }
    let (typ, len) = check_header(&header)?;
    let mut body = vec![0u8; len];
    if !read_full(stream, &mut body, shared)? {
        return Err(Error::Io(std::io::Error::new(
            ErrorKind::UnexpectedEof,
            "connection closed mid-frame",
        )));
    }
    decode_body(typ, &body).map(Some)
}

/// What to do when a round outlives `round_timeout_ms`
/// (`wire_on_timeout` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFaultPolicy {
    /// Force-drop every unsettled slot and complete the round — the
    /// networked analogue of the simulator's failure injection.
    #[default]
    Drop,
    /// Abort the whole run with an error.
    Abort,
}

impl WireFaultPolicy {
    /// Parse `drop | abort`.
    pub fn parse(s: &str) -> Option<WireFaultPolicy> {
        match s {
            "drop" => Some(WireFaultPolicy::Drop),
            "abort" => Some(WireFaultPolicy::Abort),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            WireFaultPolicy::Drop => "drop",
            WireFaultPolicy::Abort => "abort",
        }
    }
}

/// One slot of a gathering round.
#[derive(Debug)]
enum Slot {
    /// Sampled, nobody has claimed it yet.
    Open,
    /// A connection owns it until the lease deadline.
    Claimed { lease_deadline_ms: u64 },
    /// The result is in (upload, drop, or pre-planned cancellation).
    Settled(ClientResult),
}

/// Outcome of a [`ClaimTable::claim`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClaimGrant {
    /// The slot is yours; train and upload (or report a drop).
    Granted,
    /// This client is not in the round's sample.
    NotSampled,
    /// Sampled but pre-cancelled by the coordinator (oversampling cut)
    /// — nothing to do, the server already accounted the download.
    Cancelled,
    /// The slot is already claimed or settled — a protocol violation.
    Conflict,
}

/// The gathering state of one announced round: one slot per sampled
/// client, in sampling order. Pure data plus injected `now_ms`
/// timestamps — no clock, no socket — so its lease/expiry state
/// machine unit-tests deterministically (`tests/wire.rs`).
///
/// Cancelled slots are pre-settled at construction with the same
/// `ClientResult` shape the in-process executors produce (download
/// charged, no update, `cancelled: true`), because a pre-cancelled
/// wire client never downloads — the coordinator accounts it.
#[derive(Debug)]
pub struct ClaimTable {
    round: usize,
    /// Sampled ids, sorted ascending (the sampler contract) — slot
    /// order is sampling order, which is the merge's fold order.
    ids: Vec<usize>,
    slots: Vec<Slot>,
    /// Broadcast size every slot charges as its download.
    down_bytes: usize,
    lease_ms: u64,
}

impl ClaimTable {
    pub fn new(
        round: usize,
        client_ids: &[usize],
        cancelled_ids: &[usize],
        down_bytes: usize,
        lease_ms: u64,
    ) -> ClaimTable {
        let slots = client_ids
            .iter()
            .map(|&cid| {
                if cancelled_ids.binary_search(&cid).is_ok() {
                    Slot::Settled(ClientResult {
                        cid,
                        down_bytes,
                        update: None,
                        cancelled: true,
                    })
                } else {
                    Slot::Open
                }
            })
            .collect();
        ClaimTable {
            round,
            ids: client_ids.to_vec(),
            slots,
            down_bytes,
            lease_ms,
        }
    }

    pub fn round(&self) -> usize {
        self.round
    }

    pub fn down_bytes(&self) -> usize {
        self.down_bytes
    }

    fn idx(&self, cid: usize) -> Option<usize> {
        self.ids.binary_search(&cid).ok()
    }

    fn dropped_result(cid: usize, down_bytes: usize) -> ClientResult {
        ClientResult { cid, down_bytes, update: None, cancelled: false }
    }

    /// Try to claim `cid`'s slot, leasing it until
    /// `now_ms + lease_ms`.
    pub fn claim(&mut self, cid: usize, now_ms: u64) -> ClaimGrant {
        let Some(i) = self.idx(cid) else {
            return ClaimGrant::NotSampled;
        };
        match &self.slots[i] {
            Slot::Open => {
                self.slots[i] = Slot::Claimed {
                    lease_deadline_ms: now_ms + self.lease_ms,
                };
                ClaimGrant::Granted
            }
            Slot::Settled(r) if r.cancelled => ClaimGrant::Cancelled,
            _ => ClaimGrant::Conflict,
        }
    }

    /// Extend a live lease; `false` if the slot holds no live claim.
    pub fn heartbeat(&mut self, cid: usize, now_ms: u64) -> bool {
        let Some(i) = self.idx(cid) else { return false };
        match &mut self.slots[i] {
            Slot::Claimed { lease_deadline_ms } => {
                *lease_deadline_ms = now_ms + self.lease_ms;
                true
            }
            _ => false,
        }
    }

    /// Deliver a claimed slot's result; `false` if there is no live
    /// claim to settle (a late upload after a lease expiry must not
    /// double-count — the drop already stands).
    pub fn settle(&mut self, cid: usize, res: ClientResult) -> bool {
        let Some(i) = self.idx(cid) else { return false };
        if !matches!(self.slots[i], Slot::Claimed { .. }) {
            return false;
        }
        self.slots[i] = Slot::Settled(res);
        true
    }

    /// Settle a live claim as a dropout (the client hung up, or told
    /// us so with `Complete(DROPPED)`).
    pub fn drop_claim(&mut self, cid: usize) -> bool {
        let res = Self::dropped_result(cid, self.down_bytes);
        self.settle(cid, res)
    }

    /// Settle every lease-expired claim as a dropout; returns how
    /// many expired.
    pub fn expire(&mut self, now_ms: u64) -> usize {
        let down_bytes = self.down_bytes;
        let mut n = 0;
        for (slot, &cid) in self.slots.iter_mut().zip(&self.ids) {
            match *slot {
                Slot::Claimed { lease_deadline_ms }
                    if lease_deadline_ms <= now_ms =>
                {
                    *slot = Slot::Settled(Self::dropped_result(
                        cid, down_bytes,
                    ));
                    n += 1;
                }
                _ => {}
            }
        }
        n
    }

    /// Round-deadline policy `drop`: every unsettled slot — claimed
    /// or never claimed — becomes a dropout, exactly like the
    /// simulator's failure injection, and the round completes without
    /// the stragglers.
    pub fn force_drop(&mut self) -> usize {
        let down_bytes = self.down_bytes;
        let mut n = 0;
        for (slot, &cid) in self.slots.iter_mut().zip(&self.ids) {
            if !matches!(slot, Slot::Settled(_)) {
                *slot =
                    Slot::Settled(Self::dropped_result(cid, down_bytes));
                n += 1;
            }
        }
        n
    }

    /// Every slot settled?
    pub fn complete(&self) -> bool {
        self.slots.iter().all(|s| matches!(s, Slot::Settled(_)))
    }

    /// The settled results in slot (sampling) order; errors if the
    /// table is read out before completion.
    pub fn into_results(self) -> Result<Vec<ClientResult>> {
        let round = self.round;
        self.slots
            .into_iter()
            .map(|s| match s {
                Slot::Settled(r) => Ok(r),
                _ => Err(Error::invalid(format!(
                    "round {round} claim table read out before \
                     completion"
                ))),
            })
            .collect()
    }
}

/// Hands socket-delivered results to the shard merge in slot order —
/// the executor the wire server passes to
/// [`Simulation::merge_round`]. Keyed by cid so the sharded fan-out
/// (each shard asks for its own contiguous slice, possibly from its
/// own thread) finds its results regardless of partitioning.
struct ReplayExecutor {
    results: Mutex<BTreeMap<usize, ClientResult>>,
}

impl ReplayExecutor {
    fn new(results: Vec<ClientResult>) -> ReplayExecutor {
        ReplayExecutor {
            results: Mutex::new(
                results.into_iter().map(|r| (r.cid, r)).collect(),
            ),
        }
    }
}

impl ClientExecutor for ReplayExecutor {
    fn name(&self) -> &'static str {
        "replay"
    }

    fn execute(
        &self,
        _ctx: &RoundContext<'_>,
        clients: &[usize],
        sink: &mut dyn RoundSink,
    ) -> Result<()> {
        for (i, &cid) in clients.iter().enumerate() {
            let res =
                lock(&self.results).remove(&cid).ok_or_else(|| {
                    Error::invalid(format!(
                        "replay executor has no result for client {cid}"
                    ))
                })?;
            sink.push(i, res)?;
        }
        Ok(())
    }
}

/// Monotonic millisecond clock for leases and deadlines.
struct WireClock {
    start: Instant,
}

impl WireClock {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }
}

/// Server tunables (`flocora serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Claim lease: a client silent this long is settled as a drop.
    pub lease_ms: u64,
    /// Whole-round deadline before `on_timeout` applies.
    pub round_timeout_ms: u64,
    pub on_timeout: WireFaultPolicy,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            lease_ms: 30_000,
            round_timeout_ms: 60_000,
            on_timeout: WireFaultPolicy::Drop,
        }
    }
}

/// Mutable server state behind the one mutex; the condvar signals
/// round installs, settles and shutdown.
struct WireState {
    /// The round currently gathering, if any.
    cur: Option<ClaimTable>,
    /// The broadcast message served while `cur` is live.
    download: Option<Message>,
    /// First round index not yet merged — claims below it are stale.
    next_round: usize,
    finished: bool,
    aborted: Option<String>,
    shutdown: bool,
    /// Live handler connections (graceful-drain accounting).
    conns: usize,
}

struct Shared {
    state: Mutex<WireState>,
    cv: Condvar,
    config_blob: String,
    num_clients: usize,
    clock: WireClock,
}

/// Lock a mutex, riding over poisoning: a panicking handler must not
/// wedge the coordinator (the state it guards is valid at every
/// release point).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Short condvar wait. Under the real `std` primitives this is a
/// timed wait so deadline/expiry checks make progress even with no
/// traffic; under loom (which models no time) it degrades to a plain
/// wait — the protocol must therefore never *rely* on the timeout
/// for correctness, only for liveness of the wall-clock checks.
#[cfg(not(loom))]
fn wait_brief<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(g, Duration::from_millis(25)) {
        Ok((g, _)) => g,
        Err(p) => p.into_inner().0,
    }
}

#[cfg(loom)]
fn wait_brief<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Run the full federated schedule as a networked coordinator on an
/// already-bound listener. Returns the run summary plus the dropped
/// count (everything `run_json` needs), with `rec` holding the same
/// evaluated-round records an in-process run produces — byte-identical
/// once wall-clock fields are stripped.
pub fn serve_on(
    listener: TcpListener,
    engine: &Engine,
    cfg: FlConfig,
    opts: &ServeOpts,
    rec: &mut Recorder,
) -> Result<(RunSummary, u64)> {
    cfg.validate()?;
    if !cfg.hetero_ranks.is_empty() {
        return Err(Error::invalid(
            "wire mode serves homogeneous federations only \
             (hetero_ranks must be empty)",
        ));
    }
    let config_blob = cfg.to_blob();
    let num_clients = cfg.num_clients;
    let mut sim = Simulation::new(engine, cfg)?;
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared {
        state: Mutex::new(WireState {
            cur: None,
            download: None,
            next_round: 0,
            finished: false,
            aborted: None,
            shutdown: false,
            conns: 0,
        }),
        cv: Condvar::new(),
        config_blob,
        num_clients,
        clock: WireClock { start: Instant::now() },
    });

    let handles: Arc<Mutex<JoinSet>> = Arc::new(Mutex::new(Vec::new()));
    let acceptor = {
        let shared = Arc::clone(&shared);
        let handles = Arc::clone(&handles);
        thread::spawn(move || accept_loop(listener, &shared, &handles))
    };

    let result =
        sim.run_with(rec, |sim| drive_round(sim, &shared, opts));

    finish(&shared, &result, opts.round_timeout_ms);
    // The acceptor is parked in `accept`; a self-connection wakes it
    // so it can observe the shutdown flag and return.
    let _ = TcpStream::connect(addr);
    let _ = acceptor.join();
    let joins = std::mem::take(&mut *lock(&handles));
    for h in joins {
        let _ = h.join();
    }
    let summary = result?;
    Ok((summary, sim.dropped_clients))
}

/// One wire round: announce the plan, gather the uploads, merge.
fn drive_round(
    sim: &mut Simulation,
    shared: &Shared,
    opts: &ServeOpts,
) -> Result<(f64, f64)> {
    let rp: RoundPlan = sim.plan_round()?;
    let msg = rp.shared_msg.clone().ok_or_else(|| {
        Error::invalid("wire round produced no broadcast message")
    })?;
    let down_bytes = msg.size_bytes();
    let table = ClaimTable::new(
        rp.round,
        &rp.client_ids,
        &rp.cancelled_ids,
        down_bytes,
        opts.lease_ms,
    );
    {
        let mut st = lock(&shared.state);
        st.cur = Some(table);
        st.download = Some(msg);
    }
    shared.cv.notify_all();

    let deadline = shared.clock.now_ms() + opts.round_timeout_ms;
    let results = loop {
        let mut st = lock(&shared.state);
        let now = shared.clock.now_ms();
        let table =
            st.cur.as_mut().expect("round table installed above");
        table.expire(now);
        if table.complete() {
            let table = st.cur.take().expect("checked above");
            st.download = None;
            st.next_round = rp.round + 1;
            drop(st);
            shared.cv.notify_all();
            break table.into_results()?;
        }
        if now >= deadline {
            match opts.on_timeout {
                WireFaultPolicy::Drop => {
                    table.force_drop();
                    continue;
                }
                WireFaultPolicy::Abort => {
                    return Err(Error::invalid(format!(
                        "wire round {} timed out after {} ms with \
                         unsettled clients",
                        rp.round, opts.round_timeout_ms
                    )));
                }
            }
        }
        let _st = wait_brief(&shared.cv, st);
    };
    let replay = ReplayExecutor::new(results);
    sim.merge_round(&rp, Some(&replay))
}

/// Post-run teardown: publish the outcome, give connected clients a
/// drain window to read their final replies and hang up, then cut the
/// handlers off.
fn finish(
    shared: &Shared,
    result: &Result<RunSummary>,
    drain_ms: u64,
) {
    let mut st = lock(&shared.state);
    if let Err(e) = result {
        st.aborted = Some(e.to_string());
    }
    st.finished = true;
    shared.cv.notify_all();
    let deadline = shared.clock.now_ms() + drain_ms;
    while st.conns > 0 && shared.clock.now_ms() < deadline {
        st = wait_brief(&shared.cv, st);
    }
    st.shutdown = true;
    drop(st);
    shared.cv.notify_all();
}

/// Handler threads spawned by the acceptor, joined at shutdown.
type JoinSet = Vec<thread::JoinHandle<()>>;

fn accept_loop(
    listener: TcpListener,
    shared: &Arc<Shared>,
    handles: &Mutex<JoinSet>,
) {
    loop {
        let conn = listener.accept();
        if lock(&shared.state).shutdown {
            return;
        }
        let Ok((stream, _)) = conn else { continue };
        lock(&shared.state).conns += 1;
        let shared = Arc::clone(shared);
        let h = thread::spawn(move || {
            handle_conn(stream, &shared);
            lock(&shared.state).conns -= 1;
            shared.cv.notify_all();
        });
        lock(handles).push(h);
    }
}

/// One connection's request/reply loop. On exit — clean hang-up,
/// protocol error, or shutdown — any claims this connection still
/// holds are settled as dropouts (the crash path the kill tests
/// exercise).
fn handle_conn(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let _ = stream.set_nodelay(true);
    let mut claimed: Vec<(usize, usize)> = Vec::new();
    loop {
        let frame = match read_frame_poll(&mut stream, Some(shared)) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                let _ = write_frame(
                    &mut stream,
                    &Frame::Abort { reason: e.to_string() },
                );
                break;
            }
        };
        let reply = match handle_frame(frame, shared, &mut claimed) {
            Ok(reply) => reply,
            Err(e) => Frame::Abort { reason: e.to_string() },
        };
        let abort = matches!(reply, Frame::Abort { .. });
        if write_frame(&mut stream, &reply).is_err() || abort {
            break;
        }
    }
    settle_orphans(shared, &claimed);
}

/// Dispatch one client frame to its reply.
fn handle_frame(
    frame: Frame,
    shared: &Shared,
    claimed: &mut Vec<(usize, usize)>,
) -> Result<Frame> {
    match frame {
        Frame::Hello { .. } => Ok(Frame::Hello {
            config: shared.config_blob.clone(),
        }),
        Frame::Register { lo, hi } => {
            if lo > hi || hi as usize >= shared.num_clients {
                return Err(Error::invalid(format!(
                    "register range {lo}-{hi} outside the \
                     federation's 0-{}",
                    shared.num_clients - 1
                )));
            }
            Ok(Frame::Register { lo, hi })
        }
        Frame::Claim { round, cid } => {
            claim_reply(shared, round, cid, claimed)
        }
        Frame::Download { round, cid, .. } => {
            download_reply(shared, round, cid)
        }
        Frame::Upload {
            round,
            cid,
            weight,
            mean_loss,
            mean_acc,
            codec,
            payload,
        } => {
            let (r, c) = (round as usize, cid as usize);
            let up_bytes = payload.len();
            let mut st = lock(&shared.state);
            let Some(table) =
                st.cur.as_mut().filter(|t| t.round() == r)
            else {
                return Err(Error::invalid(format!(
                    "upload for round {round}, which is not gathering"
                )));
            };
            let res = ClientResult {
                cid: c,
                down_bytes: table.down_bytes(),
                update: Some(ClientUpdate {
                    params: UpdateVector::Encoded(Message {
                        payload,
                        codec,
                    }),
                    weight,
                    up_bytes,
                    mean_loss,
                    mean_acc,
                }),
                cancelled: false,
            };
            if !table.settle(c, res) {
                return Err(Error::invalid(format!(
                    "upload from client {cid} round {round}: no live \
                     claim (lease expired?)"
                )));
            }
            drop(st);
            shared.cv.notify_all();
            claimed.retain(|&(cr, cc)| !(cr == r && cc == c));
            Ok(Frame::Complete { round, cid, status: STATUS_ACK })
        }
        Frame::Complete { round, cid, status } => {
            if status != STATUS_DROPPED {
                return Err(Error::invalid(format!(
                    "client sent complete status {status}; only a \
                     dropped notice ({STATUS_DROPPED}) flows upstream"
                )));
            }
            let (r, c) = (round as usize, cid as usize);
            let mut st = lock(&shared.state);
            let ok = st
                .cur
                .as_mut()
                .filter(|t| t.round() == r)
                .is_some_and(|t| t.drop_claim(c));
            drop(st);
            if !ok {
                return Err(Error::invalid(format!(
                    "drop notice from client {cid} round {round}: no \
                     live claim"
                )));
            }
            shared.cv.notify_all();
            claimed.retain(|&(cr, cc)| !(cr == r && cc == c));
            Ok(Frame::Complete { round, cid, status: STATUS_ACK })
        }
        Frame::Heartbeat { round, cid } => {
            let mut st = lock(&shared.state);
            let now = shared.clock.now_ms();
            if let Some(t) =
                st.cur.as_mut().filter(|t| t.round() == round as usize)
            {
                t.heartbeat(cid as usize, now);
            }
            Ok(Frame::Heartbeat { round, cid })
        }
        Frame::Abort { reason } => {
            Err(Error::invalid(format!("client aborted: {reason}")))
        }
        other => Err(Error::invalid(format!(
            "unexpected {} frame from a client",
            other.kind()
        ))),
    }
}

/// Answer a claim, blocking until the requested round is gathering
/// (or known to be over/stale).
fn claim_reply(
    shared: &Shared,
    round: u64,
    cid: u64,
    claimed: &mut Vec<(usize, usize)>,
) -> Result<Frame> {
    let r = round as usize;
    let c = cid as usize;
    let mut st = lock(&shared.state);
    loop {
        if let Some(reason) = &st.aborted {
            return Ok(Frame::Abort { reason: reason.clone() });
        }
        if let Some(table) =
            st.cur.as_mut().filter(|t| t.round() == r)
        {
            let now = shared.clock.now_ms();
            return match table.claim(c, now) {
                ClaimGrant::Granted => {
                    claimed.push((r, c));
                    Ok(Frame::Plan {
                        round,
                        cid,
                        sampled: true,
                        cancelled: false,
                    })
                }
                ClaimGrant::Cancelled => Ok(Frame::Plan {
                    round,
                    cid,
                    sampled: true,
                    cancelled: true,
                }),
                ClaimGrant::NotSampled => Ok(Frame::Plan {
                    round,
                    cid,
                    sampled: false,
                    cancelled: false,
                }),
                ClaimGrant::Conflict => Err(Error::invalid(format!(
                    "client {cid} claimed an already-taken slot in \
                     round {round}"
                ))),
            };
        }
        if r < st.next_round {
            // Already merged (or merging): whatever this client's slot
            // was — unsampled, or force-dropped at the deadline — the
            // round is spoken for and there is nothing left to do.
            return Ok(Frame::Plan {
                round,
                cid,
                sampled: false,
                cancelled: false,
            });
        }
        if st.finished || st.shutdown {
            return Ok(Frame::Complete {
                round,
                cid,
                status: STATUS_FINISHED,
            });
        }
        st = wait_brief(&shared.cv, st);
    }
}

/// Serve the broadcast download for a live claim (and extend its
/// lease — pulling the message is proof of life).
fn download_reply(shared: &Shared, round: u64, cid: u64) -> Result<Frame> {
    let r = round as usize;
    let mut st = lock(&shared.state);
    let now = shared.clock.now_ms();
    let live = st
        .cur
        .as_mut()
        .filter(|t| t.round() == r)
        .is_some_and(|t| t.heartbeat(cid as usize, now));
    if !live {
        return Err(Error::invalid(format!(
            "download for round {round} client {cid}: no live claim"
        )));
    }
    let msg = st
        .download
        .as_ref()
        .expect("download present while a round gathers");
    Ok(Frame::Download {
        round,
        cid,
        codec: msg.codec.clone(),
        payload: msg.payload.clone(),
    })
}

/// Settle any claims a dead connection still holds as dropouts.
fn settle_orphans(shared: &Shared, claimed: &[(usize, usize)]) {
    if claimed.is_empty() {
        return;
    }
    let mut settled = false;
    let mut st = lock(&shared.state);
    for &(round, cid) in claimed {
        if let Some(t) =
            st.cur.as_mut().filter(|t| t.round() == round)
        {
            settled |= t.drop_claim(cid);
        }
    }
    drop(st);
    if settled {
        shared.cv.notify_all();
    }
}

/// Client tunables (`flocora client` flags).
#[derive(Debug, Clone)]
pub struct ClientOpts {
    /// `host:port` of the coordinator.
    pub connect: String,
    /// Inclusive client-id range this process hosts.
    pub lo: usize,
    pub hi: usize,
    /// Extra connect attempts after the first fails.
    pub retries: u32,
    /// Base backoff between attempts (doubles, capped).
    pub backoff_ms: u64,
    /// Fault injection: hang up right after downloading for this
    /// `(round, cid)` — the server must account it as a dropout.
    pub kill_at: Option<(usize, usize)>,
    /// Artifacts directory (`synthetic` for the synthetic backend).
    pub artifacts: String,
}

impl Default for ClientOpts {
    fn default() -> ClientOpts {
        ClientOpts {
            connect: "127.0.0.1:7070".into(),
            lo: 0,
            hi: 0,
            retries: 5,
            backoff_ms: 200,
            kill_at: None,
            artifacts: "synthetic".into(),
        }
    }
}

/// What a client process did, for operator logs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClientReport {
    /// Claims granted (slots this process trained or was killed in).
    pub claims: usize,
    pub uploads: usize,
    /// Voluntary dropouts (the dropout coin / `drop_plan`).
    pub self_drops: usize,
    /// The `kill_at` injection fired.
    pub killed: bool,
}

fn connect_with_retry(opts: &ClientOpts) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..=opts.retries {
        if attempt > 0 {
            let backoff =
                opts.backoff_ms << (attempt - 1).min(4);
            // det-lint: allow(std-sync) — client-side connect backoff
            // sleeps real time between attempts; nothing simulated
            // (or loom-modelled) depends on it.
            std::thread::sleep(Duration::from_millis(backoff));
        }
        match TcpStream::connect(&opts.connect) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(Error::invalid(format!(
        "cannot reach {} after {} attempt(s): {}",
        opts.connect,
        opts.retries + 1,
        last.map_or_else(|| "no attempt made".into(), |e| e.to_string())
    )))
}

fn unexpected(frame: &Frame, stage: &str) -> Error {
    Error::invalid(format!(
        "unexpected {} frame during {stage}",
        frame.kind()
    ))
}

/// Blocking read of the server's lockstep reply.
fn read_reply(stream: &mut TcpStream) -> Result<Frame> {
    read_frame_poll(stream, None)?.ok_or_else(|| {
        Error::Io(std::io::Error::new(
            ErrorKind::UnexpectedEof,
            "server closed the connection",
        ))
    })
}

/// Hello handshake: fetch the config blob and rebuild the run config.
fn hello_handshake(stream: &mut TcpStream) -> Result<FlConfig> {
    write_frame(stream, &Frame::Hello { config: String::new() })?;
    let blob = match read_reply(stream)? {
        Frame::Hello { config } => config,
        other => return Err(unexpected(&other, "hello")),
    };
    let mut cfg = FlConfig::default();
    loader::apply_str(&mut cfg, &blob)?;
    cfg.validate()?;
    Ok(cfg)
}

fn register(stream: &mut TcpStream, opts: &ClientOpts) -> Result<()> {
    write_frame(
        stream,
        &Frame::Register { lo: opts.lo as u64, hi: opts.hi as u64 },
    )?;
    match read_reply(stream)? {
        Frame::Register { .. } => Ok(()),
        Frame::Abort { reason } => Err(Error::invalid(format!(
            "server rejected registration: {reason}"
        ))),
        other => Err(unexpected(&other, "register")),
    }
}

/// Run a wire client hosting cids `lo..=hi`: register, then for every
/// round claim each hosted slot, download, train via the *same*
/// [`run_client`] the in-process executors run, and upload (or report
/// the dropout coin's verdict). Returns when the server says the run
/// is finished or every round has been visited.
pub fn run_client_loop(opts: &ClientOpts) -> Result<ClientReport> {
    if opts.lo > opts.hi {
        return Err(Error::invalid(format!(
            "client id range {}-{} is empty",
            opts.lo, opts.hi
        )));
    }
    let mut stream = connect_with_retry(opts)?;
    let cfg = hello_handshake(&mut stream)?;
    register(&mut stream, opts)?;

    let engine = Engine::new(&opts.artifacts)?;
    let session = engine.session(&cfg.tag)?;
    let spec = &session.spec;
    // Bit-for-bit the federation the server built: same LDA partition
    // coordinates, same frozen base from the same init artifact/seed.
    let federation = lda_partition(
        cfg.num_clients,
        cfg.samples_per_client,
        spec.num_classes,
        spec.image_size,
        cfg.lda_alpha,
        cfg.seed,
    );
    let (_global, frozen) = session.init(cfg.seed)?;
    // One codec instance for the whole run: stateful codecs (sparse
    // error feedback) key their residuals by cid, and this process
    // hosts its cids exclusively — so the residual streams match the
    // server-side simulation exactly.
    let codec = cfg.codec.build();
    let lora_scale = cfg.lora_scale(spec.rank);

    let mut report = ClientReport::default();
    let mut killed_at: Option<(usize, usize)> = None;
    for round in 0..cfg.rounds {
        for cid in opts.lo..=opts.hi {
            if killed_at == Some((round, cid)) {
                // The pre-kill connection already claimed this slot;
                // the server settled it as a dropout on our EOF.
                continue;
            }
            write_frame(
                &mut stream,
                &Frame::Claim {
                    round: round as u64,
                    cid: cid as u64,
                },
            )?;
            match read_reply(&mut stream)? {
                Frame::Complete { status: STATUS_FINISHED, .. } => {
                    return Ok(report)
                }
                Frame::Plan { sampled: false, .. } => continue,
                Frame::Plan { cancelled: true, .. } => continue,
                Frame::Plan { .. } => {}
                Frame::Abort { reason } => {
                    return Err(Error::invalid(format!(
                        "server aborted: {reason}"
                    )))
                }
                other => return Err(unexpected(&other, "claim")),
            }
            report.claims += 1;

            write_frame(
                &mut stream,
                &Frame::Download {
                    round: round as u64,
                    cid: cid as u64,
                    codec: String::new(),
                    payload: Vec::new(),
                },
            )?;
            let msg = match read_reply(&mut stream)? {
                Frame::Download { codec, payload, .. } => {
                    Message { payload, codec }
                }
                Frame::Abort { reason } => {
                    return Err(Error::invalid(format!(
                        "server aborted: {reason}"
                    )))
                }
                other => return Err(unexpected(&other, "download")),
            };
            // Keep the lease warm before the training stretch.
            write_frame(
                &mut stream,
                &Frame::Heartbeat {
                    round: round as u64,
                    cid: cid as u64,
                },
            )?;
            match read_reply(&mut stream)? {
                Frame::Heartbeat { .. } => {}
                other => return Err(unexpected(&other, "heartbeat")),
            }

            if opts.kill_at == Some((round, cid)) {
                // Fault injection: vanish mid-round. The server's EOF
                // path settles this slot as a drop — bit-identical to
                // a sim-side `drop_plan` entry — then this process
                // comes back as a fresh connection for its remaining
                // slots.
                drop(stream);
                report.killed = true;
                killed_at = Some((round, cid));
                stream = connect_with_retry(opts)?;
                let _ = hello_handshake(&mut stream)?;
                register(&mut stream, opts)?;
                continue;
            }

            let lr = cfg.lr * cfg.lr_decay.powi(round as i32);
            let ctx = RoundContext {
                session: &session,
                codec: codec.as_ref(),
                federation: &federation,
                frozen: &frozen,
                downloads: Downloads::Homogeneous(&msg),
                trainer: LocalTrainer {
                    local_epochs: cfg.local_epochs,
                    lr,
                    lora_scale,
                },
                cfg: &cfg,
                round,
                plan: None,
                // The server pre-settles planned cancellations, so a
                // slot that reaches this client is never cancelled.
                cancelled: &[],
            };
            let result = run_client(&ctx, cid)?;
            match result.update {
                None => {
                    report.self_drops += 1;
                    write_frame(
                        &mut stream,
                        &Frame::Complete {
                            round: round as u64,
                            cid: cid as u64,
                            status: STATUS_DROPPED,
                        },
                    )?;
                }
                Some(up) => {
                    let UpdateVector::Encoded(up_msg) = up.params
                    else {
                        return Err(Error::invalid(
                            "homogeneous client produced a dense \
                             update",
                        ));
                    };
                    report.uploads += 1;
                    write_frame(
                        &mut stream,
                        &Frame::Upload {
                            round: round as u64,
                            cid: cid as u64,
                            weight: up.weight,
                            mean_loss: up.mean_loss,
                            mean_acc: up.mean_acc,
                            codec: up_msg.codec,
                            payload: up_msg.payload,
                        },
                    )?;
                }
            }
            match read_reply(&mut stream)? {
                Frame::Complete { status: STATUS_ACK, .. } => {}
                Frame::Abort { reason } => {
                    return Err(Error::invalid(format!(
                        "server aborted: {reason}"
                    )))
                }
                other => return Err(unexpected(&other, "round close")),
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_rejects_magic_version_and_oversize() {
        let good = Frame::Claim { round: 1, cid: 2 }.encode();
        assert_eq!(good[0], WIRE_MAGIC[0]);
        let mut h = [0u8; HEADER_LEN];
        h.copy_from_slice(&good[..HEADER_LEN]);
        assert!(check_header(&h).is_ok());

        let mut bad_magic = h;
        bad_magic[0] ^= 0xFF;
        assert!(check_header(&bad_magic).is_err());

        let mut bad_version = h;
        bad_version[2] = WIRE_VERSION + 1;
        assert!(check_header(&bad_version).is_err());

        let mut oversize = h;
        oversize[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = check_header(&oversize).unwrap_err();
        assert!(matches!(err, Error::Invalid(_)), "{err}");
    }

    #[test]
    fn claim_table_lifecycle() {
        let mut t = ClaimTable::new(3, &[1, 4, 7], &[4], 100, 1_000);
        assert_eq!(t.round(), 3);
        assert!(!t.complete());
        assert_eq!(t.claim(2, 0), ClaimGrant::NotSampled);
        assert_eq!(t.claim(4, 0), ClaimGrant::Cancelled);
        assert_eq!(t.claim(1, 0), ClaimGrant::Granted);
        assert_eq!(t.claim(1, 0), ClaimGrant::Conflict);
        // Heartbeat extends the lease past the original deadline.
        assert!(t.heartbeat(1, 500));
        assert_eq!(t.expire(1_200), 0);
        assert_eq!(t.expire(1_600), 1);
        // The expired slot settled as a drop; the open one remains.
        assert_eq!(t.claim(7, 0), ClaimGrant::Granted);
        assert!(t.drop_claim(7));
        assert!(t.complete());
        let res = t.into_results().unwrap();
        assert_eq!(
            res.iter().map(|r| r.cid).collect::<Vec<_>>(),
            [1, 4, 7]
        );
        assert!(res[1].cancelled);
        assert!(res.iter().all(|r| r.down_bytes == 100));
        assert!(res.iter().all(|r| r.update.is_none()));
    }

    #[test]
    fn fault_policy_parses() {
        assert_eq!(
            WireFaultPolicy::parse("drop"),
            Some(WireFaultPolicy::Drop)
        );
        assert_eq!(
            WireFaultPolicy::parse("abort"),
            Some(WireFaultPolicy::Abort)
        );
        assert_eq!(WireFaultPolicy::parse("panic"), None);
        assert_eq!(WireFaultPolicy::Drop.label(), "drop");
    }
}
