//! The simulated transport stage: where wire time gets charged.
//!
//! Before this module, the round merge charged each client's whole
//! simulated round trip as one lump the moment its result drained —
//! wire transfer was priced *inside* the client task, so the engine
//! could only express the no-overlap regime. The transport stage
//! decouples that accounting: executors and the round sink describe
//! what happened as a stream of [`StageEvent`]s (download, train,
//! upload, drop, cancel), and the [`TransferStage`] — which owns the
//! [`NetworkModel`]/[`ClientProfiles`] clock and the round's
//! [`RoundLoad`] accumulator — turns them into the three concurrency
//! estimates (`serial`, `parallel`, `pipelined`) plus the transfer
//! wait the pipelined regime hides.
//!
//! **Event contract.** Events arrive on the coordinator thread, in
//! result-drain order (the sink contract guarantees sampling order).
//! Per client the legal sequences are:
//!
//! * `Download → Train → Upload` — a surviving client;
//! * `Download → Dropped` — failure injection before the upload;
//! * `Download → Cancelled` — the server cut the client mid-round
//!   (oversampled rounds end at the K-th accepted upload). Under
//!   `overlap = transfer` the cut lands mid-*transfer*: the wire and
//!   serial clocks still charge the download that was in flight, but
//!   the pipelined round never waits for it.
//!
//! **Once-per-direction charging.** The stage keys its per-client
//! state by `cid` and finalizes each client exactly once: a duplicate
//! terminal event for a cid that already settled is ignored. This
//! fixes a double-count the raw `RoundLoad` API allowed — calling
//! `add_timed` and then `add_cancelled` for the same client (e.g. an
//! oversampled round feeding one cid through both paths) charged its
//! download leg twice. The regression is pinned in this module's
//! tests.

use std::collections::BTreeMap;

use crate::transport::network::{NetworkModel, RoundLoad};
use crate::transport::profile::ClientProfiles;
use crate::transport::sim::{ClientLoad, TimeModel};

/// The `overlap` knob: what may run concurrently with client compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapKind {
    /// Transfer stays on the client task's critical path (the
    /// reference engine). Executors run each client's
    /// download → train → upload as one unit of work.
    #[default]
    None,
    /// Wire transfer overlaps compute: the parallel executor moves
    /// decode/encode onto dedicated transport threads, so client A's
    /// upload is prepared while client B still trains. Results and
    /// every simulated estimate stay bit-identical — only wall clock
    /// and the regime the `sim_net_pipelined_s` column models change.
    Transfer,
}

impl OverlapKind {
    /// Parse `none | transfer`.
    pub fn parse(s: &str) -> Option<OverlapKind> {
        match s {
            "none" => Some(OverlapKind::None),
            "transfer" => Some(OverlapKind::Transfer),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            OverlapKind::None => "none",
            OverlapKind::Transfer => "transfer",
        }
    }
}

/// One observed step of a client's round, pushed by the round sink as
/// results drain (see the module docs for the legal per-client
/// sequences).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageEvent {
    /// The client pulled its download message.
    Download { cid: usize, bytes: usize },
    /// The client ran its local epochs (compute happened).
    Train { cid: usize },
    /// The client pushed its update — terminal for a survivor.
    Upload { cid: usize, bytes: usize },
    /// The client failed before uploading — terminal for a dropout.
    Dropped { cid: usize },
    /// The server cut the client mid-transfer — terminal for a
    /// cancellation.
    Cancelled { cid: usize },
}

impl StageEvent {
    fn cid(&self) -> usize {
        match *self {
            StageEvent::Download { cid, .. }
            | StageEvent::Train { cid }
            | StageEvent::Upload { cid, .. }
            | StageEvent::Dropped { cid }
            | StageEvent::Cancelled { cid } => cid,
        }
    }
}

/// Per-client in-flight state (between its `Download` and its terminal
/// event).
#[derive(Debug, Default, Clone, Copy)]
struct ClientStage {
    down_bytes: usize,
    /// Terminal event already charged: further events for this cid are
    /// duplicates and are ignored (once-per-direction charging).
    settled: bool,
}

/// Everything one round's transport accounting produced.
#[derive(Debug, Clone)]
pub struct RoundTransport {
    /// Clients one after another: sum of full round trips.
    pub serial_s: f64,
    /// Clients concurrent, transfer inside each client task.
    pub parallel_s: f64,
    /// Clients concurrent, transfer streamed off the client task
    /// (`overlap = transfer`) — never above `parallel_s`.
    pub pipelined_s: f64,
    /// Simulated time-on-wire the pipelined regime overlaps with
    /// compute (downloads + uploads, cancelled downloads included).
    pub transfer_wait_s: f64,
    /// The active [`TimeModel`]'s round estimate: the ideal pipelined
    /// envelope under `time_model = closed`, the chunk-granularity
    /// discrete-event result under `time_model = event` (see
    /// [`crate::transport::sim`]).
    pub event_s: f64,
    /// Peak inter-stage queue occupancy the event simulator observed
    /// (chunks; 0 under the closed backend).
    pub queue_peak: usize,
    /// Total producer-blocked time on full stage queues (seconds; 0
    /// under the closed backend).
    pub queue_block_s: f64,
    /// Simulated round trip of every client the server waited on
    /// (survivors and dropouts, sampling order) — feeds the straggler
    /// p50/max stats.
    pub times: Vec<f64>,
}

/// One round's transport accountant: owns the link clock
/// ([`NetworkModel`] + [`ClientProfiles`]) and the [`RoundLoad`]
/// accumulator, fed by [`StageEvent`]s.
pub struct TransferStage<'a> {
    net: &'a NetworkModel,
    profiles: &'a ClientProfiles,
    /// The backend that prices the round from the settled loads (the
    /// `time_model` knob: closed envelope or discrete-event replay).
    model: &'a dyn TimeModel,
    load: RoundLoad,
    times: Vec<f64>,
    /// Per-client stage splits in settle order, for the event
    /// simulator's chunk-granularity replay.
    loads: Vec<ClientLoad>,
    states: BTreeMap<usize, ClientStage>,
}

impl<'a> TransferStage<'a> {
    /// Start a round's accounting against a link profile table and a
    /// round-time backend.
    pub fn begin_round(
        net: &'a NetworkModel,
        profiles: &'a ClientProfiles,
        model: &'a dyn TimeModel,
    ) -> TransferStage<'a> {
        TransferStage {
            net,
            profiles,
            model,
            load: RoundLoad::new(),
            times: Vec::new(),
            loads: Vec::new(),
            states: BTreeMap::new(),
        }
    }

    /// Feed one event. Out-of-contract duplicates (a second terminal
    /// event for an already-settled cid, a repeated `Download`) are
    /// ignored rather than double-charged.
    pub fn push(&mut self, event: StageEvent) {
        let state = self.states.entry(event.cid()).or_default();
        if state.settled {
            return;
        }
        match event {
            StageEvent::Download { bytes, .. } => {
                state.down_bytes = state.down_bytes.max(bytes);
            }
            // Compute is priced from the profile table when the client
            // settles (a dropout never trained, so its terminal event
            // charges no compute); the event marks the sequence.
            StageEvent::Train { .. } => {}
            StageEvent::Upload { cid, bytes } => {
                state.settled = true;
                let down = state.down_bytes;
                let (td, tc, tu) =
                    self.profiles.stage_times(self.net, cid, down, bytes);
                self.load.add_stages(td, tc, tu, down, bytes);
                self.times.push(td + (tc + tu));
                self.loads.push(ClientLoad {
                    cid,
                    td,
                    tc,
                    tu,
                    down_bytes: down,
                    up_bytes: bytes,
                    waited: true,
                });
            }
            StageEvent::Dropped { cid } => {
                state.settled = true;
                let down = state.down_bytes;
                let (td, tc, tu) =
                    self.profiles.stage_times(self.net, cid, down, 0);
                self.load.add_stages(td, tc, tu, down, 0);
                self.times.push(td + (tc + tu));
                self.loads.push(ClientLoad {
                    cid,
                    td,
                    tc,
                    tu,
                    down_bytes: down,
                    up_bytes: 0,
                    waited: true,
                });
            }
            StageEvent::Cancelled { cid } => {
                state.settled = true;
                let down = state.down_bytes;
                let t_down =
                    self.profiles.get(cid).download_time(self.net, down);
                self.load.add_cancelled(t_down, down);
                self.loads.push(ClientLoad {
                    cid,
                    td: t_down,
                    tc: 0.0,
                    tu: 0.0,
                    down_bytes: down,
                    up_bytes: 0,
                    waited: false,
                });
            }
        }
    }

    /// Close the round: the three concurrency estimates, the transfer
    /// wait, the active time model's round estimate and the per-client
    /// waited-on times.
    pub fn finish(self) -> RoundTransport {
        let est = self.model.round_time(self.net, &self.load, &self.loads);
        RoundTransport {
            serial_s: self.load.serial_s(),
            parallel_s: self.load.parallel_s(self.net),
            pipelined_s: self.load.pipelined_s(self.net),
            transfer_wait_s: self.load.wire_s(),
            event_s: est.round_s,
            queue_peak: est.queue_peak,
            queue_block_s: est.queue_block_s,
            times: self.times,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::network::Sharing;
    use crate::transport::sim::{ClosedTimeModel, EventTimeModel, SimParams};

    fn net() -> NetworkModel {
        NetworkModel::edge_lte()
    }

    const CLOSED: ClosedTimeModel = ClosedTimeModel;

    #[test]
    fn overlap_kind_parses_and_labels() {
        assert_eq!(OverlapKind::parse("none"), Some(OverlapKind::None));
        assert_eq!(OverlapKind::parse("transfer"),
                   Some(OverlapKind::Transfer));
        assert_eq!(OverlapKind::parse("both"), None);
        assert_eq!(OverlapKind::None.label(), "none");
        assert_eq!(OverlapKind::Transfer.label(), "transfer");
        assert_eq!(OverlapKind::default(), OverlapKind::None);
    }

    #[test]
    fn survivor_events_match_direct_accounting() {
        let net = net();
        let profiles = ClientProfiles::tiered(6, 3);
        let mut stage = TransferStage::begin_round(&net, &profiles, &CLOSED);
        stage.push(StageEvent::Download { cid: 2, bytes: 10_000 });
        stage.push(StageEvent::Train { cid: 2 });
        stage.push(StageEvent::Upload { cid: 2, bytes: 8_000 });
        let out = stage.finish();
        let expect = profiles.client_time(&net, 2, 10_000, 8_000);
        assert_eq!(out.serial_s, expect);
        assert_eq!(out.parallel_s, expect);
        assert_eq!(out.times, vec![expect]);
        let (td, tc, tu) = profiles.stage_times(&net, 2, 10_000, 8_000);
        assert_eq!(out.pipelined_s, td.max(tc).max(tu));
        assert_eq!(out.transfer_wait_s, td + tu);
        assert!(out.pipelined_s < out.parallel_s);
    }

    #[test]
    fn dropped_and_cancelled_terminalize() {
        let net = net();
        let profiles = ClientProfiles::tiered(6, 7);
        let mut stage = TransferStage::begin_round(&net, &profiles, &CLOSED);
        stage.push(StageEvent::Download { cid: 0, bytes: 5_000 });
        stage.push(StageEvent::Dropped { cid: 0 });
        stage.push(StageEvent::Download { cid: 1, bytes: 5_000 });
        stage.push(StageEvent::Cancelled { cid: 1 });
        let out = stage.finish();
        let dropped = profiles.client_time(&net, 0, 5_000, 0);
        let cancelled = profiles.get(1).download_time(&net, 5_000);
        assert_eq!(out.serial_s, dropped + cancelled);
        // Only the dropped client is waited on.
        assert_eq!(out.times, vec![dropped]);
        assert_eq!(out.parallel_s, dropped);
    }

    #[test]
    fn duplicate_terminal_events_charge_once_per_direction() {
        // The regression the raw RoundLoad API allowed: a cid fed
        // through both the survivor and the cancellation path had its
        // download leg charged twice. The stage settles each client
        // exactly once.
        let net = net();
        let profiles = ClientProfiles::uniform(4);
        let run = |dup: bool| {
            let mut stage = TransferStage::begin_round(&net, &profiles, &CLOSED);
            stage.push(StageEvent::Download { cid: 3, bytes: 10_000 });
            stage.push(StageEvent::Train { cid: 3 });
            stage.push(StageEvent::Upload { cid: 3, bytes: 10_000 });
            if dup {
                // A second pass over the same client must be inert.
                stage.push(StageEvent::Download { cid: 3, bytes: 10_000 });
                stage.push(StageEvent::Cancelled { cid: 3 });
            }
            stage.finish()
        };
        let clean = run(false);
        let with_dup = run(true);
        assert_eq!(clean.serial_s, with_dup.serial_s);
        assert_eq!(clean.transfer_wait_s, with_dup.transfer_wait_s);
        assert_eq!(clean.times, with_dup.times);
    }

    #[test]
    fn closed_model_pins_event_column_to_the_pipelined_envelope() {
        let net = net();
        let profiles = ClientProfiles::tiered(6, 3);
        let mut stage = TransferStage::begin_round(&net, &profiles, &CLOSED);
        stage.push(StageEvent::Download { cid: 2, bytes: 10_000 });
        stage.push(StageEvent::Train { cid: 2 });
        stage.push(StageEvent::Upload { cid: 2, bytes: 8_000 });
        let out = stage.finish();
        assert_eq!(out.event_s, out.pipelined_s);
        assert_eq!(out.queue_peak, 0);
        assert_eq!(out.queue_block_s, 0.0);
    }

    #[test]
    fn event_model_lands_between_the_envelopes() {
        let net = net();
        let profiles = ClientProfiles::tiered(6, 3);
        let event = EventTimeModel {
            params: SimParams { chunk_kb: 1, stage_queue: 2 },
        };
        let mut stage = TransferStage::begin_round(&net, &profiles, &event);
        for cid in 0..4 {
            stage.push(StageEvent::Download { cid, bytes: 40_000 });
            stage.push(StageEvent::Train { cid });
            stage.push(StageEvent::Upload { cid, bytes: 40_000 });
        }
        let out = stage.finish();
        assert!(
            out.pipelined_s - 1e-9 <= out.event_s
                && out.event_s <= out.parallel_s + 1e-9,
            "event {} outside [{}, {}]",
            out.event_s,
            out.pipelined_s,
            out.parallel_s
        );
        // 40 kB at 1 kB chunks: real chunking, so the event round sits
        // strictly inside the envelopes (every client has all three
        // stages).
        assert!(out.event_s > out.pipelined_s);
        assert!(out.event_s < out.parallel_s);
        assert!(out.queue_peak >= 1);
    }

    #[test]
    fn shared_pipe_estimates_flow_through() {
        let net = NetworkModel::edge_lte().with_sharing(Sharing::Shared);
        let profiles = ClientProfiles::uniform(8);
        let mut stage = TransferStage::begin_round(&net, &profiles, &CLOSED);
        for cid in 0..4 {
            stage.push(StageEvent::Download { cid, bytes: 1_000_000 });
            stage.push(StageEvent::Train { cid });
            stage.push(StageEvent::Upload { cid, bytes: 1_000_000 });
        }
        let out = stage.finish();
        assert!(out.pipelined_s < out.parallel_s);
        assert!(out.parallel_s < out.serial_s);
        let loads = [(1_000_000, 1_000_000); 4];
        assert_eq!(out.parallel_s, net.round_time_parallel(&loads));
        assert_eq!(out.pipelined_s, net.round_time_pipelined(&loads));
    }
}
