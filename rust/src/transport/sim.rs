//! Discrete-event network simulator: the `time_model = event` backend.
//!
//! The three closed-form estimators in [`crate::transport::network`]
//! are envelopes. The pipelined one in particular assumes *ideal*
//! overlap — a round costs its slowest single stage, pipes are full
//! duplex with infinite queues — which no real staged executor
//! achieves: transfers move in finite chunks, the buffers between
//! download → train → upload hold finitely many of them, and clients
//! on a shared pipe get a bandwidth *share*, recomputed as flows come
//! and go. This module replays a round's settled client loads through
//! exactly that machinery and reports where between the envelopes the
//! round actually lands.
//!
//! **The model.** Each waited-on client is a three-stage pipeline over
//! `n = ceil(max(down_bytes, up_bytes) / chunk_kb·1024)` uniform
//! chunks: its profiled stage times `(td, tc, tu)` split evenly across
//! them. Chunks flow download → queue → train → queue → upload; each
//! inter-stage queue holds `stage_queue` chunks (0 = unbounded) and a
//! producer that finds its queue full *blocks* holding the finished
//! chunk — on a shared pipe that backpressure frees the blocked
//! client's bandwidth share for everyone else. Under
//! [`Sharing::Shared`] the per-direction pipes allocate bandwidth by
//! max-min fair sharing (water-filling over per-client rate caps),
//! recomputed at every flow start/finish/block; a one-way base-latency
//! handshake gates each client's first chunk per direction. Under
//! [`Sharing::Dedicated`] every transfer runs at the client's own
//! profiled rate.
//!
//! **Determinism.** The event loop advances to the next completion
//! time and settles every zero-time transition to a fixed point in
//! `(cid, stage)` order — upload before train before download within a
//! client, clients by ascending id. Simultaneous completions therefore
//! resolve identically on every run: the simulated time is a pure
//! function of the load set, so `time_model = event` runs stay
//! bit-identical across serial/parallel/windowed/pipelined executors
//! (the loads arrive in sampling order from the round sink either
//! way).
//!
//! **Pinned envelopes** (`tests/properties.rs`). On dedicated links,
//! for arbitrary loads, queues and chunk sizes:
//!
//! ```text
//! round_time_pipelined <= round_time_event <= round_time_parallel
//! ```
//!
//! with convergence to the pipelined envelope as `chunk_kb → 0` and
//! `stage_queue → ∞` (the per-client gap is `(chain − slowest_stage) /
//! n_chunks`), and equality with the parallel envelope at one chunk
//! per message. On a shared pipe only the lower bound is guaranteed,
//! and only for rounds whose loads are all waited on: the event round
//! then floors at each direction's busy time and every client's
//! slowest stage. (A *cancelled* client's bytes inflate the closed
//! pipe floor but the simulator never waits for them, so rounds with
//! cancellations can legitimately finish below the closed shared
//! envelope; and coarse chunks serialize compute against the pipe
//! phases, which the closed parallel form — pipe busy-times plus
//! straggler max — deliberately ignores. Both gaps are the queueing
//! fidelity the simulator exists to expose.)

use crate::transport::network::{NetworkModel, RoundLoad, Sharing};

/// Settling tolerance for simulated clocks (seconds / pipe-seconds):
/// a service whose remaining work drops below this is complete.
const EPS: f64 = 1e-12;

/// Which backend computes the `sim_net_event_s` column (the
/// `time_model` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TimeModelKind {
    /// Today's closed forms: `sim_net_event_s` reports the ideal
    /// pipelined envelope (bit-identical to `sim_net_pipelined_s`),
    /// queue stats stay zero.
    #[default]
    Closed,
    /// The discrete-event simulator in this module.
    Event,
}

impl TimeModelKind {
    /// Parse `closed | event`.
    pub fn parse(s: &str) -> Option<TimeModelKind> {
        match s {
            "closed" => Some(TimeModelKind::Closed),
            "event" => Some(TimeModelKind::Event),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TimeModelKind::Closed => "closed",
            TimeModelKind::Event => "event",
        }
    }

    /// Build the backend for a config's `chunk_kb` / `stage_queue`.
    pub fn build(&self, chunk_kb: usize, stage_queue: usize)
                 -> Box<dyn TimeModel> {
        match self {
            TimeModelKind::Closed => Box::new(ClosedTimeModel),
            TimeModelKind::Event => Box::new(EventTimeModel {
                params: SimParams { chunk_kb, stage_queue },
            }),
        }
    }
}

/// Event-simulator granularity knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimParams {
    /// Transfer chunk size in KiB (>= 1). Messages split into
    /// `ceil(bytes / chunk_kb·1024)` uniform chunks.
    pub chunk_kb: usize,
    /// Capacity of each inter-stage queue, in chunks; 0 = unbounded.
    pub stage_queue: usize,
}

impl Default for SimParams {
    fn default() -> SimParams {
        SimParams { chunk_kb: 64, stage_queue: 4 }
    }
}

/// One settled client of a round, as the transport stage priced it:
/// profiled stage times plus the byte counts behind them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientLoad {
    pub cid: usize,
    /// Profiled download / compute / upload stage seconds (dropped
    /// clients: `tc == tu == 0`; cancelled: the charged download leg).
    pub td: f64,
    pub tc: f64,
    pub tu: f64,
    pub down_bytes: usize,
    pub up_bytes: usize,
    /// Whether the round waits for this client (false for clients the
    /// server cancelled — their downloads still contend for shared
    /// pipes but never extend the round).
    pub waited: bool,
}

/// What a time model reports for one round.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TimeEstimate {
    /// Simulated round duration (the `sim_net_event_s` column).
    pub round_s: f64,
    /// Peak occupancy of any inter-stage queue (chunks); 0 under the
    /// closed backend.
    pub queue_peak: usize,
    /// Total time producers spent blocked on a full stage queue,
    /// summed over clients and stages; 0 under the closed backend.
    pub queue_block_s: f64,
}

/// A round-time backend: turns a round's settled loads into the
/// `sim_net_event_s` estimate (the `time_model` knob selects one).
pub trait TimeModel: Send + Sync {
    fn label(&self) -> &'static str;

    /// Price one round. `load` is the closed-form accumulator the
    /// transport stage already filled; `clients` the per-client stage
    /// splits in sampling order.
    fn round_time(&self, net: &NetworkModel, load: &RoundLoad,
                  clients: &[ClientLoad]) -> TimeEstimate;
}

/// The closed backend: today's ideal-overlap pipelined envelope.
pub struct ClosedTimeModel;

impl TimeModel for ClosedTimeModel {
    fn label(&self) -> &'static str {
        "closed"
    }

    fn round_time(&self, net: &NetworkModel, load: &RoundLoad,
                  _clients: &[ClientLoad]) -> TimeEstimate {
        TimeEstimate {
            round_s: load.pipelined_s(net),
            queue_peak: 0,
            queue_block_s: 0.0,
        }
    }
}

/// The event backend: chunked transfers, finite stage queues,
/// fair-share pipes.
pub struct EventTimeModel {
    pub params: SimParams,
}

impl TimeModel for EventTimeModel {
    fn label(&self) -> &'static str {
        "event"
    }

    fn round_time(&self, net: &NetworkModel, _load: &RoundLoad,
                  clients: &[ClientLoad]) -> TimeEstimate {
        simulate_round(net, clients, &self.params)
    }
}

/// One stage server of a client's pipeline.
#[derive(Debug, Clone, Copy)]
enum Srv {
    /// Waiting for input (or for the first chunk to exist).
    Idle,
    /// Fixed-duration service at rate 1 (dedicated transfers, compute,
    /// shared-pipe handshakes). When the phase ends and `then_pipe >
    /// 0`, the service continues as a pipe transfer of that much work.
    Fixed { left: f64, then_pipe: f64 },
    /// Shared-pipe transfer: `left` pipe-seconds of work, depleted at
    /// the flow's current max-min rate.
    Pipe { left: f64 },
    /// Service finished but the downstream queue is full: the producer
    /// holds the chunk (and, for transfers, its pipe share is freed).
    Blocked,
}

/// One client's pipeline state.
#[derive(Debug, Clone)]
struct ClientSim {
    n: usize,
    /// Per-chunk stage durations at the client's dedicated rate.
    dt: f64,
    ct: f64,
    ut: f64,
    /// Per-chunk pipe work (pipe-seconds; 0 = off-pipe fixed timing).
    wd: f64,
    wu: f64,
    /// Max-min rate caps on the shared pipe (the client's own link).
    cap_d: f64,
    cap_u: f64,
    /// Per-direction handshake charged before the first chunk on a
    /// shared pipe — carved out of the slack the profiled stage time
    /// already carries over its pure wire work (up to one base
    /// latency), so an uncontended shared transfer still takes exactly
    /// its profiled stage time.
    setup_d: f64,
    setup_u: f64,
    dl: Srv,
    tr: Srv,
    ul: Srv,
    q1: usize,
    q2: usize,
    dl_started: usize,
    ul_started: usize,
    ul_done: usize,
    waited: bool,
    finish: f64,
}

impl ClientSim {
    fn new(net: &NetworkModel, load: &ClientLoad, chunk_bytes: usize)
           -> ClientSim {
        let shared = net.sharing == Sharing::Shared;
        let bytes = load.down_bytes.max(load.up_bytes);
        let n = bytes.div_ceil(chunk_bytes).max(1);
        let nf = n as f64;
        let pipe_split = |bytes: usize, stage_s: f64, bps: f64| {
            // A transfer rides the shared pipe only when it moves real
            // bytes over a link with real time: zero-byte or zero-time
            // legs keep their fixed dedicated duration. The handshake
            // (`setup`) is the slack the profiled stage time carries
            // over its pure wire work, capped at one base latency —
            // for any >= 1x client that is exactly `latency_s`, which
            // keeps the round above the closed pipe-floor (latency +
            // total work) without double-charging the latency the
            // stage time already includes. The cap is the rate the
            // client's own profiled link sustains over the remainder,
            // never more than the whole pipe.
            if shared && bytes > 0 && stage_s > 0.0 {
                let work = bytes as f64 * 8.0 / bps;
                if work > 0.0 {
                    let setup =
                        (stage_s - work).clamp(0.0, net.latency_s);
                    let cap = (work / (stage_s - setup)).min(1.0);
                    return (work / nf, cap, setup);
                }
            }
            (0.0, 1.0, 0.0)
        };
        let (wd, cap_d, setup_d) =
            pipe_split(load.down_bytes, load.td, net.down_bps);
        let (wu, cap_u, setup_u) =
            pipe_split(load.up_bytes, load.tu, net.up_bps);
        ClientSim {
            n,
            dt: load.td / nf,
            ct: load.tc / nf,
            ut: load.tu / nf,
            wd,
            wu,
            cap_d,
            cap_u,
            setup_d,
            setup_u,
            dl: Srv::Idle,
            tr: Srv::Idle,
            ul: Srv::Idle,
            q1: 0,
            q2: 0,
            dl_started: 0,
            ul_started: 0,
            ul_done: 0,
            waited: load.waited,
            finish: 0.0,
        }
    }

    fn complete(&self) -> bool {
        self.ul_done >= self.n
    }

    fn start_download(&self) -> Srv {
        if self.wd > 0.0 {
            if self.dl_started == 0 && self.setup_d > 0.0 {
                Srv::Fixed { left: self.setup_d, then_pipe: self.wd }
            } else {
                Srv::Pipe { left: self.wd }
            }
        } else {
            Srv::Fixed { left: self.dt, then_pipe: 0.0 }
        }
    }

    fn start_upload(&self) -> Srv {
        if self.wu > 0.0 {
            if self.ul_started == 0 && self.setup_u > 0.0 {
                Srv::Fixed { left: self.setup_u, then_pipe: self.wu }
            } else {
                Srv::Pipe { left: self.wu }
            }
        } else {
            Srv::Fixed { left: self.ut, then_pipe: 0.0 }
        }
    }

    /// Fire every zero-time transition currently enabled, downstream
    /// stage first so freed slots propagate upstream within the pass.
    /// Returns whether anything changed (the caller loops to a fixed
    /// point).
    fn cascade(&mut self, t: f64, q_cap: usize, peak: &mut usize) -> bool {
        let unbounded = q_cap == 0;
        let mut changed = false;

        // Uploader: settle a finished service (terminal stage).
        let ul_finished = match self.ul {
            Srv::Fixed { left, then_pipe } if left <= EPS => {
                if then_pipe > 0.0 {
                    self.ul = Srv::Pipe { left: then_pipe };
                    changed = true;
                    false
                } else {
                    true
                }
            }
            Srv::Pipe { left } if left <= EPS => true,
            _ => false,
        };
        if ul_finished {
            self.ul = Srv::Idle;
            self.ul_done += 1;
            if self.ul_done == self.n {
                self.finish = t;
            }
            changed = true;
        }
        // Uploader: pull the next chunk.
        if matches!(self.ul, Srv::Idle) && self.q2 > 0 && !self.complete() {
            self.q2 -= 1;
            self.ul = self.start_upload();
            self.ul_started += 1;
            changed = true;
        }

        // Train: a blocked chunk enters the freed upload queue.
        if matches!(self.tr, Srv::Blocked) && (unbounded || self.q2 < q_cap) {
            self.q2 += 1;
            *peak = (*peak).max(self.q2);
            self.tr = Srv::Idle;
            changed = true;
        }
        // Train: settle finished compute (enqueue or block).
        if let Srv::Fixed { left, .. } = self.tr {
            if left <= EPS {
                if unbounded || self.q2 < q_cap {
                    self.q2 += 1;
                    *peak = (*peak).max(self.q2);
                    self.tr = Srv::Idle;
                } else {
                    self.tr = Srv::Blocked;
                }
                changed = true;
            }
        }
        // Train: pull the next chunk.
        if matches!(self.tr, Srv::Idle) && self.q1 > 0 {
            self.q1 -= 1;
            self.tr = Srv::Fixed { left: self.ct, then_pipe: 0.0 };
            changed = true;
        }

        // Downloader: a blocked chunk enters the freed train queue.
        if matches!(self.dl, Srv::Blocked) && (unbounded || self.q1 < q_cap) {
            self.q1 += 1;
            *peak = (*peak).max(self.q1);
            self.dl = Srv::Idle;
            changed = true;
        }
        // Downloader: settle a finished transfer (enqueue or block).
        let dl_finished = match self.dl {
            Srv::Fixed { left, then_pipe } if left <= EPS => {
                if then_pipe > 0.0 {
                    self.dl = Srv::Pipe { left: then_pipe };
                    changed = true;
                    false
                } else {
                    true
                }
            }
            Srv::Pipe { left } if left <= EPS => true,
            _ => false,
        };
        if dl_finished {
            if unbounded || self.q1 < q_cap {
                self.q1 += 1;
                *peak = (*peak).max(self.q1);
                self.dl = Srv::Idle;
            } else {
                self.dl = Srv::Blocked;
            }
            changed = true;
        }
        // Downloader: start the next chunk.
        if matches!(self.dl, Srv::Idle) && self.dl_started < self.n {
            self.dl = self.start_download();
            self.dl_started += 1;
            changed = true;
        }

        changed
    }

    /// Time until this client's next service completion at the given
    /// pipe rates.
    fn next_event(&self, rd: f64, ru: f64) -> f64 {
        let mut dt = f64::INFINITY;
        match self.dl {
            Srv::Fixed { left, .. } => dt = dt.min(left.max(0.0)),
            Srv::Pipe { left } if rd > 0.0 => {
                dt = dt.min((left / rd).max(0.0));
            }
            _ => {}
        }
        if let Srv::Fixed { left, .. } = self.tr {
            dt = dt.min(left.max(0.0));
        }
        match self.ul {
            Srv::Fixed { left, .. } => dt = dt.min(left.max(0.0)),
            Srv::Pipe { left } if ru > 0.0 => {
                dt = dt.min((left / ru).max(0.0));
            }
            _ => {}
        }
        dt
    }

    /// Advance every active service by `dt`; returns producer-blocked
    /// time accrued.
    fn advance(&mut self, dt: f64, rd: f64, ru: f64) -> f64 {
        let mut blocked = 0.0;
        match &mut self.dl {
            Srv::Fixed { left, .. } => *left -= dt,
            Srv::Pipe { left } => *left -= dt * rd,
            Srv::Blocked => blocked += dt,
            Srv::Idle => {}
        }
        match &mut self.tr {
            Srv::Fixed { left, .. } => *left -= dt,
            Srv::Blocked => blocked += dt,
            _ => {}
        }
        match &mut self.ul {
            Srv::Fixed { left, .. } => *left -= dt,
            Srv::Pipe { left } => *left -= dt * ru,
            Srv::Blocked => blocked += dt,
            Srv::Idle => {}
        }
        blocked
    }
}

/// Max-min fair allocation of one unit of pipe capacity across flows
/// with per-flow rate caps (water-filling): flows whose cap is at or
/// below the running fair share get their cap; the leftover is
/// re-split among the rest.
///
/// Delegates to [`crate::kernels::waterfill`], which replays the exact
/// sequential `left -= caps[i]` chain of the original allocating loop
/// (retained as `kernels::waterfill_ref`, property-pinned bitwise) but
/// without a per-call allocation. This wrapper keeps the old signature
/// for the tests; the per-event hot call inside [`simulate_round`]
/// uses [`crate::kernels::waterfill_pair`] with reused scratch.
#[cfg(test)]
fn max_min_rates(caps: &[f64], rates: &mut [f64]) {
    let mut scratch = Vec::new();
    crate::kernels::waterfill(caps, rates, &mut scratch);
}

/// Replay one round's settled loads through the chunked three-stage
/// pipeline and report how long the round takes plus the queue
/// pressure it saw. Pure and deterministic in its inputs.
pub fn simulate_round(net: &NetworkModel, clients: &[ClientLoad],
                      params: &SimParams) -> TimeEstimate {
    let chunk_bytes = params.chunk_kb.max(1).saturating_mul(1024);
    let q_cap = params.stage_queue;
    let shared = net.sharing == Sharing::Shared;
    // Simulate in ascending-cid order whatever order the loads arrived
    // in (the sink delivers sampling order, which need not be sorted):
    // every fold below — water-filling, blocked-time sums, tie settles
    // — then runs in one canonical order, so the result is
    // bit-identical across arrival orders, executors and windows.
    let mut by_cid: Vec<usize> = (0..clients.len()).collect();
    by_cid.sort_by_key(|&i| (clients[i].cid, i));
    let mut cs: Vec<ClientSim> = by_cid
        .iter()
        .map(|&i| ClientSim::new(net, &clients[i], chunk_bytes))
        .collect();

    let mut t = 0.0f64;
    let mut peak = 0usize;
    let mut block_s = 0.0f64;
    let mut down_rates = vec![1.0f64; cs.len()];
    let mut up_rates = vec![1.0f64; cs.len()];
    let mut down_caps = vec![0.0f64; cs.len()];
    let mut up_caps = vec![0.0f64; cs.len()];
    // Active-set scratch reused across every water-filling event (the
    // per-event hot path allocates nothing; see `kernels::waterfill`).
    let mut down_scratch: Vec<u32> = Vec::new();
    let mut up_scratch: Vec<u32> = Vec::new();

    loop {
        // Settle every enabled zero-time transition, deterministically.
        loop {
            let mut changed = false;
            for c in cs.iter_mut() {
                changed |= c.cascade(t, q_cap, &mut peak);
            }
            if !changed {
                break;
            }
        }
        if cs.iter().filter(|c| c.waited).all(|c| c.complete()) {
            break;
        }

        // Pipe shares for the current flow set (shared links only).
        if shared {
            for (i, c) in cs.iter().enumerate() {
                down_caps[i] = if matches!(c.dl, Srv::Pipe { .. }) {
                    c.cap_d
                } else {
                    0.0
                };
                up_caps[i] = if matches!(c.ul, Srv::Pipe { .. }) {
                    c.cap_u
                } else {
                    0.0
                };
            }
            crate::kernels::waterfill_pair(
                &down_caps, &mut down_rates, &mut down_scratch,
                &up_caps, &mut up_rates, &mut up_scratch,
            );
        }

        // Jump to the next completion anywhere in the system.
        let mut dt = f64::INFINITY;
        for (i, c) in cs.iter().enumerate() {
            dt = dt.min(c.next_event(down_rates[i], up_rates[i]));
        }
        if !dt.is_finite() {
            // No active service while a waited client is incomplete
            // would be a pipeline deadlock; the stage topology makes
            // that impossible (the terminal stage never blocks).
            debug_assert!(false, "event simulator stalled at t={t}");
            break;
        }
        t += dt;
        for (i, c) in cs.iter_mut().enumerate() {
            block_s += c.advance(dt, down_rates[i], up_rates[i]);
        }
    }

    let round_s = cs
        .iter()
        .filter(|c| c.waited)
        .map(|c| c.finish)
        .fold(0.0, f64::max);
    TimeEstimate { round_s, queue_peak: peak, queue_block_s: block_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> NetworkModel {
        NetworkModel::edge_lte()
    }

    fn survivor(cid: usize, td: f64, tc: f64, tu: f64, down: usize,
                up: usize) -> ClientLoad {
        ClientLoad {
            cid,
            td,
            tc,
            tu,
            down_bytes: down,
            up_bytes: up,
            waited: true,
        }
    }

    #[test]
    fn kind_parses_labels_and_builds() {
        assert_eq!(TimeModelKind::parse("closed"),
                   Some(TimeModelKind::Closed));
        assert_eq!(TimeModelKind::parse("event"), Some(TimeModelKind::Event));
        assert_eq!(TimeModelKind::parse("fluid"), None);
        assert_eq!(TimeModelKind::default(), TimeModelKind::Closed);
        assert_eq!(TimeModelKind::Closed.label(), "closed");
        assert_eq!(TimeModelKind::Event.build(8, 2).label(), "event");
        assert_eq!(SimParams::default().chunk_kb, 64);
        assert_eq!(SimParams::default().stage_queue, 4);
    }

    #[test]
    fn single_chunk_equals_the_full_chain() {
        // One chunk per message leaves nothing to overlap: the event
        // time is the download + compute + upload chain, i.e. the
        // parallel envelope.
        let loads = [survivor(0, 0.9, 0.5, 0.3, 10_000, 10_000)];
        let params = SimParams { chunk_kb: 1024, stage_queue: 1 };
        let out = simulate_round(&net(), &loads, &params);
        assert!((out.round_s - 1.7).abs() < 1e-9, "{}", out.round_s);
    }

    #[test]
    fn fine_chunks_converge_to_the_slowest_stage() {
        // 100 chunks: the gap to the pipelined envelope is
        // (chain - max_stage) / n.
        let loads = [survivor(0, 0.9, 0.5, 0.3, 102_400, 102_400)];
        let params = SimParams { chunk_kb: 1, stage_queue: 0 };
        let out = simulate_round(&net(), &loads, &params);
        let expect = 0.9 + (1.7 - 0.9) / 100.0;
        assert!((out.round_s - expect).abs() < 1e-9,
                "{} vs {}", out.round_s, expect);
    }

    #[test]
    fn finite_queue_blocks_producers_without_stretching_dedicated_rounds() {
        // Constant per-stage chunk times: the bottleneck stage is never
        // starved even at queue capacity 1, so the round time matches
        // the unbounded-queue pipeline — but the producers upstream of
        // the slow compute stage visibly block.
        let loads = [survivor(0, 0.2, 2.0, 0.2, 409_600, 409_600)];
        let tight = simulate_round(
            &net(), &loads, &SimParams { chunk_kb: 100, stage_queue: 1 });
        let open = simulate_round(
            &net(), &loads, &SimParams { chunk_kb: 100, stage_queue: 0 });
        assert!((tight.round_s - open.round_s).abs() < 1e-9);
        assert!(tight.queue_block_s > 0.0);
        assert!(tight.queue_peak <= 1);
        assert!(open.queue_block_s == 0.0);
        assert!(open.queue_peak > 1);
    }

    #[test]
    fn dropped_clients_cost_their_download_only() {
        let loads = [ClientLoad {
            cid: 3,
            td: 0.7,
            tc: 0.0,
            tu: 0.0,
            down_bytes: 50_000,
            up_bytes: 0,
            waited: true,
        }];
        for chunk_kb in [1usize, 16, 1024] {
            let out = simulate_round(
                &net(), &loads,
                &SimParams { chunk_kb, stage_queue: 2 });
            assert!((out.round_s - 0.7).abs() < 1e-9, "{}", out.round_s);
        }
    }

    #[test]
    fn cancelled_clients_never_extend_the_round() {
        let mut loads = vec![survivor(0, 0.1, 0.2, 0.1, 5_000, 5_000)];
        let base = simulate_round(&net(), &loads, &SimParams::default());
        loads.push(ClientLoad {
            cid: 1,
            td: 50.0,
            tc: 0.0,
            tu: 0.0,
            down_bytes: 50_000_000,
            up_bytes: 0,
            waited: false,
        });
        let with_cancel =
            simulate_round(&net(), &loads, &SimParams::default());
        // Dedicated links: the cancelled straggler is invisible (up to
        // clock-accumulation rounding — its chunk completions
        // interleave with the survivor's event times).
        assert!((base.round_s - with_cancel.round_s).abs() < 1e-9,
                "{} vs {}", base.round_s, with_cancel.round_s);
        // Only cancelled clients: the round never waits at all.
        let only = simulate_round(&net(), &loads[1..],
                                  &SimParams::default());
        assert_eq!(only.round_s, 0.0);
    }

    #[test]
    fn shared_pipe_floors_at_busy_time_and_contends() {
        let shared = net().with_sharing(Sharing::Shared);
        let loads: Vec<ClientLoad> = (0..4)
            .map(|cid| {
                let td = shared.download_time(1_000_000);
                let tu = shared.upload_time(1_000_000);
                survivor(cid, td, 0.25, tu, 1_000_000, 1_000_000)
            })
            .collect();
        let params = SimParams { chunk_kb: 64, stage_queue: 0 };
        let out = simulate_round(&shared, &loads, &params);
        // Closed envelopes from the same loads.
        let mut acc = RoundLoad::new();
        for l in &loads {
            acc.add_stages(l.td, l.tc, l.tu, l.down_bytes, l.up_bytes);
        }
        assert!(out.round_s >= acc.pipelined_s(&shared) - 1e-9,
                "{} < pipelined {}", out.round_s,
                acc.pipelined_s(&shared));
        assert!(out.round_s <= acc.serial_s() + 1e-9);
        // And contention is real: four clients on one pipe take longer
        // than the same four on dedicated links.
        let dedicated = simulate_round(&net(), &loads, &params);
        assert!(out.round_s > dedicated.round_s);
    }

    #[test]
    fn simulation_is_deterministic() {
        let loads: Vec<ClientLoad> = (0..7)
            .map(|cid| {
                survivor(cid, 0.1 * (cid + 1) as f64, 0.3, 0.2,
                         90_000 + cid * 1_000, 70_000)
            })
            .collect();
        for sharing in [Sharing::Dedicated, Sharing::Shared] {
            let n = net().with_sharing(sharing);
            let params = SimParams { chunk_kb: 8, stage_queue: 2 };
            let a = simulate_round(&n, &loads, &params);
            let b = simulate_round(&n, &loads, &params);
            assert_eq!(a, b, "{sharing:?}");
            // Load arrival order must not matter either: the loop
            // settles in cid order.
            let mut rev = loads.clone();
            rev.reverse();
            let c = simulate_round(&n, &rev, &params);
            assert_eq!(a, c, "{sharing:?} reversed arrival");
        }
    }

    #[test]
    fn closed_model_reports_the_pipelined_envelope() {
        let n = net();
        let mut acc = RoundLoad::new();
        acc.add_stages(0.1, 0.5, 0.3, 1_000, 2_000);
        let est = ClosedTimeModel.round_time(&n, &acc, &[]);
        assert_eq!(est.round_s, acc.pipelined_s(&n));
        assert_eq!(est.queue_peak, 0);
        assert_eq!(est.queue_block_s, 0.0);
    }

    #[test]
    fn max_min_water_filling() {
        let mut rates = [0.0; 3];
        // Uncapped flows split evenly.
        max_min_rates(&[1.0, 1.0, 1.0], &mut rates);
        for r in rates {
            assert!((r - 1.0 / 3.0).abs() < 1e-12);
        }
        // A slow flow keeps its cap; the others share the rest.
        max_min_rates(&[0.1, 1.0, 1.0], &mut rates);
        assert!((rates[0] - 0.1).abs() < 1e-12);
        assert!((rates[1] - 0.45).abs() < 1e-12);
        assert!((rates[2] - 0.45).abs() < 1e-12);
        // Under-subscribed pipe: everyone runs at cap.
        max_min_rates(&[0.2, 0.3], &mut rates[..2]);
        assert!((rates[0] - 0.2).abs() < 1e-12);
        assert!((rates[1] - 0.3).abs() < 1e-12);
    }
}
