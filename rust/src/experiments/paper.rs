//! The paper's published numbers (FLoCoRA, EUSIPCO 2024), encoded once
//! so every bench prints paper-vs-ours side by side and EXPERIMENTS.md
//! can be regenerated mechanically.

/// Table I — ResNet-8 parameter counts. `(rank, total, trained)`;
/// rank 0 encodes the FedAvg row.
pub const TABLE1: &[(usize, f64, f64)] = &[
    (0, 1.23e6, 1.23e6),
    (8, 1.30e6, 69.45e3),
    (16, 1.36e6, 131.92e3),
    (32, 1.48e6, 256.84e3),
    (64, 1.73e6, 506.70e3),
    (128, 2.23e6, 1.00e6),
];

/// Table II — layer ablation, ResNet-8 r=32 α=512, CIFAR-10 LDA(0.5).
/// `(label, params_to_update, acc_mean, acc_std)`.
pub const TABLE2: &[(&str, f64, f64, f64)] = &[
    ("FedAvg", 1.23e6, 76.14, 0.74),
    ("FLoCoRA Vanilla", 0.26e6, 22.14, 3.99),
    ("+ Norm. layers", 0.26e6, 39.80, 12.05),
    ("+ Final FC", 0.26e6, 75.51, 1.34),
];

/// Table III — TCC over 100 rounds, ResNet-8 r=32 α=512.
/// `(label, tcc_mb, ratio, acc_mean, acc_std)`.
pub const TABLE3: &[(&str, f64, f64, f64, f64)] = &[
    ("FedAvg FP", 982.07, 1.0, 76.14, 0.74),
    ("FLoCoRA FP", 205.47, 4.8, 75.51, 1.34),
    ("FLoCoRA int8", 55.56, 17.7, 74.21, 1.05),
    ("FLoCoRA int4", 30.15, 32.6, 73.15, 0.18),
    ("FLoCoRA int2", 17.44, 56.3, 55.03, 1.90),
];

/// Figure 2 — accuracy vs rank for α = 2r and α = 16r (ResNet-8,
/// CIFAR-10 LDA(0.5)). Values are read off the published plot to ~±0.5
/// and serve for shape comparison only. `(rank, acc_2r, acc_16r)`.
pub const FIG2: &[(usize, f64, f64)] = &[
    (8, 66.0, 71.5),
    (16, 69.0, 73.5),
    (32, 71.0, 75.5),
    (64, 73.0, 76.5),
    (128, 75.5, 78.1),
];

/// FedAvg reference line in Fig. 2.
pub const FIG2_FEDAVG: f64 = 76.14;

/// Figure 3 — convergence: the qualitative claims we verify at scale:
/// FP and int8 curves track each other; int2 collapses well below.
pub const FIG3_CLAIMS: &[&str] = &[
    "FLoCoRA-FP reaches within 1% of FedAvg",
    "int8 convergence is not delayed vs FP",
    "int4 degrades ~2%; int2 collapses by >15%",
];

/// Table IV — ResNet-18, 700 rounds, LDA(1.0), 100 clients, 1 epoch.
/// `(label, message_mb, ratio, tcc_gb, acc_mean, acc_std)`.
pub const TABLE4: &[(&str, f64, f64, f64, f64, f64)] = &[
    ("FedAvg Full Model", 44.7, 1.0, 62.6, 84.43, 0.36),
    ("ZeroFL 90%SP+0.2MR", 27.3, 1.6, 38.2, 81.04, 0.28),
    ("ZeroFL 90%SP+0.0MR", 10.1, 4.4, 14.1, 73.87, 0.50),
    ("MagPrune 40%", 27.1, 1.6, 38.0, 85.20, 0.20),
    ("MagPrune 80%", 9.8, 4.6, 13.7, 80.70, 0.24),
    ("FLoCoRA r=64", 9.2, 4.9, 12.9, 85.17, 0.44),
    ("FLoCoRA r=32", 4.6, 9.7, 6.5, 83.90, 0.20),
    ("FLoCoRA r=16", 2.4, 18.6, 3.3, 82.33, 0.35),
    ("FLoCoRA r=64 Q8", 2.4, 18.6, 3.3, 85.24, 0.23),
    ("FLoCoRA r=32 Q8", 1.2, 37.3, 1.7, 83.95, 0.32),
    ("FLoCoRA r=16 Q8", 0.7, 63.9, 1.0, 81.89, 1.01),
];

/// Headline claims (abstract): compression ratios at <1% accuracy loss.
pub const HEADLINE_RESNET8_RATIO: f64 = 4.8;
pub const HEADLINE_RESNET18_RATIO: f64 = 18.6;
