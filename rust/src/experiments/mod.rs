//! Experiment reproduction: one module per paper table/figure.
//!
//! * [`paper`]  — the published numbers, encoded once.
//! * [`tables`] — analytic regenerators (exact at paper scale) for every
//!   size/TCC column, plus the scaled-accuracy run matrices.
//! * [`runners`] — multi-seed scaled runs on the live stack.

pub mod paper;
pub mod runners;
pub mod tables;
