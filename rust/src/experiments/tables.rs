//! Analytic table regenerators — exact reproductions of every *size*
//! column in the paper, computed from the same `ParamSpec` arithmetic
//! the artifacts are built from (no training required).

use crate::compression::affine::segment_encoded_size;
use crate::compression::{SparseEfCodec, TopKCodec, ZeroFlCodec};
use crate::model::{build_spec, ModelCfg, ParamSpec, Variant};
use crate::transport::tcc_equation2;

/// A printable table: header + rows of cells.
#[derive(Debug, Clone)]
pub struct TableOut {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableOut {
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

fn resnet8(variant: Variant, rank: usize) -> ParamSpec {
    build_spec(ModelCfg::by_name("resnet8").unwrap(), variant, rank)
}

fn resnet18(variant: Variant, rank: usize) -> ParamSpec {
    build_spec(ModelCfg::by_name("resnet18").unwrap(), variant, rank)
}

/// Exact affine-quantized message bytes for a spec's trainable vector
/// (codes + fp scale/zero-point overhead + fp norm layers — the same
/// accounting the paper applies in Table III).
pub fn quantized_message_bytes(spec: &ParamSpec, bits: u32) -> usize {
    spec.trainable
        .iter()
        .map(|s| segment_encoded_size(s, bits))
        .sum()
}

/// Table I — parameter counts for the ResNet-8 rank ladder.
pub fn table1() -> TableOut {
    let mut rows = Vec::new();
    let full = resnet8(Variant::Full, 0);
    let base = full.num_trainable() as f64;
    rows.push(vec![
        "FedAvg".to_string(),
        format!("{:.2}M", base / 1e6),
        format!("{:.2}M", base / 1e6),
        "100.00".to_string(),
        "1.23M / 1.23M".to_string(),
    ]);
    for &(rank, total_p, trained_p) in &crate::experiments::paper::TABLE1[1..] {
        let spec = resnet8(Variant::LoraFc, rank);
        let total = spec.num_total() as f64;
        let trained = spec.num_trainable() as f64;
        rows.push(vec![
            format!("FLoCoRA (r={rank})"),
            format!("{:.2}M", total / 1e6),
            if trained >= 1e6 {
                format!("{:.2}M", trained / 1e6)
            } else {
                format!("{:.2}K", trained / 1e3)
            },
            format!("{:.2}", 100.0 * trained / total),
            format!("{:.2}M / {:.2}K", total_p / 1e6, trained_p / 1e3),
        ]);
    }
    TableOut {
        title: "Table I — ResNet-8 parameters (ours vs paper)".into(),
        header: vec!["Method".into(), "Total".into(), "Trained".into(),
                     "% Trained".into(), "Paper (total/trained)".into()],
        rows,
    }
}

/// Table III — TCC over 100 rounds, ResNet-8 r=32 (exact analytic).
/// Returns the table plus `(label, ours_mb)` pairs for tests.
pub fn table3() -> (TableOut, Vec<(String, f64)>) {
    let rounds = 100;
    let full = resnet8(Variant::Full, 0);
    let lora = resnet8(Variant::LoraFc, 32);
    let mut pairs = Vec::new();
    let mut rows = Vec::new();

    let fedavg_mb = tcc_equation2(rounds, 32, full.num_trainable()) / 1e6;
    let flocora_fp_mb = tcc_equation2(rounds, 32, lora.num_trainable()) / 1e6;
    let paper = crate::experiments::paper::TABLE3;
    let mut push = |label: &str, ours_mb: f64, paper_mb: f64| {
        rows.push(vec![
            label.to_string(),
            format!("{:.2} MB", ours_mb),
            format!("÷{:.1}", fedavg_mb / ours_mb),
            format!("{:.2} MB (÷{:.1})", paper_mb, fedavg_mb / paper_mb),
        ]);
        pairs.push((label.to_string(), ours_mb));
    };

    push("FedAvg FP", fedavg_mb, paper[0].1);
    push("FLoCoRA FP", flocora_fp_mb, paper[1].1);
    for (i, bits) in [(2usize, 8u32), (3, 4), (4, 2)] {
        let msg = quantized_message_bytes(&lora, bits) as f64;
        let mb = 2.0 * rounds as f64 * msg / 1e6;
        push(&format!("FLoCoRA int{bits}"), mb, paper[i].1);
    }

    (
        TableOut {
            title: "Table III — TCC, 100 rounds, ResNet-8 r=32 (ours vs paper)"
                .into(),
            header: vec!["Method".into(), "TCC (ours)".into(),
                         "Ratio (ours)".into(), "Paper".into()],
            rows,
        },
        pairs,
    )
}

/// Table IV — message sizes and TCC for ResNet-18 at 700 rounds
/// (exact analytic sizes; accuracies come from the scaled runners).
pub fn table4_sizes() -> (TableOut, Vec<(String, f64)>) {
    let rounds = 700;
    let full = resnet18(Variant::Full, 0);
    let full_mb = full.num_trainable() as f64 * 4.0 / 1e6;
    let mut pairs = Vec::new();
    let mut rows = Vec::new();
    let paper = crate::experiments::paper::TABLE4;

    let mut push = |label: &str, msg_mb: f64, paper_msg: f64| {
        rows.push(vec![
            label.to_string(),
            format!("{:.2} MB", msg_mb),
            format!("÷{:.1}", full_mb / msg_mb),
            format!("{:.1} GB", 2.0 * rounds as f64 * msg_mb / 1e3),
            format!("{paper_msg} MB"),
        ]);
        pairs.push((label.to_string(), msg_mb));
    };

    push("FedAvg Full Model", full_mb, paper[0].1);

    // ZeroFL: (index, value) pairs over the full model.
    for (row, sp, mr) in [(1usize, 0.9f32, 0.2f32), (2, 0.9, 0.0)] {
        let c = ZeroFlCodec::new(sp, mr);
        let bytes = 8.0 + c.kept_count(full.num_trainable()) as f64 * 8.0;
        push(&format!("ZeroFL {:.0}%SP+{:.1}MR", sp * 100.0, mr),
             bytes / 1e6, paper[row].1);
    }

    // Magnitude pruning: bitmap + survivors.
    for (row, prune) in [(3usize, 0.4f32), (4, 0.8)] {
        let keep = 1.0 - prune;
        let c = TopKCodec::new(keep);
        let n = full.num_trainable();
        let bytes = 8.0 + n.div_ceil(8) as f64 + c.kept_count(n) as f64 * 4.0;
        push(&format!("MagPrune {:.0}%", prune * 100.0), bytes / 1e6,
             paper[row].1);
    }

    // FLoCoRA rank ladder, FP and Q8.
    for (row, rank) in [(5usize, 64usize), (6, 32), (7, 16)] {
        let spec = resnet18(Variant::LoraFc, rank);
        push(&format!("FLoCoRA r={rank}"),
             spec.num_trainable() as f64 * 4.0 / 1e6, paper[row].1);
    }
    for (row, rank) in [(8usize, 64usize), (9, 32), (10, 16)] {
        let spec = resnet18(Variant::LoraFc, rank);
        push(&format!("FLoCoRA r={rank} Q8"),
             quantized_message_bytes(&spec, 8) as f64 / 1e6, paper[row].1);
    }

    (
        TableOut {
            title: "Table IV — ResNet-18 message sizes (ours vs paper)".into(),
            header: vec!["Method".into(), "Msg (ours)".into(), "Ratio".into(),
                         "TCC@700r".into(), "Paper msg".into()],
            rows,
        },
        pairs,
    )
}

/// Aggregation-zoo bytes table — per-round upload message size for
/// each wire codec on the ResNet-8 r=32 adapter vector, plus the
/// broadcast size SVT reaches when the energy threshold keeps only
/// `k` of the 32 singular directions (adapter params scale linearly
/// in rank, so rank-k broadcast ≡ the rank-k layout's vector).
/// Accuracy columns come from training runs (`--preset svt_micro`,
/// `--preset sparse_ef_micro` with `--json`); this table prices the
/// bytes axis exactly. Returns `(label, bytes)` pairs for tests.
pub fn table_zoo() -> (TableOut, Vec<(String, f64)>) {
    let lora = resnet8(Variant::LoraFc, 32);
    let n = lora.num_trainable();
    let fp_bytes = n as f64 * 4.0;
    let mut pairs = Vec::new();
    let mut rows = Vec::new();
    let mut push = |label: &str, bytes: f64, note: &str| {
        rows.push(vec![
            label.to_string(),
            format!("{:.1} kB", bytes / 1e3),
            format!("÷{:.1}", fp_bytes / bytes),
            note.to_string(),
        ]);
        pairs.push((label.to_string(), bytes));
    };

    push("FP32", fp_bytes, "baseline adapter vector");
    push("Q8", quantized_message_bytes(&lora, 8) as f64,
         "affine per-row quantization");
    // Bitmap sparse codecs: header + presence bitmap + survivors.
    let bitmap = |keep: usize| 8.0 + n.div_ceil(8) as f64 + keep as f64 * 4.0;
    push("TopK 25%", bitmap(TopKCodec::new(0.25).kept_count(n)),
         "stateless magnitude top-k");
    push("SparseEF 25%", bitmap(SparseEfCodec::new(0.25).kept_count(n)),
         "same wire as TopK + residual carry");
    // SVT: the kept rank prices the broadcast.
    for k in [8usize, 16, 32] {
        let spec = resnet8(Variant::LoraFc, k);
        push(&format!("SVT rank {k}"),
             spec.num_trainable() as f64 * 4.0,
             if k == 32 { "τ = 1.0 (no truncation)" }
             else { "energy-thresholded broadcast" });
    }

    (
        TableOut {
            title: "Aggregation zoo — per-round message bytes, ResNet-8 r=32"
                .into(),
            header: vec!["Method".into(), "Msg".into(), "Ratio".into(),
                         "Notes".into()],
            rows,
        },
        pairs,
    )
}

/// Fig. 2 x-axis: trained parameters per rank (exact).
pub fn fig2_param_axis() -> Vec<(usize, usize)> {
    [8usize, 16, 32, 64, 128]
        .iter()
        .map(|&r| (r, resnet8(Variant::LoraFc, r).num_trainable()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows_match_paper_within_2pct() {
        let t = table1();
        assert_eq!(t.rows.len(), 6);
        // Spot-check r=32: ours 1.48M / 258.0K vs paper 1.48M / 256.84K.
        let spec = resnet8(Variant::LoraFc, 32);
        assert!((spec.num_total() as f64 - 1.48e6).abs() / 1.48e6 < 0.02);
        assert!(
            (spec.num_trainable() as f64 - 256.84e3).abs() / 256.84e3 < 0.02
        );
    }

    #[test]
    fn table3_ratios_match_paper_shape() {
        let (_t, pairs) = table3();
        let fedavg = pairs[0].1;
        let expect = [
            ("FLoCoRA FP", 4.8),
            ("FLoCoRA int8", 17.7),
            ("FLoCoRA int4", 32.6),
            ("FLoCoRA int2", 56.3),
        ];
        for (label, paper_ratio) in expect {
            let ours = pairs.iter().find(|(l, _)| l == label).unwrap().1;
            let ratio = fedavg / ours;
            // Within 6% of the paper's printed ratio — the residual is
            // the (paper-unspecified) exact ResNet-8 layout.
            assert!(
                (ratio - paper_ratio).abs() / paper_ratio < 0.06,
                "{label}: ours ÷{ratio:.2} vs paper ÷{paper_ratio}"
            );
        }
    }

    #[test]
    fn table4_messages_match_paper_shape() {
        let (_t, pairs) = table4_sizes();
        let get = |l: &str| pairs.iter().find(|(p, _)| p == l).unwrap().1;
        // Full model exact.
        assert!((get("FedAvg Full Model") - 44.7).abs() < 0.5);
        // FLoCoRA FP ladder within 6%.
        for (label, paper_mb) in
            [("FLoCoRA r=64", 9.2), ("FLoCoRA r=32", 4.6), ("FLoCoRA r=16", 2.4)]
        {
            let ours = get(label);
            assert!((ours - paper_mb).abs() / paper_mb < 0.06,
                    "{label}: {ours} vs {paper_mb}");
        }
        // Q8 ladder within 10% (scale/zp overhead model).
        for (label, paper_mb) in [("FLoCoRA r=64 Q8", 2.4),
                                  ("FLoCoRA r=32 Q8", 1.2),
                                  ("FLoCoRA r=16 Q8", 0.7)] {
            let ours = get(label);
            assert!((ours - paper_mb).abs() / paper_mb < 0.10,
                    "{label}: {ours} vs {paper_mb}");
        }
        // Sparse baselines within 15% (paper does not itemize overheads).
        for (label, paper_mb) in [("ZeroFL 90%SP+0.2MR", 27.3),
                                  ("ZeroFL 90%SP+0.0MR", 10.1),
                                  ("MagPrune 40%", 27.1),
                                  ("MagPrune 80%", 9.8)] {
            let ours = get(label);
            assert!((ours - paper_mb).abs() / paper_mb < 0.15,
                    "{label}: {ours} vs {paper_mb}");
        }
    }

    #[test]
    fn table_zoo_prices_the_bytes_axis() {
        let (t, pairs) = table_zoo();
        assert_eq!(t.rows.len(), pairs.len());
        let get = |l: &str| pairs.iter().find(|(p, _)| p == l).unwrap().1;
        // Sparse-EF changes payload contents, never payload size.
        assert_eq!(get("SparseEF 25%"), get("TopK 25%"));
        // Every truncating row beats the FP32 baseline (SVT at τ = 1.0
        // is deliberately the identity).
        let fp = get("FP32");
        for (label, bytes) in &pairs {
            if label != "FP32" && label != "SVT rank 32" {
                assert!(*bytes < fp, "{label}: {bytes} >= {fp}");
            }
        }
        // SVT broadcast bytes grow monotonically with the kept rank,
        // and τ = 1.0 (rank 32) prices as the untruncated adapter.
        assert!(get("SVT rank 8") < get("SVT rank 16"));
        assert!(get("SVT rank 16") < get("SVT rank 32"));
        assert_eq!(get("SVT rank 32"), fp);
    }

    #[test]
    fn fig2_axis_monotone() {
        let axis = fig2_param_axis();
        assert!(axis.windows(2).all(|w| w[0].1 < w[1].1));
    }

    #[test]
    fn render_is_aligned() {
        let t = table1();
        let s = t.render();
        assert!(s.contains("Table I"));
        assert!(s.lines().count() >= 8);
    }
}
