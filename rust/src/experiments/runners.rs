//! Multi-seed scaled experiment runners: the accuracy columns of every
//! table/figure, executed on the live three-layer stack at the scaled
//! profiles (DESIGN.md §2 explains the substitution; the benches print
//! paper-vs-ours with both clearly labelled).

use crate::config::FlConfig;
use crate::coordinator::Simulation;
use crate::error::Result;
use crate::metrics::{mean_std, Recorder};
use crate::runtime::Engine;

/// Summary over seeds.
#[derive(Debug, Clone)]
pub struct SeedSweep {
    pub label: String,
    pub accs: Vec<f64>,
    pub acc_mean: f64,
    pub acc_std: f64,
    pub mean_up_msg_bytes: f64,
    pub total_bytes: u64,
    pub recorders: Vec<Recorder>,
}

/// Run `cfg` once per seed; returns accuracy stats (tail-averaged, the
/// paper reports end-of-training accuracy over 3 seeds).
pub fn run_seeds(
    engine: &Engine,
    base: &FlConfig,
    label: &str,
    seeds: &[u64],
) -> Result<SeedSweep> {
    let mut accs = Vec::new();
    let mut recorders = Vec::new();
    let mut mean_up = 0.0;
    let mut total_bytes = 0u64;
    for &seed in seeds {
        let mut cfg = base.clone();
        cfg.seed = seed;
        let mut sim = Simulation::new(engine, cfg)?;
        let mut rec = Recorder::new(format!("{label}/seed{seed}"));
        let summary = sim.run(&mut rec)?;
        accs.push(summary.tail_acc * 100.0);
        mean_up = summary.mean_up_msg_bytes;
        total_bytes = summary.total_bytes;
        recorders.push(rec);
    }
    let (acc_mean, acc_std) = mean_std(&accs);
    Ok(SeedSweep {
        label: label.to_string(),
        accs,
        acc_mean,
        acc_std,
        mean_up_msg_bytes: mean_up,
        total_bytes,
        recorders,
    })
}

/// Format a sweep like the paper's `mean ± std` cells.
pub fn cell(s: &SeedSweep) -> String {
    format!("{:.2} ± {:.2}", s.acc_mean, s.acc_std)
}
