//! Parameter-layout construction (rust mirror of `configs.build_spec`).
//!
//! Everything the coordinator knows about a model comes from here or the
//! manifest: flat-vector offsets, per-tensor quantization grouping,
//! trainable/frozen split per variant, and the paper-scale parameter
//! counts behind Tables I, III and IV.

use std::fmt;

/// Static architecture description (matches `configs.ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: &'static str,
    pub widths: &'static [usize],
    pub blocks_per_stage: usize,
    pub image_size: usize,
    pub num_classes: usize,
    pub batch_size: usize,
}

/// The four models of the reproduction (DESIGN.md §2).
pub const MODELS: &[ModelCfg] = &[
    ModelCfg { name: "micro8", widths: &[4, 8, 16], blocks_per_stage: 1,
               image_size: 16, num_classes: 10, batch_size: 8 },
    ModelCfg { name: "tiny8", widths: &[8, 16, 32], blocks_per_stage: 1,
               image_size: 32, num_classes: 10, batch_size: 32 },
    ModelCfg { name: "resnet8", widths: &[64, 128, 256], blocks_per_stage: 1,
               image_size: 32, num_classes: 10, batch_size: 32 },
    ModelCfg { name: "resnet18", widths: &[64, 128, 256, 512],
               blocks_per_stage: 2, image_size: 32, num_classes: 10,
               batch_size: 32 },
];

impl ModelCfg {
    pub fn by_name(name: &str) -> Option<&'static ModelCfg> {
        MODELS.iter().find(|m| m.name == name)
    }
}

/// Training variant — the Table II ablation axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// FedAvg: everything trainable.
    Full,
    /// "FLoCoRA Vanilla": adapters everywhere incl. FC; norm/FC frozen.
    LoraAll,
    /// + norm layers trained.
    LoraNorm,
    /// + final FC trained directly (the paper's standard FLoCoRA).
    LoraFc,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        Some(match s {
            "full" => Variant::Full,
            "lora_all" => Variant::LoraAll,
            "lora_norm" => Variant::LoraNorm,
            "lora_fc" => Variant::LoraFc,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Full => "full",
            Variant::LoraAll => "lora_all",
            Variant::LoraNorm => "lora_norm",
            Variant::LoraFc => "lora_fc",
        }
    }

    pub fn is_lora(&self) -> bool {
        !matches!(self, Variant::Full)
    }
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Parameter-tensor kind (drives trainability + quant grouping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    Conv,
    LoraB,
    LoraA,
    NormW,
    NormB,
    FcW,
    FcB,
    FcLoraB,
    FcLoraA,
}

impl ParamKind {
    pub fn parse(s: &str) -> Option<ParamKind> {
        Some(match s {
            "conv" => ParamKind::Conv,
            "lora_b" => ParamKind::LoraB,
            "lora_a" => ParamKind::LoraA,
            "norm_w" => ParamKind::NormW,
            "norm_b" => ParamKind::NormB,
            "fc_w" => ParamKind::FcW,
            "fc_b" => ParamKind::FcB,
            "fc_lora_b" => ParamKind::FcLoraB,
            "fc_lora_a" => ParamKind::FcLoraA,
            _ => return None,
        })
    }
}

/// One tensor segment inside a flat vector.
#[derive(Debug, Clone)]
pub struct Segment {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    pub kind: ParamKind,
    pub offset: usize,
    /// Leading-dim row count for per-channel/per-column quantization;
    /// `None` => never quantized (norm layers, paper §IV).
    pub quant_rows: Option<usize>,
}

/// Fully resolved layout for (model, variant, rank).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub cfg: ModelCfg,
    pub variant: Variant,
    pub rank: usize,
    pub trainable: Vec<Segment>,
    pub frozen: Vec<Segment>,
}

impl ParamSpec {
    pub fn num_trainable(&self) -> usize {
        self.trainable.iter().map(|s| s.numel).sum()
    }

    pub fn num_frozen(&self) -> usize {
        self.frozen.iter().map(|s| s.numel).sum()
    }

    pub fn num_total(&self) -> usize {
        self.num_trainable() + self.num_frozen()
    }

    /// Artifact tag, e.g. `resnet8_lora_fc_r32`.
    pub fn tag(&self) -> String {
        if self.variant == Variant::Full {
            format!("{}_full", self.cfg.name)
        } else {
            format!("{}_{}_r{}", self.cfg.name, self.variant, self.rank)
        }
    }
}

/// Conv enumeration: (name, out_ch, in_ch, kernel, stride), identical
/// order to `configs.iter_convs` — downsample convs included.
pub fn conv_enumeration(
    cfg: &ModelCfg,
) -> Vec<(String, usize, usize, usize, usize)> {
    let mut out = Vec::new();
    let w0 = cfg.widths[0];
    out.push(("conv1".to_string(), w0, 3, 3, 1));
    let mut in_ch = w0;
    for (s, &width) in cfg.widths.iter().enumerate() {
        let stride = if s == 0 { 1 } else { 2 };
        for b in 0..cfg.blocks_per_stage {
            let bs = if b == 0 { stride } else { 1 };
            let pre = format!("s{s}.b{b}");
            out.push((format!("{pre}.conv1"), width, in_ch, 3, bs));
            out.push((format!("{pre}.conv2"), width, width, 3, 1));
            if bs != 1 || in_ch != width {
                out.push((format!("{pre}.down"), width, in_ch, 1, bs));
            }
            in_ch = width;
        }
    }
    out
}

/// Build the deterministic layout (rust mirror of `configs.build_spec`).
pub fn build_spec(cfg: &ModelCfg, variant: Variant, rank: usize) -> ParamSpec {
    let mut spec = ParamSpec {
        cfg: cfg.clone(),
        variant,
        rank,
        trainable: Vec::new(),
        frozen: Vec::new(),
    };

    let lora = variant.is_lora();
    let train_norm = matches!(variant,
                              Variant::Full | Variant::LoraNorm | Variant::LoraFc);
    let train_fc = matches!(variant, Variant::Full | Variant::LoraFc);

    fn add(spec: &mut ParamSpec, trainable: bool, name: String,
           shape: Vec<usize>, kind: ParamKind, quant_rows: Option<usize>) {
        let numel = shape.iter().product();
        let side = if trainable { &mut spec.trainable } else { &mut spec.frozen };
        let offset = side.iter().map(|s| s.numel).sum();
        side.push(Segment { name, shape, numel, kind, offset, quant_rows });
    }

    for (name, o, i, k, _stride) in conv_enumeration(cfg) {
        add(&mut spec, !lora, name.clone(), vec![o, i, k, k],
            ParamKind::Conv, Some(o));
        if lora {
            add(&mut spec, true, format!("{name}.lora_b"),
                vec![rank, i, k, k], ParamKind::LoraB, Some(rank));
            add(&mut spec, true, format!("{name}.lora_a"),
                vec![o, rank, 1, 1], ParamKind::LoraA, Some(o));
        }
        add(&mut spec, train_norm, format!("{name}.gn.w"), vec![o],
            ParamKind::NormW, None);
        add(&mut spec, train_norm, format!("{name}.gn.b"), vec![o],
            ParamKind::NormB, None);
    }

    let d = *cfg.widths.last().unwrap();
    let c = cfg.num_classes;
    add(&mut spec, train_fc, "fc.w".into(), vec![d, c], ParamKind::FcW,
        Some(c));
    add(&mut spec, train_fc, "fc.b".into(), vec![c], ParamKind::FcB,
        Some(c));
    if variant == Variant::LoraAll {
        add(&mut spec, true, "fc.lora_b".into(), vec![d, rank],
            ParamKind::FcLoraB, Some(rank));
        add(&mut spec, true, "fc.lora_a".into(), vec![rank, c],
            ParamKind::FcLoraA, Some(c));
    }
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(name: &str) -> &'static ModelCfg {
        ModelCfg::by_name(name).unwrap()
    }

    #[test]
    fn resnet8_matches_paper_table1_base() {
        // Paper Table I: FedAvg row = 1.23 M params.
        let spec = build_spec(cfg("resnet8"), Variant::Full, 0);
        assert_eq!(spec.num_frozen(), 0);
        let p = spec.num_trainable() as f64;
        assert!((p - 1.23e6).abs() / 1.23e6 < 0.005, "{p}");
    }

    #[test]
    fn resnet8_lora_counts_near_paper_table1() {
        // (rank, total, trained) from Table I.
        for &(r, total, trained) in &[
            (8usize, 1.30e6, 69.45e3),
            (16, 1.36e6, 131.92e3),
            (32, 1.48e6, 256.84e3),
            (64, 1.73e6, 506.70e3),
            (128, 2.23e6, 1.00e6),
        ] {
            let spec = build_spec(cfg("resnet8"), Variant::LoraFc, r);
            let tot = spec.num_total() as f64;
            let tr = spec.num_trainable() as f64;
            assert!((tot - total).abs() / total < 0.02, "r={r} total {tot}");
            assert!((tr - trained).abs() / trained < 0.02, "r={r} trained {tr}");
        }
    }

    #[test]
    fn resnet18_is_44_7_mb() {
        // Table IV: the full ResNet-18 message is 44.7 MB in fp32.
        let spec = build_spec(cfg("resnet18"), Variant::Full, 0);
        let mb = spec.num_trainable() as f64 * 4.0 / 1e6;
        assert!((mb - 44.7).abs() / 44.7 < 0.01, "{mb}");
    }

    #[test]
    fn offsets_contiguous_all_models_variants() {
        for m in MODELS {
            for v in [Variant::Full, Variant::LoraAll, Variant::LoraNorm,
                      Variant::LoraFc] {
                let spec = build_spec(m, v, 4);
                for side in [&spec.trainable, &spec.frozen] {
                    let mut off = 0;
                    for seg in side.iter() {
                        assert_eq!(seg.offset, off, "{} {:?} {}", m.name, v,
                                   seg.name);
                        off += seg.numel;
                    }
                }
            }
        }
    }

    #[test]
    fn variant_semantics() {
        let full = build_spec(cfg("micro8"), Variant::Full, 0);
        assert!(full.frozen.is_empty());

        let vanilla = build_spec(cfg("micro8"), Variant::LoraAll, 4);
        assert!(vanilla.trainable.iter().all(|s| matches!(
            s.kind,
            ParamKind::LoraB | ParamKind::LoraA | ParamKind::FcLoraB
                | ParamKind::FcLoraA
        )));
        assert!(vanilla.frozen.iter().any(|s| s.kind == ParamKind::NormW));

        let fc = build_spec(cfg("micro8"), Variant::LoraFc, 4);
        assert!(fc.trainable.iter().any(|s| s.kind == ParamKind::FcW));
        assert!(fc.trainable.iter().any(|s| s.kind == ParamKind::NormW));
        assert!(!fc.trainable.iter().any(|s| s.kind == ParamKind::FcLoraB));
    }

    #[test]
    fn conv_count_resnet8_and_18() {
        assert_eq!(conv_enumeration(cfg("resnet8")).len(), 9);
        assert_eq!(conv_enumeration(cfg("resnet18")).len(), 20);
    }

    #[test]
    fn tags() {
        assert_eq!(build_spec(cfg("resnet8"), Variant::Full, 0).tag(),
                   "resnet8_full");
        assert_eq!(build_spec(cfg("tiny8"), Variant::LoraFc, 8).tag(),
                   "tiny8_lora_fc_r8");
    }

    #[test]
    fn rank_above_channels_allowed() {
        // Paper Fig. 2 uses r=128 on 64-channel convs; counts must still
        // be well-defined (adapter may exceed the base conv's size).
        let spec = build_spec(cfg("resnet8"), Variant::LoraFc, 128);
        assert!(spec.num_trainable() > 0);
    }
}
