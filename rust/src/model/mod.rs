//! Model-architecture arithmetic: an exact rust mirror of
//! `python/compile/configs.py`.
//!
//! The paper's size/communication numbers (Table I, the TCC column of
//! Table III, the message sizes of Table IV) are deterministic functions
//! of the architecture. This module computes them *at paper scale*
//! without needing artifacts, and the python tests + the manifest
//! cross-check that both sides agree segment-by-segment.

pub mod spec;

pub use spec::{
    build_spec, conv_enumeration, ModelCfg, ParamKind, ParamSpec, Segment,
    Variant, MODELS,
};
