//! Unified error type for the crate.
//!
//! The `xla` crate surfaces its own error enum; everything else in this
//! crate is IO, parsing or invariant violations. A single lightweight
//! enum keeps signatures readable without pulling in error-derive
//! machinery (the offline vendor set has no `thiserror` feature parity
//! we need).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA failures (compile, execute, literal conversion).
    Xla(xla::Error),
    /// Filesystem problems (artifacts, configs, exports).
    Io(std::io::Error),
    /// JSON / TOML / CLI parse errors with human context.
    Parse(String),
    /// Violated invariants (shape mismatches, bad configs, ...).
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Invalid(m) => write!(f, "invalid: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Shorthand constructors used throughout the crate.
impl Error {
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::Invalid(msg.into())
    }
}
