//! Deterministic random numbers: SplitMix64 seeding + Xoshiro256**,
//! plus the distribution samplers the FL substrate needs (uniform,
//! normal, gamma, Dirichlet) and Fisher-Yates shuffling.
//!
//! Everything is seeded explicitly — every experiment in EXPERIMENTS.md
//! is reproducible bit-for-bit from its config seed.

/// Xoshiro256** (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (client-/round-scoped RNGs).
    ///
    /// The child seed depends on the parent's *position in its own
    /// stream*, so two forks with the same tag taken at different times
    /// differ. That also means fork order matters — for streams that
    /// must be identical regardless of iteration or thread order, use
    /// [`Rng::derive`] instead.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.fork_seed(tag))
    }

    /// The seed [`Rng::fork`] would build its child from, without
    /// constructing the child. Lets callers precompute a table of fork
    /// seeds cheaply (u64 each) and materialize the actual streams on
    /// demand — `Rng::new(fork_seed(t))` is bit-identical to `fork(t)`.
    pub fn fork_seed(&mut self, tag: u64) -> u64 {
        self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15)
    }

    /// Derive a stream purely from immutable coordinates — no parent
    /// state is consumed, so the result is independent of evaluation
    /// order and thread count. This is the derivation the round engine
    /// uses for per-client streams: `derive(seed, &[round, cid])` is
    /// bit-identical whether clients run serially or fanned out.
    pub fn derive(seed: u64, tags: &[u64]) -> Rng {
        let mut state = seed;
        let mut acc = splitmix64(&mut state);
        for &t in tags {
            let mut s = acc ^ t.wrapping_mul(0x9E3779B97F4A7C15);
            acc = splitmix64(&mut s);
        }
        Rng::new(acc)
    }

    /// The round engine's per-client stream: stable in `(seed, round,
    /// cid)` and nothing else.
    pub fn for_client(seed: u64, round: u64, cid: u64) -> Rng {
        Rng::derive(seed, &[round, cid])
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via polar Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang; boosted for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a).
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `k` categories.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = v.iter().sum();
        if sum <= 0.0 {
            // Pathologically small alpha: degenerate to a random one-hot.
            let hot = self.below(k);
            return (0..k).map(|i| if i == hot { 1.0 } else { 0.0 }).collect();
        }
        for x in &mut v {
            *x /= sum;
        }
        v
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (client sampling).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher-Yates: only the first k swaps matter.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(3);
        for &shape in &[0.3, 0.5, 1.0, 2.5, 10.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() / shape < 0.08,
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentrates() {
        let mut r = Rng::new(4);
        // Large alpha => near-uniform; small alpha => spiky.
        let flat = r.dirichlet(100.0, 10);
        assert!((flat.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(flat.iter().all(|&p| (p - 0.1).abs() < 0.05));
        let mut max_spike = 0.0f64;
        for _ in 0..20 {
            let spiky = r.dirichlet(0.05, 10);
            assert!((spiky.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            max_spike = max_spike.max(spiky.iter().cloned().fold(0.0, f64::max));
        }
        assert!(max_spike > 0.8, "alpha=0.05 should concentrate");
    }

    #[test]
    fn choose_k_distinct_and_complete() {
        let mut r = Rng::new(5);
        let picked = r.choose_k(100, 10);
        assert_eq!(picked.len(), 10);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        let all = r.choose_k(5, 5);
        let mut all_sorted = all;
        all_sorted.sort_unstable();
        assert_eq!(all_sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(6);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..50).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_is_order_independent_and_distinct() {
        // Same coordinates => same stream, regardless of when/where the
        // derivation happens (no parent state is involved at all).
        let mut a = Rng::for_client(42, 3, 7);
        let mut b = Rng::for_client(42, 3, 7);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Any coordinate change decorrelates the stream.
        for mut other in [
            Rng::for_client(43, 3, 7),
            Rng::for_client(42, 4, 7),
            Rng::for_client(42, 3, 8),
            Rng::for_client(42, 7, 3), // tags are position-sensitive
        ] {
            let mut me = Rng::for_client(42, 3, 7);
            let same =
                (0..50).filter(|_| me.next_u64() == other.next_u64()).count();
            assert_eq!(same, 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
