//! Minimal JSON: a recursive-descent parser and a serializer.
//!
//! Scope: everything `artifacts/manifest.json`, config files and metric
//! exports need — objects, arrays, strings (with escapes), numbers,
//! booleans, null. Not a general-purpose validator; unknown escapes and
//! exotic unicode surrogate pairs are passed through leniently.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chaining that errors with the full path.
    pub fn at(&self, path: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for (i, key) in path.iter().enumerate() {
            cur = cur.get(key).ok_or_else(|| {
                Error::parse(format!("missing key `{}`", path[..=i].join(".")))
            })?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::parse(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::parse(format!("expected usize, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::parse(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::parse(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(Error::parse(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::parse(format!("expected object, got {self:?}"))),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ----- serializer ------------------------------------------------------

    /// Compact serialization (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building exports.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(Error::parse(format!(
            "trailing characters at byte {}",
            p.pos
        )));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| Error::parse("unexpected EOF"))?;
        self.pos += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.bump()?;
        if got != c {
            return Err(Error::parse(format!(
                "expected `{}` at byte {}, got `{}`",
                c as char,
                self.pos - 1,
                got as char
            )));
        }
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| Error::parse("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => {
                    return Err(Error::parse(format!(
                        "expected , or }} at byte {}, got `{}`",
                        self.pos - 1,
                        c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(a)),
                c => {
                    return Err(Error::parse(format!(
                        "expected , or ] at byte {}, got `{}`",
                        self.pos - 1,
                        c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            cp = cp * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    Error::parse("bad \\u escape")
                                })?;
                        }
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    c => {
                        return Err(Error::parse(format!(
                            "bad escape `\\{}`",
                            c as char
                        )))
                    }
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Re-assemble multi-byte UTF-8 (input is &str, so the
                    // bytes are valid; find the char at pos-1).
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    let chunk =
                        std::str::from_utf8(&self.b[start..start + width])
                            .map_err(|_| Error::parse("bad utf8"))?;
                    out.push_str(chunk);
                    self.pos = start + width;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| Error::parse("bad number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::parse(format!("bad number `{text}`")))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(r#""hi\nthere""#).unwrap(),
                   Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": "c"}, null], "d": {}}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[1]
                .at(&["b"])
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"k":[1,2.5,"x","\"q\"",true,null],"z":{"n":-3}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_pass_through() {
        let v = parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }

    #[test]
    fn accessor_errors_carry_path() {
        let v = parse(r#"{"a": {"b": 1}}"#).unwrap();
        let err = v.at(&["a", "c"]).unwrap_err().to_string();
        assert!(err.contains("a.c"), "{err}");
    }
}
