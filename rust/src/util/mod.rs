//! Small in-repo substrates that would normally come from crates.io —
//! the offline vendor set only covers the `xla` closure, so JSON and
//! random-number generation are implemented here (DESIGN.md §2).

pub mod benchkit;
pub mod json;
pub mod rng;
