//! Minimal benchmarking harness (the offline vendor set has no
//! `criterion`): warmup + timed iterations with mean / std / min / p50,
//! plus a tabular reporter shared by the `cargo bench` targets.

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

impl BenchStats {
    pub fn row(&self) -> String {
        format!(
            "{:<40} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.min_s),
        )
    }

    /// Throughput helper: items per second at the mean time.
    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.mean_s
    }
}

pub fn header() -> String {
    format!(
        "{:<40} {:>10} {:>12} {:>12} {:>12}",
        "benchmark", "iters", "mean", "p50", "min"
    )
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
        / iters as f64;
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: sorted[0],
        p50_s: sorted[iters / 2],
    }
}

/// Read an env-var knob with default (bench scaling).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let mut x = 0u64;
        let st = bench("noop-ish", 2, 50, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert_eq!(st.iters, 50);
        assert!(st.mean_s >= 0.0 && st.min_s <= st.mean_s);
        assert!(st.per_sec(1.0) > 0.0);
    }

    #[test]
    fn env_knob() {
        assert_eq!(env_usize("FLOCORA_SURELY_UNSET_XYZ", 7), 7);
    }

    #[test]
    fn formatting() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-5).contains("µs"));
        assert!(fmt_time(2e-2).contains("ms"));
        assert!(fmt_time(2.0).contains(" s"));
        assert!(header().contains("benchmark"));
    }
}
