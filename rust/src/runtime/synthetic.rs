//! Deterministic pure-Rust surrogate dynamics — the artifact-free
//! backend behind [`Engine::synthetic`](crate::runtime::Engine).
//!
//! The protocol layers (wire codecs, transport accounting, executors,
//! samplers, aggregation) never look inside a training step: they only
//! need `init`/`train_step`/`eval_step` to be **deterministic pure
//! functions** with the right shapes. This module provides exactly
//! that — a convex pseudo-objective whose target vector is derived by
//! hashing the minibatch, optimized with the same SGD-with-momentum
//! update rule the real artifacts lower:
//!
//! * every quantity is a pure function of `(spec, params, batch,
//!   hyperparameters)`, so runs are bit-identical across executors,
//!   thread counts, windows and overlap modes — the property the
//!   engine's parity tests and CI's `sim-smoke` job pin;
//! * loss decreases and the pseudo-accuracy rises as parameters
//!   approach the data-dependent targets, so convergence plumbing
//!   (recorders, summaries, CSV exports) sees realistic-shaped curves;
//! * a step costs O(params + batch) with no BLAS, XLA or threads.
//!
//! It is a *plumbing* surrogate: nothing here claims to model real
//! learning. Accuracy columns from synthetic runs are meaningless as
//! science and are only compared against other synthetic runs.

use crate::runtime::{Batch, SpecEntry, StepStats};
use crate::util::rng::Rng;

/// Stream salt separating synthetic init from every other consumer of
/// the run seed.
const INIT_SALT: u64 = 0x53_59_4E_54_48_45_54;

/// Target amplitude of the pseudo-objective.
const TARGET_AMP: f32 = 0.2;

/// SGD momentum (matches the real train artifacts' 0.9).
const MOMENTUM: f32 = 0.9;

/// SplitMix64 finalizer — the per-coordinate hash behind targets and
/// pseudo-accuracy draws.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Map a hash to [-1, 1).
fn unit(h: u64) -> f32 {
    ((h >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
}

/// Map a hash to [0, 1).
fn uniform01(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// FNV-1a accumulator over word streams.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    fn u64(&mut self, v: u64) -> &mut Fnv {
        for b in v.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
        self
    }

    fn f32s(&mut self, vs: &[f32]) -> &mut Fnv {
        for v in vs {
            self.u64(v.to_bits() as u64);
        }
        self
    }

    fn i32s(&mut self, vs: &[i32]) -> &mut Fnv {
        for v in vs {
            self.u64(*v as u32 as u64);
        }
        self
    }

    fn finish(&self) -> u64 {
        mix(self.0)
    }
}

fn tag_hash(tag: &str) -> u64 {
    let mut h = Fnv::new();
    for b in tag.bytes() {
        h.u64(b as u64);
    }
    h.finish()
}

/// Squash the LoRA scale so the effective curvature stays below 1 for
/// any alpha/rank the configs produce (keeps SGD stable at paper
/// learning rates while the scale still shapes the dynamics).
fn scale_norm(lora_scale: f32) -> f32 {
    lora_scale / (1.0 + lora_scale * lora_scale).sqrt()
}

/// Per-coordinate target derived from the batch digest.
fn target(digest: u64, j: usize) -> f32 {
    TARGET_AMP * unit(mix(digest ^ (j as u64).wrapping_mul(0x9E3779B97F4A7C15)))
}

/// Seeded surrogate init: `(trainable, frozen)` with the spec's exact
/// lengths, deterministic in `(tag, seed)` like the real init
/// artifact.
pub fn init(spec: &SpecEntry, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::derive(seed ^ INIT_SALT, &[tag_hash(&spec.tag)]);
    let trainable = (0..spec.num_trainable)
        .map(|_| 0.05 * rng.normal() as f32)
        .collect();
    let frozen = (0..spec.num_frozen)
        .map(|_| 0.05 * rng.normal() as f32)
        .collect();
    (trainable, frozen)
}

/// Mean residual loss of `params` against the digest's targets, and
/// the scaled residual needed for the gradient. O(params).
fn residual_loss(params: &[f32], digest: u64, ls: f32) -> f64 {
    if params.is_empty() {
        return 0.0;
    }
    let mut sum = 0.0f64;
    for (j, &p) in params.iter().enumerate() {
        let r = ls * p - target(digest, j);
        sum += (r * r) as f64;
    }
    0.5 * sum / params.len() as f64
}

/// Monotone map from loss to a plausible accuracy in (0, 1).
fn pseudo_acc(loss: f64) -> f64 {
    1.0 / (1.0 + 40.0 * loss)
}

/// One surrogate SGD-with-momentum step: pull `params` toward the
/// batch's hashed target vector. Updates `params`/`momentum` in place,
/// mirroring the PJRT train step's contract.
pub fn train_step(
    spec: &SpecEntry,
    params: &mut [f32],
    momentum: &mut [f32],
    batch: &Batch,
    lr: f32,
    lora_scale: f32,
) -> StepStats {
    let digest = Fnv::new()
        .u64(tag_hash(&spec.tag))
        .i32s(&batch.y)
        .f32s(&batch.x)
        .finish();
    let ls = scale_norm(lora_scale);
    let mut loss_sum = 0.0f64;
    for (j, (p, m)) in params.iter_mut().zip(momentum.iter_mut()).enumerate()
    {
        let r = ls * *p - target(digest, j);
        loss_sum += (r * r) as f64;
        let g = ls * r;
        *m = MOMENTUM * *m + g;
        *p -= lr * *m;
    }
    let n = params.len().max(1) as f64;
    let loss = 0.5 * loss_sum / n;
    StepStats {
        loss: loss as f32,
        acc: pseudo_acc(loss) as f32,
    }
}

/// Masked surrogate eval → `(loss_sum, correct_count)` over the
/// batch's valid examples, mirroring the PJRT eval step's contract.
pub fn eval_step(
    spec: &SpecEntry,
    params: &[f32],
    batch: &Batch,
    lora_scale: f32,
) -> (f64, f64) {
    let digest = Fnv::new()
        .u64(tag_hash(&spec.tag))
        .i32s(&batch.y)
        .f32s(&batch.x)
        .finish();
    let ls = scale_norm(lora_scale);
    let loss = residual_loss(params, digest, ls);
    let p_acc = pseudo_acc(loss);
    // Fold the parameter state into the per-example draws so the
    // correctness pattern evolves with training, not just its rate.
    let param_digest = Fnv::new().f32s(params).finish();
    let px = spec.image_size * spec.image_size * 3;
    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    for i in 0..batch.y.len() {
        let mask = *batch.mask.get(i).unwrap_or(&1.0) as f64;
        if mask == 0.0 {
            continue;
        }
        let ex = Fnv::new()
            .u64(i as u64)
            .i32s(&batch.y[i..i + 1])
            .f32s(&batch.x[i * px..(i + 1) * px])
            .finish();
        // Deterministic per-example spread around the batch loss.
        loss_sum += mask * loss * (1.0 + 0.1 * unit(ex) as f64);
        if uniform01(mix(ex ^ param_digest)) < p_acc {
            correct += mask;
        }
    }
    (loss_sum, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_spec, ModelCfg, Variant};
    use crate::runtime::manifest::Manifest;

    fn spec() -> SpecEntry {
        Manifest::synthetic_entry(
            &build_spec(ModelCfg::by_name("micro8").unwrap(),
                        Variant::LoraFc, 4),
        )
    }

    fn batch(spec: &SpecEntry, seed: u64) -> Batch {
        let px = spec.image_size * spec.image_size * 3;
        let mut rng = Rng::new(seed);
        Batch {
            x: (0..spec.batch_size * px).map(|_| rng.f32()).collect(),
            y: (0..spec.batch_size).map(|_| rng.below(10) as i32).collect(),
            mask: vec![1.0; spec.batch_size],
            n: spec.batch_size,
        }
    }

    #[test]
    fn init_is_deterministic_and_sized() {
        let s = spec();
        let (t1, f1) = init(&s, 7);
        let (t2, f2) = init(&s, 7);
        let (t3, _) = init(&s, 8);
        assert_eq!(t1.len(), s.num_trainable);
        assert_eq!(f1.len(), s.num_frozen);
        assert_eq!(t1, t2);
        assert_eq!(f1, f2);
        assert_ne!(t1, t3, "seed must matter");
    }

    #[test]
    fn train_step_is_deterministic_and_converges() {
        let s = spec();
        let b = batch(&s, 1);
        let (mut p1, _) = init(&s, 3);
        let mut m1 = vec![0.0f32; p1.len()];
        let (mut p2, _) = init(&s, 3);
        let mut m2 = vec![0.0f32; p2.len()];
        let first = train_step(&s, &mut p1, &mut m1, &b, 0.05, 16.0);
        train_step(&s, &mut p2, &mut m2, &b, 0.05, 16.0);
        assert_eq!(p1, p2, "same inputs must give the same step");
        let mut last = first.loss;
        for _ in 0..30 {
            last = train_step(&s, &mut p1, &mut m1, &b, 0.05, 16.0).loss;
        }
        assert!(last < 0.2 * first.loss,
                "no convergence: {} -> {}", first.loss, last);
    }

    #[test]
    fn different_batches_pull_differently() {
        let s = spec();
        let (p0, _) = init(&s, 3);
        let mut pa = p0.clone();
        let mut pb = p0;
        let mut ma = vec![0.0f32; pa.len()];
        let mut mb = vec![0.0f32; pb.len()];
        train_step(&s, &mut pa, &mut ma, &batch(&s, 1), 0.05, 16.0);
        train_step(&s, &mut pb, &mut mb, &batch(&s, 2), 0.05, 16.0);
        assert_ne!(pa, pb, "batch content must shape the update");
    }

    #[test]
    fn eval_respects_the_mask() {
        let s = spec();
        let b = batch(&s, 5);
        let (p, _) = init(&s, 3);
        let (full_loss, full_correct) = eval_step(&s, &p, &b, 16.0);
        let mut masked = batch(&s, 5);
        masked.mask = vec![0.0; s.batch_size];
        let (l0, c0) = eval_step(&s, &p, &masked, 16.0);
        assert_eq!((l0, c0), (0.0, 0.0));
        assert!(full_loss > 0.0);
        assert!((0.0..=s.batch_size as f64).contains(&full_correct));
    }

    #[test]
    fn scale_norm_is_bounded() {
        for ls in [0.5f32, 1.0, 16.0, 512.0] {
            let n = scale_norm(ls);
            assert!(n > 0.0 && n < 1.0, "{ls} -> {n}");
        }
    }
}
