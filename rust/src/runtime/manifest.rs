//! `artifacts/manifest.json` model — the contract between `aot.py` and
//! the coordinator.  Parsing is strict: a manifest that disagrees with
//! the in-repo [`crate::model::build_spec`] arithmetic is rejected at
//! load time rather than corrupting state mid-run.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::model::{build_spec, ModelCfg, ParamKind, ParamSpec, Segment,
                   Variant, MODELS};
use crate::util::json::{self, Json};

/// Files for one lowered spec.
#[derive(Debug, Clone)]
pub struct SpecFiles {
    pub train: String,
    pub eval: String,
    pub init: String,
}

/// One (model, variant, rank) entry.
#[derive(Debug, Clone)]
pub struct SpecEntry {
    pub tag: String,
    pub model: String,
    pub variant: Variant,
    pub rank: usize,
    pub image_size: usize,
    pub batch_size: usize,
    pub num_classes: usize,
    pub num_trainable: usize,
    pub num_frozen: usize,
    pub files: SpecFiles,
    pub trainable_segments: Vec<Segment>,
    pub frozen_segments: Vec<Segment>,
}

/// Quant-oracle artifact (rust-codec parity tests).
#[derive(Debug, Clone)]
pub struct QuantOracle {
    pub file: String,
    pub rows: usize,
    pub cols: usize,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub specs: BTreeMap<String, SpecEntry>,
    pub quant_oracles: BTreeMap<u32, QuantOracle>,
}

fn parse_segments(arr: &Json) -> Result<Vec<Segment>> {
    let mut out = Vec::new();
    for seg in arr.as_arr()? {
        let kind_str = seg.at(&["kind"])?.as_str()?;
        let kind = ParamKind::parse(kind_str)
            .ok_or_else(|| Error::parse(format!("unknown kind {kind_str}")))?;
        let quant_rows = match seg.at(&["quant_rows"])? {
            Json::Null => None,
            v => Some(v.as_usize()?),
        };
        out.push(Segment {
            name: seg.at(&["name"])?.as_str()?.to_string(),
            shape: seg
                .at(&["shape"])?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            numel: seg.at(&["numel"])?.as_usize()?,
            kind,
            offset: seg.at(&["offset"])?.as_usize()?,
            quant_rows,
        });
    }
    Ok(out)
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::invalid(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let root = json::parse(&text)?;
        let mut specs = BTreeMap::new();
        for (tag, spec) in root.at(&["specs"])?.as_obj()? {
            let variant_str = spec.at(&["variant"])?.as_str()?;
            let variant = Variant::parse(variant_str).ok_or_else(|| {
                Error::parse(format!("unknown variant {variant_str}"))
            })?;
            let files = spec.at(&["files"])?;
            let entry = SpecEntry {
                tag: tag.clone(),
                model: spec.at(&["model"])?.as_str()?.to_string(),
                variant,
                rank: spec.at(&["rank"])?.as_usize()?,
                image_size: spec.at(&["image_size"])?.as_usize()?,
                batch_size: spec.at(&["batch_size"])?.as_usize()?,
                num_classes: spec.at(&["num_classes"])?.as_usize()?,
                num_trainable: spec.at(&["num_trainable"])?.as_usize()?,
                num_frozen: spec.at(&["num_frozen"])?.as_usize()?,
                files: SpecFiles {
                    train: files.at(&["train"])?.as_str()?.to_string(),
                    eval: files.at(&["eval"])?.as_str()?.to_string(),
                    init: files.at(&["init"])?.as_str()?.to_string(),
                },
                trainable_segments: parse_segments(
                    spec.at(&["trainable_segments"])?,
                )?,
                frozen_segments: parse_segments(
                    spec.at(&["frozen_segments"])?,
                )?,
            };
            entry.validate()?;
            specs.insert(tag.clone(), entry);
        }

        let mut quant_oracles = BTreeMap::new();
        for (bits, meta) in root.at(&["quant_oracles"])?.as_obj()? {
            let bits: u32 = bits
                .parse()
                .map_err(|_| Error::parse("bad quant oracle bits key"))?;
            quant_oracles.insert(
                bits,
                QuantOracle {
                    file: meta.at(&["file"])?.as_str()?.to_string(),
                    rows: meta.at(&["rows"])?.as_usize()?,
                    cols: meta.at(&["cols"])?.as_usize()?,
                },
            );
        }
        Ok(Manifest { specs, quant_oracles })
    }

    pub fn spec(&self, tag: &str) -> Result<&SpecEntry> {
        self.specs.get(tag).ok_or_else(|| {
            Error::invalid(format!(
                "spec `{tag}` not in manifest (available: {:?})",
                self.specs.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// The synthetic backend's manifest: every model × variant × rank
    /// the in-repo spec arithmetic can express, with placeholder file
    /// names (nothing is ever loaded) and no quant oracles. Entries
    /// are derived from [`build_spec`], so
    /// [`SpecEntry::validate`] holds by construction.
    pub fn synthetic() -> Manifest {
        let mut specs = BTreeMap::new();
        for cfg in MODELS {
            for variant in [Variant::Full, Variant::LoraAll,
                            Variant::LoraNorm, Variant::LoraFc] {
                let ranks: &[usize] = if variant == Variant::Full {
                    &[0]
                } else {
                    &[1, 2, 4, 8, 16, 32, 64, 128]
                };
                for &rank in ranks {
                    let entry = Manifest::synthetic_entry(
                        &build_spec(cfg, variant, rank),
                    );
                    specs.insert(entry.tag.clone(), entry);
                }
            }
        }
        Manifest { specs, quant_oracles: BTreeMap::new() }
    }

    /// One synthetic-manifest entry from a resolved layout.
    pub fn synthetic_entry(spec: &ParamSpec) -> SpecEntry {
        let tag = spec.tag();
        SpecEntry {
            model: spec.cfg.name.to_string(),
            variant: spec.variant,
            rank: spec.rank,
            image_size: spec.cfg.image_size,
            batch_size: spec.cfg.batch_size,
            num_classes: spec.cfg.num_classes,
            num_trainable: spec.num_trainable(),
            num_frozen: spec.num_frozen(),
            files: SpecFiles {
                train: format!("synthetic://{tag}/train"),
                eval: format!("synthetic://{tag}/eval"),
                init: format!("synthetic://{tag}/init"),
            },
            trainable_segments: spec.trainable.clone(),
            frozen_segments: spec.frozen.clone(),
            tag,
        }
    }
}

impl SpecEntry {
    /// Cross-check the manifest against the in-repo spec arithmetic:
    /// byte-level wire formats depend on both sides agreeing exactly.
    pub fn validate(&self) -> Result<()> {
        let cfg = ModelCfg::by_name(&self.model).ok_or_else(|| {
            Error::invalid(format!("unknown model `{}`", self.model))
        })?;
        let local = build_spec(cfg, self.variant, self.rank);
        if local.num_trainable() != self.num_trainable
            || local.num_frozen() != self.num_frozen
        {
            return Err(Error::invalid(format!(
                "manifest/spec mismatch for {}: trainable {} vs {}, frozen \
                 {} vs {} — artifacts are stale, re-run `make artifacts`",
                self.tag,
                self.num_trainable,
                local.num_trainable(),
                self.num_frozen,
                local.num_frozen(),
            )));
        }
        for (a, b) in local.trainable.iter().zip(&self.trainable_segments) {
            if a.name != b.name || a.offset != b.offset || a.numel != b.numel {
                return Err(Error::invalid(format!(
                    "segment mismatch in {}: {} vs {}",
                    self.tag, a.name, b.name
                )));
            }
        }
        Ok(())
    }
}
