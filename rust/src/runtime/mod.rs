//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects).
//!
//! [`Engine`] owns the client and an executable cache (compile once per
//! artifact per process); [`ModelSession`] bundles the train/eval/init
//! executables of one spec behind a typed, flat-`Vec<f32>` API.
//!
//! Both [`Engine`] and [`ModelSession`] are `Send + Sync`: the parallel
//! round engine (`coordinator::executor`) fans client work out across a
//! thread pool, and every worker drives the *same* compiled executables
//! concurrently. Thread-safety is **structural, not asserted**: there
//! is deliberately no `unsafe impl` here — these types are `Send +
//! Sync` exactly when the linked `xla` crate's handles are (true for
//! the vendored stub's plain-data types). Swapping in a wrapper whose
//! PJRT handles are not thread-safe (e.g. one with internal `Rc`
//! refcounts) makes the parallel executor **fail to compile** instead
//! of racing — write an audited, internally-locked wrapper in that
//! case (see the note in `rust/Cargo.toml`). The only shared mutable
//! state on our side is the compile cache, which sits behind a
//! `Mutex`.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
pub use manifest::{Manifest, QuantOracle, SpecEntry};

/// A compiled PJRT executable handle, shareable across executor
/// threads. `Send + Sync` follows automatically from the inner type —
/// see the module docs for why that is a deliberate compile-time gate.
#[derive(Clone)]
pub struct Executable(Arc<xla::PjRtLoadedExecutable>);

impl std::ops::Deref for Executable {
    type Target = xla::PjRtLoadedExecutable;

    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

/// PJRT client + compiled-executable cache over an artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Executable>>,
}

impl Engine {
    /// Open `dir` (usually `artifacts/`), parse + validate the manifest,
    /// and stand up the CPU PJRT client.
    pub fn new(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) one HLO-text artifact.
    pub fn load(&self, file: &str) -> Result<Executable> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(exe.clone());
        }
        // Compile outside the lock: XLA compilation is slow and two
        // threads racing on the same artifact just deduplicate below.
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Executable(Arc::new(self.client.compile(&comp)?));
        let mut cache = self.cache.lock().unwrap();
        Ok(cache.entry(file.to_string()).or_insert(exe).clone())
    }

    /// Open a [`ModelSession`] for a manifest tag
    /// (e.g. `"tiny8_lora_fc_r8"`).
    pub fn session(&self, tag: &str) -> Result<ModelSession> {
        let spec = self.manifest.spec(tag)?.clone();
        Ok(ModelSession {
            train: self.load(&spec.files.train)?,
            eval: self.load(&spec.files.eval)?,
            init: self.load(&spec.files.init)?,
            spec,
        })
    }

    /// Execute a quant-oracle artifact: `w (rows, cols)` →
    /// `(dequantized, scale, zero_point)` — the HLO ground truth the
    /// rust affine codec is parity-tested against.
    pub fn quant_oracle(
        &self,
        bits: u32,
        w: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let oracle = self
            .manifest
            .quant_oracles
            .get(&bits)
            .ok_or_else(|| {
                Error::invalid(format!("no quant oracle for {bits} bits"))
            })?;
        if w.len() != oracle.rows * oracle.cols {
            return Err(Error::invalid(format!(
                "quant oracle expects {}x{} input, got {} elements",
                oracle.rows,
                oracle.cols,
                w.len()
            )));
        }
        let exe = self.load(&oracle.file)?;
        let lit = xla::Literal::vec1(w)
            .reshape(&[oracle.rows as i64, oracle.cols as i64])?;
        let mut outs = execute_tuple(&exe, &[lit])?;
        if outs.len() != 3 {
            return Err(Error::invalid(format!(
                "quant oracle returned {} outputs",
                outs.len()
            )));
        }
        let zp = outs.pop().unwrap().to_vec::<f32>()?;
        let scale = outs.pop().unwrap().to_vec::<f32>()?;
        let deq = outs.pop().unwrap().to_vec::<f32>()?;
        Ok((deq, scale, zp))
    }
}

/// Run an executable whose root is a tuple (aot.py lowers with
/// `return_tuple=True`) and decompose the result.
fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args)?;
    let lit = result[0][0].to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

/// One minibatch, already flattened to NHWC f32 and i32 labels.
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// Valid-example mask (eval pads the ragged final batch).
    pub mask: Vec<f32>,
    /// Number of real (unpadded) examples.
    pub n: usize,
}

/// Result of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub acc: f32,
}

/// The train/eval/init executables of one lowered spec.
///
/// `Send + Sync` (via [`Executable`]): the parallel round engine shares
/// one session across all client-executor threads.
pub struct ModelSession {
    pub spec: SpecEntry,
    train: Executable,
    eval: Executable,
    init: Executable,
}

impl ModelSession {
    fn batch_literals(
        &self,
        batch: &Batch,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let s = self.spec.image_size as i64;
        let b = self.spec.batch_size;
        if batch.x.len() != b * (s * s * 3) as usize || batch.y.len() != b {
            return Err(Error::invalid(format!(
                "batch shape mismatch: x={} y={} expected b={b} s={s}",
                batch.x.len(),
                batch.y.len()
            )));
        }
        let x = xla::Literal::vec1(&batch.x).reshape(&[b as i64, s, s, 3])?;
        let y = xla::Literal::vec1(&batch.y);
        Ok((x, y))
    }

    /// Run the init artifact: seeded He init → `(trainable, frozen)`.
    pub fn init(&self, seed: u64) -> Result<(Vec<f32>, Vec<f32>)> {
        let key = xla::Literal::vec1(&[(seed >> 32) as u32, seed as u32]);
        let mut outs = execute_tuple(&self.init, &[key])?;
        if outs.len() != 2 {
            return Err(Error::invalid("init must return (trainable, frozen)"));
        }
        let frozen = outs.pop().unwrap().to_vec::<f32>()?;
        let trainable = outs.pop().unwrap().to_vec::<f32>()?;
        if trainable.len() != self.spec.num_trainable
            || frozen.len() != self.spec.num_frozen
        {
            return Err(Error::invalid(format!(
                "init returned {}/{} params, manifest says {}/{}",
                trainable.len(),
                frozen.len(),
                self.spec.num_trainable,
                self.spec.num_frozen
            )));
        }
        Ok((trainable, frozen))
    }

    /// One SGD-with-momentum minibatch step. `params` and `momentum` are
    /// updated in place (reusing their allocations).
    pub fn train_step(
        &self,
        params: &mut [f32],
        momentum: &mut [f32],
        frozen: &[f32],
        batch: &Batch,
        lr: f32,
        lora_scale: f32,
    ) -> Result<StepStats> {
        let (x, y) = self.batch_literals(batch)?;
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(momentum),
            xla::Literal::vec1(frozen),
            x,
            y,
            xla::Literal::scalar(lr),
            xla::Literal::scalar(lora_scale),
        ];
        let mut outs = execute_tuple(&self.train, &args)?;
        if outs.len() != 4 {
            return Err(Error::invalid("train must return 4 outputs"));
        }
        let acc = outs.pop().unwrap().get_first_element::<f32>()?;
        let loss = outs.pop().unwrap().get_first_element::<f32>()?;
        let new_m = outs.pop().unwrap();
        let new_p = outs.pop().unwrap();
        new_p.copy_raw_to(params)?;
        new_m.copy_raw_to(momentum)?;
        Ok(StepStats { loss, acc })
    }

    /// Masked eval on one batch → `(loss_sum, correct_count)`.
    pub fn eval_step(
        &self,
        params: &[f32],
        frozen: &[f32],
        batch: &Batch,
        lora_scale: f32,
    ) -> Result<(f64, f64)> {
        let (x, y) = self.batch_literals(batch)?;
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(frozen),
            x,
            y,
            xla::Literal::vec1(&batch.mask),
            xla::Literal::scalar(lora_scale),
        ];
        let mut outs = execute_tuple(&self.eval, &args)?;
        if outs.len() != 2 {
            return Err(Error::invalid("eval must return 2 outputs"));
        }
        let correct = outs.pop().unwrap().get_first_element::<f32>()? as f64;
        let loss = outs.pop().unwrap().get_first_element::<f32>()? as f64;
        Ok((loss, correct))
    }
}
