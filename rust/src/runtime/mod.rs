//! Model runtime: the PJRT backend (AOT-compiled HLO artifacts) and
//! the synthetic backend (pure-Rust surrogate dynamics).
//!
//! **PJRT** — pattern per /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. HLO
//! *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id protos
//! that xla_extension 0.5.1 rejects).
//!
//! **Synthetic** — [`Engine::synthetic`] (or the artifact-dir sentinel
//! [`SYNTHETIC_ARTIFACTS`], i.e. `--artifacts synthetic` on the CLI)
//! swaps every executable for the deterministic pure-Rust surrogate in
//! [`synthetic`]: same specs, same flat-vector API, no XLA anywhere.
//! It exists so the *protocol* layers — transport accounting, round
//! engine, executor parity, straggler machinery — run end-to-end in
//! environments without artifacts (CI's `sim-smoke` job, this repo's
//! offline container). It proves determinism and plumbing, not
//! learning.
//!
//! [`Engine`] owns the client and an executable cache (compile once per
//! artifact per process); [`ModelSession`] bundles the train/eval/init
//! executables of one spec behind a typed, flat-`Vec<f32>` API.
//!
//! Both [`Engine`] and [`ModelSession`] are `Send + Sync`: the parallel
//! round engine (`coordinator::executor`) fans client work out across a
//! thread pool, and every worker drives the *same* compiled executables
//! concurrently. Thread-safety is **structural, not asserted**: there
//! is deliberately no `unsafe impl` here — these types are `Send +
//! Sync` exactly when the linked `xla` crate's handles are (true for
//! the vendored stub's plain-data types). Swapping in a wrapper whose
//! PJRT handles are not thread-safe (e.g. one with internal `Rc`
//! refcounts) makes the parallel executor **fail to compile** instead
//! of racing — write an audited, internally-locked wrapper in that
//! case (see the note in `rust/Cargo.toml`). The only shared mutable
//! state on our side is the compile cache, which sits behind a
//! `Mutex`.

pub mod manifest;
pub mod synthetic;

// Keyed access only (compile-or-fetch by artifact file name) — the
// cache is never iterated, so hash order is unobservable; HashMap is
// fine here and `lint-determinism`'s map-iter rule only polices the
// coordinator/transport settle paths.
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::sync::{Arc, Mutex};
pub use manifest::{Manifest, QuantOracle, SpecEntry};

/// Artifact-directory sentinel that selects the synthetic backend
/// (`flocora train --artifacts synthetic`).
pub const SYNTHETIC_ARTIFACTS: &str = "synthetic";

/// A compiled PJRT executable handle, shareable across executor
/// threads. `Send + Sync` follows automatically from the inner type —
/// see the module docs for why that is a deliberate compile-time gate.
#[derive(Clone)]
pub struct Executable(Arc<xla::PjRtLoadedExecutable>);

impl std::ops::Deref for Executable {
    type Target = xla::PjRtLoadedExecutable;

    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

/// The engine's execution substrate: a PJRT client + executable cache,
/// or the synthetic surrogate (no XLA at all).
enum EngineBackend {
    Pjrt {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: Mutex<HashMap<String, Executable>>,
    },
    Synthetic,
}

/// Model runtime over an artifact directory (PJRT) or the synthetic
/// surrogate.
pub struct Engine {
    backend: EngineBackend,
    manifest: Manifest,
}

impl Engine {
    /// Open `dir` (usually `artifacts/`), parse + validate the manifest,
    /// and stand up the CPU PJRT client. The sentinel directory
    /// [`SYNTHETIC_ARTIFACTS`] selects [`Engine::synthetic`] instead —
    /// no filesystem, no XLA.
    pub fn new(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        if dir.as_path() == Path::new(SYNTHETIC_ARTIFACTS) {
            return Ok(Engine::synthetic());
        }
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            backend: EngineBackend::Pjrt {
                client,
                dir,
                cache: Mutex::new(HashMap::new()),
            },
            manifest,
        })
    }

    /// The artifact-free engine: every known spec served by the
    /// deterministic pure-Rust surrogate (see [`synthetic`]). Never
    /// fails — there is nothing to load.
    pub fn synthetic() -> Engine {
        Engine {
            backend: EngineBackend::Synthetic,
            manifest: Manifest::synthetic(),
        }
    }

    /// `true` when this engine runs the synthetic surrogate instead of
    /// PJRT-compiled artifacts.
    pub fn is_synthetic(&self) -> bool {
        matches!(self.backend, EngineBackend::Synthetic)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            EngineBackend::Pjrt { client, .. } => client.platform_name(),
            EngineBackend::Synthetic => "synthetic".to_string(),
        }
    }

    /// Compile (or fetch from cache) one HLO-text artifact. PJRT only:
    /// the synthetic backend has no executables.
    pub fn load(&self, file: &str) -> Result<Executable> {
        let EngineBackend::Pjrt { client, dir, cache } = &self.backend
        else {
            return Err(Error::invalid(format!(
                "cannot load `{file}`: the synthetic engine has no \
                 compiled executables"
            )));
        };
        if let Some(exe) = cache.lock().unwrap().get(file) {
            return Ok(exe.clone());
        }
        // Compile outside the lock: XLA compilation is slow and two
        // threads racing on the same artifact just deduplicate below.
        let path = dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Executable(Arc::new(client.compile(&comp)?));
        let mut cache = cache.lock().unwrap();
        Ok(cache.entry(file.to_string()).or_insert(exe).clone())
    }

    /// Open a [`ModelSession`] for a manifest tag
    /// (e.g. `"tiny8_lora_fc_r8"`).
    pub fn session(&self, tag: &str) -> Result<ModelSession> {
        let spec = self.manifest.spec(tag)?.clone();
        let backend = match &self.backend {
            EngineBackend::Pjrt { .. } => SessionBackend::Pjrt {
                train: self.load(&spec.files.train)?,
                eval: self.load(&spec.files.eval)?,
                init: self.load(&spec.files.init)?,
            },
            EngineBackend::Synthetic => SessionBackend::Synthetic,
        };
        Ok(ModelSession { spec, backend })
    }

    /// Execute a quant-oracle artifact: `w (rows, cols)` →
    /// `(dequantized, scale, zero_point)` — the HLO ground truth the
    /// rust affine codec is parity-tested against.
    pub fn quant_oracle(
        &self,
        bits: u32,
        w: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let oracle = self
            .manifest
            .quant_oracles
            .get(&bits)
            .ok_or_else(|| {
                Error::invalid(format!("no quant oracle for {bits} bits"))
            })?;
        if w.len() != oracle.rows * oracle.cols {
            return Err(Error::invalid(format!(
                "quant oracle expects {}x{} input, got {} elements",
                oracle.rows,
                oracle.cols,
                w.len()
            )));
        }
        let exe = self.load(&oracle.file)?;
        let lit = xla::Literal::vec1(w)
            .reshape(&[oracle.rows as i64, oracle.cols as i64])?;
        let mut outs = execute_tuple(&exe, &[lit])?;
        if outs.len() != 3 {
            return Err(Error::invalid(format!(
                "quant oracle returned {} outputs",
                outs.len()
            )));
        }
        let zp = outs.pop().unwrap().to_vec::<f32>()?;
        let scale = outs.pop().unwrap().to_vec::<f32>()?;
        let deq = outs.pop().unwrap().to_vec::<f32>()?;
        Ok((deq, scale, zp))
    }
}

/// Run an executable whose root is a tuple (aot.py lowers with
/// `return_tuple=True`) and decompose the result.
fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args)?;
    let lit = result[0][0].to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

/// One minibatch, already flattened to NHWC f32 and i32 labels.
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// Valid-example mask (eval pads the ragged final batch).
    pub mask: Vec<f32>,
    /// Number of real (unpadded) examples.
    pub n: usize,
}

/// Result of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub acc: f32,
}

/// A session's execution substrate: the three compiled executables, or
/// the synthetic surrogate (pure functions of the spec).
enum SessionBackend {
    Pjrt {
        train: Executable,
        eval: Executable,
        init: Executable,
    },
    Synthetic,
}

/// The train/eval/init entry points of one lowered spec.
///
/// `Send + Sync` (via [`Executable`]; the synthetic backend is plain
/// data): the parallel round engine shares one session across all
/// client-executor threads.
pub struct ModelSession {
    pub spec: SpecEntry,
    backend: SessionBackend,
}

impl ModelSession {
    fn check_batch(&self, batch: &Batch) -> Result<()> {
        let s = self.spec.image_size;
        let b = self.spec.batch_size;
        if batch.x.len() != b * s * s * 3 || batch.y.len() != b {
            return Err(Error::invalid(format!(
                "batch shape mismatch: x={} y={} expected b={b} s={s}",
                batch.x.len(),
                batch.y.len()
            )));
        }
        Ok(())
    }

    fn batch_literals(
        &self,
        batch: &Batch,
    ) -> Result<(xla::Literal, xla::Literal)> {
        self.check_batch(batch)?;
        let s = self.spec.image_size as i64;
        let b = self.spec.batch_size as i64;
        let x = xla::Literal::vec1(&batch.x).reshape(&[b, s, s, 3])?;
        let y = xla::Literal::vec1(&batch.y);
        Ok((x, y))
    }

    /// Run the init artifact: seeded He init → `(trainable, frozen)`.
    pub fn init(&self, seed: u64) -> Result<(Vec<f32>, Vec<f32>)> {
        let SessionBackend::Pjrt { init, .. } = &self.backend else {
            return Ok(synthetic::init(&self.spec, seed));
        };
        let key = xla::Literal::vec1(&[(seed >> 32) as u32, seed as u32]);
        let mut outs = execute_tuple(init, &[key])?;
        if outs.len() != 2 {
            return Err(Error::invalid("init must return (trainable, frozen)"));
        }
        let frozen = outs.pop().unwrap().to_vec::<f32>()?;
        let trainable = outs.pop().unwrap().to_vec::<f32>()?;
        if trainable.len() != self.spec.num_trainable
            || frozen.len() != self.spec.num_frozen
        {
            return Err(Error::invalid(format!(
                "init returned {}/{} params, manifest says {}/{}",
                trainable.len(),
                frozen.len(),
                self.spec.num_trainable,
                self.spec.num_frozen
            )));
        }
        Ok((trainable, frozen))
    }

    /// One SGD-with-momentum minibatch step. `params` and `momentum` are
    /// updated in place (reusing their allocations).
    pub fn train_step(
        &self,
        params: &mut [f32],
        momentum: &mut [f32],
        frozen: &[f32],
        batch: &Batch,
        lr: f32,
        lora_scale: f32,
    ) -> Result<StepStats> {
        let SessionBackend::Pjrt { train, .. } = &self.backend else {
            self.check_batch(batch)?;
            return Ok(synthetic::train_step(
                &self.spec, params, momentum, batch, lr, lora_scale,
            ));
        };
        let (x, y) = self.batch_literals(batch)?;
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(momentum),
            xla::Literal::vec1(frozen),
            x,
            y,
            xla::Literal::scalar(lr),
            xla::Literal::scalar(lora_scale),
        ];
        let mut outs = execute_tuple(train, &args)?;
        if outs.len() != 4 {
            return Err(Error::invalid("train must return 4 outputs"));
        }
        let acc = outs.pop().unwrap().get_first_element::<f32>()?;
        let loss = outs.pop().unwrap().get_first_element::<f32>()?;
        let new_m = outs.pop().unwrap();
        let new_p = outs.pop().unwrap();
        new_p.copy_raw_to(params)?;
        new_m.copy_raw_to(momentum)?;
        Ok(StepStats { loss, acc })
    }

    /// Masked eval on one batch → `(loss_sum, correct_count)`.
    pub fn eval_step(
        &self,
        params: &[f32],
        frozen: &[f32],
        batch: &Batch,
        lora_scale: f32,
    ) -> Result<(f64, f64)> {
        let SessionBackend::Pjrt { eval, .. } = &self.backend else {
            self.check_batch(batch)?;
            return Ok(synthetic::eval_step(
                &self.spec, params, batch, lora_scale,
            ));
        };
        let (x, y) = self.batch_literals(batch)?;
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(frozen),
            x,
            y,
            xla::Literal::vec1(&batch.mask),
            xla::Literal::scalar(lora_scale),
        ];
        let mut outs = execute_tuple(eval, &args)?;
        if outs.len() != 2 {
            return Err(Error::invalid("eval must return 2 outputs"));
        }
        let correct = outs.pop().unwrap().get_first_element::<f32>()? as f64;
        let loss = outs.pop().unwrap().get_first_element::<f32>()? as f64;
        Ok((loss, correct))
    }
}
