//! The crate's single doorway to concurrency primitives — and the
//! hook the loom model checker enters through.
//!
//! Every concurrency site in flocora (`coordinator::executor`'s
//! bounded window and pipelined ring, `compression::sparse`'s residual
//! map, `runtime`'s executable cache, `kernels::waterfill_pair`'s
//! scoped split) imports `Mutex`/`Condvar`/atomics/`Arc`/`thread` from
//! *here*, never from `std::sync` directly. Normally these re-exports
//! are exactly `std`'s — zero cost, zero behavior change. Under
//! `RUSTFLAGS="--cfg loom"` they swap for the vendored `loom` model
//! checker's instrumented twins, and `tests/loom.rs` exhaustively
//! explores every thread interleaving of the real protocol code
//! (bounded by a CHESS-style preemption budget — see `rust/loom`).
//!
//! The `cargo xtask lint-determinism` rule `std-sync` enforces the
//! funnel statically: a `std::sync`/`std::thread` import anywhere else
//! in `src/` fails CI, so a new concurrency site cannot silently opt
//! out of model checking.
//!
//! Only the names flocora actually uses are re-exported — the shim is
//! an inventory of the crate's concurrency surface, not a facade over
//! all of `std::sync`. Add a name here (and loom coverage for its call
//! site) before using it.

// det-lint: allow(std-sync) — this module IS the shim the rule
// funnels everything through; its whole point is to name std::sync.
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, LockResult, Mutex, MutexGuard,
                    PoisonError};

#[cfg(not(loom))]
pub mod atomic {
    // det-lint: allow(std-sync) — shim re-export (see module docs).
    pub use std::sync::atomic::{AtomicUsize, Ordering};
}

#[cfg(not(loom))]
pub mod thread {
    // det-lint: allow(std-sync) — shim re-export (see module docs).
    pub use std::thread::{available_parallelism, panicking, scope,
                          spawn, JoinHandle, Scope, ScopedJoinHandle};
}

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, LockResult, Mutex, MutexGuard,
                     PoisonError};

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{AtomicUsize, Ordering};
}

#[cfg(loom)]
pub mod thread {
    pub use loom::thread::{available_parallelism, panicking, scope,
                           spawn, JoinHandle, Scope, ScopedJoinHandle};
}
