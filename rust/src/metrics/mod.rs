//! Metrics: per-round records, summary statistics and CSV/JSON export
//! (the data behind Fig. 3's convergence curves and EXPERIMENTS.md).

use std::io::Write;
use std::path::Path;

use crate::coordinator::RunSummary;
use crate::error::Result;
use crate::util::json::{arr, num, obj, s, Json};

/// One evaluated round.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub test_acc: f64,
    pub test_loss: f64,
    pub train_loss: f64,
    /// Cumulative bytes moved (all clients, both directions).
    pub cum_bytes: u64,
    /// Sampled clients that failed before uploading in the rounds this
    /// record covers (everything since the previous record, so the
    /// column sums to the run-level `Simulation::dropped_clients` even
    /// when `eval_every` skips rounds).
    pub dropped: u64,
    /// Sampled clients the server cancelled (oversampled rounds end at
    /// the K-th accepted upload) in the rounds this record covers;
    /// sums to the run-level `Simulation::cancelled_clients`.
    pub cancelled: u64,
    /// Median simulated client round-trip (profiled wire + compute)
    /// over the clients the server waited on in the covered rounds.
    pub client_p50_s: f64,
    /// Slowest simulated client round-trip in the covered rounds — the
    /// straggler the dedicated-link round time is made of.
    pub client_max_s: f64,
    /// Simulated round time under the transport-stage overlap regime
    /// (`overlap = transfer`), summed over the covered rounds; sums to
    /// the run-level `RunSummary::sim_net_pipelined_s`.
    pub sim_net_pipelined_s: f64,
    /// Simulated wire wait (downloads + uploads) in the covered rounds
    /// — the time the pipelined regime hides behind compute; sums to
    /// `RunSummary::transfer_wait_s`.
    pub transfer_wait_s: f64,
    /// The active `time_model`'s simulated round time over the covered
    /// rounds (pipelined envelope under `closed`, discrete-event
    /// result under `event`); sums to `RunSummary::sim_net_event_s`.
    pub sim_net_event_s: f64,
    /// Peak inter-stage queue occupancy (chunks) the event simulator
    /// saw in the covered rounds; run max in `RunSummary::queue_peak`.
    pub queue_peak: usize,
    /// Simulated producer-blocked time on full stage queues in the
    /// covered rounds; sums to `RunSummary::queue_block_s`.
    pub queue_block_s: f64,
    /// Mean effective adapter rank the server broadcast over the
    /// covered rounds (static server rank under `aggregator = fedavg`,
    /// the energy-kept rank under `svt`; 0.0 when no round aggregated
    /// or the layout has no adapter pairs).
    pub eff_rank: f64,
    pub wall_ms: f64,
}

/// Recorder for a single run.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
}

impl Recorder {
    pub fn new(name: impl Into<String>) -> Recorder {
        Recorder { name: name.into(), rounds: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn final_acc(&self) -> f64 {
        self.rounds.last().map(|r| r.test_acc).unwrap_or(0.0)
    }

    pub fn best_acc(&self) -> f64 {
        self.rounds.iter().map(|r| r.test_acc).fold(0.0, f64::max)
    }

    /// Mean accuracy over the last `k` evaluated rounds (stabler than
    /// the final point; used for the paper-table comparisons).
    pub fn tail_acc(&self, k: usize) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        let start = self.rounds.len().saturating_sub(k);
        let tail = &self.rounds[start..];
        tail.iter().map(|r| r.test_acc).sum::<f64>() / tail.len() as f64
    }

    /// First round at which accuracy reached `target` (convergence-time
    /// comparisons, Fig. 3).
    pub fn rounds_to_acc(&self, target: f64) -> Option<usize> {
        self.rounds.iter().find(|r| r.test_acc >= target).map(|r| r.round)
    }

    pub fn to_csv(&self) -> String {
        // `wall_ms` must stay the last column: CI's cross-executor CSV
        // diffs strip it positionally (`rev | cut -d, -f2- | rev`).
        let mut out = String::from(
            "round,test_acc,test_loss,train_loss,cum_bytes,dropped,\
             cancelled,client_p50_s,client_max_s,sim_net_pipelined_s,\
             transfer_wait_s,sim_net_event_s,queue_peak,queue_block_s,\
             eff_rank,wall_ms\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{:.4},{:.4},{:.4},{},{},{},{:.4},{:.4},{:.4},{:.4},\
                 {:.4},{},{:.4},{:.4},{:.1}\n",
                r.round, r.test_acc, r.test_loss, r.train_loss, r.cum_bytes,
                r.dropped, r.cancelled, r.client_p50_s, r.client_max_s,
                r.sim_net_pipelined_s, r.transfer_wait_s, r.sim_net_event_s,
                r.queue_peak, r.queue_block_s, r.eff_rank, r.wall_ms
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        // A fully-dropped recorded round reports a NaN train loss, and
        // NaN is not valid JSON — map non-finite floats to null so the
        // export always parses.
        let fnum = |v: f64| if v.is_finite() { num(v) } else { Json::Null };
        obj(vec![
            ("name", s(self.name.clone())),
            (
                "rounds",
                arr(self
                    .rounds
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("round", num(r.round as f64)),
                            ("test_acc", fnum(r.test_acc)),
                            ("test_loss", fnum(r.test_loss)),
                            ("train_loss", fnum(r.train_loss)),
                            ("cum_bytes", num(r.cum_bytes as f64)),
                            ("dropped", num(r.dropped as f64)),
                            ("cancelled", num(r.cancelled as f64)),
                            ("client_p50_s", fnum(r.client_p50_s)),
                            ("client_max_s", fnum(r.client_max_s)),
                            ("sim_net_pipelined_s",
                             fnum(r.sim_net_pipelined_s)),
                            ("transfer_wait_s", fnum(r.transfer_wait_s)),
                            ("sim_net_event_s", fnum(r.sim_net_event_s)),
                            ("queue_peak", num(r.queue_peak as f64)),
                            ("queue_block_s", fnum(r.queue_block_s)),
                            ("eff_rank", fnum(r.eff_rank)),
                            ("wall_ms", fnum(r.wall_ms)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// JSON export of one run (the `--json` flag): the summary plus the
/// per-round records. Wall-clock fields (`wall_s`, `wall_ms`) are the
/// only non-deterministic values; CI's sim-smoke job strips them and
/// diffs the rest to pin bit-identity across overlap modes, executors
/// and time models. Every `RunSummary` field must appear here —
/// `tests/pipeline.rs` round-trips the export and fails if a field is
/// silently dropped.
pub fn run_json(rec: &Recorder, summary: &RunSummary, dropped: u64) -> Json {
    // NaN is not valid JSON (a fully-dropped final round reports a NaN
    // train loss); map non-finite to null.
    let fnum = |v: f64| if v.is_finite() { num(v) } else { Json::Null };
    obj(vec![
        ("name", s(rec.name.clone())),
        (
            "summary",
            obj(vec![
                ("final_acc", fnum(summary.final_acc)),
                ("tail_acc", fnum(summary.tail_acc)),
                ("final_train_loss", fnum(summary.final_train_loss)),
                ("total_bytes", num(summary.total_bytes as f64)),
                ("mean_up_msg_bytes", fnum(summary.mean_up_msg_bytes)),
                ("per_client_tcc_bytes", fnum(summary.per_client_tcc_bytes)),
                ("rounds", num(summary.rounds as f64)),
                ("sim_net_serial_s", fnum(summary.sim_net_serial_s)),
                ("sim_net_parallel_s", fnum(summary.sim_net_parallel_s)),
                ("sim_net_pipelined_s", fnum(summary.sim_net_pipelined_s)),
                ("transfer_wait_s", fnum(summary.transfer_wait_s)),
                ("sim_net_event_s", fnum(summary.sim_net_event_s)),
                ("queue_peak", num(summary.queue_peak as f64)),
                ("queue_block_s", fnum(summary.queue_block_s)),
                ("cancelled_clients", num(summary.cancelled_clients as f64)),
                ("dropped_clients", num(dropped as f64)),
                ("sim_client_p50_s", fnum(summary.sim_client_p50_s)),
                ("sim_client_max_s", fnum(summary.sim_client_max_s)),
                ("mean_eff_rank", fnum(summary.mean_eff_rank)),
                // Deterministic and shard-invariant (depends only on
                // the non-empty block count), so it sits inside the
                // diffed region — before the stripped `wall_s`.
                ("merge_depth", num(summary.merge_depth as f64)),
                ("wall_s", fnum(summary.wall_s)),
            ]),
        ),
        ("rounds", {
            let Json::Obj(m) = rec.to_json() else {
                unreachable!("Recorder::to_json returns an object")
            };
            m.get("rounds").cloned().unwrap_or_else(|| arr(Vec::new()))
        }),
    ])
}

/// Recursively remove the wall-clock fields (`wall_s`, `wall_ms`)
/// from a run document — the only non-deterministic values in a
/// [`run_json`] export. The in-process version of CI's
/// `jq 'del(.summary.wall_s) | del(.rounds[].wall_ms)'`: the wire
/// loopback tests strip both documents with this and assert the
/// remainder is byte-identical.
pub fn strip_wall(j: &Json) -> Json {
    match j {
        Json::Arr(items) => {
            Json::Arr(items.iter().map(strip_wall).collect())
        }
        Json::Obj(map) => Json::Obj(
            map.iter()
                .filter(|(k, _)| k.as_str() != "wall_s"
                    && k.as_str() != "wall_ms")
                .map(|(k, v)| (k.clone(), strip_wall(v)))
                .collect(),
        ),
        other => other.clone(),
    }
}

/// Median (p50) of a sample; 0.0 for an empty slice. Used for the
/// per-round straggler stats (median simulated client time).
pub fn p50(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mid = sorted.len() / 2;
    if sorted.len() % 2 == 1 {
        sorted[mid]
    } else {
        0.5 * (sorted[mid - 1] + sorted[mid])
    }
}

/// Mean ± sample standard deviation over seeds (the paper reports
/// `mean ± std` over 3 seeds everywhere).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
        / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> Recorder {
        let mut r = Recorder::new("t");
        for i in 0..5 {
            r.push(RoundRecord {
                round: i,
                test_acc: 0.1 * i as f64,
                test_loss: 2.0 - 0.1 * i as f64,
                train_loss: 2.0,
                cum_bytes: (i * 100) as u64,
                dropped: i as u64 % 2,
                cancelled: i as u64 % 3,
                client_p50_s: 0.5,
                client_max_s: 1.5,
                sim_net_pipelined_s: 0.25 * i as f64,
                transfer_wait_s: 0.75,
                sim_net_event_s: 0.3 * i as f64,
                queue_peak: i,
                queue_block_s: 0.125,
                eff_rank: 4.0,
                wall_ms: 1.0,
            });
        }
        r
    }

    #[test]
    fn summaries() {
        let r = rec();
        assert_eq!(r.final_acc(), 0.4);
        assert_eq!(r.best_acc(), 0.4);
        assert!((r.tail_acc(2) - 0.35).abs() < 1e-12);
        assert_eq!(r.rounds_to_acc(0.25), Some(3));
        assert_eq!(r.rounds_to_acc(0.9), None);
    }

    #[test]
    fn csv_shape() {
        let csv = rec().to_csv();
        assert_eq!(csv.lines().count(), 6);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn json_round_trip() {
        let j = rec().to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.at(&["name"]).unwrap().as_str().unwrap(), "t");
        let rounds = parsed.at(&["rounds"]).unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 5);
        assert_eq!(
            rounds[1].at(&["dropped"]).unwrap().as_usize().unwrap(),
            1
        );
    }

    #[test]
    fn csv_carries_dropped_column() {
        let csv = rec().to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.split(',').any(|c| c == "dropped"), "{header}");
        // Row for round 1 (dropped = 1): ...,cum_bytes,dropped,...
        let row: Vec<&str> = csv.lines().nth(2).unwrap().split(',').collect();
        assert_eq!(row[5], "1");
    }

    #[test]
    fn csv_and_json_carry_straggler_columns() {
        let csv = rec().to_csv();
        let header: Vec<&str> = csv.lines().next().unwrap().split(',')
            .collect();
        for col in ["cancelled", "client_p50_s", "client_max_s",
                    "sim_net_pipelined_s", "transfer_wait_s",
                    "sim_net_event_s", "queue_peak", "queue_block_s"] {
            assert!(header.contains(&col), "{header:?} missing {col}");
        }
        // Row for round 2 (cancelled = 2), right after `dropped`.
        let row: Vec<&str> = csv.lines().nth(3).unwrap().split(',').collect();
        assert_eq!(row[6], "2");
        let j = rec().to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        let rounds = parsed.at(&["rounds"]).unwrap().as_arr().unwrap();
        assert_eq!(
            rounds[2].at(&["cancelled"]).unwrap().as_usize().unwrap(),
            2
        );
        assert_eq!(
            rounds[2].at(&["sim_net_pipelined_s"]).unwrap()
                .as_f64().unwrap(),
            0.5
        );
        assert_eq!(
            rounds[1].at(&["transfer_wait_s"]).unwrap().as_f64().unwrap(),
            0.75
        );
        assert_eq!(
            rounds[2].at(&["sim_net_event_s"]).unwrap().as_f64().unwrap(),
            0.6
        );
        assert_eq!(
            rounds[3].at(&["queue_peak"]).unwrap().as_usize().unwrap(),
            3
        );
        assert_eq!(
            rounds[1].at(&["queue_block_s"]).unwrap().as_f64().unwrap(),
            0.125
        );
    }

    #[test]
    fn eff_rank_column_sits_before_wall_ms() {
        // CI strips the wall column positionally (`rev | cut -d, -f2- |
        // rev`), so `wall_ms` must stay last and `eff_rank` just before.
        let csv = rec().to_csv();
        let header: Vec<&str> = csv.lines().next().unwrap().split(',')
            .map(str::trim).collect();
        assert_eq!(header[header.len() - 1], "wall_ms");
        assert_eq!(header[header.len() - 2], "eff_rank");
        let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row[row.len() - 2], "4.0000");
        let j = rec().to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        let rounds = parsed.at(&["rounds"]).unwrap().as_arr().unwrap();
        assert_eq!(
            rounds[0].at(&["eff_rank"]).unwrap().as_f64().unwrap(),
            4.0
        );
    }

    #[test]
    fn json_maps_non_finite_to_null() {
        // A fully-dropped recorded round carries a NaN train loss; the
        // export must still be valid JSON (null, not a bare NaN).
        let mut r = Recorder::new("nan");
        let mut rec = rec().rounds[0].clone();
        rec.train_loss = f64::NAN;
        r.push(rec);
        let text = r.to_json().to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert!(parsed.at(&["rounds"]).unwrap().as_arr().unwrap()[0]
            .at(&["train_loss"])
            .unwrap()
            .is_null());
    }

    #[test]
    fn strip_wall_removes_every_wall_field_and_nothing_else() {
        let j = rec().to_json();
        let stripped = strip_wall(&j);
        let text = stripped.to_string();
        assert!(!text.contains("wall_ms"), "{text}");
        assert!(!text.contains("wall_s"), "{text}");
        // Everything else survives, values intact.
        let rounds = stripped.at(&["rounds"]).unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 5);
        assert_eq!(
            rounds[2].at(&["cancelled"]).unwrap().as_usize().unwrap(),
            2
        );
        // Two identical runs differing only in wall time strip equal.
        let mut other = rec();
        for r in &mut other.rounds {
            r.wall_ms += 123.0;
        }
        assert_ne!(j.to_string(), other.to_json().to_string());
        assert_eq!(
            strip_wall(&j).to_string(),
            strip_wall(&other.to_json()).to_string()
        );
    }

    #[test]
    fn p50_is_the_median() {
        assert_eq!(p50(&[]), 0.0);
        assert_eq!(p50(&[3.0]), 3.0);
        assert_eq!(p50(&[1.0, 9.0]), 5.0);
        assert_eq!(p50(&[9.0, 1.0, 5.0]), 5.0);
        assert_eq!(p50(&[4.0, 1.0, 2.0, 100.0]), 3.0);
    }

    #[test]
    fn mean_std_basics() {
        let (m, sd) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((sd - 1.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(mean_std(&[5.0]).1, 0.0);
    }
}
