//! Aggregation-zoo property suite: codec round-trip bounds, sparse
//! error-feedback conservation, SVT energy-threshold monotonicity, and
//! the β-identity cases where every factor-aware mode must degrade to
//! bit-for-bit FedAvg — plus full-run bit-identity of the new presets
//! across every executor on the synthetic backend.
//!
//! These pin the contracts ISSUE 6 introduced: the zoo may *change*
//! the model trajectory (that is its job), but it must change it
//! deterministically, conserve what the sparsifiers defer, and vanish
//! exactly when its knobs are set to the identity.

use flocora::compression::{AffineCodec, Codec, CodecKind, SparseEfCodec,
                           TopKCodec};
use flocora::config::{presets, FlConfig};
use flocora::coordinator::{adapter_pairs, Aggregator, AggregatorKind,
                           ClientUpdate, ExecutorKind, Simulation};
use flocora::metrics::Recorder;
use flocora::model::{build_spec, ModelCfg, Segment, Variant};
use flocora::runtime::Engine;
use flocora::transport::OverlapKind;
use flocora::util::rng::Rng;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
}

fn lora_spec(rank: usize) -> (Vec<Segment>, usize) {
    let spec = build_spec(
        ModelCfg::by_name("micro8").unwrap(),
        Variant::LoraFc,
        rank,
    );
    let n = spec.num_trainable();
    (spec.trainable, n)
}

// ---------------------------------------------------------------------------
// Codec round-trip error bounds
// ---------------------------------------------------------------------------

#[test]
fn affine_round_trip_error_is_bounded_by_the_step_size() {
    let (segs, n) = lora_spec(4);
    let v = randv(n, 1);
    let lo = v.iter().copied().fold(f32::INFINITY, f32::min) as f64;
    let hi = v.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    for bits in [8u32, 4, 2] {
        let c = AffineCodec::new(bits);
        let out = c.decode(&c.encode(&v, &segs).unwrap(), &segs).unwrap();
        assert_eq!(out.len(), v.len());
        // Per-row scale ≤ global range / (levels - 1); affine RTN error
        // is at most one step. Norm segments ride through in FP exactly.
        let bound = (hi - lo) / ((1u32 << bits) - 1) as f64 + 1e-6;
        let err = v
            .iter()
            .zip(&out)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        assert!(err <= bound, "q{bits}: max err {err} > step bound {bound}");
    }
}

#[test]
fn topk_round_trip_error_is_exactly_the_dropped_tail() {
    let (segs, n) = lora_spec(4);
    let v = randv(n, 2);
    let c = TopKCodec::new(0.25);
    let out = c.decode(&c.encode(&v, &segs).unwrap(), &segs).unwrap();
    let kept: Vec<usize> = (0..n).filter(|&i| out[i] != 0.0).collect();
    assert_eq!(kept.len(), c.kept_count(n));
    // Kept entries are verbatim; dropped entries are the whole error.
    for &i in &kept {
        assert_eq!(out[i], v[i]);
    }
    let min_kept = kept.iter().map(|&i| v[i].abs()).fold(f32::INFINITY,
                                                         f32::min);
    let max_dropped = (0..n)
        .filter(|&i| out[i] == 0.0)
        .map(|i| v[i].abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_dropped <= min_kept,
        "a dropped |{max_dropped}| beat a kept |{min_kept}|"
    );
}

#[test]
fn sparse_ef_round_trip_error_is_the_banked_residual() {
    // For the EF codec the "error" of one upload is not lost — it is
    // exactly the residual the codec banked, bit-for-bit.
    let (segs, n) = lora_spec(4);
    let c = SparseEfCodec::new(0.25);
    let mut carried = vec![0.0f32; n];
    for round in 0..6 {
        let v = randv(n, 40 + round);
        let sent = c
            .decode(&c.encode_client(5, &v, &segs).unwrap(), &segs)
            .unwrap();
        let residual = c.residual(5).unwrap();
        for i in 0..n {
            // corrected = v + carried; sent/residual partition it.
            assert_eq!(sent[i] + residual[i], v[i] + carried[i],
                       "round {round}, element {i}");
            assert!(sent[i] == 0.0 || residual[i] == 0.0);
        }
        carried = residual;
    }
    // Over the horizon, deferral is bounded: the residual only holds
    // entries the mask dropped this round, never an accumulated blob
    // larger than one corrected vector.
    assert_eq!(carried.len(), n);
    assert!(carried.iter().filter(|&&x| x != 0.0).count()
            <= n - c.kept_count(n));
}

// ---------------------------------------------------------------------------
// SVT energy-threshold monotonicity
// ---------------------------------------------------------------------------

/// Count the nonzero adapter-pair coordinates of a vector — the bytes
/// proxy: under any sparse wire codec, broadcast bytes grow with the
/// surviving coordinates.
fn adapter_nonzeros(v: &[f32], segs: &[Segment]) -> usize {
    adapter_pairs(segs)
        .iter()
        .map(|p| {
            let mut cnt = 0;
            for o in 0..p.outer {
                for j in 0..p.rank {
                    if v[p.left_offset + o * p.rank + j] != 0.0 {
                        cnt += 1;
                    }
                }
            }
            for t in 0..p.rank * p.inner {
                if v[p.right_offset + t] != 0.0 {
                    cnt += 1;
                }
            }
            cnt
        })
        .sum()
}

#[test]
fn svt_rank_and_bytes_grow_with_retained_energy() {
    // Higher retained-energy τ keeps more singular directions: the
    // reported effective rank and the surviving adapter coordinates
    // (the bytes a sparse broadcast would pay) are both non-decreasing
    // in τ, capped by the server rank.
    let (segs, n) = lora_spec(8);
    let pairs = adapter_pairs(&segs);
    let clients: Vec<Vec<f32>> =
        (0..3).map(|i| randv(n, 70 + i as u64)).collect();
    let run = |tau: f64| {
        let mut agg = AggregatorKind::Svt.build(n, &pairs, tau);
        for (i, v) in clients.iter().enumerate() {
            agg.fold(i, ClientUpdate::Dense(v), 1.0 + i as f64).unwrap();
        }
        agg.finish().unwrap()
    };
    let taus = [0.1, 0.3, 0.5, 0.7, 0.9, 0.999, 1.0];
    let outs: Vec<_> = taus.iter().map(|&t| run(t)).collect();
    for w in outs.windows(2) {
        assert!(
            w[1].eff_rank >= w[0].eff_rank,
            "eff_rank dropped as τ grew: {} then {}",
            w[0].eff_rank,
            w[1].eff_rank
        );
        assert!(
            adapter_nonzeros(&w[1].global, &segs)
                >= adapter_nonzeros(&w[0].global, &segs),
            "surviving coordinates shrank as τ grew"
        );
    }
    for (t, o) in taus.iter().zip(&outs) {
        assert!(o.eff_rank <= 8.0, "τ={t}: rank above the server budget");
        assert!(o.eff_rank >= 1.0, "τ={t}: kept nothing");
    }
    // The grid actually exercises truncation: the low end keeps fewer
    // directions than the top.
    assert!(outs[0].eff_rank < outs[taus.len() - 1].eff_rank,
            "threshold never truncated anything");
}

// ---------------------------------------------------------------------------
// β-identity cases: the zoo must vanish exactly
// ---------------------------------------------------------------------------

/// Full observable state of one finished synthetic run.
struct Observed {
    global: Vec<f32>,
    final_acc: f64,
    final_train_loss: f64,
    total_bytes: u64,
    per_round: Vec<u64>,
    dropped: u64,
    cancelled: u64,
    mean_eff_rank: f64,
}

fn run(cfg: FlConfig) -> Observed {
    let engine = Engine::synthetic();
    let mut sim = Simulation::new(&engine, cfg).unwrap();
    let mut rec = Recorder::new("aggregation");
    let summary = sim.run(&mut rec).unwrap();
    Observed {
        global: sim.global.clone(),
        final_acc: summary.final_acc,
        final_train_loss: summary.final_train_loss,
        total_bytes: summary.total_bytes,
        per_round: sim.ledger.per_round.clone(),
        dropped: sim.dropped_clients,
        cancelled: sim.cancelled_clients,
        mean_eff_rank: summary.mean_eff_rank,
    }
}

fn assert_identical(a: &Observed, b: &Observed, what: &str) {
    assert_eq!(a.global, b.global, "{what}: global vector diverged");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: total_bytes");
    assert_eq!(a.per_round, b.per_round, "{what}: per-round ledger");
    assert_eq!(a.dropped, b.dropped, "{what}: dropout count");
    assert_eq!(a.cancelled, b.cancelled, "{what}: cancelled count");
    assert_eq!(a.mean_eff_rank, b.mean_eff_rank, "{what}: mean_eff_rank");
    assert!(
        a.final_train_loss == b.final_train_loss
            || (a.final_train_loss.is_nan() && b.final_train_loss.is_nan()),
        "{what}: final_train_loss {} vs {}",
        a.final_train_loss,
        b.final_train_loss
    );
}

fn small(mut cfg: FlConfig) -> FlConfig {
    cfg.rounds = 6;
    cfg.local_epochs = 1;
    cfg.samples_per_client = 16;
    cfg.test_samples = 40;
    cfg.eval_every = 2;
    cfg
}

fn with_exec(mut cfg: FlConfig, kind: ExecutorKind, threads: usize,
             window: usize, overlap: OverlapKind) -> FlConfig {
    cfg.executor = kind;
    cfg.threads = threads;
    cfg.window = window;
    cfg.overlap = overlap;
    cfg
}

#[test]
fn svt_full_energy_run_is_bitwise_fedavg() {
    // τ = 1.0 must be indistinguishable from FedAvg across a whole run
    // — globals, ledger, stats, and the eff_rank report alike.
    let mut fed = small(presets::by_name("svt_micro").unwrap());
    fed.aggregator = AggregatorKind::FedAvg;
    let mut svt = small(presets::by_name("svt_micro").unwrap());
    svt.svt_energy = 1.0;
    let (fed, svt) = (run(fed), run(svt));
    assert_identical(&fed, &svt, "svt τ=1.0 vs fedavg");
    assert_eq!(fed.mean_eff_rank, 8.0, "static rank of micro8 r=8");
}

#[test]
fn exact_single_contributor_run_is_bitwise_fedavg() {
    // One client per round: the mean of one product is the product of
    // one mean, so the exact mode must be a no-op.
    let mut base = small(presets::by_name("scaled_micro").unwrap());
    base.clients_per_round = 1;
    base.dropout = 0.0;
    let mut exact = base.clone();
    exact.aggregator = AggregatorKind::Exact;
    let (fed, exact) = (run(base), run(exact));
    assert_identical(&fed, &exact, "exact K=1 vs fedavg");
}

#[test]
fn svt_below_full_energy_changes_the_trajectory() {
    // The identity tests above would pass vacuously if the refactor
    // never ran; pin that τ < 1.0 with several contributors actually
    // moves the model while keeping the rank report in budget.
    let mut fed = small(presets::by_name("svt_micro").unwrap());
    fed.aggregator = AggregatorKind::FedAvg;
    let svt = small(presets::by_name("svt_micro").unwrap());
    let (fed, svt) = (run(fed), run(svt));
    assert_ne!(fed.global, svt.global,
               "svt τ=0.9 never perturbed the trajectory");
    assert!(svt.mean_eff_rank > 0.0 && svt.mean_eff_rank <= 8.0,
            "mean_eff_rank {} out of (0, 8]", svt.mean_eff_rank);
    // Bytes are identical — SVT reshapes what is broadcast, not how
    // much of it this codec sends.
    assert_eq!(fed.total_bytes, svt.total_bytes);
}

// ---------------------------------------------------------------------------
// Cross-executor bit-identity of the new presets
// ---------------------------------------------------------------------------

fn assert_executor_invariant(cfg: FlConfig, what: &str) {
    let serial = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0, 0,
                               OverlapKind::None));
    let parallel = run(with_exec(cfg.clone(), ExecutorKind::Parallel, 3, 0,
                                 OverlapKind::None));
    let pipelined = run(with_exec(cfg.clone(), ExecutorKind::Parallel, 3, 0,
                                  OverlapKind::Transfer));
    let windowed = run(with_exec(cfg, ExecutorKind::Parallel, 3, 2,
                                 OverlapKind::Transfer));
    assert_identical(&serial, &parallel, &format!("{what}: parallel"));
    assert_identical(&serial, &pipelined, &format!("{what}: pipelined"));
    assert_identical(&serial, &windowed, &format!("{what}: windowed"));
}

#[test]
fn svt_preset_bit_identical_across_executors() {
    assert_executor_invariant(
        small(presets::by_name("svt_micro").unwrap()),
        "svt_micro",
    );
}

#[test]
fn sparse_ef_preset_bit_identical_across_executors() {
    // The stateful codec is the sharp edge here: residuals key on the
    // client id, so thread scheduling must not perturb the stream.
    assert_executor_invariant(
        small(presets::by_name("sparse_ef_micro").unwrap()),
        "sparse_ef_micro",
    );
}

#[test]
fn exact_mode_bit_identical_under_stragglers() {
    // Exact aggregation under the oversample/cancel regime: ragged
    // contributor sets every round, still executor-invariant.
    let mut cfg = small(presets::by_name("straggler_micro").unwrap());
    cfg.aggregator = AggregatorKind::Exact;
    cfg.rounds = 8;
    assert_executor_invariant(cfg, "straggler+exact");
}

#[test]
fn svt_mode_bit_identical_under_hetero_ranks_and_dropout() {
    // Hetero uploads reach the aggregator zero-padded into the server
    // rank space; the all-zero slots must not perturb the refactor's
    // determinism (they are skipped while stacking).
    let mut cfg = small(presets::by_name("hetero_micro").unwrap());
    cfg.aggregator = AggregatorKind::Svt;
    cfg.svt_energy = 0.8;
    cfg.dropout = 0.2;
    assert_executor_invariant(cfg, "hetero+svt");
}

#[test]
fn sparse_ef_run_defers_but_never_loses_mass() {
    // Integration-level conservation: with dropout making clients skip
    // rounds, the run must still complete deterministically and move
    // fewer upload bytes than fp32 — deferral shows up as compression,
    // not loss (the codec-level invariant is pinned above).
    let mut ef = small(presets::by_name("sparse_ef_micro").unwrap());
    ef.dropout = 0.25;
    ef.rounds = 8;
    let mut fp = ef.clone();
    fp.codec = CodecKind::Fp32;
    let (ef, fp) = (run(ef), run(fp));
    assert!(ef.total_bytes < fp.total_bytes,
            "sparse_ef {} B did not beat fp32 {} B",
            ef.total_bytes, fp.total_bytes);
    assert!(ef.dropped > 0, "dropout never fired at 0.25");
}
