//! Transport-stage pipeline parity on the synthetic backend.
//!
//! These are the artifact-free twins of `tests/executor.rs`: the
//! synthetic engine ([`Engine::synthetic`]) swaps the PJRT train step
//! for deterministic pure-Rust surrogate dynamics, so the *protocol*
//! invariants — bit-identical model trajectories across
//! serial/parallel/windowed/pipelined execution with only simulated
//! time accounting differing — run everywhere, including CI's plain
//! runners (`cargo test --test pipeline`) and this repo's offline
//! container. CI's `sim-smoke` job re-verifies the same bit-identity
//! end-to-end through the binary.

use flocora::compression::{CodecKind, Fp32Codec};
use flocora::config::{presets, FlConfig};
use flocora::coordinator::executor::{ClientResult, Downloads,
                                     PipelinedExecutor, RoundContext};
use flocora::coordinator::sink::RoundSink;
use flocora::coordinator::{AggregatorKind, ClientExecutor, ExecutorKind,
                           LocalTrainer, SamplerKind, Simulation, VecSink};
use flocora::data::lda_partition;
use flocora::metrics::Recorder;
use flocora::runtime::Engine;
use flocora::transport::OverlapKind;

fn base_cfg() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 8,
        clients_per_round: 4,
        rounds: 3,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        seed: 21,
        ..FlConfig::default()
    }
}

/// The straggler regime at test size: tiered profiles, oversampled
/// participation, planned cancellations.
fn straggler_cfg() -> FlConfig {
    let mut cfg = presets::by_name("straggler_micro").unwrap();
    cfg.rounds = 8;
    cfg.local_epochs = 1;
    cfg.samples_per_client = 16;
    cfg.test_samples = 40;
    cfg.seed = 21;
    cfg
}

fn hetero_cfg() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r8".into(),
        num_clients: 12,
        clients_per_round: 4,
        rounds: 3,
        local_epochs: 1,
        lora_alpha: 64.0,
        samples_per_client: 16,
        test_samples: 40,
        seed: 33,
        hetero_ranks: vec![2, 4, 8],
        ..FlConfig::default()
    }
}

/// Full observable state of one finished synthetic run.
struct Observed {
    global: Vec<f32>,
    final_acc: f64,
    final_train_loss: f64,
    total_bytes: u64,
    per_round: Vec<u64>,
    dropped: u64,
    cancelled: u64,
    tier_bytes: Vec<u64>,
    sim_net_serial_s: f64,
    sim_net_parallel_s: f64,
    sim_net_pipelined_s: f64,
    transfer_wait_s: f64,
    sim_net_event_s: f64,
    queue_peak: usize,
    queue_block_s: f64,
    sim_client_p50_s: f64,
    sim_client_max_s: f64,
    merge_depth: usize,
    record_pipelined_sum: f64,
    record_wait_sum: f64,
    record_event_sum: f64,
}

fn run(cfg: FlConfig) -> Observed {
    let engine = Engine::synthetic();
    let mut sim = Simulation::new(&engine, cfg).unwrap();
    let mut rec = Recorder::new("pipeline");
    let summary = sim.run(&mut rec).unwrap();
    Observed {
        global: sim.global.clone(),
        final_acc: summary.final_acc,
        final_train_loss: summary.final_train_loss,
        total_bytes: summary.total_bytes,
        per_round: sim.ledger.per_round.clone(),
        dropped: sim.dropped_clients,
        cancelled: sim.cancelled_clients,
        tier_bytes: sim.tier_bytes().to_vec(),
        sim_net_serial_s: summary.sim_net_serial_s,
        sim_net_parallel_s: summary.sim_net_parallel_s,
        sim_net_pipelined_s: summary.sim_net_pipelined_s,
        transfer_wait_s: summary.transfer_wait_s,
        sim_net_event_s: summary.sim_net_event_s,
        queue_peak: summary.queue_peak,
        queue_block_s: summary.queue_block_s,
        sim_client_p50_s: summary.sim_client_p50_s,
        sim_client_max_s: summary.sim_client_max_s,
        merge_depth: summary.merge_depth,
        record_pipelined_sum: rec.rounds.iter()
            .map(|r| r.sim_net_pipelined_s).sum(),
        record_wait_sum: rec.rounds.iter()
            .map(|r| r.transfer_wait_s).sum(),
        record_event_sum: rec.rounds.iter()
            .map(|r| r.sim_net_event_s).sum(),
    }
}

fn with_exec(mut cfg: FlConfig, kind: ExecutorKind, threads: usize,
             window: usize, overlap: OverlapKind) -> FlConfig {
    cfg.executor = kind;
    cfg.threads = threads;
    cfg.window = window;
    cfg.overlap = overlap;
    cfg
}

fn assert_identical(a: &Observed, b: &Observed, what: &str) {
    assert_eq!(a.global, b.global, "{what}: global vector diverged");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: total_bytes");
    assert_eq!(a.per_round, b.per_round, "{what}: per-round ledger");
    assert_eq!(a.dropped, b.dropped, "{what}: dropout count");
    assert_eq!(a.cancelled, b.cancelled, "{what}: cancelled count");
    assert_eq!(a.tier_bytes, b.tier_bytes, "{what}: per-tier bytes");
    assert_eq!(a.sim_net_serial_s, b.sim_net_serial_s,
               "{what}: serial time");
    assert_eq!(a.sim_net_parallel_s, b.sim_net_parallel_s,
               "{what}: parallel time");
    assert_eq!(a.sim_net_pipelined_s, b.sim_net_pipelined_s,
               "{what}: pipelined time");
    assert_eq!(a.transfer_wait_s, b.transfer_wait_s,
               "{what}: transfer wait");
    assert_eq!(a.sim_net_event_s, b.sim_net_event_s,
               "{what}: event-model time");
    assert_eq!(a.queue_peak, b.queue_peak, "{what}: queue peak");
    assert_eq!(a.queue_block_s, b.queue_block_s, "{what}: queue block");
    assert_eq!(a.sim_client_p50_s, b.sim_client_p50_s, "{what}: p50");
    assert_eq!(a.sim_client_max_s, b.sim_client_max_s, "{what}: max");
    // The merge tree's shape depends only on the non-empty fold
    // blocks, never on the shard partition — so its depth is part of
    // the bit-identity contract.
    assert_eq!(a.merge_depth, b.merge_depth, "{what}: merge depth");
    assert!(
        a.final_train_loss == b.final_train_loss
            || (a.final_train_loss.is_nan() && b.final_train_loss.is_nan()),
        "{what}: final_train_loss {} vs {}",
        a.final_train_loss,
        b.final_train_loss
    );
}

#[test]
fn synthetic_engine_serves_sessions_without_artifacts() {
    let engine = Engine::synthetic();
    assert!(engine.is_synthetic());
    assert_eq!(engine.platform(), "synthetic");
    let session = engine.session("micro8_lora_fc_r4").unwrap();
    let (t, f) = session.init(42).unwrap();
    assert_eq!(t.len(), session.spec.num_trainable);
    assert_eq!(f.len(), session.spec.num_frozen);
    // The sentinel artifact dir resolves to the same backend.
    assert!(Engine::new("synthetic").unwrap().is_synthetic());
    assert!(engine.session("no_such_tag").is_err());
}

#[test]
fn overlap_transfer_is_bit_identical_to_serial() {
    let serial = run(with_exec(base_cfg(), ExecutorKind::Serial, 0, 0,
                               OverlapKind::None));
    let parallel = run(with_exec(base_cfg(), ExecutorKind::Parallel, 0, 0,
                                 OverlapKind::None));
    let pipelined = run(with_exec(base_cfg(), ExecutorKind::Parallel, 0, 0,
                                  OverlapKind::Transfer));
    let windowed = run(with_exec(base_cfg(), ExecutorKind::Parallel, 4, 2,
                                 OverlapKind::Transfer));
    assert_identical(&serial, &parallel, "serial vs parallel");
    assert_identical(&serial, &pipelined, "serial vs pipelined");
    assert_identical(&serial, &windowed, "serial vs pipelined w=2");
}

#[test]
fn every_codec_identical_across_executors_via_zero_copy_merge() {
    // Homogeneous rounds now carry *encoded* uploads all the way to
    // the merge (`UpdateVector::Encoded` → `Codec::decode_into`), so
    // this matrix pins the zero-copy fold bit-identical across the
    // serial / parallel / windowed-pipelined executors for every wire
    // codec the engine can be configured with.
    for codec in ["q8", "q4", "q2", "topk:0.5", "zerofl:0.9:0.2",
                  "sparse_ef:0.5"] {
        let mut cfg = base_cfg();
        cfg.codec = CodecKind::parse(codec).unwrap();
        let serial = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0, 0,
                                   OverlapKind::None));
        let parallel = run(with_exec(cfg.clone(), ExecutorKind::Parallel,
                                     3, 0, OverlapKind::None));
        let windowed = run(with_exec(cfg, ExecutorKind::Parallel, 3, 2,
                                     OverlapKind::Transfer));
        assert_identical(&serial, &parallel,
                         &format!("{codec}: serial vs parallel"));
        assert_identical(&serial, &windowed,
                         &format!("{codec}: serial vs windowed"));
    }
}

#[test]
fn overlap_identical_under_dropout() {
    let mut cfg = base_cfg();
    cfg.dropout = 0.4;
    cfg.rounds = 4;
    let serial = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0, 0,
                               OverlapKind::None));
    let pipelined = run(with_exec(cfg, ExecutorKind::Parallel, 3, 2,
                                  OverlapKind::Transfer));
    assert!(serial.dropped > 0, "injection never fired at dropout=0.4");
    assert_identical(&serial, &pipelined, "dropout serial vs pipelined");
}

#[test]
fn straggler_preset_identical_across_overlap_modes() {
    // The acceptance bar: on straggler_micro, `overlap = transfer`
    // leaves the model trajectory, ledger bytes and straggler stats
    // bit-identical under every executor — only wall clock (and the
    // regime sim_net_pipelined_s models) may differ.
    let none_serial = run(with_exec(straggler_cfg(), ExecutorKind::Serial,
                                    0, 0, OverlapKind::None));
    let none_parallel = run(with_exec(straggler_cfg(),
                                      ExecutorKind::Parallel, 3, 0,
                                      OverlapKind::None));
    let transfer_serial = run(with_exec(straggler_cfg(),
                                        ExecutorKind::Serial, 0, 0,
                                        OverlapKind::Transfer));
    let transfer_pipe = run(with_exec(straggler_cfg(),
                                      ExecutorKind::Parallel, 3, 0,
                                      OverlapKind::Transfer));
    let transfer_w2 = run(with_exec(straggler_cfg(),
                                    ExecutorKind::Parallel, 3, 2,
                                    OverlapKind::Transfer));
    assert!(none_serial.cancelled > 0, "oversampling never cancelled");
    assert_identical(&none_serial, &none_parallel, "none: serial vs par");
    assert_identical(&none_serial, &transfer_serial,
                     "serial: none vs transfer");
    assert_identical(&none_serial, &transfer_pipe,
                     "none serial vs transfer pipelined");
    assert_identical(&none_serial, &transfer_w2,
                     "none serial vs transfer w=2");
}

#[test]
fn pipelined_time_strictly_beats_parallel_on_stragglers() {
    // Tiered profiles give every client all three stages (wire down,
    // compute, wire up), so overlap must strictly shrink the round:
    // pipelined < parallel <= serial, with a positive hidden wait.
    let o = run(with_exec(straggler_cfg(), ExecutorKind::Parallel, 0, 0,
                          OverlapKind::Transfer));
    assert!(
        o.sim_net_pipelined_s < o.sim_net_parallel_s,
        "pipelined {:.4}s did not beat parallel {:.4}s",
        o.sim_net_pipelined_s,
        o.sim_net_parallel_s
    );
    assert!(o.sim_net_parallel_s <= o.sim_net_serial_s);
    assert!(o.transfer_wait_s > 0.0);
    // The per-record column partitions the run total.
    assert!((o.record_pipelined_sum - o.sim_net_pipelined_s).abs() < 1e-9);
    assert!((o.record_wait_sum - o.transfer_wait_s).abs() < 1e-9);
}

#[test]
fn hetero_tiers_identical_under_overlap() {
    let serial = run(with_exec(hetero_cfg(), ExecutorKind::Serial, 0, 0,
                               OverlapKind::None));
    let pipelined = run(with_exec(hetero_cfg(), ExecutorKind::Parallel, 3,
                                  0, OverlapKind::Transfer));
    assert_identical(&serial, &pipelined, "hetero serial vs pipelined");
    assert_eq!(serial.tier_bytes.len(), 3);
    assert_eq!(serial.tier_bytes.iter().sum::<u64>(), serial.total_bytes,
               "tier bytes must partition total traffic");
}

// ---------------------------------------------------------------------------
// Sharded-coordinator identity: the partition must be invisible
// ---------------------------------------------------------------------------

fn with_shards(mut cfg: FlConfig, shards: usize) -> FlConfig {
    cfg.shards = shards;
    cfg
}

#[test]
fn shard_count_never_perturbs_the_round() {
    // The tentpole acceptance bar at synthetic size: splitting the
    // round into N aggregator shards is bit-for-bit invisible. shards
    // ∈ {1, 2, 3, 7} leave every observable identical to the unsharded
    // serial fold across serial / parallel / windowed-pipelined
    // executors — including the degenerate partitions (7 shards over a
    // 4-client round) where most shards own zero clients.
    let baseline = run(with_exec(base_cfg(), ExecutorKind::Serial, 0, 0,
                                 OverlapKind::None));
    for shards in [1usize, 2, 3, 7] {
        let serial = run(with_shards(
            with_exec(base_cfg(), ExecutorKind::Serial, 0, 0,
                      OverlapKind::None),
            shards,
        ));
        let parallel = run(with_shards(
            with_exec(base_cfg(), ExecutorKind::Parallel, 3, 0,
                      OverlapKind::None),
            shards,
        ));
        let windowed = run(with_shards(
            with_exec(base_cfg(), ExecutorKind::Parallel, 3, 2,
                      OverlapKind::Transfer),
            shards,
        ));
        assert_identical(&baseline, &serial,
                         &format!("shards={shards}: serial"));
        assert_identical(&baseline, &parallel,
                         &format!("shards={shards}: parallel"));
        assert_identical(&baseline, &windowed,
                         &format!("shards={shards}: windowed"));
    }
}

#[test]
fn shard_identity_holds_under_dropout_stragglers_and_hetero() {
    // The ragged regimes: dropout skips folds mid-block, stragglers
    // cancel oversampled clients, hetero tiers pad ranks — in each,
    // every shard count must reproduce the unsharded stream exactly.
    let mut dropout = base_cfg();
    dropout.dropout = 0.4;
    dropout.rounds = 4;
    for (what, cfg) in [("dropout", dropout),
                        ("straggler", straggler_cfg()),
                        ("hetero", hetero_cfg())] {
        let one = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0, 0,
                                OverlapKind::None));
        for shards in [2usize, 3, 7] {
            let n = run(with_shards(
                with_exec(cfg.clone(), ExecutorKind::Parallel, 3, 2,
                          OverlapKind::Transfer),
                shards,
            ));
            assert_identical(&one, &n, &format!("{what}: shards={shards}"));
        }
    }
}

#[test]
fn shard_identity_holds_for_every_codec_and_aggregator() {
    // The factor-aware aggregators defer their SVD to the coordinator
    // (shards stack factors, never decompose), and encoded uploads
    // decode inside the shard merge — so codec × aggregator is the
    // matrix where a sharding bug would surface as drift.
    for codec in ["fp32", "q8", "topk:0.5", "sparse_ef:0.5"] {
        for agg in [AggregatorKind::FedAvg, AggregatorKind::Svt,
                    AggregatorKind::Exact] {
            let mut cfg = base_cfg();
            cfg.codec = CodecKind::parse(codec).unwrap();
            cfg.aggregator = agg;
            let one = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0,
                                    0, OverlapKind::None));
            let sharded = run(with_shards(
                with_exec(cfg, ExecutorKind::Parallel, 3, 0,
                          OverlapKind::None),
                3,
            ));
            assert_identical(
                &one,
                &sharded,
                &format!("{codec} × {}: shards=3", agg.label()),
            );
        }
    }
}

#[test]
fn shard_merge_tree_fires_above_one_block() {
    // Rounds wider than SHARD_BLOCK sampled clients span several fold
    // blocks, so the coordinator genuinely tree-merges partials. The
    // tree is partition-invariant: every shard count reports the same
    // positive depth and the same bytes/trajectory as one shard.
    let mut cfg = base_cfg();
    cfg.num_clients = 96;
    cfg.clients_per_round = 80;
    cfg.rounds = 2;
    let one = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0, 0,
                            OverlapKind::None));
    assert!(one.merge_depth > 0,
            "an 80-client round never split a fold block");
    for shards in [2usize, 5] {
        let n = run(with_shards(
            with_exec(cfg.clone(), ExecutorKind::Parallel, 3, 0,
                      OverlapKind::None),
            shards,
        ));
        assert_identical(&one, &n, &format!("80 clients, shards={shards}"));
    }
}

#[test]
fn latency_biased_identical_under_overlap() {
    let mut cfg = straggler_cfg();
    cfg.sampler = SamplerKind::LatencyBiased;
    let serial = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0, 0,
                               OverlapKind::None));
    let pipelined = run(with_exec(cfg, ExecutorKind::Parallel, 3, 2,
                                  OverlapKind::Transfer));
    assert_identical(&serial, &pipelined, "latency_biased overlap");
    assert_eq!(serial.cancelled, 0);
}

#[test]
fn event_time_model_bit_identical_across_executors() {
    // The discrete-event simulator prices rounds from loads delivered
    // in sampling order, so `time_model = event` must be bit-identical
    // across serial/parallel/windowed/pipelined execution — including
    // the new sim_net_event_s and queue columns.
    let mut cfg = presets::by_name("event_micro").unwrap();
    cfg.rounds = 8;
    cfg.local_epochs = 1;
    cfg.samples_per_client = 16;
    cfg.test_samples = 40;
    cfg.seed = 21;
    let serial = run(with_exec(cfg.clone(), ExecutorKind::Serial, 0, 0,
                               OverlapKind::None));
    let parallel = run(with_exec(cfg.clone(), ExecutorKind::Parallel, 3, 0,
                                 OverlapKind::None));
    let pipelined = run(with_exec(cfg.clone(), ExecutorKind::Parallel, 3, 0,
                                  OverlapKind::Transfer));
    let windowed = run(with_exec(cfg, ExecutorKind::Parallel, 3, 2,
                                 OverlapKind::Transfer));
    assert_identical(&serial, &parallel, "event: serial vs parallel");
    assert_identical(&serial, &pipelined, "event: serial vs pipelined");
    assert_identical(&serial, &windowed, "event: serial vs windowed");
    // The event round is a real simulation: sandwiched between the
    // closed envelopes on these dedicated links, with the per-record
    // column partitioning the run total.
    assert!(
        serial.sim_net_pipelined_s <= serial.sim_net_event_s + 1e-9
            && serial.sim_net_event_s <= serial.sim_net_parallel_s + 1e-9,
        "event {} outside [{}, {}]",
        serial.sim_net_event_s,
        serial.sim_net_pipelined_s,
        serial.sim_net_parallel_s
    );
    assert!((serial.record_event_sum - serial.sim_net_event_s).abs()
            < 1e-9);
}

#[test]
fn time_model_never_perturbs_training() {
    // Swapping the round-time backend must leave everything that
    // reaches training — the model trajectory, the ledger, sampling,
    // cancellations, the closed-form columns — bit-identical; only
    // sim_net_event_s and the queue stats may move.
    let closed = run(with_exec(straggler_cfg(), ExecutorKind::Serial, 0, 0,
                               OverlapKind::None));
    let mut cfg = straggler_cfg();
    cfg.time_model = flocora::transport::TimeModelKind::Event;
    cfg.chunk_kb = 1;
    cfg.stage_queue = 2;
    let event = run(with_exec(cfg, ExecutorKind::Serial, 0, 0,
                              OverlapKind::None));
    assert_eq!(closed.global, event.global, "trajectory diverged");
    assert_eq!(closed.final_acc, event.final_acc);
    assert_eq!(closed.total_bytes, event.total_bytes);
    assert_eq!(closed.per_round, event.per_round);
    assert_eq!(closed.dropped, event.dropped);
    assert_eq!(closed.cancelled, event.cancelled);
    assert_eq!(closed.sim_net_serial_s, event.sim_net_serial_s);
    assert_eq!(closed.sim_net_parallel_s, event.sim_net_parallel_s);
    assert_eq!(closed.sim_net_pipelined_s, event.sim_net_pipelined_s);
    assert_eq!(closed.transfer_wait_s, event.transfer_wait_s);
    // The closed backend reports the pipelined envelope in the event
    // column; the simulator reports something strictly above it here
    // (tiered survivors all have three stages to serialize).
    assert_eq!(closed.sim_net_event_s, closed.sim_net_pipelined_s);
    assert_eq!(closed.queue_peak, 0);
    assert!(
        event.sim_net_event_s > closed.sim_net_event_s,
        "event {} did not exceed the pipelined envelope {}",
        event.sim_net_event_s,
        closed.sim_net_event_s
    );
    assert!(event.queue_peak >= 1);
}

#[test]
fn json_export_round_trips_every_field() {
    // Guard for the `--json` run export: every RunSummary and
    // RoundRecord field must survive a trip through util::json — a
    // new field that never reaches `metrics::run_json` (or
    // `Recorder::to_json`) fails here instead of silently vanishing
    // from CI's determinism diffs.
    let engine = Engine::synthetic();
    let mut sim = Simulation::new(&engine, straggler_cfg()).unwrap();
    let mut rec = Recorder::new("roundtrip");
    let summary = sim.run(&mut rec).unwrap();
    let doc = flocora::metrics::run_json(&rec, &summary,
                                         sim.dropped_clients);
    let parsed = flocora::util::json::parse(&doc.to_string()).unwrap();

    let summary_keys: Vec<&str> = parsed
        .at(&["summary"]).unwrap()
        .as_obj().unwrap()
        .keys().map(String::as_str).collect();
    let expect_summary = [
        "final_acc", "tail_acc", "final_train_loss", "total_bytes",
        "mean_up_msg_bytes", "per_client_tcc_bytes", "rounds",
        "sim_net_serial_s", "sim_net_parallel_s", "sim_net_pipelined_s",
        "transfer_wait_s", "sim_net_event_s", "queue_peak",
        "queue_block_s", "cancelled_clients", "dropped_clients",
        "sim_client_p50_s", "sim_client_max_s", "mean_eff_rank",
        "merge_depth", "wall_s",
    ];
    for key in expect_summary {
        assert!(summary_keys.contains(&key), "summary lost `{key}`");
    }
    assert_eq!(summary_keys.len(), expect_summary.len(),
               "summary grew a field the test does not pin: \
                {summary_keys:?}");

    let rounds = parsed.at(&["rounds"]).unwrap().as_arr().unwrap();
    assert_eq!(rounds.len(), rec.rounds.len());
    let round_keys: Vec<&str> = rounds[0]
        .as_obj().unwrap()
        .keys().map(String::as_str).collect();
    let expect_round = [
        "round", "test_acc", "test_loss", "train_loss", "cum_bytes",
        "dropped", "cancelled", "client_p50_s", "client_max_s",
        "sim_net_pipelined_s", "transfer_wait_s", "sim_net_event_s",
        "queue_peak", "queue_block_s", "eff_rank", "wall_ms",
    ];
    for key in expect_round {
        assert!(round_keys.contains(&key), "round record lost `{key}`");
    }
    assert_eq!(round_keys.len(), expect_round.len(),
               "round record grew a field the test does not pin: \
                {round_keys:?}");

    // Values survive, not just keys: spot-check against the run.
    let s = parsed.at(&["summary"]).unwrap();
    assert_eq!(s.at(&["total_bytes"]).unwrap().as_usize().unwrap() as u64,
               summary.total_bytes);
    assert_eq!(
        s.at(&["cancelled_clients"]).unwrap().as_usize().unwrap() as u64,
        summary.cancelled_clients
    );
    assert_eq!(s.at(&["sim_net_event_s"]).unwrap().as_f64().unwrap(),
               summary.sim_net_event_s);
    let last = rounds.last().unwrap();
    assert_eq!(last.at(&["round"]).unwrap().as_usize().unwrap(),
               rec.rounds.last().unwrap().round);
    assert_eq!(last.at(&["cum_bytes"]).unwrap().as_usize().unwrap() as u64,
               rec.rounds.last().unwrap().cum_bytes);
}

/// In-order assertion sink that dawdles on every push, giving the
/// pipeline every opportunity to run ahead of the merge.
struct SlowCountingSink {
    next: usize,
    clients: Vec<usize>,
}

impl RoundSink for SlowCountingSink {
    fn push(&mut self, index: usize, result: ClientResult)
            -> flocora::Result<()> {
        assert_eq!(index, self.next, "sink saw an out-of-order push");
        assert_eq!(result.cid, self.clients[index],
                   "slot {index} carries the wrong client");
        std::thread::sleep(std::time::Duration::from_millis(10));
        self.next += 1;
        Ok(())
    }
}

#[test]
fn pipelined_peak_buffered_never_exceeds_window() {
    let engine = Engine::synthetic();
    let cfg = base_cfg();
    let session = engine.session(&cfg.tag).unwrap();
    let spec = session.spec.clone();
    let federation = lda_partition(
        cfg.num_clients,
        cfg.samples_per_client,
        spec.num_classes,
        spec.image_size,
        cfg.lda_alpha,
        cfg.seed,
    );
    let (global, frozen) = session.init(cfg.seed).unwrap();
    let codec = Fp32Codec;
    let down_msg = flocora::compression::Codec::encode(
        &codec, &global, &spec.trainable_segments).unwrap();
    let ctx = RoundContext {
        session: &session,
        codec: &codec,
        federation: &federation,
        frozen: &frozen,
        downloads: Downloads::Homogeneous(&down_msg),
        trainer: LocalTrainer {
            local_epochs: 1,
            lr: cfg.lr,
            lora_scale: cfg.lora_scale(spec.rank),
        },
        cfg: &cfg,
        round: 0,
        plan: None,
        cancelled: &[],
    };
    let clients: Vec<usize> = (0..cfg.num_clients).collect();
    for window in [1usize, 2, 3] {
        let exec = PipelinedExecutor::new(4).with_window(window);
        let mut sink =
            SlowCountingSink { next: 0, clients: clients.clone() };
        exec.execute(&ctx, &clients, &mut sink).unwrap();
        assert_eq!(sink.next, clients.len(), "sink missed pushes");
        let peak = exec.peak_buffered();
        assert!(peak >= 1, "window {window}: nothing ever buffered?");
        assert!(peak <= window,
                "window {window}: {peak} results buffered simultaneously");
    }
}

#[test]
fn pipelined_respects_planned_cancellations() {
    // Cancelled clients must short-circuit in the transport-in stage —
    // no training, no upload — under the staged pipeline exactly as
    // under the inline executors.
    let engine = Engine::synthetic();
    let cfg = base_cfg();
    let session = engine.session(&cfg.tag).unwrap();
    let spec = session.spec.clone();
    let federation = lda_partition(
        cfg.num_clients,
        cfg.samples_per_client,
        spec.num_classes,
        spec.image_size,
        cfg.lda_alpha,
        cfg.seed,
    );
    let (global, frozen) = session.init(cfg.seed).unwrap();
    let codec = Fp32Codec;
    let down_msg = flocora::compression::Codec::encode(
        &codec, &global, &spec.trainable_segments).unwrap();
    let cancelled = vec![1usize, 5];
    let ctx = RoundContext {
        session: &session,
        codec: &codec,
        federation: &federation,
        frozen: &frozen,
        downloads: Downloads::Homogeneous(&down_msg),
        trainer: LocalTrainer {
            local_epochs: 1,
            lr: cfg.lr,
            lora_scale: cfg.lora_scale(spec.rank),
        },
        cfg: &cfg,
        round: 0,
        plan: None,
        cancelled: &cancelled,
    };
    let clients: Vec<usize> = (0..8).collect();
    let exec = PipelinedExecutor::new(3).with_window(2);
    let mut sink = VecSink::new();
    flocora::coordinator::sink::collect_round(
        &exec, &ctx, &clients,
        &mut [Box::new(&mut sink) as Box<dyn RoundSink>])
        .unwrap();
    let results = sink.results;
    assert_eq!(results.len(), 8);
    for r in &results {
        let expect_cancel = cancelled.contains(&r.cid);
        assert_eq!(r.cancelled, expect_cancel, "cid {}", r.cid);
        assert_eq!(r.update.is_none(), expect_cancel, "cid {}", r.cid);
        assert!(r.down_bytes > 0);
    }
}
