//! Property-based tests (hand-rolled kit: seeded RNG-driven cases with
//! shrink-free replay — the offline vendor set has no `proptest`; each
//! case count is high enough to sweep the interesting structure space
//! and failures print the case seed for deterministic replay).
//!
//! These cover the pure substrates only (no PJRT), so they run fast and
//! wide: codecs, aggregation, partitioning, packing, JSON, rank
//! projection.

use flocora::compression::{AffineCodec, Codec, CodecKind, Fp32Codec,
                           TopKCodec, ZeroFlCodec};
use flocora::coordinator::aggregator::FedAvg;
use flocora::kernels;
use flocora::coordinator::hetero::project_ranks;
use flocora::data::lda_partition;
use flocora::model::{build_spec, ModelCfg, ParamKind, Segment, Variant};
use flocora::tensor;
use flocora::util::json;
use flocora::util::rng::Rng;

const CASES: usize = 60;

/// Random segment layout: mixes quantized and fp segments.
fn rand_layout(rng: &mut Rng) -> (Vec<Segment>, Vec<f32>) {
    let nsegs = 1 + rng.below(6);
    let mut segs = Vec::new();
    let mut offset = 0;
    for i in 0..nsegs {
        let rows = 1 + rng.below(12);
        let cols = 1 + rng.below(40);
        let numel = rows * cols;
        let quant = rng.f64() < 0.7;
        segs.push(Segment {
            name: format!("seg{i}"),
            shape: vec![rows, cols],
            numel,
            kind: ParamKind::Conv,
            offset,
            quant_rows: if quant { Some(rows) } else { None },
        });
        offset += numel;
    }
    let scale = (10.0f64).powf(rng.range_f64(-3.0, 2.0)) as f32;
    let v: Vec<f32> =
        (0..offset).map(|_| scale * rng.normal() as f32).collect();
    (segs, v)
}

#[test]
fn prop_fp32_codec_is_lossless() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let (segs, v) = rand_layout(&mut rng);
        let c = Fp32Codec;
        let out = c.decode(&c.encode(&v, &segs).unwrap(), &segs).unwrap();
        assert_eq!(out, v, "case {case}");
    }
}

#[test]
fn prop_affine_error_bounded_and_idempotent() {
    let mut rng = Rng::new(102);
    for case in 0..CASES {
        let (segs, v) = rand_layout(&mut rng);
        for bits in [2u32, 4, 8] {
            let c = AffineCodec::new(bits);
            let once = c.decode(&c.encode(&v, &segs).unwrap(), &segs).unwrap();
            // Idempotence: re-quantizing the dequantized vector is a
            // fixed point (values already on the grid).
            let twice =
                c.decode(&c.encode(&once, &segs).unwrap(), &segs).unwrap();
            let drift = tensor::max_abs_diff(&once, &twice);
            let vmax = v.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
            assert!(drift <= vmax * 2e-3,
                    "case {case} bits {bits}: drift {drift} vmax {vmax}");
            // Error bound: |deq - v| <= scale/2 + eps per quantized row
            // (checked via global bound: scale <= 2*vmax/qmax... loose
            // but structure-free).
            let qmax = ((1u32 << bits) - 1) as f32;
            let bound = 2.0 * vmax / qmax * 0.5 + vmax * 1e-4;
            for seg in &segs {
                if seg.quant_rows.is_none() {
                    continue;
                }
                for i in seg.offset..seg.offset + seg.numel {
                    assert!((once[i] - v[i]).abs() <= bound * 1.001,
                            "case {case} bits {bits} idx {i}");
                }
            }
        }
    }
}

#[test]
fn prop_affine_message_smaller_than_fp32_for_large_segments() {
    let mut rng = Rng::new(103);
    for _ in 0..CASES {
        // Force wide segments so scale/zp overhead can't dominate.
        let rows = 4 + rng.below(12);
        let cols = 64 + rng.below(100);
        let seg = Segment {
            name: "s".into(),
            shape: vec![rows, cols],
            numel: rows * cols,
            kind: ParamKind::Conv,
            offset: 0,
            quant_rows: Some(rows),
        };
        let v: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let fp = Fp32Codec.encode(&v, std::slice::from_ref(&seg)).unwrap();
        for bits in [2u32, 4, 8] {
            let q = AffineCodec::new(bits)
                .encode(&v, std::slice::from_ref(&seg))
                .unwrap();
            assert!(q.size_bytes() < fp.size_bytes(),
                    "bits {bits}: {} !< {}", q.size_bytes(), fp.size_bytes());
        }
    }
}

#[test]
fn prop_topk_decode_is_subset_with_exact_values() {
    let mut rng = Rng::new(104);
    for case in 0..CASES {
        let n = 10 + rng.below(2000);
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let keep = (rng.range_f64(0.05, 1.0)) as f32;
        let c = TopKCodec::new(keep);
        let out = c.decode(&c.encode(&v, &[]).unwrap(), &[]).unwrap();
        assert_eq!(out.len(), n);
        let mut kept = 0;
        for i in 0..n {
            if out[i] != 0.0 {
                assert_eq!(out[i], v[i], "case {case}");
                kept += 1;
            }
        }
        assert_eq!(kept, c.kept_count(n).min(
            v.iter().filter(|&&x| x != 0.0).count().max(1)),
            "case {case}");
    }
}

#[test]
fn prop_zerofl_kept_fraction_monotone_in_mask_ratio() {
    let mut rng = Rng::new(105);
    for _ in 0..CASES {
        let sp = rng.range_f64(0.1, 0.95) as f32;
        let mr1 = rng.range_f64(0.0, 0.5) as f32;
        let mr2 = (mr1 + 0.3).min(1.0);
        let a = ZeroFlCodec::new(sp, mr1);
        let b = ZeroFlCodec::new(sp, mr2);
        assert!(a.kept_fraction() <= b.kept_fraction() + 1e-9);
        let n = 500;
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ma = a.encode(&v, &[]).unwrap();
        let mb = b.encode(&v, &[]).unwrap();
        assert!(ma.size_bytes() <= mb.size_bytes());
    }
}

#[test]
fn prop_fedavg_is_convex_combination() {
    let mut rng = Rng::new(106);
    for case in 0..CASES {
        let dim = 1 + rng.below(300);
        let k = 1 + rng.below(8);
        let mut agg = FedAvg::new(dim);
        let mut lo = vec![f32::INFINITY; dim];
        let mut hi = vec![f32::NEG_INFINITY; dim];
        for _ in 0..k {
            let v: Vec<f32> =
                (0..dim).map(|_| rng.normal() as f32).collect();
            for i in 0..dim {
                lo[i] = lo[i].min(v[i]);
                hi[i] = hi[i].max(v[i]);
            }
            agg.add(&v, rng.range_f64(0.5, 100.0)).unwrap();
        }
        let out = agg.finish().unwrap();
        for i in 0..dim {
            assert!(out[i] >= lo[i] - 1e-4 && out[i] <= hi[i] + 1e-4,
                    "case {case} dim {i}: {} not in [{}, {}]",
                    out[i], lo[i], hi[i]);
        }
    }
}

#[test]
fn prop_fedavg_weight_scale_invariant() {
    // Scaling all weights by a constant must not change the mean.
    let mut rng = Rng::new(107);
    for _ in 0..CASES {
        let dim = 1 + rng.below(100);
        let k = 2 + rng.below(5);
        let vs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let ws: Vec<f64> = (0..k).map(|_| rng.range_f64(1.0, 50.0)).collect();
        let run = |scale: f64| {
            let mut agg = FedAvg::new(dim);
            for (v, w) in vs.iter().zip(&ws) {
                agg.add(v, w * scale).unwrap();
            }
            agg.finish().unwrap()
        };
        let a = run(1.0);
        let b = run(7.5);
        assert!(tensor::max_abs_diff(&a, &b) < 1e-4);
    }
}

#[test]
fn prop_lda_partition_total_and_determinism() {
    let mut rng = Rng::new(108);
    for _ in 0..20 {
        let clients = 1 + rng.below(20);
        let per = 1 + rng.below(30);
        let alpha = rng.range_f64(0.05, 10.0);
        let seed = rng.next_u64();
        let f1 = lda_partition(clients, per, 10, 8, alpha, seed);
        let f2 = lda_partition(clients, per, 10, 8, alpha, seed);
        assert_eq!(f1.total_samples(), clients * per);
        for (a, b) in f1.clients.iter().zip(&f2.clients) {
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.class_hist.iter().sum::<usize>(), per);
        }
    }
}

#[test]
fn prop_json_round_trip_arbitrary_values() {
    let mut rng = Rng::new(109);
    fn gen(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.f64() < 0.5),
            2 => json::Json::Num((rng.normal() * 1e3).round()),
            3 => json::Json::Str(format!("s{}-\"quote\"\n{}", rng.below(100),
                                          "é")),
            4 => json::arr((0..rng.below(5))
                .map(|_| gen(rng, depth + 1))
                .collect()),
            _ => {
                let mut pairs = Vec::new();
                for i in 0..rng.below(5) {
                    pairs.push((format!("k{i}"), gen(rng, depth + 1)));
                }
                json::Json::Obj(pairs.into_iter().collect())
            }
        }
    }
    for case in 0..CASES {
        let v = gen(&mut rng, 0);
        let text = v.to_string();
        let re = json::parse(&text).unwrap();
        assert_eq!(v, re, "case {case}: {text}");
    }
}

#[test]
fn prop_rank_projection_function_preserving_composition() {
    // Projecting r -> r' -> r (r' >= r) is the identity; the padded
    // slots stay zero through a round trip from any starting rank.
    let ranks = [2usize, 4, 8, 16];
    let mut rng = Rng::new(110);
    let cfg = ModelCfg::by_name("micro8").unwrap();
    for _ in 0..20 {
        let a = ranks[rng.below(ranks.len())];
        let b = ranks[rng.below(ranks.len())];
        if a > b {
            continue;
        }
        let sa = build_spec(cfg, Variant::LoraFc, a).trainable;
        let sb = build_spec(cfg, Variant::LoraFc, b).trainable;
        let na: usize = sa.iter().map(|s| s.numel).sum();
        let v: Vec<f32> = (0..na).map(|_| rng.normal() as f32).collect();
        let up = project_ranks(&v, &sa, &sb).unwrap();
        let down = project_ranks(&up, &sb, &sa).unwrap();
        assert_eq!(down, v, "{a}->{b}->{a}");
    }
}

#[test]
fn prop_latency_biased_covers_all_clients_over_time() {
    // Sampling bias must never become starvation: whatever the tiered
    // profile table looks like, every client is eventually sampled.
    use flocora::coordinator::{LatencyBiasedSampler, Sampler};
    use flocora::transport::{ClientProfiles, NetworkModel};
    let net = NetworkModel::edge_lte();
    let mut rng = Rng::new(111);
    for case in 0..CASES {
        let n = 6 + rng.below(20);
        let k = 1 + rng.below(n.min(6));
        let table = ClientProfiles::tiered(n, rng.below(1 << 20) as u64);
        let weights: Vec<f64> = (0..n)
            .map(|cid| 1.0 / table.client_time(&net, cid, 500_000, 500_000))
            .collect();
        let mut s = LatencyBiasedSampler::new(weights, case as u64);
        let mut seen = vec![false; n];
        for _ in 0..400 {
            for id in s.sample(k) {
                seen[id] = true;
            }
        }
        assert!(
            seen.iter().all(|&b| b),
            "case {case}: starved a client (n={n}, k={k})"
        );
    }
}

#[test]
fn prop_pipelined_round_time_bounded_by_serial_and_parallel() {
    // For arbitrary load sets — any mix of surviving, dropped and
    // cancelled clients, profiled slowdowns, both sharing regimes —
    // the transfer-overlap estimate never exceeds the no-overlap
    // concurrent estimate, which never exceeds… well, pipelined must
    // also never exceed fully-serial execution.
    use flocora::transport::{NetworkModel, RoundLoad, Sharing};
    let mut rng = Rng::new(113);
    for case in 0..CASES {
        for sharing in [Sharing::Dedicated, Sharing::Shared] {
            let net = NetworkModel::edge_lte().with_sharing(sharing);
            let mut acc = RoundLoad::new();
            let n = 1 + rng.below(12);
            for _ in 0..n {
                let down = rng.below(4_000_000);
                match rng.below(4) {
                    0 => {
                        // Dropped before uploading: download only.
                        acc.add(&net, down, 0);
                    }
                    1 => {
                        // Cancelled mid-transfer.
                        let mult = rng.range_f64(1.0, 10.0);
                        acc.add_cancelled(
                            net.download_time(down) * mult, down);
                    }
                    _ => {
                        // Survivor with a profiled slowdown and some
                        // local compute.
                        let up = 1 + rng.below(4_000_000);
                        let mult = rng.range_f64(1.0, 10.0);
                        acc.add_stages(
                            net.download_time(down) * mult,
                            rng.range_f64(0.0, 3.0),
                            net.upload_time(up) * mult,
                            down,
                            up,
                        );
                    }
                }
            }
            let serial = acc.serial_s();
            let parallel = acc.parallel_s(&net);
            let pipelined = acc.pipelined_s(&net);
            assert!(
                pipelined <= parallel + 1e-12,
                "case {case} {sharing:?}: pipelined {pipelined} > \
                 parallel {parallel}"
            );
            assert!(
                pipelined <= serial + 1e-12,
                "case {case} {sharing:?}: pipelined {pipelined} > \
                 serial {serial}"
            );
        }
    }
}

#[test]
fn prop_pipelined_equals_parallel_when_transfer_is_zero() {
    // With zero wire time (no bytes, zero latency) only the compute
    // stage remains, so there is nothing to overlap: the pipelined and
    // no-overlap concurrent estimates must agree bit-for-bit, under
    // both sharing regimes.
    use flocora::transport::{NetworkModel, RoundLoad, Sharing};
    let mut rng = Rng::new(114);
    for case in 0..CASES {
        for sharing in [Sharing::Dedicated, Sharing::Shared] {
            let net = NetworkModel {
                up_bps: 10e6,
                down_bps: 30e6,
                latency_s: 0.0,
                sharing,
            };
            let mut acc = RoundLoad::new();
            for _ in 0..1 + rng.below(10) {
                acc.add_stages(0.0, rng.range_f64(0.0, 5.0), 0.0, 0, 0);
            }
            assert_eq!(
                acc.pipelined_s(&net),
                acc.parallel_s(&net),
                "case {case} {sharing:?}"
            );
        }
    }
}

/// Shared generator for the event-simulator properties: an arbitrary
/// mix of survivors (profiled slowdowns + compute), dropouts and
/// cancellations, returned both as the simulator's `ClientLoad`s and
/// folded into the closed-form `RoundLoad` accumulator.
fn rand_event_loads(
    rng: &mut Rng,
    net: &flocora::transport::NetworkModel,
    allow_partial: bool,
) -> (Vec<flocora::transport::ClientLoad>, flocora::transport::RoundLoad) {
    use flocora::transport::{ClientLoad, RoundLoad};
    let mut loads = Vec::new();
    let mut acc = RoundLoad::new();
    let n = 1 + rng.below(10);
    for cid in 0..n {
        let down = 1 + rng.below(400_000);
        let mult = rng.range_f64(1.0, 10.0);
        match if allow_partial { rng.below(5) } else { 2 } {
            0 => {
                // Dropped before uploading: download only.
                let td = net.download_time(down) * mult;
                acc.add_stages(td, 0.0, 0.0, down, 0);
                loads.push(ClientLoad {
                    cid,
                    td,
                    tc: 0.0,
                    tu: 0.0,
                    down_bytes: down,
                    up_bytes: 0,
                    waited: true,
                });
            }
            1 => {
                // Cancelled mid-transfer: charged, never waited on.
                let td = net.download_time(down) * mult;
                acc.add_cancelled(td, down);
                loads.push(ClientLoad {
                    cid,
                    td,
                    tc: 0.0,
                    tu: 0.0,
                    down_bytes: down,
                    up_bytes: 0,
                    waited: false,
                });
            }
            _ => {
                // Survivor with profiled wire and some local compute.
                let up = 1 + rng.below(400_000);
                let td = net.download_time(down) * mult;
                let tc = rng.range_f64(0.0, 3.0);
                let tu = net.upload_time(up) * mult;
                acc.add_stages(td, tc, tu, down, up);
                loads.push(ClientLoad {
                    cid,
                    td,
                    tc,
                    tu,
                    down_bytes: down,
                    up_bytes: up,
                    waited: true,
                });
            }
        }
    }
    (loads, acc)
}

#[test]
fn prop_event_time_sandwiched_between_pipelined_and_parallel() {
    // The tentpole pin: on dedicated links, for ARBITRARY loads, chunk
    // sizes and queue capacities, the discrete-event round lands
    // between the ideal-overlap envelope and the no-overlap one:
    //   pipelined <= event <= parallel <= serial.
    use flocora::transport::{simulate_round, NetworkModel, SimParams};
    let chunk_choices = [1usize, 4, 16, 64, 256, 2048];
    let queue_choices = [0usize, 1, 2, 4, 8];
    let mut rng = Rng::new(115);
    for case in 0..40 {
        let net = NetworkModel::edge_lte();
        let (loads, acc) = rand_event_loads(&mut rng, &net, true);
        let params = SimParams {
            chunk_kb: chunk_choices[rng.below(chunk_choices.len())],
            stage_queue: queue_choices[rng.below(queue_choices.len())],
        };
        let event = simulate_round(&net, &loads, &params).round_s;
        let pipelined = acc.pipelined_s(&net);
        let parallel = acc.parallel_s(&net);
        let serial = acc.serial_s();
        assert!(
            pipelined - 1e-9 <= event,
            "case {case} {params:?}: event {event} < pipelined {pipelined}"
        );
        assert!(
            event <= parallel + 1e-9,
            "case {case} {params:?}: event {event} > parallel {parallel}"
        );
        assert!(event <= serial + 1e-9,
                "case {case}: event {event} > serial {serial}");
    }
}

#[test]
fn prop_event_converges_to_pipelined_envelope() {
    // chunk_kb -> 0, stage_queue -> unbounded: the event round
    // converges to the pipelined envelope. The per-client gap is
    // (chain - slowest_stage) / n_chunks, so it shrinks monotonically
    // with the chunk size and is bounded by max_i chain_i / n_i.
    use flocora::transport::{simulate_round, NetworkModel, SimParams};
    let mut rng = Rng::new(116);
    for case in 0..30 {
        let net = NetworkModel::edge_lte();
        let (loads, acc) = rand_event_loads(&mut rng, &net, false);
        let pipelined = acc.pipelined_s(&net);
        let mut last_gap = f64::INFINITY;
        for chunk_kb in [2048usize, 256, 16, 1] {
            let params = SimParams { chunk_kb, stage_queue: 0 };
            let event = simulate_round(&net, &loads, &params).round_s;
            let gap = event - pipelined;
            assert!(gap >= -1e-9, "case {case} chunk {chunk_kb}: {gap}");
            assert!(
                gap <= last_gap + 1e-9,
                "case {case}: gap grew {last_gap} -> {gap} at chunk \
                 {chunk_kb} kB"
            );
            last_gap = gap;
            // Analytic bound on the residual at this granularity.
            let bound = loads
                .iter()
                .map(|l| {
                    let n = l.down_bytes.max(l.up_bytes)
                        .div_ceil(chunk_kb * 1024).max(1);
                    (l.td + l.tc + l.tu) / n as f64
                })
                .fold(0.0f64, f64::max);
            assert!(
                gap <= bound + 1e-9,
                "case {case} chunk {chunk_kb}: gap {gap} > bound {bound}"
            );
        }
    }
}

#[test]
fn prop_event_equals_parallel_at_one_chunk_per_message() {
    // A chunk bigger than any message leaves nothing to pipeline: the
    // event time degenerates to the no-overlap parallel envelope.
    use flocora::transport::{simulate_round, NetworkModel, SimParams};
    let mut rng = Rng::new(117);
    for case in 0..30 {
        let net = NetworkModel::edge_lte();
        let (loads, acc) = rand_event_loads(&mut rng, &net, true);
        // 400 kB max message << 1 GiB chunk.
        let params = SimParams { chunk_kb: 1 << 20, stage_queue: 1 };
        let event = simulate_round(&net, &loads, &params).round_s;
        let parallel = acc.parallel_s(&net);
        assert!(
            (event - parallel).abs() <= 1e-9 * parallel.max(1.0),
            "case {case}: event {event} != parallel {parallel}"
        );
    }
}

#[test]
fn prop_event_shared_pipe_floors_at_pipelined_envelope() {
    // On a shared pipe the closed parallel form is itself optimistic
    // about compute, so only the lower bound is universal: the event
    // round never beats the full-duplex pipelined envelope (pipe busy
    // times, slowest stage) for loads the round actually waits on.
    use flocora::transport::{simulate_round, NetworkModel, Sharing,
                             SimParams};
    let chunk_choices = [1usize, 16, 256, 2048];
    let mut rng = Rng::new(118);
    for case in 0..30 {
        let net = NetworkModel::edge_lte().with_sharing(Sharing::Shared);
        let (loads, acc) = rand_event_loads(&mut rng, &net, false);
        let params = SimParams {
            chunk_kb: chunk_choices[rng.below(chunk_choices.len())],
            stage_queue: 1 + rng.below(4),
        };
        let event = simulate_round(&net, &loads, &params).round_s;
        let pipelined = acc.pipelined_s(&net);
        assert!(
            pipelined - 1e-9 <= event,
            "case {case} {params:?}: event {event} < pipelined {pipelined}"
        );
    }
}

#[test]
fn prop_event_simulation_is_reproducible_bitwise() {
    // The simulator is a pure function of the load set: same loads,
    // same result, to the bit — in any arrival order, under both
    // sharing regimes (this is what keeps `time_model = event` runs
    // bit-identical across executors and windows).
    use flocora::transport::{simulate_round, NetworkModel, Sharing,
                             SimParams};
    let mut rng = Rng::new(119);
    for case in 0..30 {
        for sharing in [Sharing::Dedicated, Sharing::Shared] {
            let net = NetworkModel::edge_lte().with_sharing(sharing);
            let (loads, _) = rand_event_loads(&mut rng, &net, true);
            let params = SimParams {
                chunk_kb: 1 + rng.below(64),
                stage_queue: rng.below(4),
            };
            let a = simulate_round(&net, &loads, &params);
            let b = simulate_round(&net, &loads, &params);
            assert_eq!(a, b, "case {case} {sharing:?}");
            let mut shuffled = loads.clone();
            shuffled.reverse();
            let c = simulate_round(&net, &shuffled, &params);
            assert_eq!(a, c, "case {case} {sharing:?}: arrival order leaked");
        }
    }
}

#[test]
fn prop_kernels_bit_identical_to_scalar_refs() {
    // The tentpole contract: every chunked kernel is bit-identical to
    // its retained scalar reference, across every length 0..100 — the
    // sweep crosses every tail residue mod 8 many times over.
    let mut rng = Rng::new(120);
    for n in 0..100usize {
        let v: Vec<f32> =
            (0..n).map(|_| 3.0 * rng.normal() as f32).collect();

        // Min/max range scan.
        let (l, h) = kernels::minmax(&v);
        let (lr, hr) = kernels::minmax_ref(&v);
        assert_eq!(l.to_bits(), lr.to_bits(), "minmax lo n={n}");
        assert_eq!(h.to_bits(), hr.to_bits(), "minmax hi n={n}");

        // Quantize / dequantize / fused dequant-accumulate.
        let scale = if h > l { (h - l) / 255.0 } else { 1.0 };
        let zp = if h > l { -l / scale } else { 0.0 };
        let mut codes = vec![0u8; n];
        kernels::quant_codes(&v, l, scale, 255.0, &mut codes);
        let mut codes_ref = Vec::new();
        kernels::quant_codes_ref(&v, l, scale, 255.0, &mut codes_ref);
        assert_eq!(codes, codes_ref, "quant n={n}");

        let mut d = vec![0.0f32; n];
        let mut dr = vec![0.0f32; n];
        kernels::dequant(&codes, scale, zp, &mut d);
        kernels::dequant_ref(&codes, scale, zp, &mut dr);
        assert!(d.iter().zip(&dr).all(|(a, b)| a.to_bits() == b.to_bits()),
                "dequant n={n}");

        let w = 0.25 + rng.f32();
        let base: Vec<f32> =
            (0..n).map(|_| rng.normal() as f32).collect();
        let mut acc = base.clone();
        let mut acc_ref = base.clone();
        kernels::dequant_axpy(&codes, scale, zp, w, &mut acc);
        kernels::axpy_ref(&mut acc_ref, &dr, w);
        assert!(acc.iter().zip(&acc_ref)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "dequant_axpy n={n}");

        // Weighted folds.
        let mut a1 = base.clone();
        let mut a2 = base.clone();
        kernels::axpy(&mut a1, &v, w);
        kernels::axpy_ref(&mut a2, &v, w);
        assert!(a1.iter().zip(&a2).all(|(x, y)| x.to_bits() == y.to_bits()),
                "axpy n={n}");
        let s1 = kernels::vadd(&base, &v);
        let s2 = kernels::vadd_ref(&base, &v);
        assert!(s1.iter().zip(&s2).all(|(x, y)| x.to_bits() == y.to_bits()),
                "vadd n={n}");
        let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
        let mut f1 = base.clone();
        let mut f2 = base.clone();
        kernels::axpy_from_le(&bytes, w, &mut f1);
        kernels::axpy_ref(&mut f2, &v, w);
        assert!(f1.iter().zip(&f2).all(|(x, y)| x.to_bits() == y.to_bits()),
                "axpy_from_le n={n}");

        // Sub-byte pack/unpack at every width.
        for bits in 1..=8u32 {
            let max = 1usize << bits;
            let cs: Vec<u8> = (0..n).map(|_| rng.below(max) as u8).collect();
            let plen = kernels::packed_len(n, bits);
            let mut p1 = vec![0u8; plen];
            let mut p2 = vec![0u8; plen];
            kernels::pack_into(&cs, bits, &mut p1);
            kernels::pack_ref(&cs, bits, &mut p2);
            assert_eq!(p1, p2, "pack bits={bits} n={n}");
            let mut u1 = vec![0u8; n];
            let mut u2 = vec![0u8; n];
            kernels::unpack_into(&p1, bits, &mut u1);
            kernels::unpack_ref(&p1, bits, &mut u2);
            assert_eq!(u1, cs, "unpack round-trip bits={bits} n={n}");
            assert_eq!(u1, u2, "unpack ref bits={bits} n={n}");
        }

        // Top-k threshold selection: same kept set as the reference.
        for k in [0usize, 1, n / 2, n] {
            let mut t1 = kernels::topk_indices(&v, k);
            let mut t2 = kernels::topk_indices_ref(&v, k);
            t1.sort_unstable();
            t2.sort_unstable();
            assert_eq!(t1, t2, "topk n={n} k={k}");
        }

        // Water-filling replays the reference's f64 chain exactly.
        let caps: Vec<f64> =
            (0..n).map(|_| 0.001 + rng.f64() * 0.3).collect();
        let mut r1 = vec![0.0f64; n];
        let mut r2 = vec![0.0f64; n];
        let mut scratch = Vec::new();
        kernels::waterfill(&caps, &mut r1, &mut scratch);
        kernels::waterfill_ref(&caps, &mut r2);
        assert!(r1.iter().zip(&r2).all(|(x, y)| x.to_bits() == y.to_bits()),
                "waterfill n={n}");
    }

    // Strided row gather (rank projection's inner copy).
    for (outer, rs, rd, w) in [(5usize, 9usize, 7usize, 6usize),
                               (3, 8, 8, 8), (2, 3, 5, 2), (1, 1, 1, 1)] {
        let src: Vec<f32> =
            (0..outer * rs).map(|_| rng.normal() as f32).collect();
        let mut d1 = vec![0.0f32; outer * rd];
        let mut d2 = vec![0.0f32; outer * rd];
        kernels::gather_rows(&src, rs, &mut d1, rd, w);
        kernels::gather_rows_ref(&src, rs, &mut d2, rd, w);
        assert_eq!(d1, d2, "gather {outer}x{rs}->{rd} w={w}");
    }
}

#[test]
fn prop_decode_into_equals_decode_then_fold_for_every_codec() {
    // The zero-copy merge contract (`Codec::decode_into`): folding an
    // encoded message straight into an accumulator is bit-identical to
    // decoding it and running the weighted fold — for every codec kind
    // the engine can be configured with, on random layouts, weights
    // and accumulator contents.
    let mut rng = Rng::new(121);
    for case in 0..CASES {
        let (segs, v) = rand_layout(&mut rng);
        let kinds = [CodecKind::Fp32, CodecKind::Affine(8),
                     CodecKind::Affine(4), CodecKind::Affine(2),
                     CodecKind::TopK(0.4), CodecKind::ZeroFl(0.9, 0.2),
                     CodecKind::SparseEf(0.3)];
        for kind in kinds {
            let c = kind.build();
            let msg = c.encode_client(case, &v, &segs).unwrap();
            let w = (0.1 + rng.f64() * 5.0) as f32;
            let base: Vec<f32> =
                (0..v.len()).map(|_| rng.normal() as f32).collect();
            let mut streamed = base.clone();
            c.decode_into(&msg, &segs, &mut streamed, w).unwrap();
            let mut folded = base;
            let dec = c.decode(&msg, &segs).unwrap();
            kernels::axpy_ref(&mut folded, &dec, w);
            let same = streamed.iter().zip(&folded)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "case {case} codec {}", c.name());
            // A wrong-dimension accumulator is rejected, not folded.
            let mut short = vec![0.0f32; v.len() + 1];
            assert!(c.decode_into(&msg, &segs, &mut short, w).is_err(),
                    "case {case} codec {} accepted a bad dim", c.name());
        }
    }
}

#[test]
fn prop_oversample_beta_zero_is_bit_identical_to_uniform() {
    // β = 0 must replay the uniform stream exactly — for any pool
    // size, round budget and seed, not just the defaults.
    use flocora::coordinator::{OversampleSampler, Sampler, UniformSampler};
    let mut rng = Rng::new(112);
    for case in 0..CASES {
        let n = 2 + rng.below(40);
        let k = 1 + rng.below(n);
        let seed = rng.below(1 << 30) as u64;
        let mut uni = UniformSampler::new(n, seed);
        let mut over = OversampleSampler::new(n, seed, 0.0);
        for round in 0..20 {
            assert_eq!(
                uni.sample(k),
                Sampler::sample(&mut over, k),
                "case {case} round {round} (n={n}, k={k}, seed={seed})"
            );
        }
    }
}
